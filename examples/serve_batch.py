"""Batched serving demo: prefill a batch of prompts, then decode tokens
step-by-step against the KV/recurrent-state cache (the serve_step the
decode dry-run shapes lower).

    PYTHONPATH=src python examples/serve_batch.py --arch gemma3-4b
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import concrete_batch, get_config
from repro.models.transformer import init_decode_state, init_model
from repro.train.steps import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    prompts = concrete_batch(cfg, args.batch, args.prompt_len)["tokens"]
    max_len = args.prompt_len + args.gen

    # prefill gives last-token logits + a decode-ready state; here we
    # re-run decode over a max_len cache so generation can append
    t0 = time.time()
    state = init_decode_state(cfg, args.batch, max_len, dtype=jnp.float32)
    serve = jax.jit(make_serve_step(cfg))
    for i in range(args.prompt_len):          # teacher-forced warm-up
        tok = prompts[:, i:i + 1]
        nxt, logits, state = serve(params, tok, state)
    t_prefill = time.time() - t0

    toks = [nxt]
    t0 = time.time()
    for _ in range(args.gen - 1):
        nxt, logits, state = serve(params, nxt, state)
        toks.append(nxt)
    jax.block_until_ready(nxt)
    t_decode = time.time() - t0
    out = jnp.concatenate(toks, axis=1)
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prompt warm-up: {t_prefill:.2f}s; decode: "
          f"{args.gen - 1} steps in {t_decode:.2f}s "
          f"({(args.gen - 1) * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
    print("generated ids (row 0):", out[0][:16].tolist(), "...")


if __name__ == "__main__":
    main()
