"""Performance-model layer: throughput curves over GPU count.

The paper's Trial Runner keeps profiling overhead under ~5% of workload
runtime by profiling only a *subset* of ⟨model, parallelism, GPU-count⟩
combinations and interpolating the rest (Saturn §2; the VLDB version
makes the same point about amortized, cached trial runs).  This module
is that layer:

- :func:`select_anchor_counts` picks the geometric subset of GPU counts
  that gets REAL trials — always including the technique-feasibility
  boundary counts (smallest and largest valid);
- :class:`ThroughputCurve` fits one ⟨job, technique⟩ scaling curve to
  those anchors — piecewise power-law, i.e. linear in (log g, log t)
  space, which preserves monotonicity between anchors and matches the
  ``t ∝ g^(-efficiency)`` shape of data/model-parallel scaling — and
  evaluates ``step_time(g)``, ``mem(g)`` and ``feasible(g)`` at ANY
  count.  Extrapolation beyond the anchored range continues the edge
  segment's slope, clamped to [-1, +1] in log-log space: never better
  than perfect linear scaling, never a worse-than-linear slowdown;
- :class:`PerfModel` is the consumer facade: a read-only Mapping with
  the legacy ``profiles[(job, tech, g)] -> Profile`` contract (missing
  counts are synthesized from the curve, ``source="interpolated"``),
  plus curve-native accessors (``curve()``, ``curves_for()``,
  ``step_time()``) for the Solver, the baselines and the runtime's
  introspection replans.

Feasibility at a count ``g`` has two independent parts, and the curve
keeps them separate: *validity* (the technique's ``search_space`` —
exact, computed for every count without a trial) and *memory fit*
(``mem(g) <= hbm_capacity`` — interpolated between anchors).
"""
from __future__ import annotations

import math
from collections.abc import Mapping
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from .profiler import Profile

# Extrapolation slope clamp in log-log space: -1 is perfect linear
# scaling (t halves when g doubles); +1 bounds observed slowdowns.
_SLOPE_LO = -1.0
_SLOPE_HI = 1.0


def select_anchor_counts(valid_counts: Iterable[int],
                         ratio: float = 2.0) -> List[int]:
    """The geometric subset of ``valid_counts`` that gets real trials.

    Walks the sorted valid counts keeping every count that is at least
    ``ratio`` times the previously kept one, and always keeps the
    smallest and largest valid counts (the technique-feasibility
    boundary points the curve must not extrapolate across).
    """
    vs = sorted(set(int(g) for g in valid_counts))
    if not vs:
        return []
    anchors = [vs[0]]
    target = vs[0] * ratio
    for g in vs[1:]:
        if g >= target - 1e-9:
            anchors.append(g)
            target = g * ratio
    if anchors[-1] != vs[-1]:
        anchors.append(vs[-1])
    return anchors


def _loglog_eval(lxs: np.ndarray, lys: np.ndarray, g: float) -> float:
    """Piecewise-linear evaluation in log-log space with slope-clamped
    extrapolation past either end."""
    x = math.log(g)
    if len(lxs) == 1:
        return math.exp(float(lys[0]))
    if x <= lxs[0]:
        s = (lys[1] - lys[0]) / (lxs[1] - lxs[0])
        s = min(max(s, _SLOPE_LO), _SLOPE_HI)
        return math.exp(float(lys[0] + s * (x - lxs[0])))
    if x >= lxs[-1]:
        s = (lys[-1] - lys[-2]) / (lxs[-1] - lxs[-2])
        s = min(max(s, _SLOPE_LO), _SLOPE_HI)
        return math.exp(float(lys[-1] + s * (x - lxs[-1])))
    return math.exp(float(np.interp(x, lxs, lys)))


class ThroughputCurve:
    """One ⟨job, technique⟩ scaling curve over GPU count, fit to real
    trial anchors."""

    def __init__(self, job: str, technique: str, hbm_capacity: float,
                 anchors: Dict[int, Profile],
                 valid: Iterable[int], domain: Iterable[int]):
        self.job = job
        self.technique = technique
        self.hbm_capacity = hbm_capacity
        self.anchors = {int(g): p for g, p in sorted(anchors.items())}
        self.valid = frozenset(int(g) for g in valid)
        self.domain = frozenset(int(g) for g in domain)
        # fit arrays: anchors with finite measurements (memory-infeasible
        # anchors still carry real numbers and inform the fit; search-
        # space-invalid ones are inf and excluded)
        fit = [(g, p) for g, p in self.anchors.items()
               if math.isfinite(p.step_time_s) and p.step_time_s > 0]
        self._fit_counts = [g for g, _ in fit]
        if fit:
            self._lg = np.log([g for g, _ in fit])
            self._lt = np.log([p.step_time_s for _, p in fit])
            self._lm = np.log([max(p.mem_per_device, 1.0) for _, p in fit])
        else:
            self._lg = self._lt = self._lm = np.zeros(0)

    # ------------------------------------------------------------- eval
    def valid_at(self, g: int) -> bool:
        """Search-space validity (exact; no trial involved)."""
        if g in self.valid:
            return True
        if g in self.domain:
            return False
        # counts outside the modeled domain: trust interpolation only
        # inside the anchored range
        return bool(self._fit_counts) and \
            self._fit_counts[0] <= g <= self._fit_counts[-1]

    def step_time(self, g: int) -> float:
        g = int(g)
        if g in self.anchors:
            return self.anchors[g].step_time_s
        if not self.valid_at(g) or not self._fit_counts:
            return float("inf")
        return _loglog_eval(self._lg, self._lt, g)

    def mem(self, g: int) -> float:
        g = int(g)
        if g in self.anchors:
            return self.anchors[g].mem_per_device
        if not self.valid_at(g) or not self._fit_counts:
            return float("inf")
        return _loglog_eval(self._lg, self._lm, g)

    def feasible(self, g: int) -> bool:
        g = int(g)
        if g in self.anchors:
            return self.anchors[g].feasible
        if not self.valid_at(g):
            return False
        m = self.mem(g)
        return math.isfinite(m) and m <= self.hbm_capacity and \
            math.isfinite(self.step_time(g))

    def profile(self, g: int) -> Profile:
        """A Profile record at any count: the anchor itself where one
        exists, an interpolated point everywhere else.  Evaluates each
        curve exactly once per field (policies rebuild grids every
        replan, so this is the hot path)."""
        g = int(g)
        if g in self.anchors:
            return self.anchors[g]
        terms = {"n_anchors": float(len(self._fit_counts))}
        if not self.valid_at(g) or not self._fit_counts:
            return Profile(self.job, self.technique, g, float("inf"),
                           float("inf"), False, "interpolated", terms)
        t = _loglog_eval(self._lg, self._lt, g)
        m = _loglog_eval(self._lg, self._lm, g)
        feas = math.isfinite(t) and math.isfinite(m) and \
            m <= self.hbm_capacity
        return Profile(self.job, self.technique, g, t, m, feas,
                       "interpolated", terms)


class PerfModel(Mapping):
    """Curves for a whole workload, with the legacy Mapping contract.

    Iteration / ``len`` / ``items()`` enumerate ``(job, technique, g)``
    over the model's count grid restricted to search-space-valid counts
    — exactly the keys an exhaustive ``profile_all`` dict would hold —
    so every dict-shaped consumer (the MILPs, baselines, the runtime's
    noise model) works unchanged.  ``__getitem__`` additionally accepts
    off-grid counts: curves are continuous, so introspection replans may
    evaluate counts nobody profiled.
    """

    def __init__(self, curves: Dict[Tuple[str, str], ThroughputCurve],
                 counts: Iterable[int]):
        self._curves = dict(curves)
        self.counts = sorted(set(int(c) for c in counts))
        self._keys = [(j, t, g) for (j, t), c in self._curves.items()
                      for g in self.counts if g in c.valid]

    # --------------------------------------------------- Mapping contract
    def __getitem__(self, key: Tuple[str, str, int]) -> Profile:
        job, tech, g = key
        c = self._curves.get((job, tech))
        if c is None:
            raise KeyError(key)
        return c.profile(int(g))

    def __iter__(self) -> Iterator[Tuple[str, str, int]]:
        return iter(self._keys)

    def __len__(self) -> int:
        return len(self._keys)

    # ----------------------------------------------------- curve access
    def curve(self, job: str, technique: str) -> ThroughputCurve:
        return self._curves[(job, technique)]

    def curves_for(self, job: str) -> List[ThroughputCurve]:
        return [c for (j, _), c in self._curves.items() if j == job]

    def step_time(self, job: str, technique: str, g: int) -> float:
        return self._curves[(job, technique)].step_time(g)

    def mem(self, job: str, technique: str, g: int) -> float:
        return self._curves[(job, technique)].mem(g)

    def feasible(self, job: str, technique: str, g: int) -> bool:
        c = self._curves.get((job, technique))
        return c.feasible(g) if c is not None else False

    # ------------------------------------------------------------ stats
    def anchor_keys(self) -> set:
        """The (job, technique, g) combos backed by real trials."""
        return {(c.job, c.technique, g)
                for c in self._curves.values() for g in c.anchors}

    def n_anchors(self) -> int:
        return sum(len(c.anchors) for c in self._curves.values())

    def to_dict(self) -> Dict[Tuple[str, str, int], Profile]:
        """Materialize the full grid as a plain dict (legacy export)."""
        return {k: self[k] for k in self._keys}


# ------------------------------------------------- dict/model adapters

def iter_job_profiles(profiles, job_name: str
                      ) -> Iterator[Tuple[str, int, Profile]]:
    """Yield (technique, g, Profile) for one job from either a legacy
    profile dict or a :class:`PerfModel`."""
    if isinstance(profiles, PerfModel):
        for curve in profiles.curves_for(job_name):
            for g in profiles.counts:
                if g in curve.valid:
                    yield curve.technique, g, curve.profile(g)
        return
    for (jn, tech, g), p in profiles.items():
        if jn == job_name:
            yield tech, g, p


def step_time_of(profiles, job: str, tech: str, g: int) -> float:
    """Estimated step time from either representation; curve-backed
    models answer at any count, dicts only at profiled ones."""
    if isinstance(profiles, PerfModel):
        return profiles.step_time(job, tech, g)
    return profiles[(job, tech, g)].step_time_s


def lookup_profile(profiles, job: str, tech: str, g: int
                   ) -> Optional[Profile]:
    """Profile record from either representation (None if unknown)."""
    try:
        return profiles[(job, tech, g)]
    except KeyError:
        return None
