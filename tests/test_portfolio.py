"""Solver portfolio (repro.core.portfolio): backend registry, the
MILP-vs-LNS race, telemetry end-to-end through the runtime, and the
guarded-import CP-SAT slot.

MILP outcomes are time-limit-nondeterministic, so the race assertions
check the portfolio's CONTRACT (feasible, never worse than greedy,
telemetry present) rather than which engine won.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.core.job import ClusterSpec, Job
from repro.core.lns import validate_capacity
from repro.core.portfolio import (HAVE_ORTOOLS, SOLVER_BACKENDS,
                                  LnsBackend, MilpRefinedBackend,
                                  SolverBackend, available_backends,
                                  join_stragglers, makespan_lower_bound,
                                  register_backend, solve_portfolio)
from repro.core.solver import (Choice, greedy_schedule, objective_value)

CFG = get_config("xlstm-125m").reduced()


def workload(n_jobs, seed):
    rng = np.random.RandomState(seed)
    jobs, cm = [], {}
    for i in range(n_jobs):
        j = Job(f"j{i}", CFG, batch_size=8, seq_len=64,
                total_steps=int(rng.randint(50, 300)))
        jobs.append(j)
        base = rng.uniform(20.0, 200.0)
        eff = rng.uniform(0.5, 0.95)
        cm[j.name] = [Choice("fsdp", g, base / g ** eff)
                      for g in (1, 2, 4, 8)]
    return jobs, cm, {None: 16}


# -------------------------------------------------------------- registry

def test_registry_has_both_engines():
    assert {"milp", "lns"} <= set(available_backends())
    assert SOLVER_BACKENDS["milp"] is MilpRefinedBackend
    assert SOLVER_BACKENDS["lns"] is LnsBackend


def test_register_custom_backend():
    """The protocol seam: any SolverBackend subclass slots into the
    race by name, exactly how CP-SAT would."""

    @register_backend
    class GreedyBackend(SolverBackend):
        name = "test-greedy"

        def solve(self, jobs, choice_map, budgets, *, reserved=(),
                  objective="makespan", time_limit_s=10.0,
                  gap_target=0.05, seed=0, warm_starts=None,
                  incumbent=None, lower_bound=None, stop=None):
            sol = greedy_schedule(jobs, choice_map, budgets,
                                  reserved=list(reserved),
                                  objective=objective)
            sol.telemetry = {"backend": self.name, "wall_s": 0.0,
                             "gap": None, "status": "greedy",
                             "n_jobs": len(jobs)}
            return sol

    try:
        jobs, cm, budgets = workload(5, 0)
        sol = solve_portfolio(jobs, cm, budgets, wall_budget_s=1.0,
                              backends=("test-greedy", "lns"))
        assert "test-greedy" in sol.telemetry["engines"]
    finally:
        del SOLVER_BACKENDS["test-greedy"]


# ------------------------------------------------------------- the race

@settings(max_examples=5)
@given(seed=st.integers(0, 10_000), n_jobs=st.integers(3, 12))
def test_portfolio_feasible_and_never_worse_than_greedy(seed, n_jobs):
    jobs, cm, budgets = workload(n_jobs, seed)
    sol = solve_portfolio(jobs, cm, budgets, wall_budget_s=1.0,
                          gap_target=0.05, seed=seed)
    join_stragglers()
    assert {a.job for a in sol.assignments} == {j.name for j in jobs}
    assert validate_capacity(sol.assignments, budgets)
    gv = greedy_schedule(jobs, cm, budgets).makespan_s
    pv = objective_value(sol.assignments, jobs, "makespan")
    assert pv <= gv + 1e-6
    assert sol.solver.startswith("portfolio[")


def test_portfolio_telemetry_shape():
    jobs, cm, budgets = workload(6, 1)
    sol = solve_portfolio(jobs, cm, budgets, wall_budget_s=1.0, seed=0)
    join_stragglers()
    tel = sol.telemetry
    assert {"backend", "wall_s", "gap", "status", "n_jobs",
            "engines"} <= set(tel)
    assert tel["n_jobs"] == 6
    assert tel["status"] in ("gap_target", "deadline")
    for name, sub in tel["engines"].items():
        assert sub["backend"] == name


def test_portfolio_respects_reserved():
    jobs, cm, budgets = workload(6, 2)
    reserved = [(None, 6, 50.0), (None, 4, float("inf"))]
    sol = solve_portfolio(jobs, cm, budgets, reserved=reserved,
                          wall_budget_s=1.0, seed=0)
    join_stragglers()
    assert validate_capacity(sol.assignments, budgets,
                             reserved=reserved)


def test_portfolio_empty_jobs():
    sol = solve_portfolio([], {}, {None: 8})
    assert sol.assignments == []
    assert sol.telemetry["status"] == "empty"


def test_portfolio_unknown_objective_raises():
    jobs, cm, budgets = workload(3, 0)
    with pytest.raises(ValueError):
        solve_portfolio(jobs, cm, budgets, objective="latency")


def test_makespan_lower_bound_is_valid():
    """The area/critical-path bound must lower-bound any feasible
    plan's makespan (it is what first-to-gap is measured against)."""
    jobs, cm, budgets = workload(10, 4)
    lb = makespan_lower_bound(jobs, cm, budgets)
    sol = greedy_schedule(jobs, cm, budgets)
    assert 0.0 < lb <= sol.makespan_s + 1e-9
    assert makespan_lower_bound([], {}, budgets) == 0.0


# --------------------------------------------- policy/runtime plumbing

def _profiles(jobs, seed):
    from repro.core.profiler import Profile
    rng = np.random.RandomState(seed)
    out = {}
    for j in jobs:
        base = rng.uniform(1.0, 4.0)
        eff = rng.uniform(0.5, 0.95)
        for g in (1, 2, 4, 8):
            for tech in ("ddp", "fsdp"):
                out[(j.name, tech, g)] = Profile(
                    j.name, tech, g, base / g ** eff, 1e9, True, "t")
    return out


def test_saturn_policy_portfolio_end_to_end():
    """SaturnPolicy(solver='portfolio') plans through the runtime and
    every (re)plan's engine telemetry lands in stats['solver']."""
    from repro.core.baselines import SaturnPolicy
    from repro.core.runtime import simulate_runtime

    jobs = [Job(f"j{i}", CFG, 8, 64,
                total_steps=int(np.random.RandomState(i).randint(60, 150)))
            for i in range(6)]
    profiles = _profiles(jobs, 0)
    cluster = ClusterSpec(nodes=1, gpus_per_node=8)
    pol = SaturnPolicy(time_limit_s=1.0, solver="portfolio",
                       mip_gap=0.05)
    res = simulate_runtime(jobs, pol, profiles, cluster,
                           introspect_every_s=100.0)
    join_stragglers()
    log = res.stats["solver"]
    assert len(log) == res.replans >= 1
    for entry in log:
        assert {"backend", "wall_s", "gap", "status", "n_jobs",
                "t"} <= set(entry)
    # at least the initial plan raced both engines
    assert "engines" in log[0]


def test_saturn_policy_milp_also_reports_telemetry():
    """stats['solver'] is not portfolio-only: the plain MILP policy
    reports which path planned (satellite: stop re-deriving the
    winner)."""
    from repro.core.baselines import SaturnPolicy
    from repro.core.runtime import simulate_runtime

    jobs = [Job(f"j{i}", CFG, 8, 64, total_steps=80) for i in range(4)]
    profiles = _profiles(jobs, 1)
    cluster = ClusterSpec(nodes=1, gpus_per_node=8)
    res = simulate_runtime(jobs, SaturnPolicy(time_limit_s=2.0),
                           profiles, cluster, introspect_every_s=100.0)
    log = res.stats["solver"]
    assert log and all("backend" in e and "wall_s" in e for e in log)


def test_saturn_policy_rejects_bad_solver():
    from repro.core.baselines import SaturnPolicy
    with pytest.raises(ValueError):
        SaturnPolicy(solver="simplex")


def test_saturn_policy_portfolio_rejects_node_placement():
    from repro.core.baselines import SaturnPolicy

    jobs = [Job("j0", CFG, 8, 64, total_steps=50)]
    profiles = _profiles(jobs, 2)
    cluster = ClusterSpec(nodes=2, gpus_per_node=8, placement="node")
    pol = SaturnPolicy(solver="portfolio")
    with pytest.raises(ValueError, match="node"):
        pol.plan(jobs, {"j0": 50}, profiles, cluster, {})


# ------------------------------------------------- optional CP-SAT slot

def test_cpsat_backend_is_optional():
    """The guarded import contract: without ortools the backend class
    exists but is NOT registered (never a hard dependency); with it,
    it registers like any other engine."""
    from repro.core.portfolio import CpSatBackend
    assert CpSatBackend.name == "cpsat"
    if HAVE_ORTOOLS:
        assert "cpsat" in SOLVER_BACKENDS
    else:
        assert "cpsat" not in SOLVER_BACKENDS
        with pytest.raises(RuntimeError, match="ortools"):
            CpSatBackend().solve(*workload(2, 0))


@pytest.mark.skipif(not HAVE_ORTOOLS,
                    reason="ortools not installed (cannot be installed "
                           "in this environment — the CP-SAT backend "
                           "is an optional slot, never required)")
def test_cpsat_backend_solves():     # pragma: no cover - optional dep
    jobs, cm, budgets = workload(5, 0)
    from repro.core.portfolio import CpSatBackend
    sol = CpSatBackend().solve(jobs, cm, budgets, time_limit_s=5.0)
    assert {a.job for a in sol.assignments} == {j.name for j in jobs}
    assert validate_capacity(sol.assignments, budgets)
