"""AdamW optimizer + LR schedules, pure-pytree (no optax dependency)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    schedule: str = "cosine"     # constant | cosine | linear
    warmup_steps: int = 100
    total_steps: int = 10000


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def init_opt_state(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    if cfg.grad_clip > 0:
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = lr_at(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu_n = cfg.b1 * mu + (1 - cfg.b1) * g32
        nu_n = cfg.b2 * nu + (1 - cfg.b2) * g32 * g32
        update = (mu_n / bc1) / (jnp.sqrt(nu_n / bc2) + cfg.eps)
        p_n = p.astype(jnp.float32) - lr * (
            update + cfg.weight_decay * p.astype(jnp.float32))
        return p_n.astype(p.dtype), mu_n, nu_n

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_state = {
        "mu": jax.tree.unflatten(tdef, [o[1] for o in out]),
        "nu": jax.tree.unflatten(tdef, [o[2] for o in out]),
        "step": step + 1,
    }
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
