"""Continuous-batching engine: batched, interleaved serving must equal
offline per-request greedy generation exactly (attention + recurrent
archs), and slot reuse must not leak state between requests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import forward, init_model
from repro.serving.engine import ContinuousBatchingEngine, Request


def _offline(params, cfg, prompt, n):
    toks = list(prompt)
    for _ in range(n):
        lg, _ = forward(params, cfg,
                        {"tokens": jnp.asarray([toks], jnp.int32)})
        toks.append(int(jnp.argmax(lg[0, -1])))
    return toks[len(prompt):]


@pytest.mark.parametrize("arch", ["gemma3-4b", "h2o-danube-3-4b",
                                  "xlstm-125m", "recurrentgemma-2b"])
def test_engine_matches_offline(arch):
    cfg = get_config(arch).reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    prompts = [rng.randint(0, cfg.vocab_size, rng.randint(3, 8)).tolist()
               for _ in range(4)]
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_len=32)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    done = eng.run()
    assert len(done) == 4
    for r in done:
        expected = _offline(params, cfg, prompts[r.rid], 4)
        assert r.output == expected, (arch, r.rid, r.output, expected)


def test_slot_reuse_no_state_leak():
    """Serving the same prompt before and after an unrelated request in
    the same slot must give identical outputs."""
    cfg = get_config("xlstm-125m").reduced()
    params = init_model(cfg, jax.random.PRNGKey(2))
    prompt = [5, 17, 42, 7]
    eng = ContinuousBatchingEngine(cfg, params, slots=1, max_len=32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
    eng.submit(Request(rid=1, prompt=[99, 3], max_new_tokens=4))
    eng.submit(Request(rid=2, prompt=prompt, max_new_tokens=4))
    done = {r.rid: r for r in eng.run()}
    assert done[0].output == done[2].output


def test_engine_accounting():
    cfg = get_config("gemma3-4b").reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_len=32)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=[1, 2, 3], max_new_tokens=5))
    done = eng.run()
    th = eng.throughput()
    assert th["requests"] == 3
    assert th["tokens"] == 15
    # continuous batching: steps << sequential token count
    sequential = 3 * (3 + 5 - 1)
    assert th["steps"] < sequential
    for r in done:
        assert r.ttft_s is not None and r.done_s is not None
        assert r.ttft_s <= r.done_s


def test_oversized_request_rejected():
    """An infeasible request is rejected at submit(), before it can
    stall a run that has already served everything ahead of it."""
    cfg = get_config("gemma3-4b").reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(cfg, params, slots=1, max_len=8)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=[1] * 6, max_new_tokens=6))
    assert not eng.queue


def test_admission_order_stable():
    """Admission follows arrival_s, with equal timestamps drained in
    submission order (not submission order ignoring arrival_s)."""
    cfg = get_config("xlstm-125m").reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(cfg, params, slots=1, max_len=32)
    eng.submit(Request(rid=0, prompt=[1, 2], max_new_tokens=2,
                       arrival_s=5.0))
    eng.submit(Request(rid=1, prompt=[3, 4], max_new_tokens=2,
                       arrival_s=1.0))
    eng.submit(Request(rid=2, prompt=[5, 6], max_new_tokens=2,
                       arrival_s=1.0))
    assert [r.rid for r in eng.queue] == [1, 2, 0]
    done = eng.run()
    # slots=1 => strictly sequential completion in admission order
    order = sorted(done, key=lambda r: r.done_s)
    assert [r.rid for r in order] == [1, 2, 0]


def test_engine_clock_persists_across_runs():
    """A second run() continues the engine clock: its completions are
    timestamped after the first run's, not restarted from zero."""
    cfg = get_config("xlstm-125m").reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(cfg, params, slots=1, max_len=32)
    eng.submit(Request(rid=0, prompt=[1, 2], max_new_tokens=3))
    eng.run()
    first_done = eng.finished[-1].done_s
    eng.submit(Request(rid=1, prompt=[3, 4], max_new_tokens=3))
    eng.run()
    assert eng.finished[-1].rid == 1
    assert eng.finished[-1].done_s > first_done
    th = eng.throughput()
    assert th["requests"] == 2
    assert th["p99_latency_s"] >= th["p50_latency_s"] > 0.0
