"""Fault injection and elasticity: typed cluster events + seeded
generators (ROADMAP item 5).

A :class:`ChaosTrace` is an ordered sequence of concrete
:class:`~repro.core.events.ClusterEvent` subtypes the runtime injects
through its :class:`~repro.core.events.EventQueue`:

- :class:`NodeFailure` — ``n_gpus`` devices of a class die, busy or not
  (lowest present ids).  Launches on dead devices are killed and salvage
  their last periodic checkpoint: progress since
  ``ChaosTrace.checkpoint_every_s`` is lost, NOT the whole launch.  An
  optional ``recover_after_s`` schedules the matching
  :class:`NodeRecovery` automatically.
- :class:`NodeRecovery` / :class:`SpotGrant` — capacity returns / a spot
  grant lands: the placement pool grows by ``n_gpus`` FRESH device ids
  (ids are never reused, so Gantt history and conservation accounting
  stay unambiguous).
- :class:`SpotRevoke` — the provider reclaims ``n_gpus`` spot devices.
  Unlike a failure, revocation is polite: free devices go first, busy
  ones only when the free pool cannot cover the revocation (victims
  still salvage their checkpoints).
- :class:`CapacityChange` — signed administrative resize: ``delta > 0``
  grows the pool, ``delta < 0`` shrinks it (free-first, like a revoke).
- :class:`WorkerFault` — fault INJECTION against a real execution
  backend's workers (SIGKILL mid-step, stalled heartbeats, truncated
  checkpoint files); detection and recovery flow through the normal
  supervision machinery.  :class:`WorkerFailure` is the engine-
  synthesized DETECTION event that routes a dead/hung worker into the
  salvage → backoff (:class:`RetryPolicy`) → relaunch → replan chain.

All events are count-based, not id-based: which concrete devices die is
resolved by the runtime at processing time against the devices actually
present then — so a trace composed of independent generators stays valid
no matter how the pool has grown or shrunk in between.

The generators are seeded and deterministic.  Failure sweeps use Poisson
THINNING: :func:`poisson_node_failures` draws the event stream once at
``max_rate_per_hour`` and keeps each event with probability
``rate / max_rate`` using per-event uniform marks — so the failures at a
higher rate are a strict superset of those at a lower rate (same seed),
which is what makes "Saturn's margin widens with churn" a monotone,
gateable claim rather than seed noise.
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Optional, Sequence, Tuple

from .events import ClusterEvent
from .job import DEFAULT_CLASS


@dataclasses.dataclass(frozen=True)
class NodeFailure(ClusterEvent):
    """``n_gpus`` devices of ``device_class`` fail hard (busy included:
    lowest present ids die).  ``recover_after_s`` schedules the matching
    :class:`NodeRecovery` for however many devices actually died."""
    n_gpus: int = 1
    device_class: str = DEFAULT_CLASS
    recover_after_s: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class NodeRecovery(ClusterEvent):
    n_gpus: int = 1
    device_class: str = DEFAULT_CLASS


@dataclasses.dataclass(frozen=True)
class SpotGrant(ClusterEvent):
    n_gpus: int = 1
    device_class: str = DEFAULT_CLASS


@dataclasses.dataclass(frozen=True)
class SpotRevoke(ClusterEvent):
    """Free devices are reclaimed first; busy ones only if the free pool
    cannot cover the revocation."""
    n_gpus: int = 1
    device_class: str = DEFAULT_CLASS


@dataclasses.dataclass(frozen=True)
class CapacityChange(ClusterEvent):
    """Administrative resize: ``delta > 0`` adds fresh devices,
    ``delta < 0`` removes (free-first)."""
    delta: int = 0
    device_class: str = DEFAULT_CLASS


@dataclasses.dataclass(frozen=True)
class WorkerFault(ClusterEvent):
    """Fault-INJECTION command for fault-capable execution backends
    (the :class:`~repro.core.process_backend.ProcessJaxBackend`): at
    ``t`` the harness really hurts a live worker —

    - ``"sigkill"``: SIGKILL the worker process mid-step (no chance to
      checkpoint; recovery must salvage the last durable checkpoint);
    - ``"hang"``: wedge the worker (it stops heartbeating but stays
      alive; the coordinator must detect the missed heartbeat deadline
      and kill it);
    - ``"corrupt"``: truncate the job's current checkpoint file on disk
      AND SIGKILL the worker (recovery must detect the corruption via
      checksum and fall back to the last-known-good checkpoint).

    ``job`` names the victim; ``None`` picks the first live launch in
    job-name order (deterministic).  Detection and recovery flow through
    the normal supervision machinery — the injection point never
    shortcuts them, so recovery is benchmarked, not assumed.  Unlike the
    other cluster events a WorkerFault does not touch the placement
    pool, so it needs no elastic backend.

    ``min_step`` > 0 defers the strike until the victim's DURABLE
    checkpoint has reached that absolute step: the event still arrives
    at ``t``, but the backend holds it until the next checkpoint-ack at
    or past ``min_step``.  Worker startup cost (process spawn, jax
    import, compile-cache load) varies with machine load, so a purely
    wall-clock fault time cannot guarantee a mid-run kill — ``min_step``
    makes "killed after at least one durable checkpoint" a property of
    the trace instead of a race.  A victim that finishes before reaching
    ``min_step`` is never struck.
    """
    kind: str = "sigkill"            # sigkill | hang | corrupt
    job: Optional[str] = None
    min_step: int = 0


@dataclasses.dataclass(frozen=True)
class WorkerFailure(ClusterEvent):
    """A DETECTED worker failure, synthesized by the runtime engine from
    the execution backend's supervision channel (process exit, missed
    heartbeat deadline, escaped worker exception) — not user-authored.
    Riding the cluster-event queue gives failures the same deterministic
    ordering as injected chaos (a failure at the instant of a completion
    wins the race) and routes them into the shared salvage → backoff →
    relaunch → replan machinery.  ``token`` pins the launch so a failure
    of an already-preempted launch is ignored as stale."""
    job: str = ""
    token: int = -1
    reason: str = ""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Relaunch policy for failed workers: exponential backoff with
    seeded jitter under a bounded per-job retry budget.

    A job's ``attempt``-th failure (1-based) waits
    ``min(cap_s, base_s * 2**(attempt-1))`` scaled by a deterministic
    jitter factor in ``[1-jitter, 1+jitter]`` (seeded per (job,
    attempt), so concurrent victims don't relaunch in lockstep) before
    it is admissible again — never less than the cluster's ordinary
    ``restart_cost_s``.  A job that fails more than ``budget`` times is
    QUARANTINED: taken out of the workload with a recorded reason while
    the rest of the sweep replans onto the surviving capacity; the run
    completes without it instead of deadlocking or crashing."""
    budget: int = 3
    base_s: float = 2.0
    cap_s: float = 60.0
    jitter: float = 0.2
    seed: int = 0

    def __post_init__(self):
        if self.budget < 0:
            raise ValueError("retry budget must be >= 0")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError("jitter must be in [0, 1)")

    def backoff_s(self, job: str, attempt: int) -> float:
        delay = min(self.cap_s, self.base_s * 2.0 ** max(0, attempt - 1))
        if self.jitter:
            # string seeds hash deterministically (sha512) across
            # processes — no PYTHONHASHSEED dependence
            rng = random.Random(f"{self.seed}:{job}:{attempt}")
            delay *= 1.0 + self.jitter * (2.0 * rng.random() - 1.0)
        return delay


@dataclasses.dataclass(frozen=True)
class ChaosTrace:
    """A seeded scenario: cluster events + the checkpoint cadence that
    governs how much progress a killed launch salvages.

    ``checkpoint_every_s`` is the periodic-checkpoint interval measured
    from each launch's start; a launch killed at ``t`` resumes from
    ``start + floor((t - start) / interval) * interval``.  The launch
    start itself always counts as a checkpoint, so a failure never
    erases progress from before the launch."""
    events: Tuple[ClusterEvent, ...] = ()
    checkpoint_every_s: float = 600.0
    name: str = "chaos"

    def __post_init__(self):
        if self.checkpoint_every_s <= 0:
            raise ValueError("checkpoint_every_s must be positive")
        for e in self.events:
            if not isinstance(e, ClusterEvent):
                raise TypeError(f"not a ClusterEvent: {e!r}")
            if e.t < 0:
                raise ValueError(f"event before t=0: {e!r}")
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events, key=lambda e: e.t)))

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)


def poisson_node_failures(rate_per_hour: float, horizon_s: float, *,
                          seed: int = 0,
                          device_class: str = DEFAULT_CLASS,
                          n_gpus: int = 1,
                          recover_after_s: Optional[float] = None,
                          max_rate_per_hour: Optional[float] = None
                          ) -> Tuple[NodeFailure, ...]:
    """Seeded Poisson failure arrivals over ``[0, horizon_s)``.

    With ``max_rate_per_hour`` set, the stream is generated ONCE at the
    max rate and thinned: an event survives iff its uniform mark is
    below ``rate / max_rate``.  Sweeping ``rate_per_hour`` under a fixed
    ``max_rate_per_hour`` and seed therefore yields nested traces —
    every failure at rate r also occurs at every rate r' > r.
    """
    if rate_per_hour < 0:
        raise ValueError("rate_per_hour must be >= 0")
    max_rate = max_rate_per_hour if max_rate_per_hour is not None \
        else rate_per_hour
    if rate_per_hour > max_rate:
        raise ValueError(f"rate_per_hour {rate_per_hour} exceeds "
                         f"max_rate_per_hour {max_rate}")
    if max_rate <= 0:
        return ()
    rng = random.Random(seed)
    lam = max_rate / 3600.0
    out: List[NodeFailure] = []
    t = 0.0
    while True:
        # draw the gap AND the thinning mark unconditionally so the
        # underlying stream is identical across rates (superset property)
        t += rng.expovariate(lam)
        keep = rng.random() * max_rate < rate_per_hour
        if t >= horizon_s:
            break
        if keep:
            out.append(NodeFailure(t, n_gpus, device_class,
                                   recover_after_s))
    return tuple(out)


def poisson_worker_faults(rate_per_hour: float, horizon_s: float, *,
                          seed: int = 0,
                          kinds: Sequence[str] = ("sigkill", "hang",
                                                  "corrupt"),
                          jobs: Optional[Sequence[str]] = None
                          ) -> Tuple[WorkerFault, ...]:
    """Seeded Poisson worker-fault arrivals over ``[0, horizon_s)``:
    each event draws its kind uniformly from ``kinds`` and its victim
    from ``jobs`` (``None``: let the backend pick the first live
    launch).  The fault-injection counterpart of
    :func:`poisson_node_failures` — same seed, same times, every run."""
    if rate_per_hour < 0:
        raise ValueError("rate_per_hour must be >= 0")
    if not kinds:
        raise ValueError("kinds must be non-empty")
    if rate_per_hour == 0:
        return ()
    rng = random.Random(seed)
    lam = rate_per_hour / 3600.0
    out: List[WorkerFault] = []
    t = 0.0
    while True:
        t += rng.expovariate(lam)
        if t >= horizon_s:
            break
        kind = kinds[rng.randrange(len(kinds))]
        job = jobs[rng.randrange(len(jobs))] if jobs else None
        out.append(WorkerFault(t, kind, job))
    return tuple(out)


def spot_capacity_trace(horizon_s: float, *, seed: int = 0,
                        device_class: str = DEFAULT_CLASS,
                        n_gpus: int = 1,
                        mean_up_s: float = 1800.0,
                        mean_down_s: float = 900.0
                        ) -> Tuple[ClusterEvent, ...]:
    """Two-state spot availability: the capacity starts granted, is
    revoked after an Exp(mean_up_s) hold, re-granted after an
    Exp(mean_down_s) outage, and so on — the classic price-spike
    availability trace, alternating :class:`SpotRevoke` /
    :class:`SpotGrant` events over ``n_gpus`` devices."""
    if mean_up_s <= 0 or mean_down_s <= 0:
        raise ValueError("mean_up_s and mean_down_s must be positive")
    rng = random.Random(seed)
    out: List[ClusterEvent] = []
    t, available = 0.0, True
    while True:
        t += rng.expovariate(1.0 / (mean_up_s if available
                                    else mean_down_s))
        if t >= horizon_s:
            break
        out.append(SpotRevoke(t, n_gpus, device_class) if available
                   else SpotGrant(t, n_gpus, device_class))
        available = not available
    return tuple(out)


def merge_events(*seqs: Sequence[ClusterEvent]
                 ) -> Tuple[ClusterEvent, ...]:
    """Merge independently generated event streams into one time-sorted
    tuple (e.g. a failure trace + a spot trace over different classes)."""
    out: List[ClusterEvent] = []
    for s in seqs:
        out.extend(s)
    return tuple(sorted(out, key=lambda e: e.t))
