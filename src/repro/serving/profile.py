"""Measure real continuous-batching serve step times.

The fleet planner (:mod:`repro.serving.fleet`) sizes replica fleets
from one number per (model, device class): the wall time of ONE batched
decode step with the slots full.  This module produces that number by
actually running a :class:`~repro.serving.engine.ContinuousBatchingEngine`
on this process's JAX devices — the serving-side analogue of the
training profiler's measured step times, and what
``LocalJaxBackend.serve_step_time`` feeds back through
``ObservedProfiles`` so replans plan over reality instead of the
analytic estimate.

The measurement excludes the JIT compile (a warm-up request triggers
it) and saturates every slot so the step time reflects the batched
regime the queueing model assumes.
"""
from __future__ import annotations

import time

import numpy as np

from ..models.config import ModelConfig


def measure_serve_step_time(cfg: ModelConfig, *, slots: int = 4,
                            max_len: int = 32, prompt_len: int = 4,
                            new_tokens: int = 8, seed: int = 0,
                            reduce_model: bool = True) -> float:
    """Wall seconds per batched decode step, slots saturated.

    Builds the (reduced, by default) model, warms the compile with a
    throwaway request, then times a burst of ``2 * slots`` requests so
    every slot stays busy and refills at least once.  ``prompt_len`` /
    ``new_tokens`` only set how many steps get sampled — the per-step
    time is what matters, so they are kept small for measurement speed.
    """
    import jax

    from ..models.transformer import init_model
    from .engine import ContinuousBatchingEngine, Request

    if reduce_model:
        cfg = cfg.reduced()
    prompt_len = max(1, min(prompt_len, max_len - new_tokens - 1))
    params = init_model(cfg, jax.random.PRNGKey(seed))
    eng = ContinuousBatchingEngine(cfg, params, slots=slots,
                                   max_len=max_len)
    rng = np.random.RandomState(seed)

    def mk(rid):
        return Request(rid=rid,
                       prompt=rng.randint(0, cfg.vocab_size,
                                          prompt_len).tolist(),
                       max_new_tokens=new_tokens)

    eng.submit(mk(-1))          # compile warm-up, not timed
    eng.run()
    steps0 = eng.steps
    for i in range(2 * slots):
        eng.submit(mk(i))
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    n = eng.steps - steps0
    if n <= 0:
        raise RuntimeError("serve measurement ran zero engine steps")
    return dt / n
