"""RecurrentGemma-2B: RG-LRU + local attention, 1:2 ratio [arXiv:2402.19427]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", arch_type="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
    d_ff=7680, vocab_size=256000, head_dim=256,
    block_pattern=("rglru", "rglru", "swa"), window_size=2048,
    d_rnn=2560, tie_embeddings=True, long_context=True,
    source="RG-LRU + local attn, 1:2 [arXiv:2402.19427]",
)
