"""SaturnSession — the user-facing facade (paper Fig. 1B API):

    sess = SaturnSession(cluster)
    sess.register_technique(MyTechnique())     # Parallelism Library
    sess.submit(jobs)                          # model selection workload
    sess.profile()                             # Trial Runner
    result = sess.run()                        # Solver + executor
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .baselines import SaturnPolicy
from .executor import Policy, SimResult, simulate
from .job import ClusterSpec, Job
from .library import ParallelismLibrary
from .profiler import HARDWARE, HardwareSpec, Profile, TrialRunner


class SaturnSession:
    def __init__(self, cluster: ClusterSpec,
                 hardware: HardwareSpec = HARDWARE["a100"],
                 cache_path: Optional[str] = None):
        self.cluster = cluster
        self.library = ParallelismLibrary()
        self.runner = TrialRunner(self.library, hardware, cache_path)
        self.jobs: List[Job] = []
        self.profiles: Dict[Tuple[str, str, int], Profile] = {}

    # ------------------------------------------------- Parallelism Library
    def register_technique(self, technique):
        return self.library.register(technique)

    # ----------------------------------------------------------- workload
    def submit(self, jobs):
        self.jobs.extend(jobs)

    def gpu_counts(self):
        g = self.cluster.total_gpus
        counts, c = [], 1
        while c <= g:
            counts.append(c)
            c *= 2
        if g not in counts:
            counts.append(g)
        return counts

    # --------------------------------------------------------- Trial Runner
    def profile(self, mode: str = "analytic"):
        self.profiles = self.runner.profile_all(
            self.jobs, self.gpu_counts(), mode=mode)
        return self.profiles

    # ------------------------------------------------------ Solver + exec
    def run(self, policy: Optional[Policy] = None,
            introspect_every_s: Optional[float] = 600.0,
            noise_sigma: float = 0.1) -> SimResult:
        if not self.profiles:
            self.profile()
        policy = policy or SaturnPolicy()
        return simulate(self.jobs, policy, self.profiles, self.cluster,
                        introspect_every_s=introspect_every_s
                        if policy.dynamic else None,
                        noise_sigma=noise_sigma)
