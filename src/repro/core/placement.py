"""Placement backends: how GPU counts become concrete device sets.

The Schedule IR says *how many* GPUs a job gets; a placement backend
decides *which* ones, and thereby which co-locations are legal:

- :class:`FlatPool` — the legacy behavior: one undifferentiated pool,
  any free devices satisfy any request (node boundaries ignored).
- :class:`NodeAware` — honors what ``solve_joint_nodes`` plans: a
  single-node config (g <= gpus_per_node) must fit inside ONE node's
  free capacity; larger configs must be whole-node multiples and take
  entirely free nodes.  Two 5-GPU jobs can therefore never share one
  8-GPU node.
- :class:`ClassPool` — heterogeneous clusters: one free pool PER device
  class over contiguous global-id ranges.  A class-pinned request
  (``device_class=...``) only draws from that class; an unpinned
  (class-blind) request takes the first class with room, in declaration
  order.  A single allocation never straddles classes.

Select via ``ClusterSpec(placement="flat"|"node")``; clusters with more
than one :class:`~repro.core.job.DeviceClass` always get a ClassPool.

FlatPool and ClassPool are ELASTIC (``supports_elasticity``): the chaos
layer (:mod:`.chaos`) shrinks them by removing concrete free devices and
grows them with :meth:`~PlacementBackend.add_devices`, which always
mints FRESH ids — an id that ever left the pool is never reissued, so
Gantt history, per-class accounting and the conservation check stay
unambiguous across arbitrary shrink/grow sequences (``class_of`` keeps
answering for removed devices).  NodeAware does not support elasticity:
node-aware plans encode node indices that renumber under churn.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from .job import DEFAULT_CLASS
from .schedule import Placement


class PlacementError(RuntimeError):
    """A planned entry can never be hosted by this backend."""


class PlacementBackend:
    kind = "base"
    supports_elasticity = False

    def __init__(self, total_gpus: int):
        self.total_gpus = total_gpus

    @property
    def free_gpus(self) -> int:
        raise NotImplementedError

    def feasible(self, n_gpus: int,
                 device_class: Optional[str] = None) -> bool:
        """Could a request of this size EVER be placed (empty cluster)?"""
        raise NotImplementedError

    def allocate(self, n_gpus: int,
                 preferred_nodes: Optional[Sequence[int]] = None,
                 device_class: Optional[str] = None
                 ) -> Optional[Placement]:
        """Return a Placement or None if it does not fit right now."""
        raise NotImplementedError

    def release(self, placement: Placement) -> None:
        raise NotImplementedError

    def class_of(self, device: int) -> str:
        """Which device class a global device id belongs to."""
        return DEFAULT_CLASS

    # ------------------------------------------------------- elasticity
    def capacity(self, device_class: Optional[str] = None) -> int:
        """Devices currently PRESENT (free + busy), optionally per class."""
        return self.total_gpus

    def free_devices(self, device_class: Optional[str] = None
                     ) -> Tuple[int, ...]:
        """The concrete free device ids, optionally per class."""
        raise NotImplementedError

    def remove_devices(self, devices: Sequence[int]) -> None:
        """Shrink: take concrete FREE devices out of the pool (callers
        kill/release any launch on them first).  ``class_of`` keeps
        answering for removed ids."""
        raise PlacementError(
            f"placement backend {self.kind!r} does not support "
            f"elasticity (shrink/grow)")

    def add_devices(self, n: int,
                    device_class: Optional[str] = None
                    ) -> Tuple[int, ...]:
        """Grow: add ``n`` devices with FRESH ids (never reused) and
        return them."""
        raise PlacementError(
            f"placement backend {self.kind!r} does not support "
            f"elasticity (shrink/grow)")


class FlatPool(PlacementBackend):
    """One big pool of interchangeable GPUs (today's executor model).

    Elastic: ``total_gpus`` tracks the present pool, so ``feasible``
    tightens under shrink and relaxes under grow.  Device classes are
    ignored — the whole pool is the single "default" class.
    """

    kind = "flat"
    supports_elasticity = True

    def __init__(self, total_gpus: int):
        super().__init__(total_gpus)
        self._free = list(range(total_gpus))   # kept sorted
        self._next_id = total_gpus             # fresh ids for add_devices

    @property
    def free_gpus(self) -> int:
        return len(self._free)

    def feasible(self, n_gpus, device_class=None):
        return 0 < n_gpus <= self.total_gpus

    def allocate(self, n_gpus, preferred_nodes=None, device_class=None):
        if n_gpus > len(self._free):
            return None
        devs = tuple(self._free[:n_gpus])
        del self._free[:n_gpus]
        return Placement(devs)

    def release(self, placement: Placement) -> None:
        self._free = sorted(set(self._free) | set(placement.devices))

    def free_devices(self, device_class=None):
        return tuple(self._free)

    def remove_devices(self, devices) -> None:
        victims = set(devices)
        missing = victims - set(self._free)
        if missing:
            raise PlacementError(
                f"cannot remove busy/unknown devices {sorted(missing)}")
        self._free = [d for d in self._free if d not in victims]
        self.total_gpus -= len(victims)

    def add_devices(self, n, device_class=None):
        fresh = tuple(range(self._next_id, self._next_id + n))
        self._next_id += n
        self._free = sorted(self._free + list(fresh))
        self.total_gpus += n
        return fresh


class NodeAware(PlacementBackend):
    """Per-node capacity: single-node configs best-fit into one node;
    whole-node-multiple configs take k fully free nodes."""

    kind = "node"

    def __init__(self, nodes: int, gpus_per_node: int):
        super().__init__(nodes * gpus_per_node)
        self.nodes = nodes
        self.gpus_per_node = gpus_per_node
        self._free: List[List[int]] = [
            list(range(nu * gpus_per_node, (nu + 1) * gpus_per_node))
            for nu in range(nodes)]

    @property
    def free_gpus(self) -> int:
        return sum(len(f) for f in self._free)

    def feasible(self, n_gpus, device_class=None):
        if n_gpus <= 0 or n_gpus > self.total_gpus:
            return False
        return (n_gpus <= self.gpus_per_node
                or n_gpus % self.gpus_per_node == 0)

    def _take(self, nu: int, n: int) -> Tuple[int, ...]:
        devs = tuple(self._free[nu][:n])
        del self._free[nu][:n]
        return devs

    def allocate(self, n_gpus, preferred_nodes=None, device_class=None):
        if not self.feasible(n_gpus):
            return None
        pref = list(preferred_nodes or [])
        if n_gpus <= self.gpus_per_node:
            # preferred node first, else best fit (smallest sufficient
            # free capacity) to limit fragmentation; ties -> lowest id
            for nu in pref:
                if 0 <= nu < self.nodes and len(self._free[nu]) >= n_gpus:
                    return Placement(self._take(nu, n_gpus))
            cands = [(len(self._free[nu]), nu) for nu in range(self.nodes)
                     if len(self._free[nu]) >= n_gpus]
            if not cands:
                return None
            _, nu = min(cands)
            return Placement(self._take(nu, n_gpus))
        k = n_gpus // self.gpus_per_node
        empty = [nu for nu in range(self.nodes)
                 if len(self._free[nu]) == self.gpus_per_node]
        if len(empty) < k:
            return None
        chosen = [nu for nu in pref if nu in empty][:k]
        for nu in empty:
            if len(chosen) >= k:
                break
            if nu not in chosen:
                chosen.append(nu)
        devs: Tuple[int, ...] = ()
        for nu in sorted(chosen):
            devs += self._take(nu, self.gpus_per_node)
        return Placement(devs)

    def release(self, placement: Placement) -> None:
        for d in placement.devices:
            nu = d // self.gpus_per_node
            self._free[nu].append(d)
        for nu in range(self.nodes):
            self._free[nu].sort()


class ClassPool(PlacementBackend):
    """Heterogeneous clusters: one flat free pool per device class.

    Initial global device ids are contiguous per class in declaration
    order (matching :meth:`ClusterSpec.device_ranges`); elastic grows
    append fresh ids past the initial ranges.  The id -> class map is
    persistent — it keeps answering for removed devices, because Gantt
    entries and the conservation check reference them after the fact.
    """

    kind = "class"
    supports_elasticity = True

    def __init__(self, classes: Sequence):
        # classes: Sequence[repro.core.job.DeviceClass]
        classes = tuple(classes)
        super().__init__(sum(dc.total_gpus for dc in classes))
        if not classes:
            raise ValueError("ClassPool needs at least one device class")
        self.classes = classes
        self._free = {}
        self._cap = {}                 # class -> devices present (free+busy)
        self._dev_class = {}           # id -> class, persistent
        off = 0
        for dc in classes:
            self._free[dc.name] = list(range(off, off + dc.total_gpus))
            self._cap[dc.name] = dc.total_gpus
            for d in range(off, off + dc.total_gpus):
                self._dev_class[d] = dc.name
            off += dc.total_gpus
        self._next_id = off

    @property
    def free_gpus(self) -> int:
        return sum(len(f) for f in self._free.values())

    def free_in(self, device_class: str) -> int:
        return len(self._free[device_class])

    def class_of(self, device: int) -> str:
        try:
            return self._dev_class[device]
        except KeyError:
            raise KeyError(f"device {device} outside cluster")

    def _capacity(self, device_class: str) -> int:
        return self._cap[device_class]

    def feasible(self, n_gpus, device_class=None):
        if n_gpus <= 0:
            return False
        if device_class is not None:
            if device_class not in self._cap:
                raise PlacementError(
                    f"unknown device class {device_class!r} "
                    f"(have {list(self._cap)})")
            return n_gpus <= self._capacity(device_class)
        return any(n_gpus <= self._capacity(n) for n in self._cap)

    def allocate(self, n_gpus, preferred_nodes=None, device_class=None):
        if device_class is not None and device_class not in self._free:
            raise PlacementError(
                f"unknown device class {device_class!r} "
                f"(have {list(self._free)})")
        names = ([device_class] if device_class is not None
                 else [dc.name for dc in self.classes])
        for name in names:
            free = self._free[name]
            if n_gpus <= len(free):
                devs = tuple(free[:n_gpus])
                del free[:n_gpus]
                return Placement(devs, device_class=name)
        return None

    def release(self, placement: Placement) -> None:
        for d in placement.devices:
            self._free[self.class_of(d)].append(d)
        for free in self._free.values():
            free.sort()

    def capacity(self, device_class: Optional[str] = None) -> int:
        if device_class is None:
            return self.total_gpus
        return self._cap[device_class]

    def free_devices(self, device_class=None):
        if device_class is None:
            return tuple(d for free in self._free.values() for d in free)
        return tuple(self._free[device_class])

    def remove_devices(self, devices) -> None:
        victims = list(devices)
        for d in victims:
            dc = self._dev_class.get(d)
            if dc is None or d not in self._free[dc]:
                raise PlacementError(
                    f"cannot remove busy/unknown device {d}")
        for d in victims:
            dc = self._dev_class[d]
            self._free[dc].remove(d)
            self._cap[dc] -= 1
        self.total_gpus -= len(victims)

    def add_devices(self, n, device_class=None):
        if device_class is None:
            if len(self.classes) != 1:
                raise PlacementError(
                    "add_devices on a multi-class pool needs an explicit "
                    "device_class")
            device_class = self.classes[0].name
        if device_class not in self._free:
            raise PlacementError(
                f"unknown device class {device_class!r} "
                f"(have {list(self._free)})")
        fresh = tuple(range(self._next_id, self._next_id + n))
        self._next_id += n
        for d in fresh:
            self._dev_class[d] = device_class
        self._free[device_class] = sorted(
            self._free[device_class] + list(fresh))
        self._cap[device_class] += n
        self.total_gpus += n
        return fresh


def make_backend(cluster, kind: Optional[str] = None) -> PlacementBackend:
    """Build the backend a ClusterSpec asks for (default: its
    ``placement`` field, falling back to flat).  Heterogeneous clusters
    always allocate from per-class pools."""
    if getattr(cluster, "hetero", False):
        if (kind or getattr(cluster, "placement", "flat")) == "node":
            raise ValueError("node-aware placement is not supported on "
                             "heterogeneous clusters yet; use per-class "
                             "pools (placement='flat')")
        return ClassPool(cluster.device_classes)
    kind = kind or getattr(cluster, "placement", "flat")
    if kind == "flat":
        return FlatPool(cluster.total_gpus)
    if kind == "node":
        return NodeAware(cluster.nodes, cluster.gpus_per_node)
    raise ValueError(f"unknown placement backend: {kind!r}")
