"""Gemma-3-4B: 5:1 local:global attention, 128k ctx [hf:google/gemma-3-1b-pt]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", arch_type="dense",
    num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4,
    d_ff=10240, vocab_size=262144, head_dim=256,
    block_pattern=("swa",) * 5 + ("attn",), window_size=1024,
    rope_theta=1000000.0, tie_embeddings=True, long_context=True,
    source="5:1 local:global, 128k [hf:google/gemma-3-1b-pt]",
)
