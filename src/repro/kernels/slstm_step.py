"""Pallas TPU kernel for the sLSTM recurrence (xLSTM scalar memory).

The sLSTM's per-step recurrent matmuls (h @ R_z/i/f/o, each (D, D) per
head) make it latency-bound when expressed as a 4096-iteration XLA while
loop over HBM-resident state (see EXPERIMENTS.md §Perf pair 1).  This
kernel keeps the state (c, n, m, h) AND the four recurrent matrices
resident in VMEM across the whole sequence:

Grid: (batch, heads, num_s_blocks) — s minor-most, so each (b, h)
program walks its sequence blocks in order; gate pre-activations stream
in (block_s, D, 4) tiles; per step a (1, D) x (D, D) matmul per gate
runs on the MXU from VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _slstm_kernel(gates_ref, rz_ref, ri_ref, rf_ref, ro_ref, y_ref,
                  c_ref, n_ref, m_ref, h_ref, *, block_s: int):
    isb = pl.program_id(2)

    @pl.when(isb == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        h_ref[...] = jnp.zeros_like(h_ref)

    rz = rz_ref[0].astype(jnp.float32)       # (D, D), R[h]: out, in
    ri = ri_ref[0].astype(jnp.float32)
    rf = rf_ref[0].astype(jnp.float32)
    ro = ro_ref[0].astype(jnp.float32)
    g = gates_ref[0, 0].astype(jnp.float32)  # (block_s, D, 4)

    def step(t, state):
        c, n, m, h = state                   # each (1, D) f32
        # recurrent contribution: pres_e = gx_e + sum_d h_d R[e, d]
        hz = jax.lax.dot_general(h, rz, (((1,), (1,)), ((), ())))
        hi = jax.lax.dot_general(h, ri, (((1,), (1,)), ((), ())))
        hf = jax.lax.dot_general(h, rf, (((1,), (1,)), ((), ())))
        ho = jax.lax.dot_general(h, ro, (((1,), (1,)), ((), ())))
        z = jnp.tanh(g[t, :, 0][None] + hz)
        i_pre = g[t, :, 1][None] + hi
        lf = jax.nn.log_sigmoid(g[t, :, 2][None] + hf)
        o = jax.nn.sigmoid(g[t, :, 3][None] + ho)
        m_new = jnp.maximum(lf + m, i_pre)
        fg = jnp.exp(lf + m - m_new)
        ig = jnp.exp(i_pre - m_new)
        c_new = fg * c + ig * z
        n_new = jnp.maximum(fg * n + ig, 1e-6)
        h_new = o * c_new / n_new
        y_ref[0, 0, t, :] = h_new[0].astype(y_ref.dtype)
        return (c_new, n_new, m_new, h_new)

    state = (c_ref[...], n_ref[...], m_ref[...], h_ref[...])
    c, n, m, h = jax.lax.fori_loop(0, block_s, step, state)
    c_ref[...], n_ref[...], m_ref[...], h_ref[...] = c, n, m, h


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def slstm_step_scan(gates, rz, ri, rf, ro, *, block_s: int = 128,
                    interpret: bool = False):
    """gates: (B, S, H, D, 4) pre-activations (incl. biases);
    rz/ri/rf/ro: (H, D, D) recurrent weights (R[h, out, in]).
    Returns h sequence (B, S, H, D).  Matches the naive scan in
    ``repro.models.recurrent.slstm_block``."""
    b, s, h, d, _ = gates.shape
    block_s = min(block_s, s)
    assert s % block_s == 0
    gt = gates.transpose(0, 2, 1, 3, 4)      # (B, H, S, D, 4)
    grid = (b, h, s // block_s)
    out = pl.pallas_call(
        functools.partial(_slstm_kernel, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_s, d, 4),
                         lambda b_, h_, isb: (b_, h_, isb, 0, 0)),
            pl.BlockSpec((1, d, d), lambda b_, h_, isb: (h_, 0, 0)),
            pl.BlockSpec((1, d, d), lambda b_, h_, isb: (h_, 0, 0)),
            pl.BlockSpec((1, d, d), lambda b_, h_, isb: (h_, 0, 0)),
            pl.BlockSpec((1, d, d), lambda b_, h_, isb: (h_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_s, d),
                               lambda b_, h_, isb: (b_, h_, isb, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), gates.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),  # c
            pltpu.VMEM((1, d), jnp.float32),  # n
            pltpu.VMEM((1, d), jnp.float32),  # m
            pltpu.VMEM((1, d), jnp.float32),  # h
        ],
        interpret=interpret,
    )(gt, rz, ri, rf, ro)
    return out.transpose(0, 2, 1, 3)
