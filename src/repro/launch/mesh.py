"""Production meshes and logical->mesh sharding rules for the dry-run.

``make_production_mesh`` builds the 256-chip single-pod (16x16
data x model) or 512-chip two-pod (2x16x16 pod x data x model) mesh.
Functions, not module constants — importing this module never touches
jax device state.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np

from ..models.config import InputShape, ModelConfig
from ..models.params import is_spec
from ..models.transformer import decode_state_spec, model_spec


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def _axis_sizes(spec_tree, logical: str):
    """All dim sizes that carry a given logical axis name in the model."""
    sizes = set()
    for leaf in jax.tree.leaves(spec_tree, is_leaf=is_spec):
        for dim, ax in zip(leaf.shape, leaf.axes):
            if ax == logical:
                sizes.add(dim)
    return sizes


def production_param_rules(cfg: ModelConfig, mesh,
                           multi_pod: bool) -> Dict[str, Optional[str]]:
    """2-D sharding: FSDP ("embed" over data) x TP ("heads"/"ffn"/
    "experts"/"vocab"/"rnn" over model), filtered by divisibility of
    every tensor dim that carries the logical axis.  Params are
    replicated across pods (pure data parallelism on the pod axis)."""
    spec_tree = model_spec(cfg)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    want = [("vocab", "model"), ("embed", "data"), ("heads", "model"),
            ("kv_heads", "model"), ("ffn", "model"), ("experts", "model"),
            ("rnn", "model")]
    rules: Dict[str, Optional[str]] = {}
    for logical, mesh_ax in want:
        n = sizes[mesh_ax]
        occ = _axis_sizes(spec_tree, logical)
        if occ and all(s % n == 0 for s in occ):
            rules[logical] = mesh_ax
    return rules


def activation_rules(cfg: ModelConfig, shape: InputShape,
                     multi_pod: bool) -> Dict[str, Optional[str]]:
    bax = batch_axes(multi_pod)
    total_b = 32 if multi_pod else 16
    return {
        "batch": bax if shape.global_batch % total_b == 0 else None,
        "seq": None,
        "vocab": "model" if cfg.vocab_size % 16 == 0 else None,
        "experts": ("model" if cfg.is_moe and
                    cfg.moe.num_experts % 16 == 0 else None),
    }


def cache_shardings(cfg: ModelConfig, shape: InputShape, mesh,
                    multi_pod: bool, dtype=None, policy: str = "heads"):
    """NamedShardings for the decode state (KV caches / recurrent states).

    Policy (baseline): batch over (pod,)data when divisible; for the
    KV cache prefer kv_heads -> model, then head_dim -> model, then the
    sequence dim -> model; long_500k (batch=1) shards the sequence dim
    over data.  Recurrent states shard their largest feature dim over
    model when divisible."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec

    dtype = dtype or jnp.bfloat16
    b, L = shape.global_batch, shape.seq_len
    spec = decode_state_spec(cfg, b, L, dtype)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize, dsize = sizes["model"], sizes["data"]
    total_b = int(np.prod([sizes[a] for a in batch_axes(multi_pod)]))
    bax = batch_axes(multi_pod) if b % total_b == 0 else None
    long_ctx = b == 1

    def leaf(path, s):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shp = s.shape
        entries = [None] * len(shp)
        if name == "pos":
            return NamedSharding(mesh, PartitionSpec())
        if name in ("k", "v"):
            off = len(shp) - 4           # stacked layer dim(s) lead
            if bax:
                entries[off] = bax
            if long_ctx and shp[off + 1] % dsize == 0:
                entries[off + 1] = "data"
            if policy == "seq" and shp[off + 1] % (
                    (dsize if long_ctx else 1) * msize) == 0:
                # sequence-sharded cache: decode attention reduces over
                # the sharded L dim (small score all-reduce) and the DUS
                # append touches one shard — no cache all-gather
                entries[off + 1] = (("data", "model") if long_ctx
                                    else "model")
            elif shp[off + 2] % msize == 0:
                entries[off + 2] = "model"          # kv heads
            elif shp[off + 3] % msize == 0:
                entries[off + 3] = "model"          # head_dim
            elif shp[off + 1] % (dsize * msize if long_ctx else msize) == 0:
                if long_ctx:
                    entries[off + 1] = ("data", "model")
                else:
                    entries[off + 1] = "model"      # sequence dim
            return NamedSharding(mesh, PartitionSpec(*entries))
        # recurrent states: (layers?, B, features...)
        # find batch dim: first dim equal to b after stacked dims
        off = 0
        for i, d in enumerate(shp):
            if d == b:
                off = i
                break
        if bax and shp[off] == b:
            entries[off] = bax
        # largest feature dim divisible by model size
        feat = [(d, i) for i, d in enumerate(shp) if i > off]
        feat.sort(reverse=True)
        for d, i in feat:
            if d % msize == 0:
                entries[i] = "model"
                break
        return NamedSharding(mesh, PartitionSpec(*entries))

    return jax.tree_util.tree_map_with_path(leaf, spec), spec
