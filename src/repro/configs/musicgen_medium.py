"""MusicGen-medium decoder over EnCodec tokens [arXiv:2306.05284].

Frontend (EnCodec + pattern interleaver) is a stub per the assignment
carve-out: ``input_specs`` supplies precomputed frame embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", arch_type="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    block_pattern=("attn",), frontend="audio",
    tie_embeddings=False,
    source="decoder-only over EnCodec tokens [arXiv:2306.05284]",
)
