"""Launch-layer units: production mesh/rules builders and the optimized
preset (the beyond-paper sharding policy must stay well-formed)."""
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.config import INPUT_SHAPES


def test_production_rules_divisibility():
    """Every rule the builder emits must divide its logical axis sizes
    by the mesh axis size (this is what guarantees compile)."""
    # use a fake mesh-shape view: rules builder only needs names/sizes
    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)
    from repro.launch.mesh import production_param_rules, _axis_sizes
    from repro.models.transformer import model_spec
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        rules = production_param_rules(cfg, FakeMesh, False)
        spec = model_spec(cfg)
        for logical, mesh_ax in rules.items():
            if mesh_ax is None:
                continue
            n = {"data": 16, "model": 16}[mesh_ax]
            for s in _axis_sizes(spec, logical):
                assert s % n == 0, (arch, logical, s, n)


def test_gemma3_heads_not_sharded():
    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)
    from repro.launch.mesh import production_param_rules
    rules = production_param_rules(get_config("gemma3-4b"), FakeMesh, False)
    assert "heads" not in rules          # 8 heads % 16 != 0
    assert rules.get("ffn") == "model"   # 10240 % 16 == 0
    assert rules.get("vocab") == "model"


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_optimized_preset_well_formed(arch, shape_name):
    from repro.launch.dryrun import optimized_overrides
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    kw = optimized_overrides(cfg, shape)
    assert isinstance(kw.get("extra_opts", {}), dict)
    ro = kw.get("rules_override")
    if shape.mode == "decode":
        # windowed archs keep the heads cache policy (measured better)
        if cfg.window_size:
            assert kw.get("cache_policy", "heads") == "heads"
        elif cfg.has_global_attention():
            assert kw.get("cache_policy") == "seq"
    if shape.mode == "train" and not cfg.is_moe:
        assert ro and "batch" in ro      # DP/FSDP over both axes
