"""Event types + queue for the cluster runtime.

The runtime is a discrete-event simulation over five event families:

- :class:`JobArrival` — a job enters the system (online workloads carry
  ``Job.arrival_s``; offline workloads all arrive at t=0).
- :class:`ClusterEvent` — the cluster itself changes: node failures,
  spot grants/revocations, capacity grow/shrink.  Concrete types live
  in :mod:`repro.core.chaos`; only the base class (and its priority
  slot) is defined here so the queue's total order is in one place.
- :class:`JobCompletion` — a running job finishes its remaining steps.
  Carries a launch token so completions of preempted launches are
  ignored as stale.
- :class:`RestartDone` — a preempted job finished its checkpoint +
  relaunch penalty and is admissible again.  This is what makes the
  restart cost *real*: the job cannot re-occupy GPUs before this fires.
- :class:`IntrospectionTick` — the paper's introspection interval:
  settle observed progress and (for dynamic policies) re-solve.

Tie-breaking at equal timestamps follows the legacy simulator:
arrivals first, then cluster events, then completions, then restart
wake-ups, then introspection; among equals, FIFO by push order.
A :class:`~repro.core.chaos.NodeFailure` at the same instant as a
:class:`JobCompletion` therefore deterministically processes FIRST — a
job whose devices die at the very moment it would have finished loses
the race (conservative, and pinned by tests/test_events.py).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional, Tuple, Type


@dataclasses.dataclass(frozen=True)
class Event:
    t: float
    PRIORITY = 99


@dataclasses.dataclass(frozen=True)
class JobArrival(Event):
    PRIORITY = 0
    job: object = None            # core.job.Job


@dataclasses.dataclass(frozen=True)
class ClusterEvent(Event):
    """Base for cluster-topology events (failures, spot churn, capacity
    changes).  Processes after same-instant arrivals but BEFORE
    same-instant completions; see module docstring."""
    PRIORITY = 1


@dataclasses.dataclass(frozen=True)
class JobCompletion(Event):
    PRIORITY = 2
    job: str = ""
    token: int = -1               # launch token; stale if it mismatches


@dataclasses.dataclass(frozen=True)
class RestartDone(Event):
    PRIORITY = 3
    job: str = ""


@dataclasses.dataclass(frozen=True)
class IntrospectionTick(Event):
    PRIORITY = 4


class EventQueue:
    """Min-heap over (t, priority, seq); seq keeps FIFO order stable."""

    def __init__(self):
        self._heap = []
        self._seq = 0

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def push(self, ev: Event) -> None:
        heapq.heappush(self._heap, (ev.t, ev.PRIORITY, self._seq, ev))
        self._seq += 1

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[3]

    def peek(self) -> Optional[Event]:
        return self._heap[0][3] if self._heap else None

    def pop_while(self, kind: Type[Event], t: float, eps: float = 1e-12):
        """Pop and yield consecutive events of ``kind`` at time ~t (used
        to coalesce same-instant arrival batches into one replan)."""
        out = []
        while self._heap:
            nxt = self._heap[0][3]
            if isinstance(nxt, kind) and abs(nxt.t - t) <= eps:
                out.append(heapq.heappop(self._heap)[3])
            else:
                break
        return out

    def has_any(self, kinds: Tuple[Type[Event], ...]) -> bool:
        return any(isinstance(item[3], kinds) for item in self._heap)
