"""Worker-failure machinery in the event engine, exercised with a
scripted virtual-time backend (no real processes): detection events,
checkpoint salvage, retry backoff, quarantine on budget exhaustion,
stale-failure drops, and the RetryPolicy / poisson_worker_faults
contracts."""
import pytest

from repro.configs import get_config
from repro.core.baselines import CurrentPractice
from repro.core.chaos import (ChaosTrace, NodeFailure, RetryPolicy,
                              WorkerFailure, WorkerFault,
                              poisson_worker_faults)
from repro.core.executor import simulate
from repro.core.job import ClusterSpec, Job
from repro.core.profiler import Profile
from repro.core.runtime import SimBackend

CFG = get_config("xlstm-125m").reduced()
CLUSTER = ClusterSpec(nodes=1, gpus_per_node=4, restart_cost_s=1.0)


def mk_workload(n_jobs=2, steps=200):
    jobs, profiles = [], {}
    for i in range(n_jobs):
        j = Job(f"j{i}", CFG, 8, 64, total_steps=steps + 50 * i, seed=i)
        jobs.append(j)
        for g in (1, 2, 4):
            profiles[(j.name, "ddp", g)] = Profile(
                j.name, "ddp", g, (1.0 + 0.2 * i) / g ** 0.8, 1e9, True, "t")
    return jobs, profiles


class ScriptedFaultBackend(SimBackend):
    """Virtual-time backend that really honors WorkerFault injection:
    the victim's launch is recorded as pending-failed with a scripted
    durable-step answer, delivered through drain_failures() exactly like
    a real supervision channel — so engine-side detection, salvage,
    backoff and quarantine run for real at sim speed."""

    def __init__(self, durable_fraction=0.5, retry_policy=None, **kw):
        super().__init__(**kw)
        self.durable_fraction = durable_fraction
        self.retry_policy = retry_policy
        self._pending = []           # (handle, reason)
        self._durable = {}           # launch token -> durable steps
        self.injected = []           # (kind, job, t) audit trail

    def inject_fault(self, fault, running, t):
        if fault.job is not None:
            h = running.get(fault.job)
            if h is None:
                return               # victim not live: injection no-ops
        else:
            if not running:
                return
            h = running[min(running)]
        self.injected.append((fault.kind, h.job.name, t))
        done = self.steps_done(h, t)
        self._durable[h.token] = int(done * self.durable_fraction)
        self._pending.append((h, f"injected {fault.kind}"))

    def drain_failures(self):
        out, self._pending = tuple(self._pending), []
        return out

    def salvage(self, handle):
        return self._durable.get(handle.token, 0)


class AlwaysFailBackend(ScriptedFaultBackend):
    """Every launch of ``victim`` crashes (salvaging nothing): the only
    way out for that job is the quarantine path."""

    def __init__(self, victim, **kw):
        super().__init__(**kw)
        self.victim = victim

    def launch(self, job, entry, placement, device_class, remaining, t,
               token):
        h = super().launch(job, entry, placement, device_class, remaining,
                           t, token)
        if job.name == self.victim:
            self._pending.append((h, "scripted crash"))
        return h


# ------------------------------------------------ salvage and relaunch

def test_fault_salvages_and_relaunches_to_completion():
    jobs, profiles = mk_workload(n_jobs=2, steps=200)
    be = ScriptedFaultBackend(
        durable_fraction=0.5,
        retry_policy=RetryPolicy(budget=3, base_s=50.0, jitter=0.0),
        noise_sigma=0.0)
    trace = ChaosTrace((WorkerFault(30.0, "sigkill", "j0"),))
    res = simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                   exec_backend=be, chaos=trace)
    assert be.injected == [("sigkill", "j0", 30.0)]
    assert res.worker_failures == 1
    assert res.quarantined == {}
    # half the victim's progress was durable: the relaunch reruns the
    # other half, so j0 burns MORE gpu-seconds than its budget alone
    runs = [g for g in res.gantt if g.job == "j0" and g.kind == "run"]
    assert len(runs) == 2
    # the failure restart charges the full scripted backoff (50s beats
    # the 1s cluster restart cost), exactly once
    restarts = [g for g in res.gantt if g.job == "j0"
                and g.kind == "restart"]
    assert len(restarts) == 1 and res.restarts == 1
    assert restarts[0].end_s - restarts[0].start_s == pytest.approx(50.0)
    # relaunch waits out the backoff before running again
    assert runs[1].start_s >= restarts[0].end_s - 1e-9


def test_everything_durable_means_no_relaunch():
    """A worker that dies AFTER its last step was checkpointed loses
    nothing: the launch closes as complete, no retry is charged."""
    jobs, profiles = mk_workload(n_jobs=1, steps=100)
    be = ScriptedFaultBackend(durable_fraction=1.0, noise_sigma=0.0)

    class FullSalvage(ScriptedFaultBackend):
        def salvage(self, handle):
            return handle.steps_at_start

    be = FullSalvage(noise_sigma=0.0)
    trace = ChaosTrace((WorkerFault(30.0, "sigkill", "j0"),))
    res = simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                   exec_backend=be, chaos=trace)
    assert res.worker_failures == 1
    assert res.restarts == 0
    assert res.quarantined == {}
    # the run ends at the detection point, not the job's natural eta
    assert res.makespan_s < 100 * 1.0 / 1 ** 0.8


def test_unnamed_fault_picks_first_live_launch_deterministically():
    jobs, profiles = mk_workload(n_jobs=3, steps=200)
    be = ScriptedFaultBackend(
        retry_policy=RetryPolicy(budget=3, base_s=2.0, jitter=0.0),
        noise_sigma=0.0)
    trace = ChaosTrace((WorkerFault(10.0, "hang", None),))
    res = simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                   exec_backend=be, chaos=trace)
    assert [v for _, v, _ in be.injected] == ["j0"]
    assert res.worker_failures == 1


def test_fault_against_finished_job_is_noop():
    jobs, profiles = mk_workload(n_jobs=1, steps=10)
    be = ScriptedFaultBackend(noise_sigma=0.0)
    # j0 finishes at t=10; the fault at t=50 finds nothing to hurt
    trace = ChaosTrace((WorkerFault(50.0, "sigkill", "j0"),))
    res = simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                   exec_backend=be, chaos=trace)
    assert be.injected == []
    assert res.worker_failures == 0 and res.quarantined == {}


# --------------------------------------------------------- quarantine

def test_budget_exhaustion_quarantines_with_reason():
    jobs, profiles = mk_workload(n_jobs=2, steps=100)
    be = AlwaysFailBackend(
        "j0", retry_policy=RetryPolicy(budget=2, base_s=1.0, jitter=0.0),
        noise_sigma=0.0)
    res = simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                   exec_backend=be)
    # budget 2: two relaunches, the third failure quarantines — the run
    # COMPLETES (no deadlock, no raise) with the reason recorded
    assert res.worker_failures == 3
    assert "j0" in res.quarantined
    assert "retry budget exhausted after 3 failures" in res.quarantined["j0"]
    assert "scripted crash" in res.quarantined["j0"]
    # the healthy job still ran its full budget
    j1_runs = [g for g in res.gantt if g.job == "j1" and g.kind == "run"]
    assert j1_runs and res.makespan_s > 0


def test_zero_budget_quarantines_on_first_failure():
    jobs, profiles = mk_workload(n_jobs=1, steps=100)
    be = AlwaysFailBackend("j0", retry_policy=RetryPolicy(budget=0),
                           noise_sigma=0.0)
    res = simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                   exec_backend=be)
    assert res.worker_failures == 1 and res.restarts == 0
    assert "after 1 failures" in res.quarantined["j0"]


# ------------------------------------------------------ stale failures

def test_stale_token_failure_is_dropped():
    """A WorkerFailure whose token does not match the live launch (the
    launch it saw die was already preempted/replaced) must be ignored —
    same-name-different-launch is not the same failure."""
    jobs, profiles = mk_workload(n_jobs=2, steps=200)
    trace = ChaosTrace((WorkerFailure(30.0, job="j0", token=999,
                                      reason="stale ghost"),))
    base = simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                    noise_sigma=0.0)
    res = simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                   noise_sigma=0.0, chaos=trace)
    assert res.worker_failures == 0
    assert res.quarantined == {}
    assert res.makespan_s == base.makespan_s
    assert len(res.gantt) == len(base.gantt)


# ------------------------------------------- backend capability gating

def test_sim_backend_refuses_fault_injection():
    jobs, profiles = mk_workload(n_jobs=1)
    trace = ChaosTrace((WorkerFault(5.0, "sigkill", "j0"),))
    with pytest.raises(RuntimeError, match="ProcessJaxBackend"):
        simulate(jobs, CurrentPractice(), profiles, CLUSTER, chaos=trace)


def test_workerfault_trace_allowed_on_non_elastic_placement():
    """WorkerFaults never touch the placement pool, so a fault-only
    trace runs under node placement; mixing in a pool-shrinking event
    still requires elasticity."""
    jobs, profiles = mk_workload(n_jobs=1, steps=100)
    cluster = ClusterSpec(nodes=1, gpus_per_node=4, restart_cost_s=1.0,
                          placement="node")
    be = ScriptedFaultBackend(
        retry_policy=RetryPolicy(budget=3, base_s=1.0, jitter=0.0),
        noise_sigma=0.0)
    res = simulate(jobs, CurrentPractice(), profiles, cluster,
                   exec_backend=be,
                   chaos=ChaosTrace((WorkerFault(10.0, "sigkill", "j0"),)))
    assert res.worker_failures == 1
    with pytest.raises(ValueError, match="elastic"):
        simulate(jobs, CurrentPractice(), profiles, cluster,
                 exec_backend=ScriptedFaultBackend(noise_sigma=0.0),
                 chaos=ChaosTrace((WorkerFault(10.0, "sigkill", "j0"),
                                   NodeFailure(20.0))))


# ---------------------------------------------------------- RetryPolicy

def test_retry_backoff_doubles_and_caps():
    rp = RetryPolicy(budget=5, base_s=2.0, cap_s=10.0, jitter=0.0)
    assert rp.backoff_s("j", 1) == 2.0
    assert rp.backoff_s("j", 2) == 4.0
    assert rp.backoff_s("j", 3) == 8.0
    assert rp.backoff_s("j", 4) == 10.0        # capped
    assert rp.backoff_s("j", 9) == 10.0


def test_retry_jitter_bounded_and_deterministic():
    rp = RetryPolicy(base_s=8.0, cap_s=8.0, jitter=0.25, seed=3)
    a = rp.backoff_s("jobA", 1)
    assert 8.0 * 0.75 <= a <= 8.0 * 1.25
    assert a == rp.backoff_s("jobA", 1)        # seeded: reproducible
    # per-(job, attempt) seeding: concurrent victims desynchronize
    assert a != rp.backoff_s("jobB", 1)
    assert rp.backoff_s("jobA", 2) != 2.0 * a


def test_retry_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(budget=-1)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=1.0)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=-0.1)


# ------------------------------------------------ poisson_worker_faults

def test_poisson_worker_faults_deterministic_and_typed():
    a = poisson_worker_faults(60.0, 3600.0, seed=5)
    b = poisson_worker_faults(60.0, 3600.0, seed=5)
    assert a == b and len(a) > 10
    assert all(isinstance(e, WorkerFault) for e in a)
    assert all(0 <= e.t < 3600.0 for e in a)
    assert {e.kind for e in a} <= {"sigkill", "hang", "corrupt"}
    assert poisson_worker_faults(60.0, 3600.0, seed=6) != a
    assert poisson_worker_faults(0.0, 3600.0) == ()


def test_poisson_worker_faults_kinds_and_jobs():
    evs = poisson_worker_faults(120.0, 3600.0, seed=1,
                                kinds=("sigkill",), jobs=("a", "b"))
    assert {e.kind for e in evs} == {"sigkill"}
    assert {e.job for e in evs} <= {"a", "b"}
    with pytest.raises(ValueError):
        poisson_worker_faults(1.0, 10.0, kinds=())
    with pytest.raises(ValueError):
        poisson_worker_faults(-1.0, 10.0)
