"""OLMoE-1B-7B: 64 experts, top-8 [arXiv:2409.02060]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", arch_type="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=0, vocab_size=50304, head_dim=128,
    block_pattern=("attn",),
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024),
    tie_embeddings=False,
    source="64 experts top-8 [arXiv:2409.02060]",
)
