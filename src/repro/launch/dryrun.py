import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count at first
#   init.  512 placeholder host devices host the production meshes.

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and record memory / cost / collective analysis.

  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun

Every combination must compile; failures are bugs in the sharding
config.  Results feed EXPERIMENTS.md §Dry-run and §Roofline.
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from ..configs import ARCH_IDS, get_config, input_specs, shape_supported
from ..models.config import INPUT_SHAPES, InputShape, ModelConfig
from ..models.params import abstract_params
from ..models.transformer import model_spec
from .hlo_analysis import analyze as analyze_hlo
from ..parallelism.context import axis_rules
from ..parallelism.shardings import param_shardings_from_rules
from .mesh import (activation_rules, cache_shardings,
                   make_production_mesh, production_param_rules)


def _batch_shardings(batch_specs, mesh, bax):
    def mk(x):
        if x.ndim == 0 or bax is None:
            return NamedSharding(mesh, PartitionSpec())
        return NamedSharding(mesh, PartitionSpec(bax))
    return jax.tree.map(mk, batch_specs)


def build_lowerable(cfg: ModelConfig, shape: InputShape, mesh,
                    multi_pod: bool, *, remat: Optional[bool] = None,
                    extra_opts: Optional[dict] = None,
                    rules_override: Optional[dict] = None,
                    param_rules_override: Optional[dict] = None,
                    cache_policy: str = "heads"):
    """Returns (fn, args, in_shardings) ready for jit/lower."""
    from ..optim.adamw import AdamWConfig
    from ..train.steps import make_train_step
    from ..models.transformer import prefill_forward, decode_step

    prules = production_param_rules(cfg, mesh, multi_pod)
    if param_rules_override:
        prules.update(param_rules_override)
        prules = {k: v for k, v in prules.items() if v is not None}
    arules = activation_rules(cfg, shape, multi_pod)
    rules = {**prules, **arules}
    if rules_override:
        rules.update(rules_override)
    spec_tree = model_spec(cfg)
    p_sh = param_shardings_from_rules(spec_tree, prules, mesh)
    p_abs = abstract_params(spec_tree, jnp.bfloat16)
    bax = arules["batch"]
    opts = extra_opts or {}

    if shape.mode == "train":
        if remat is None:
            remat = True  # large-model default: activation checkpointing
        opt_cfg = AdamWConfig()
        base = make_train_step(cfg, opt_cfg, remat=remat, opts=opts)

        def fn(params, opt_state, batch):
            with axis_rules(rules, mesh):
                return base(params, opt_state, batch)

        o_sh = {"mu": p_sh, "nu": p_sh,
                "step": NamedSharding(mesh, PartitionSpec())}
        o_abs = {"mu": abstract_params(spec_tree, jnp.float32),
                 "nu": abstract_params(spec_tree, jnp.float32),
                 "step": jax.ShapeDtypeStruct((), jnp.int32)}
        batch_specs = input_specs(cfg, shape)
        in_sh = (p_sh, o_sh, _batch_shardings(batch_specs, mesh, bax))
        return fn, (p_abs, o_abs, batch_specs), in_sh

    if shape.mode == "prefill":
        def fn(params, batch):
            with axis_rules(rules, mesh):
                return prefill_forward(params, cfg, batch, opts=opts)
        batch_specs = input_specs(cfg, shape)
        in_sh = (p_sh, _batch_shardings(batch_specs, mesh, bax))
        return fn, (p_abs, batch_specs), in_sh

    # decode: serve_step — one token against a seq_len cache
    state_sh, state_abs = cache_shardings(cfg, shape, mesh, multi_pod,
                                          policy=cache_policy)

    def fn(params, tokens, state):
        with axis_rules(rules, mesh):
            logits, new_state = decode_step(params, cfg, tokens, state,
                                            opts=opts)
            nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            return nxt, new_state

    tok_specs = input_specs(cfg, shape)["tokens"]
    tok_sh = NamedSharding(
        mesh, PartitionSpec(bax) if bax else PartitionSpec())
    in_sh = (p_sh, tok_sh, state_sh)
    return fn, (p_abs, tok_specs, state_abs), in_sh


def optimized_overrides(cfg: ModelConfig, shape: InputShape) -> dict:
    """The beyond-paper sharding presets found in EXPERIMENTS.md §Perf.

    - small models (<1B): pure data parallelism over all 256/512 chips
      (TP of a small model is pure overhead), no remat, batched-gradient
      sLSTM.
    - large dense train: FSDP-256 (ZeRO-3 over both axes) instead of
      2-D FSDP x TP — param all-gathers replace per-layer activation
      all-reduces; larger blockwise-attention kv chunks.
    - MoE: keep expert parallelism (experts must shard), FSDP the rest.
    - decode: sequence-sharded KV cache + token-replicated activations
      (weights stay put; tokens move).
    """
    from functools import partial
    from ..models.blockwise import blockwise_attention
    from ..models.params import param_count
    kw: dict = {"extra_opts": {}}
    n_params = param_count(model_spec(cfg))
    small = n_params < 1e9
    if shape.mode == "train":
        if small:
            kw["rules_override"] = {"batch": ("data", "model")}
            kw["param_rules_override"] = {
                "ffn": None, "heads": None, "rnn": None, "vocab": None,
                "embed": None, "kv_heads": None, "experts": None}
            kw["remat"] = False
        elif not cfg.is_moe:
            kw["rules_override"] = {"batch": ("data", "model"),
                                    "vocab": None}
            kw["param_rules_override"] = {
                "heads": None, "kv_heads": None, "ffn": None,
                "rnn": None, "vocab": None}
        # MoE train keeps the expert-parallel 2-D layout (experts must
        # shard over model; embed stays FSDP over data)
        kw["extra_opts"]["slstm_batched_grad"] = True
        if not small:
            kw["extra_opts"]["attn_fn"] = partial(
                _blockwise_big_chunks)
    elif shape.mode == "prefill":
        kw["extra_opts"]["slstm_batched_grad"] = True
        kw["extra_opts"]["attn_fn"] = partial(_blockwise_big_chunks)
    else:  # decode
        # sequence-sharded cache wins when kv heads / head_dim cannot
        # shard cleanly; windowed-attention archs (gemma3, recurrent-
        # gemma, danube) measured better with the baseline heads policy
        if cfg.window_size == 0:
            kw["cache_policy"] = "seq"
        if cfg.is_moe or shape.global_batch <= 1:
            kw["rules_override"] = {"batch": None}
    return kw


def _blockwise_big_chunks(q, k, v, w):
    from ..models.blockwise import blockwise_attention
    s = q.shape[1]
    qc = 1024 if s % 1024 == 0 else 512
    kc = 2048 if s % 2048 == 0 else 512
    return blockwise_attention(q, k, v, window=w, q_chunk=qc, kv_chunk=kc)


def run_one(arch: str, shape_name: str, multi_pod: bool, *,
            remat: Optional[bool] = None, extra_opts: Optional[dict] = None,
            rules_override: Optional[dict] = None,
            param_rules_override: Optional[dict] = None,
            cache_policy: str = "heads", preset: str = "baseline",
            keep_hlo: bool = False, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if preset == "optimized":
        kw = optimized_overrides(cfg, shape)
        extra_opts = {**kw.get("extra_opts", {}), **(extra_opts or {})}
        rules_override = {**kw.get("rules_override", {}),
                          **(rules_override or {})} or None
        param_rules_override = {**kw.get("param_rules_override", {}),
                                **(param_rules_override or {})} or None
        cache_policy = kw.get("cache_policy", cache_policy)
        remat = kw.get("remat", remat)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "mode": shape.mode, "preset": preset}
    if not shape_supported(cfg, shape):
        rec["status"] = "skipped"
        rec["reason"] = ("pure full-attention arch: long_500k requires "
                        "sub-quadratic attention (DESIGN.md)")
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()
    try:
        fn, args, in_sh = build_lowerable(
            cfg, shape, mesh, multi_pod, remat=remat,
            extra_opts=extra_opts, rules_override=rules_override,
            param_rules_override=param_rules_override,
            cache_policy=cache_policy)
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        if keep_hlo:
            rec["hlo_text"] = compiled.as_text()
        cost = compiled.cost_analysis()
        rec["status"] = "ok"
        rec["lower_s"] = round(t_lower, 2)
        rec["compile_s"] = round(t_compile, 2)
        # raw cost_analysis counts while-loop (lax.scan layer) bodies ONCE
        rec["xla_flops_scanfolded"] = float(cost.get("flops", 0.0))
        rec["xla_bytes_scanfolded"] = float(cost.get("bytes accessed", 0.0))
        # loop-aware analysis of the compiled HLO (per-device numbers)
        hlo = analyze_hlo(compiled.as_text())
        rec["flops"] = hlo["flops"]
        rec["bytes_written"] = hlo["bytes_written"]
        rec["collectives"] = hlo["collectives"]
        try:
            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "peak_per_device": int(
                    (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                     + ma.output_size_in_bytes) / n_dev),
            }
        except Exception as e:  # pragma: no cover
            rec["memory"] = {"error": str(e)}
        if verbose:
            print(f"  cost: flops={rec['flops']:.3e} "
                  f"bytes={rec['bytes_written']:.3e} "
                  f"coll={rec['collectives']['total']:.3e}")
            print(f"  memory: {rec['memory']}")
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["wall_s"] = round(time.time() - t0, 2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--preset", default="baseline",
                    choices=["baseline", "optimized"])
    args = ap.parse_args()
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "multipod" if mp else "pod"
                tag = f"{arch}_{shape}_{mesh_name}"
                print(f"[dryrun] {tag}", flush=True)
                rec = run_one(arch, shape, mp, preset=args.preset,
                              remat=False if args.no_remat else None)
                print(f"  -> {rec['status']} ({rec.get('wall_s', 0)}s)"
                      + (f" {rec.get('error', '')}"
                         if rec["status"] == "fail" else ""), flush=True)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "fail":
                    n_fail += 1
    print(f"[dryrun] done, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
