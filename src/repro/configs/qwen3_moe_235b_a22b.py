"""Qwen3-MoE-235B-A22B: 128 experts, top-8 [hf:Qwen/Qwen3-30B-A3B]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", arch_type="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    d_ff=0, vocab_size=151936, head_dim=128,
    block_pattern=("attn",), rope_theta=1000000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536),
    tie_embeddings=False,
    source="128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]",
)
