"""Saturn's core system: Parallelism Library -> Trial Runner -> joint
Solver -> event-driven cluster runtime.

Layering (each layer only imports downward):

    schedule.py   Schedule IR: Placement / ScheduleEntry / Schedule, the
                  Policy interface all planners implement
    events.py     event types + queue (arrival, completion, restart, tick)
    placement.py  pluggable device assignment: FlatPool | NodeAware
    runtime.py    ClusterState + the discrete-event execution engine
    perfmodel.py  throughput curves over GPU count: anchor trials +
                  interpolation (PerfModel, the profiles contract)
    solver.py     the joint MILPs (flat + node-locality), greedy fallback
    baselines.py  paper baselines + the Saturn policy (emit Schedule IR)
    executor.py   simulate() compatibility wrapper + legacy comparator,
                  LocalRunner for real local execution
    api.py        SaturnSession facade
"""
from .api import SaturnSession                              # noqa: F401
from .job import ClusterSpec, DeviceClass, Job, hpo_grid    # noqa: F401
from .perfmodel import PerfModel, ThroughputCurve, select_anchor_counts  # noqa: F401
from .placement import ClassPool, FlatPool, NodeAware, make_backend  # noqa: F401
from .runtime import SimResult, simulate_runtime            # noqa: F401
from .schedule import Placement, Policy, Schedule, ScheduleEntry  # noqa: F401
