"""End-to-end driver: REALLY train a ~100M-param xLSTM on CPU for a few
hundred steps through the full Saturn pipeline — empirical Trial-Runner
profiling, MILP plan, LocalRunner execution with checkpoint/resume (the
introspection relaunch path).

    PYTHONPATH=src python examples/train_e2e.py --steps 300 --size small

--size full uses the real xlstm-125m config (slower on CPU);
--size small uses a ~30M same-family variant for quick runs.
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


from repro.configs import get_config
from repro.core.executor import LocalRunner
from repro.core.job import Job
from repro.core.library import ParallelismLibrary
from repro.core.profiler import HARDWARE, TrialRunner
from repro.core.solver import solve_joint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--size", default="small", choices=["small", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/saturn_e2e")
    args = ap.parse_args()

    base = get_config("xlstm-125m")
    if args.size == "small":
        # ~12M same-family variant — CPU-tractable for a few hundred
        # steps; --size full runs the real 125M config (use on TPU/GPU
        # or be patient)
        cfg = dataclasses.replace(base, num_layers=4, d_model=256,
                                  num_heads=4, head_dim=64,
                                  name="xlstm-12m")
    else:
        cfg = base
    jobs = [Job(f"{cfg.name}-lr{lr:g}", cfg, args.batch, args.seq,
                total_steps=args.steps, lr=lr, seed=i)
            for i, (lr) in enumerate([3e-4, 1e-3])]

    lib = ParallelismLibrary()
    runner = TrialRunner(lib, HARDWARE["a100"])
    print("== Trial Runner (empirical, 2 minibatches each) ==")
    profiles = {}
    for j in jobs:
        p = runner.profile(j, "ddp", 1, mode="empirical")
        profiles[(j.name, "ddp", 1)] = p
        print(f"  {j.name}: {p.step_time_s * 1e3:.0f} ms/step")

    sol = solve_joint(jobs, profiles, total_gpus=1, n_slots=8)
    print(f"== Solver ({sol.solver}) ==  plan:")
    for a in sol.order():
        print(f"  t={a.start_s:.0f}s {a.job} ({a.technique} x{a.n_gpus})")

    local = LocalRunner(ckpt_dir=args.ckpt_dir)
    print("== Executing (LocalRunner, real training, checkpointed) ==")
    for a in sol.order():
        job = next(j for j in jobs if j.name == a.job)
        tech = lib.get(a.technique)
        # run in two halves with a checkpoint/relaunch between — the
        # introspection mechanism's restart path, exercised for real
        t0 = time.time()
        r1 = local.run_job(job, tech, a.n_gpus, steps=job.total_steps // 2)
        r2 = local.run_job(job, tech, a.n_gpus)  # resumes from checkpoint
        print(f"  {job.name}: loss {r1['loss']:.3f} -> {r2['loss']:.3f} "
              f"({job.total_steps} steps, {time.time() - t0:.0f}s, "
              f"resumed at step {job.total_steps // 2})")
        assert r2["done"]


if __name__ == "__main__":
    main()
