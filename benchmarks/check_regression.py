"""CI bench regression gate: compare a freshly generated BENCH_*.json
against the committed baseline and fail on makespan regressions.

    python benchmarks/check_regression.py \\
        --baseline /tmp/BENCH_schedule.base.json \\
        --fresh BENCH_schedule.json [--tolerance 0.10]

Only *makespan-like* metrics are gated (lower is better); wall-clock
fields are machine-dependent and ignored.  Metrics present in the fresh
file but absent from the baseline are skipped (adding new scenarios
never breaks the gate), but a baseline metric MISSING from the fresh
run fails — silently dropping a scenario is a coverage regression.
"""
from __future__ import annotations

import argparse
import json
import sys

# lower-is-better metrics worth gating across machines
GATED_METRICS = (
    "saturn_s",
    "current_practice_s",
    "makespan_exhaustive_s",
    "makespan_interpolated_s",
    "interp_err_median",
    "makespan_aware_s",
    "makespan_blind_s",
)


def collect(obj, prefix=""):
    """Flatten nested dicts to {dotted.path: value} for gated metrics."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            path = f"{prefix}.{k}" if prefix else str(k)
            if isinstance(v, dict):
                out.update(collect(v, path))
            elif k in GATED_METRICS and isinstance(v, (int, float)):
                out[path] = float(v)
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed relative regression (default 10%)")
    args = ap.parse_args()

    with open(args.baseline) as f:
        base = collect(json.load(f))
    with open(args.fresh) as f:
        fresh = collect(json.load(f))

    if not base:
        print(f"no gated metrics in baseline {args.baseline}; skipping")
        return 0

    failures = []
    for path, b in sorted(base.items()):
        if path not in fresh:
            print(f"FAIL {path}: missing from fresh run "
                  f"(scenario dropped?)")
            failures.append(path)
            continue
        fv = fresh[path]
        limit = b * (1.0 + args.tolerance)
        status = "FAIL" if fv > limit else "ok"
        print(f"{status:4s} {path}: baseline={b:.4g} fresh={fv:.4g} "
              f"(limit {limit:.4g})")
        if fv > limit:
            failures.append(path)

    if failures:
        print(f"\n{len(failures)} metric(s) regressed beyond "
              f"{100 * args.tolerance:.0f}%: {', '.join(failures)}")
        return 1
    print("\nno regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
