import os

# Tests run on ONE CPU device (the dry-run sets its own 512-device env in
# a subprocess); keep threads bounded for the single-core container.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
