"""Core NN layers: RMSNorm, RoPE, GQA attention (full / sliding-window,
train / prefill / decode-with-KV-cache).

All functions are pure; params are pytrees from ``params.init_params``.
Logical axis names used here: vocab, embed, heads, kv_heads, head_dim,
ffn, mlp, experts, rnn.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import P

# ---------------------------------------------------------------- RMSNorm

def rmsnorm_spec(d: int) -> P:
    return P((d,), ("embed",), init="ones")


def rmsnorm(scale, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# ------------------------------------------------------------------- RoPE

def rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    ang = ang[..., None, :]                                # (..., S, 1, half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- Attention

def attention_spec(cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "wq": P((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((h, hd, d), ("heads", "head_dim", "embed")),
        "norm": rmsnorm_spec(d),
    }


def _gqa_scores(q, k):
    """q: (B,S,H,D) k: (B,L,Kv,D) -> (B, Kv, Q, S, L) with H = Kv*Q."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    q = q.reshape(b, s, kvh, h // kvh, d)
    return jnp.einsum("bskqd,blkd->bkqsl", q, k)


def _gqa_out(probs, v):
    """probs: (B,Kv,Q,S,L), v: (B,L,Kv,D) -> (B,S,H,D)."""
    b, kvh, qpk, s, _ = probs.shape
    out = jnp.einsum("bkqsl,blkd->bskqd", probs, v)
    return out.reshape(b, s, kvh * qpk, v.shape[-1])


_BLOCKWISE_THRESHOLD = 2048


def attention(p, x, cfg: ModelConfig, *, window: int = 0,
              cache: Optional[dict] = None, positions=None, pos=None,
              attn_fn=None, return_cache: bool = False):
    """Causal (optionally windowed) GQA attention.

    cache=None  -> full-sequence (train / prefill); returns (y, None).
                   Sequences >= 2048 use blockwise online-softmax
                   attention (never materializes S^2).
    cache=dict  -> single-token decode; x is (B, 1, d); cache holds
                   k,v of shape (B, L, Kv, D); ``pos`` is the scalar
                   index the new token is written at (= tokens so far).
    attn_fn     -> optional fused attention (Pallas flash) used for the
                   full-sequence path: (q, k, v, window) -> out.
    """
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    if positions is None:
        if pos is not None and jnp.ndim(pos) == 0:
            positions = jnp.full((b, s), pos, dtype=jnp.int32)
        elif pos is not None:
            positions = pos[:, None].astype(jnp.int32)  # per-row pos
        else:
            positions = jnp.arange(s, dtype=jnp.int32)[None, :]

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    scale = hd ** -0.5

    if cache is None:
        if attn_fn is not None:
            out = attn_fn(q * scale, k, v, window)
        elif s >= _BLOCKWISE_THRESHOLD:
            from .blockwise import blockwise_attention
            out = blockwise_attention(q * scale, k, v, window=window)
        else:
            scores = _gqa_scores(q * scale, k).astype(jnp.float32)
            i = jnp.arange(s)[:, None]
            j = jnp.arange(s)[None, :]
            mask = j <= i
            if window:
                mask &= (i - j) < window
            scores = jnp.where(mask[None, None, None], scores, -1e30)
            probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
            out = _gqa_out(probs, v)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        if return_cache:
            return y, {"k": k, "v": v}
        return y, None

    # ----- decode: write the new k/v at ``pos``, attend over the cache.
    # pos may be a scalar (dry-run / lockstep serving) or a (B,) array
    # (continuous batching: every slot at its own position).
    L = cache["k"].shape[1]
    if jnp.ndim(pos) == 0:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
        j = jnp.arange(L)
        mask = (j <= pos)[None]                   # (1, L)
    else:
        onehot = jax.nn.one_hot(pos, L, dtype=cache["k"].dtype)  # (B, L)
        oh = onehot[:, :, None, None]
        ck = cache["k"] * (1 - oh) + k.astype(cache["k"].dtype) * oh
        cv = cache["v"] * (1 - oh) + v.astype(cache["v"].dtype) * oh
        j = jnp.arange(L)[None]
        mask = j <= pos[:, None]                  # (B, L)
    scores = _gqa_scores(q * scale, ck).astype(jnp.float32)  # (B,Kv,Q,1,L)
    if window:
        wpos = pos if jnp.ndim(pos) else jnp.full((1,), pos)
        mask = mask & ((wpos[:, None] - j) < window)
    scores = jnp.where(mask[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_out(probs, cv)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": ck, "v": cv}


def attn_cache_spec(cfg: ModelConfig, batch: int, length: int, dtype):
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jax.ShapeDtypeStruct((batch, length, kv, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, length, kv, hd), dtype),
    }


# -------------------------------------------------------------- dense FFN

def ffn_spec(cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wi_gate": P((d, f), ("embed", "ffn")),
        "wi_up": P((d, f), ("embed", "ffn")),
        "wo": P((f, d), ("ffn", "embed")),
        "norm": rmsnorm_spec(d),
    }


def ffn(p, x):
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wi_gate"]))
    u = jnp.einsum("bsd,df->bsf", x, p["wi_up"])
    return jnp.einsum("bsf,fd->bsd", g * u, p["wo"])
