"""Schedule IR — the typed contract between planners and the runtime.

Every policy (baselines and the Saturn MILPs alike) emits a
:class:`Schedule`: an ordered list of :class:`ScheduleEntry` records, one
per job, carrying the chosen parallelism technique, GPU count, the
planner's estimated start/runtime, and (for node-aware planners) a node
hint.  The runtime consumes Schedules; concrete per-device assignments
(:class:`Placement`) are made by a placement backend at launch time and
recorded in the Gantt chart.

Legacy policies that still return ``[(job, technique, n_gpus), ...]``
tuples are accepted everywhere via :meth:`Schedule.coerce`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Placement:
    """A concrete device-set assignment (global GPU indices).

    ``device_class`` records which class pool the devices came from
    (class-aware backends set it; single-class backends leave the
    "default" tag).
    """
    devices: Tuple[int, ...]
    device_class: str = "default"

    @property
    def n_gpus(self) -> int:
        return len(self.devices)

    def nodes(self, gpus_per_node: int) -> Tuple[int, ...]:
        """Node indices this placement touches."""
        return tuple(sorted({d // gpus_per_node for d in self.devices}))


@dataclasses.dataclass(frozen=True)
class ScheduleEntry:
    """One job's planned execution: technique + GPU count, plus optional
    planner estimates (start/runtime) and a node-set hint."""
    job: str
    technique: str
    n_gpus: int
    start_s: Optional[float] = None     # planner-estimated start
    runtime_s: Optional[float] = None   # planner-estimated total runtime
    nodes: Optional[Tuple[int, ...]] = None  # node hint (node-aware MILP)
    device_class: Optional[str] = None  # class pin (class-aware planners);
    #                                     None = any class (class-blind)

    @property
    def assignment(self) -> Tuple:
        """The identity the runtime diffs on replans: preempting when it
        changes.  Class-aware entries include the device class, so a
        replan that migrates a job across classes pays a real restart."""
        if self.device_class is None:
            return (self.technique, self.n_gpus)
        return (self.technique, self.n_gpus, self.device_class)

    @property
    def end_s(self) -> Optional[float]:
        if self.start_s is None or self.runtime_s is None:
            return None
        return self.start_s + self.runtime_s

    def as_tuple(self) -> Tuple[str, str, int]:
        return (self.job, self.technique, self.n_gpus)


@dataclasses.dataclass
class Schedule:
    """An ordered plan over jobs.  Order is the list-scheduling priority:
    the runtime starts the first entry that fits whenever capacity frees
    up."""
    entries: List[ScheduleEntry] = dataclasses.field(default_factory=list)
    solver: str = "policy"              # which planner produced it
    makespan_s: Optional[float] = None  # planner-estimated makespan
    # solver telemetry {backend, wall_s, gap, status, n_jobs} attached by
    # planners that measure their solve; the runtime copies it per
    # (re)plan into SimResult.stats["solver"]
    telemetry: Optional[dict] = None

    def __iter__(self):
        return iter(self.entries)

    def __len__(self) -> int:
        return len(self.entries)

    def jobs(self) -> List[str]:
        return [e.job for e in self.entries]

    def assignment_map(self) -> Dict[str, Tuple]:
        """job -> (technique, n_gpus[, device_class]); used for
        preemption diffs."""
        return {e.job: e.assignment for e in self.entries}

    def entry_for(self, job: str) -> Optional[ScheduleEntry]:
        for e in self.entries:
            if e.job == job:
                return e
        return None

    def to_tuples(self) -> List[Tuple[str, str, int]]:
        return [e.as_tuple() for e in self.entries]

    @staticmethod
    def from_tuples(tuples: Iterable[Sequence], solver: str = "policy"
                    ) -> "Schedule":
        entries = [ScheduleEntry(str(j), str(tech), int(g))
                   for (j, tech, g) in tuples]
        return Schedule(entries, solver=solver)

    @staticmethod
    def coerce(obj) -> "Schedule":
        """Accept a Schedule, a list of ScheduleEntry, or legacy
        (job, technique, n_gpus) tuples."""
        if isinstance(obj, Schedule):
            return obj
        if obj is None:
            return Schedule([])
        items = list(obj)
        if not items:
            return Schedule([])
        if isinstance(items[0], ScheduleEntry):
            return Schedule(items)
        return Schedule.from_tuples(items)


class Policy:
    """Planner interface: produce a :class:`Schedule` over the live jobs.

    The runtime starts jobs in schedule order whenever GPUs free up
    (list scheduling).  ``plan`` is re-invoked at introspection
    intervals (if ``dynamic``), at job arrivals (if
    ``replan_on_arrival``), and at completion events (if ``dynamic`` and
    ``replan_on_completion``).  Legacy implementations may return
    ``[(job, technique, n_gpus), ...]``; callers coerce.
    """

    name = "policy"
    dynamic = False                # replan (with preemption) at introspection?
    replan_on_completion = True    # also replan when a job finishes?
    replan_on_arrival = True       # also replan when a new job arrives?

    def plan(self, jobs, remaining: Dict[str, int], profiles, cluster,
             current: Dict[str, Tuple[str, int]]) -> "Schedule":
        raise NotImplementedError

    def plan_incremental(self, jobs, remaining: Dict[str, int], profiles,
                         cluster, current: Dict[str, Tuple], *,
                         prev: Optional["Schedule"] = None,
                         now_s: float = 0.0,
                         running=frozenset()) -> "Schedule":
        """Replan hook with warm-start context.

        The runtime calls this (not ``plan``) on every replan, handing
        over the previous :class:`Schedule` (``prev``), the current sim
        time and the set of currently RUNNING job names.  The default
        ignores the context and replans from scratch — exactly the
        historical behavior, so existing policies are untouched.
        Policies that can re-solve incrementally (fix running jobs,
        warm-start from ``prev``) override this.
        """
        return self.plan(jobs, remaining, profiles, cluster, current)
