"""Optional-dependency shim for ``hypothesis``.

If hypothesis is installed, this module re-exports it unchanged.  If
not (the CI container does not ship it), a minimal fallback runs each
property test over a small deterministic sample drawn from the declared
strategies — so tier-1 collects and runs everywhere instead of erroring
at import time.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random

    _FALLBACK_EXAMPLES = 5   # keep MILP-heavy property tests bounded

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: rng.choice(seq))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

    st = _Strategies()

    def given(**strategies):
        def deco(fn):
            # NOT functools.wraps: pytest must see the wrapper's empty
            # signature, not the strategy parameters of ``fn``
            def wrapper():
                rng = random.Random(0)
                n = min(getattr(wrapper, "_max_examples",
                                _FALLBACK_EXAMPLES), _FALLBACK_EXAMPLES)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(**drawn)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(**kwargs):
        def deco(fn):
            fn._max_examples = kwargs.get("max_examples",
                                          _FALLBACK_EXAMPLES)
            return fn
        return deco
