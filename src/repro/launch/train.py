"""Training launcher: run any assigned architecture on the local device
pool (TPU slice in production; CPU here) with a chosen parallelism plan.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
      --technique fsdp --devices 8 --steps 100 --batch 8 --seq 512 \
      [--reduced] [--ckpt /tmp/ck.npz] [--resume]

On a real TPU slice, run one process per host with the same flags; jax
initializes the global device pool and the per-job mesh spans it.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--technique", default="fsdp")
    ap.add_argument("--devices", type=int, default=0,
                    help="0 = all local devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU smoke scale)")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--use-kernels", action="store_true",
                    help="route hot-spots through the Pallas kernels "
                         "(TPU backend; interpret on CPU)")
    args = ap.parse_args()

    import jax

    from ..configs import get_config
    from ..checkpoint.store import (load_checkpoint, load_metadata,
                                    save_checkpoint)
    from ..core.library import ParallelismLibrary
    from ..data.synthetic import SyntheticLM
    from ..optim.adamw import AdamWConfig
    from ..parallelism.build import BuiltJob

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n_dev = args.devices or len(jax.devices())
    lib = ParallelismLibrary()
    tech = lib.get(args.technique)
    if not tech.search_space(cfg, n_dev):
        raise SystemExit(
            f"{args.technique} invalid for {cfg.name} at {n_dev} devices "
            f"(valid: {[t for t, g in lib.candidates(cfg, [n_dev])]})")
    plan = tech.plan(cfg, n_dev)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(1, args.steps // 20))
    built = BuiltJob(cfg, plan, opt_cfg, devices=jax.devices()[:n_dev])
    params, opt = built.init(jax.random.PRNGKey(0))
    start = 0
    if args.resume and args.ckpt:
        meta = load_metadata(args.ckpt) or {}
        start = int(meta.get("step", 0))
        state = load_checkpoint(args.ckpt, {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"resumed from {args.ckpt} at step {start}")

    print(f"{cfg.name}: {args.technique} x{n_dev} devices, "
          f"batch {args.batch} x seq {args.seq}, steps {start}..{args.steps}")
    data = SyntheticLM(cfg, seed=0).batches(
        args.batch, args.seq, num_batches=args.steps - start)
    t0 = time.perf_counter()
    m = {}
    for i, b in enumerate(data, start=start):
        params, opt, m = built.step(params, opt, built.place_batch(b))
        if (i + 1) % args.log_every == 0:
            jax.block_until_ready(m["loss"])
            dt = (time.perf_counter() - t0) / (i + 1 - start)
            print(f"step {i + 1:6d}  loss {float(m['loss']):.4f}  "
                  f"ppl {float(m['perplexity']):.1f}  "
                  f"grad_norm {float(m['grad_norm']):.2f}  "
                  f"{dt * 1e3:.0f} ms/step", flush=True)
    jax.block_until_ready(params)
    if args.ckpt:
        save_checkpoint(args.ckpt, {"params": params, "opt": opt},
                        {"step": args.steps,
                         "loss": float(m.get("loss", float("nan")))})
        print(f"saved {args.ckpt}")


if __name__ == "__main__":
    main()
