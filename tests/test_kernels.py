"""Pallas kernel validation (interpret mode): sweep shapes/dtypes and
assert_allclose against the pure-jnp oracles in repro.kernels.ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_chunk import mlstm_chunk
from repro.kernels.rglru_scan import rglru_scan


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,h,kv,d,window,bq,bk", [
    (256, 4, 4, 64, 0, 128, 128),     # MHA
    (256, 4, 2, 64, 0, 128, 64),      # GQA
    (512, 8, 1, 32, 0, 128, 128),     # MQA
    (256, 4, 2, 64, 100, 64, 64),     # sliding window
    (384, 2, 2, 128, 128, 128, 128),  # window == block
])
def test_flash_attention(dtype, s, h, kv, d, window, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = (jax.random.normal(ks[0], (2, s, h, d)) * d ** -0.5).astype(dtype)
    k = jax.random.normal(ks[1], (2, s, kv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (2, s, kv, d)).astype(dtype)
    out = flash_attention(q, k, v, window=window, block_q=bq, block_k=bk,
                          interpret=True)
    expected = ref.attention_ref(q.astype(jnp.float32),
                                 k.astype(jnp.float32),
                                 v.astype(jnp.float32), window)
    np.testing.assert_allclose(out.astype(jnp.float32), expected,
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,r,bs,br", [
    (256, 128, 128, 128),
    (512, 256, 256, 128),
    (128, 384, 64, 128),
])
def test_rglru_scan(dtype, s, r, bs, br):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    a = (jax.nn.sigmoid(jax.random.normal(k1, (2, s, r))) * 0.2 + 0.8
         ).astype(dtype)
    b = (jax.random.normal(k2, (2, s, r)) * 0.1).astype(dtype)
    out = rglru_scan(a, b, block_s=bs, block_r=br, interpret=True)
    expected = ref.rglru_scan_ref(a.astype(jnp.float32),
                                  b.astype(jnp.float32))
    np.testing.assert_allclose(out.astype(jnp.float32), expected,
                               atol=_tol(dtype), rtol=1e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,h,d,chunk", [
    (256, 2, 64, 128),
    (512, 4, 128, 128),
    (256, 2, 64, 64),
])
def test_mlstm_chunk(dtype, s, h, d, chunk):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    q, k, v = [jax.random.normal(kk, (2, s, h, d)).astype(dtype)
               for kk in ks[:3]]
    ip = jax.random.normal(ks[3], (2, s, h)).astype(dtype)
    fp = (jax.random.normal(ks[4], (2, s, h)) * 2 + 2).astype(dtype)
    out = mlstm_chunk(q, k, v, ip, fp, chunk=chunk, interpret=True)
    expected = ref.mlstm_ref(*(x.astype(jnp.float32)
                               for x in (q, k, v, ip, fp)))
    np.testing.assert_allclose(out.astype(jnp.float32), expected,
                               atol=5e-2 if dtype == jnp.bfloat16 else 2e-3,
                               rtol=5e-2)


def test_chunked_equals_quadratic_reference():
    """The chunkwise and quadratic mLSTM forms agree (model-layer oracle
    self-consistency, feeding both the kernel and the dry-run path)."""
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q, k, v = [jax.random.normal(kk, (1, 256, 2, 32)) for kk in ks[:3]]
    ip = jax.random.normal(ks[3], (1, 256, 2))
    fp = jax.random.normal(ks[4], (1, 256, 2)) * 2 + 2
    a = ref.mlstm_ref(q, k, v, ip, fp)
    b = ref.mlstm_chunked_ref(q, k, v, ip, fp, chunk=64)
    np.testing.assert_allclose(a, b, atol=2e-3, rtol=2e-3)


def test_blockwise_attention_oracle():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 32)) * 32 ** -0.5
    k = jax.random.normal(ks[1], (2, 256, 2, 32))
    v = jax.random.normal(ks[2], (2, 256, 2, 32))
    for w in (0, 64):
        np.testing.assert_allclose(
            ref.blockwise_attention_ref(q, k, v, w),
            ref.attention_ref(q, k, v, w), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("s,h,d,bs", [
    (256, 2, 128, 64),
    (128, 4, 64, 128),
    (256, 1, 256, 32),
])
def test_slstm_step_kernel(dtype, s, h, d, bs):
    from repro.kernels.slstm_step import slstm_step_scan
    from repro.models.slstm_scan import slstm_scan
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    gates = (jax.random.normal(ks[0], (2, s, h, d, 4)) * 0.5).astype(dtype)
    R = {k: (jax.random.normal(kk, (h, d, d)) * 0.05).astype(dtype)
         for k, kk in zip(["rz", "ri", "rf", "ro"], ks[1:5])}
    init = (jnp.zeros((2, h, d)), jnp.zeros((2, h, d)),
            jnp.full((2, h, d), -1e30), jnp.zeros((2, h, d), dtype))
    _, hs = slstm_scan(R, jnp.swapaxes(gates, 0, 1), init)
    expected = jnp.swapaxes(hs, 0, 1)
    out = slstm_step_scan(gates, R["rz"], R["ri"], R["rf"], R["ro"],
                          block_s=bs, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=1e-5, rtol=1e-4)
