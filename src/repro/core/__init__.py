"""Saturn's core system: Parallelism Library -> Trial Runner -> joint
Solver -> event-driven cluster runtime.

Layering (each layer only imports downward):

    schedule.py      Schedule IR: Placement / ScheduleEntry / Schedule, the
                     Policy interface all planners implement
    events.py        event types + queue (arrival, completion, restart,
                     cluster events, tick)
    chaos.py         fault injection: ChaosTrace + typed cluster events
                     (failures, spot churn, resizes) + seeded generators
    placement.py     pluggable device assignment: FlatPool | NodeAware
                     (elastic pools grow/shrink under cluster events)
    runtime.py       ClusterState + the backend-agnostic discrete-event
                     engine; the ExecutionBackend protocol + SimBackend
    local_backend.py LocalJaxBackend: the same Schedule IR really trains
                     on this machine's JAX devices (checkpointed
                     preemption, measured-throughput feedback)
    process_backend.py ProcessJaxBackend: supervised per-job worker
                     processes — heartbeats, crash/hang detection,
                     retry with backoff, checkpoint-verified recovery
    perfmodel.py     throughput curves over GPU count: anchor trials +
                     interpolation (PerfModel, the profiles contract);
                     ObservedProfiles measured-feedback overlay
    solver.py        the joint MILPs (flat + node-locality), greedy fallback
    lns.py           interval-time Large-Neighborhood-Search scheduler
                     (no slot grid: real-valued starts, event-sweep
                     capacity) — the portfolio's second engine
    portfolio.py     SolverBackend protocol + registry; races MILP vs
                     LNS under a shared wall budget, first-to-gap wins
                     (optional CP-SAT slot behind a guarded import)
    baselines.py     paper baselines + the Saturn policy (emit Schedule IR;
                     SaturnPolicy(solver="portfolio") races the engines)
    executor.py      simulate() compatibility wrapper + legacy comparator,
                     LocalRunner serial building block
    api.py           SaturnSession facade
                     (run(backend="sim"|"local"|"process"))
"""
from .api import SaturnSession                              # noqa: F401
from .chaos import (CapacityChange, ChaosTrace,             # noqa: F401
                    NodeFailure, NodeRecovery, RetryPolicy, SpotGrant,
                    SpotRevoke, WorkerFailure, WorkerFault, merge_events,
                    poisson_node_failures, poisson_worker_faults,
                    spot_capacity_trace)
from .job import (ClusterSpec, DeviceClass, Job,            # noqa: F401
                  ServeJob, hpo_grid)
from .perfmodel import (MergedProfiles, ObservedProfiles,   # noqa: F401
                        PerfModel, ThroughputCurve, select_anchor_counts)
from .placement import ClassPool, FlatPool, NodeAware, make_backend  # noqa: F401
from .portfolio import (SolverBackend, available_backends,  # noqa: F401
                        register_backend, solve_portfolio)
from .process_backend import ProcessJaxBackend              # noqa: F401
from .runtime import (ExecutionBackend, SimBackend,         # noqa: F401
                      SimResult, execute_runtime, simulate_runtime)
from .schedule import Placement, Policy, Schedule, ScheduleEntry  # noqa: F401
