"""Assigned architecture configs (``--arch <id>``) and input-spec
construction for the four workload shapes.

Every config cites its source in ``ModelConfig.source``.  ``input_specs``
returns ShapeDtypeStruct stand-ins (no allocation) for the dry-run, or
concrete arrays for smoke tests via ``concrete_batch``.
"""
from __future__ import annotations

import importlib
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import InputShape, ModelConfig

ARCH_IDS = [
    "stablelm-12b",
    "internlm2-20b",
    "xlstm-125m",
    "recurrentgemma-2b",
    "musicgen-medium",
    "qwen3-moe-235b-a22b",
    "gemma3-4b",
    "internvl2-1b",
    "h2o-danube-3-4b",
    "olmoe-1b-7b",
]


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def shape_supported(cfg: ModelConfig, shape: InputShape) -> bool:
    """long_500k only for sub-quadratic / windowed archs (see DESIGN.md)."""
    if shape.name == "long_500k":
        return cfg.long_context
    return True


def input_specs(cfg: ModelConfig, shape: InputShape, dtype=jnp.bfloat16):
    """Abstract model inputs for ``shape`` (train/prefill: full sequence;
    decode: one token + decode state built separately)."""
    b, s = shape.global_batch, shape.seq_len
    tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)
    if shape.mode == "decode":
        return {"tokens": tok(b, 1)}
    if cfg.frontend == "audio":
        # EnCodec frame embeddings (stub frontend) + codec-token labels
        return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype),
                "labels": tok(b, s)}
    if cfg.frontend == "vision":
        p = cfg.num_patch_tokens
        return {"embeds": jax.ShapeDtypeStruct((b, p, cfg.d_model), dtype),
                "tokens": tok(b, s - p)}
    return {"tokens": tok(b, s)}


def concrete_batch(cfg: ModelConfig, batch: int, seq: int, key=None,
                   dtype=jnp.float32):
    """Concrete synthetic batch for smoke tests / CPU training."""
    rng = np.random.RandomState(0 if key is None else key)
    out = {}
    if cfg.frontend == "audio":
        out["embeds"] = jnp.asarray(
            rng.randn(batch, seq, cfg.d_model), dtype)
        out["labels"] = jnp.asarray(
            rng.randint(0, cfg.vocab_size, (batch, seq)), jnp.int32)
    elif cfg.frontend == "vision":
        p = min(cfg.num_patch_tokens, seq - 1)
        out["embeds"] = jnp.asarray(rng.randn(batch, p, cfg.d_model), dtype)
        out["tokens"] = jnp.asarray(
            rng.randint(0, cfg.vocab_size, (batch, seq - p)), jnp.int32)
    else:
        out["tokens"] = jnp.asarray(
            rng.randint(0, cfg.vocab_size, (batch, seq)), jnp.int32)
    return out
