"""The paper's four baselines (§3) plus the SATURN policy itself.

- Current Practice: all GPUs of a node to one job, jobs in sequence,
  task parallelism across nodes.
- Random: random parallelism, allocation and order (seeded).
- Optimus (Peng et al., EuroSys'18): greedy marginal-gain GPU allocation.
- Optimus-Dynamic: Optimus + the introspection mechanism.
- Saturn: the joint MILP (+ introspection); under a node-aware cluster
  (``ClusterSpec(placement="node")``) it runs the node-locality MILP
  and emits node placement hints the runtime honors.

All policies emit Schedule IR (:class:`repro.core.schedule.Schedule`).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from .job import Job
from .perfmodel import iter_job_profiles
from .schedule import Policy, Schedule, ScheduleEntry
from .solver import solve_joint, solve_joint_nodes


def _feasible(job, profiles):
    """Feasible (technique, g, step_time) triples for one job — from
    the legacy dict or straight off a PerfModel's curves."""
    return [(tech, g, p.step_time_s)
            for tech, g, p in iter_job_profiles(profiles, job.name)
            if p.feasible]


def _best_at_count(job, profiles, g):
    cands = [(tech, p.step_time_s)
             for tech, gg, p in iter_job_profiles(profiles, job.name)
             if gg == g and p.feasible]
    if not cands:
        return None
    return min(cands, key=lambda x: x[1])


class CurrentPractice(Policy):
    """Typical current practice (paper §3): every job gets a full node
    and runs under the standard go-to setup — FSDP — one job per node at
    a time, task-parallel across nodes.  (No per-job tuning: that is
    exactly what Saturn automates.)"""

    name = "current-practice"
    dynamic = False
    default_technique = "fsdp"

    def plan(self, jobs, remaining, profiles, cluster, current):
        entries = []
        for j in jobs:
            g = cluster.gpus_per_node
            if (j.name, self.default_technique, g) in profiles and \
                    profiles[(j.name, self.default_technique, g)].feasible:
                tech = self.default_technique
            else:
                best = _best_at_count(j, profiles, g)
                if best is None:  # fall back to any feasible
                    feas = _feasible(j, profiles)
                    if not feas:
                        raise ValueError(f"{j.name}: infeasible everywhere")
                    tech, g, _ = min(feas, key=lambda x: x[2])
                else:
                    tech = best[0]
            entries.append(ScheduleEntry(j.name, tech, g))
        return Schedule(entries, solver=self.name)


class CurrentPracticeTuned(CurrentPractice):
    """Ablation: current practice but with the per-job BEST technique at
    full-node allocation (isolates Saturn's packing/allocation gains
    from its parallelism-selection gains)."""

    name = "current-practice-tuned"

    def plan(self, jobs, remaining, profiles, cluster, current):
        entries = []
        for j in jobs:
            g = cluster.gpus_per_node
            best = _best_at_count(j, profiles, g)
            if best is None:
                feas = _feasible(j, profiles)
                if not feas:
                    raise ValueError(f"{j.name}: infeasible everywhere")
                tech, g, _ = min(feas, key=lambda x: x[2])
            else:
                tech = best[0]
            entries.append(ScheduleEntry(j.name, tech, g))
        return Schedule(entries, solver=self.name)


class RandomPolicy(Policy):
    name = "random"
    dynamic = False

    def __init__(self, seed: int = 0):
        self.seed = seed

    def plan(self, jobs, remaining, profiles, cluster, current):
        rng = np.random.RandomState(self.seed)
        order = []
        for j in jobs:
            feas = _feasible(j, profiles)
            tech, g, _ = feas[rng.randint(len(feas))]
            order.append((j.name, tech, g))
        rng.shuffle(order)
        return Schedule.from_tuples(order, solver=self.name)


class Optimus(Policy):
    """Greedy marginal-gain allocation: every job starts at its smallest
    feasible GPU count; remaining GPUs go one-at-a-time to the job with
    the largest estimated marginal runtime reduction."""

    name = "optimus"
    dynamic = False

    def plan(self, jobs, remaining, profiles, cluster, current):
        live = [j for j in jobs if remaining.get(j.name, 0) > 0]
        runtime_at: Dict[str, Dict[int, Tuple[str, float]]] = {}
        for j in live:
            per_g: Dict[int, Tuple[str, float]] = {}
            for tech, g, p in iter_job_profiles(profiles, j.name):
                if not p.feasible:
                    continue
                t = p.step_time_s * remaining[j.name]
                if g not in per_g or t < per_g[g][1]:
                    per_g[g] = (tech, t)
            runtime_at[j.name] = per_g
        alloc: Dict[str, int] = {}
        budget = cluster.total_gpus
        # min feasible first (paper: one GPU at a time, from zero)
        for j in sorted(live, key=lambda j: -remaining.get(j.name, 0)):
            gmin = min(runtime_at[j.name]) if runtime_at[j.name] else None
            if gmin is not None and gmin <= budget:
                alloc[j.name] = gmin
                budget -= gmin
        # marginal gains
        improved = True
        while budget > 0 and improved:
            improved = False
            best_gain, best_job, best_g = 0.0, None, None
            for jname, g in alloc.items():
                per_g = runtime_at[jname]
                uppers = [gg for gg in per_g if gg > g and gg - g <= budget]
                if not uppers:
                    continue
                g2 = min(uppers)
                gain = (per_g[g][1] - per_g[g2][1]) / max(g2 - g, 1)
                if gain > best_gain:
                    best_gain, best_job, best_g = gain, jname, g2
            if best_job is not None:
                budget -= best_g - alloc[best_job]
                alloc[best_job] = best_g
                improved = True
        order = []
        for j in live:
            if j.name in alloc:
                g = alloc[j.name]
                order.append((j.name, runtime_at[j.name][g][0], g))
        # unallocated jobs queue behind with their min feasible config
        for j in live:
            if j.name not in alloc and runtime_at[j.name]:
                gmin = min(runtime_at[j.name])
                order.append((j.name, runtime_at[j.name][gmin][0], gmin))
        return Schedule.from_tuples(order, solver=self.name)


class OptimusDynamic(Optimus):
    name = "optimus-dynamic"
    dynamic = True


class SaturnPolicy(Policy):
    """The joint MILP; with ``dynamic`` the runtime re-invokes it at
    introspection intervals / arrivals on observed remaining work.

    On a node-aware cluster (``cluster.placement == "node"``) the plan
    comes from ``solve_joint_nodes`` and carries node assignments, so
    the runtime's placement honors node locality end to end.
    """

    name = "saturn"
    dynamic = True
    replan_on_completion = False  # paper: re-solve on fixed intervals

    def __init__(self, n_slots: int = 24, time_limit_s: float = 10.0):
        self.n_slots = n_slots
        self.time_limit_s = time_limit_s

    def plan(self, jobs, remaining, profiles, cluster, current):
        live = []
        for j in jobs:
            rem = remaining.get(j.name, j.total_steps)
            if rem > 0:
                live.append(Job(j.name, j.cfg, j.batch_size, j.seq_len,
                                rem, j.lr, j.seed))
        if not live:
            return Schedule([], solver=self.name)
        if getattr(cluster, "placement", "flat") == "node":
            sol = solve_joint_nodes(
                live, profiles, cluster.nodes, cluster.gpus_per_node,
                n_slots=min(self.n_slots, 16),
                time_limit_s=self.time_limit_s, mip_gap=0.05)
        else:
            sol = solve_joint(live, profiles, cluster.total_gpus,
                              n_slots=self.n_slots,
                              time_limit_s=self.time_limit_s, mip_gap=0.05)
        return sol.to_schedule()


class SaturnStatic(SaturnPolicy):
    """Ablation: the MILP without introspection."""
    name = "saturn-static"
    dynamic = False
