"""Pallas TPU kernel for chunkwise-parallel mLSTM (xLSTM matrix memory).

Grid: (batch, heads, num_chunks), chunks minor-most so each (b, h)
program walks its sequence chunks in order carrying the recurrent state
(C: d x d matrix memory, n: d normalizer, m: scalar stabilizer) in VMEM
scratch.  Within a chunk the math is the quadratic intra-chunk form —
two (L, d) x (d, L/d) matmuls on the MXU — plus rank-L state update,
exactly mirroring ``repro.models.blockwise.mlstm_chunked`` (the oracle).

TPU adaptation: the stabilizer m is a lane-replicated (1, 128) tile; the
decay matrix is built from a cumulative-sum of log-sigmoid forget gates
with a tril mask from 2-D iota (no warp-level primitives involved).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_LANES = 128


def _mlstm_kernel(q_ref, k_ref, v_ref, i_ref, f_ref, o_ref,
                  c_ref, n_ref, m_ref, *, chunk: int, head_dim: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)

    L, d = chunk, head_dim
    scale = d ** -0.5
    q = q_ref[0, 0].astype(jnp.float32) * scale        # (L, d)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    it = i_ref[0, 0].astype(jnp.float32)               # (L,)
    lf = jax.nn.log_sigmoid(f_ref[0, 0].astype(jnp.float32))
    m_prev = m_ref[0, 0]
    C = c_ref[...]
    n = n_ref[...][:, 0]                               # (d,)

    cum = jnp.cumsum(lf)                               # (L,)
    g = cum[-1]
    row = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    logd = cum[:, None] - cum[None, :] + it[None, :]
    logd = jnp.where(row >= col, logd, -jnp.inf)
    m_intra = jnp.max(logd, axis=1)                    # (L,)
    m_inter = cum + m_prev
    m_i = jnp.maximum(jnp.maximum(m_intra, m_inter), -1e30)
    dmat = jnp.exp(logd - m_i[:, None])
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
    cmat = scores * dmat                               # (L, L)
    inter_w = jnp.exp(m_inter - m_i)                   # (L,)
    h_inter = jax.lax.dot_general(q, C, (((1,), (0,)), ((), ()))) \
        * inter_w[:, None]                             # (L, d)
    n_inter = (q @ n) * inter_w                        # (L,)
    h_intra = jax.lax.dot_general(cmat, v, (((1,), (0,)), ((), ())))
    n_total = jnp.sum(cmat, axis=1) + n_inter
    denom = jnp.maximum(jnp.abs(n_total), jnp.exp(-m_i))
    o_ref[0, 0, :, :] = ((h_intra + h_inter)
                         / denom[:, None]).astype(o_ref.dtype)

    # ---- state update
    m_next = jnp.maximum(g + m_prev, jnp.max(it + g - cum))
    decay = jnp.exp(g + m_prev - m_next)
    w_in = jnp.exp(it + g - cum - m_next)              # (L,)
    kw = k * w_in[:, None]                             # (L, d)
    c_ref[...] = decay * C + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())))               # (d, d)
    n_new = decay * n + jnp.sum(kw, axis=0)            # (d,)
    n_ref[...] = jnp.broadcast_to(n_new[:, None], n_ref.shape)
    m_ref[...] = jnp.full_like(m_ref, m_next)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk(q, k, v, i_pre, f_pre, *, chunk: int = 128,
                interpret: bool = False):
    """q,k,v: (B,S,H,D); i_pre,f_pre: (B,S,H) -> (B,S,H,D).

    Matches ``repro.models.blockwise.mlstm_chunked`` /
    ``repro.models.recurrent.mlstm_parallel_ref``."""
    b, s, h, d = q.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    qt = q.transpose(0, 2, 1, 3)                       # (B,H,S,D)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    ip = i_pre.transpose(0, 2, 1)                      # (B,H,S)
    fp = f_pre.transpose(0, 2, 1)
    grid = (b, h, nc)
    seq_spec = pl.BlockSpec((1, 1, chunk, d),
                            lambda b_, h_, ic: (b_, h_, ic, 0))
    gate_spec = pl.BlockSpec((1, 1, chunk),
                             lambda b_, h_, ic: (b_, h_, ic))
    out = pl.pallas_call(
        functools.partial(_mlstm_kernel, chunk=chunk, head_dim=d),
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, gate_spec, gate_spec],
        out_specs=seq_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((d, d), jnp.float32),           # C
            pltpu.VMEM((d, _LANES), jnp.float32),      # n (lane-replicated)
            pltpu.VMEM((1, _LANES), jnp.float32),      # m (lane-replicated)
        ],
        interpret=interpret,
    )(qt, kt, vt, ip, fp)
    return out.transpose(0, 2, 1, 3)
