"""Parallelism technique interface — the paper's two-function API.

Saturn's Parallelism Library registers techniques implementing (Fig. 1B):

  ``search_space(cfg, n_devices) -> bool``  — is this technique valid for
      this model at this device count?
  ``plan(cfg, n_devices) -> Plan``          — how to execute it: mesh
      axes, logical->mesh rules, param shardings, step-fn wrapping.

``Plan`` is consumed by ``repro.parallelism.build.build_train_fn`` (real
execution + profiling) and by the launch/dryrun path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Plan:
    technique: str
    n_devices: int
    # mesh axis names and sizes, e.g. (("data", 8),) or (("stage", 4),)
    mesh_axes: Tuple[Tuple[str, int], ...]
    # logical activation axis -> mesh axis (for context.axis_rules)
    rules: Dict[str, Optional[str]]
    # per-param sharding policy: "replicate" | "fsdp" | "rules" | "stage"
    param_policy: str = "replicate"
    remat: bool = False
    microbatches: int = 1
    stages: int = 1

    @property
    def mesh_shape(self):
        return tuple(n for _, n in self.mesh_axes)

    @property
    def mesh_axis_names(self):
        return tuple(a for a, _ in self.mesh_axes)


class Technique:
    """Base class; subclasses are registered in the Parallelism Library."""

    name: str = "base"

    def search_space(self, cfg: ModelConfig, n_devices: int) -> bool:
        raise NotImplementedError

    def plan(self, cfg: ModelConfig, n_devices: int) -> Plan:
        raise NotImplementedError

    # -- analytic hints used by the Trial Runner's cost model ------------
    def memory_fraction(self, cfg: ModelConfig, n_devices: int) -> float:
        """Approx fraction of total model+opt state held per device."""
        return 1.0

    def step_overhead(self) -> float:
        """Multiplicative runtime overhead vs ideal scaling (collectives,
        bubbles, recompute).  Refined empirically by the Trial Runner."""
        return 1.0


def largest_divisible_axis(shape, n: int) -> Optional[int]:
    """Index of the largest dim divisible by n (for FSDP-style sharding)."""
    best, best_size = None, 0
    for i, s in enumerate(shape):
        if s % n == 0 and s > best_size:
            best, best_size = i, s
    return best
