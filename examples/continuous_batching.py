"""Continuous-batching serving demo: requests with random lengths and
staggered arrivals stream through a fixed slot pool; the engine
interleaves chunk-1 prefill with decode at token granularity.

    PYTHONPATH=src python examples/continuous_batching.py --arch gemma3-4b
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models.transformer import init_model
from repro.serving.engine import ContinuousBatchingEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(cfg, params, slots=args.slots,
                                   max_len=args.max_len)
    rng = np.random.RandomState(0)
    total_toks = 0
    for i in range(args.requests):
        plen = int(rng.randint(4, 24))
        gen = int(rng.randint(4, 16))
        total_toks += plen + gen
        eng.submit(Request(rid=i, prompt=rng.randint(
            0, cfg.vocab_size, plen).tolist(), max_new_tokens=gen))
    done = eng.run()
    th = eng.throughput()
    print(f"{cfg.name}: {th['requests']} requests, {th['tokens']} generated "
          f"tokens in {th['steps']} engine steps "
          f"(sequential would take ~{total_toks} steps)")
    print(f"mean latency {th['mean_latency_s']:.2f}s  "
          f"mean TTFT {th['mean_ttft_s']:.2f}s")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.output[:8]}...")


if __name__ == "__main__":
    main()
