"""Performance-model layer: throughput curves over GPU count.

The paper's Trial Runner keeps profiling overhead under ~5% of workload
runtime by profiling only a *subset* of ⟨model, parallelism, GPU-count⟩
combinations and interpolating the rest (Saturn §2; the VLDB version
makes the same point about amortized, cached trial runs).  This module
is that layer:

- :func:`select_anchor_counts` picks the geometric subset of GPU counts
  that gets REAL trials — always including the technique-feasibility
  boundary counts (smallest and largest valid);
- :class:`ThroughputCurve` fits one ⟨job, technique⟩ scaling curve to
  those anchors — piecewise power-law, i.e. linear in (log g, log t)
  space, which preserves monotonicity between anchors and matches the
  ``t ∝ g^(-efficiency)`` shape of data/model-parallel scaling — and
  evaluates ``step_time(g)``, ``mem(g)`` and ``feasible(g)`` at ANY
  count.  Extrapolation beyond the anchored range continues the edge
  segment's slope, clamped to [-1, +1] in log-log space: never better
  than perfect linear scaling, never a worse-than-linear slowdown;
- :class:`PerfModel` is the consumer facade: a read-only Mapping with
  the legacy ``profiles[(job, tech, g)] -> Profile`` contract (missing
  counts are synthesized from the curve, ``source="interpolated"``),
  plus curve-native accessors (``curve()``, ``curves_for()``,
  ``step_time()``) for the Solver, the baselines and the runtime's
  introspection replans.

Feasibility at a count ``g`` has two independent parts, and the curve
keeps them separate: *validity* (the technique's ``search_space`` —
exact, computed for every count without a trial) and *memory fit*
(``mem(g) <= hbm_capacity`` — interpolated between anchors).
"""
from __future__ import annotations

import dataclasses
import math
from collections.abc import Mapping
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from .job import DEFAULT_CLASS
from .profiler import Profile

# Extrapolation slope clamp in log-log space: -1 is perfect linear
# scaling (t halves when g doubles); +1 bounds observed slowdowns.
_SLOPE_LO = -1.0
_SLOPE_HI = 1.0


def select_anchor_counts(valid_counts: Iterable[int],
                         ratio: float = 2.0) -> List[int]:
    """The geometric subset of ``valid_counts`` that gets real trials.

    Walks the sorted valid counts keeping every count that is at least
    ``ratio`` times the previously kept one, and always keeps the
    smallest and largest valid counts (the technique-feasibility
    boundary points the curve must not extrapolate across).
    """
    vs = sorted(set(int(g) for g in valid_counts))
    if not vs:
        return []
    anchors = [vs[0]]
    target = vs[0] * ratio
    for g in vs[1:]:
        if g >= target - 1e-9:
            anchors.append(g)
            target = g * ratio
    if anchors[-1] != vs[-1]:
        anchors.append(vs[-1])
    return anchors


def _loglog_eval(lxs: np.ndarray, lys: np.ndarray, g: float) -> float:
    """Piecewise-linear evaluation in log-log space with slope-clamped
    extrapolation past either end."""
    x = math.log(g)
    if len(lxs) == 1:
        return math.exp(float(lys[0]))
    if x <= lxs[0]:
        s = (lys[1] - lys[0]) / (lxs[1] - lxs[0])
        s = min(max(s, _SLOPE_LO), _SLOPE_HI)
        return math.exp(float(lys[0] + s * (x - lxs[0])))
    if x >= lxs[-1]:
        s = (lys[-1] - lys[-2]) / (lxs[-1] - lxs[-2])
        s = min(max(s, _SLOPE_LO), _SLOPE_HI)
        return math.exp(float(lys[-1] + s * (x - lxs[-1])))
    return math.exp(float(np.interp(x, lxs, lys)))


class ThroughputCurve:
    """One ⟨job, technique, device class⟩ scaling curve over GPU count,
    fit to real trial anchors.  On heterogeneous clusters every device
    class gets its own curve (own anchors, own HBM capacity)."""

    def __init__(self, job: str, technique: str, hbm_capacity: float,
                 anchors: Dict[int, Profile],
                 valid: Iterable[int], domain: Iterable[int],
                 device_class: str = DEFAULT_CLASS):
        self.job = job
        self.technique = technique
        self.hbm_capacity = hbm_capacity
        self.device_class = device_class
        self.anchors = {int(g): p for g, p in sorted(anchors.items())}
        self.valid = frozenset(int(g) for g in valid)
        self.domain = frozenset(int(g) for g in domain)
        # fit arrays: anchors with finite measurements (memory-infeasible
        # anchors still carry real numbers and inform the fit; search-
        # space-invalid ones are inf and excluded)
        fit = [(g, p) for g, p in self.anchors.items()
               if math.isfinite(p.step_time_s) and p.step_time_s > 0]
        self._fit_counts = [g for g, _ in fit]
        if fit:
            self._lg = np.log([g for g, _ in fit])
            self._lt = np.log([p.step_time_s for _, p in fit])
            self._lm = np.log([max(p.mem_per_device, 1.0) for _, p in fit])
        else:
            self._lg = self._lt = self._lm = np.zeros(0)

    # ------------------------------------------------------------- eval
    def valid_at(self, g: int) -> bool:
        """Search-space validity (exact; no trial involved)."""
        if g in self.valid:
            return True
        if g in self.domain:
            return False
        # counts outside the modeled domain: trust interpolation only
        # inside the anchored range
        return bool(self._fit_counts) and \
            self._fit_counts[0] <= g <= self._fit_counts[-1]

    def step_time(self, g: int) -> float:
        g = int(g)
        if g in self.anchors:
            return self.anchors[g].step_time_s
        if not self.valid_at(g) or not self._fit_counts:
            return float("inf")
        return _loglog_eval(self._lg, self._lt, g)

    def mem(self, g: int) -> float:
        g = int(g)
        if g in self.anchors:
            return self.anchors[g].mem_per_device
        if not self.valid_at(g) or not self._fit_counts:
            return float("inf")
        return _loglog_eval(self._lg, self._lm, g)

    def feasible(self, g: int) -> bool:
        g = int(g)
        if g in self.anchors:
            return self.anchors[g].feasible
        if not self.valid_at(g):
            return False
        m = self.mem(g)
        return math.isfinite(m) and m <= self.hbm_capacity and \
            math.isfinite(self.step_time(g))

    def profile(self, g: int) -> Profile:
        """A Profile record at any count: the anchor itself where one
        exists, an interpolated point everywhere else.  Evaluates each
        curve exactly once per field (policies rebuild grids every
        replan, so this is the hot path)."""
        g = int(g)
        if g in self.anchors:
            return self.anchors[g]
        terms = {"n_anchors": float(len(self._fit_counts))}
        if not self.valid_at(g) or not self._fit_counts:
            return Profile(self.job, self.technique, g, float("inf"),
                           float("inf"), False, "interpolated", terms,
                           device_class=self.device_class)
        t = _loglog_eval(self._lg, self._lt, g)
        m = _loglog_eval(self._lg, self._lm, g)
        feas = math.isfinite(t) and math.isfinite(m) and \
            m <= self.hbm_capacity
        return Profile(self.job, self.technique, g, t, m, feas,
                       "interpolated", terms,
                       device_class=self.device_class)


class PerfModel(Mapping):
    """Curves for a whole workload, with the legacy Mapping contract.

    Single-class models: iteration / ``len`` / ``items()`` enumerate
    ``(job, technique, g)`` over the model's count grid restricted to
    search-space-valid counts — exactly the keys an exhaustive
    ``profile_all`` dict would hold — so every dict-shaped consumer (the
    MILPs, baselines, the runtime's noise model) works unchanged.
    ``__getitem__`` additionally accepts off-grid counts: curves are
    continuous, so introspection replans may evaluate counts nobody
    profiled.

    Heterogeneous models (curves keyed ``(job, tech, device_class)``)
    enumerate 4-tuple keys ``(job, tech, device_class, g)`` over each
    class's own count grid; 3-tuple lookups resolve against the
    "default" class only, so class-blind code cannot silently read the
    wrong device generation.
    """

    def __init__(self, curves: Dict[Tuple, ThroughputCurve],
                 counts: Iterable[int],
                 counts_by_class: Optional[Dict[str, Iterable[int]]] = None):
        self._curves: Dict[Tuple[str, str, str], ThroughputCurve] = {}
        for k, c in curves.items():
            if len(k) == 2:
                k = (k[0], k[1], getattr(c, "device_class", DEFAULT_CLASS))
            self._curves[k] = c
        self.classes = sorted({k[2] for k in self._curves}) or \
            [DEFAULT_CLASS]
        self.hetero = self.classes != [DEFAULT_CLASS]
        self.counts = sorted(set(int(c) for c in counts))
        self._counts_by_class = {
            dc: sorted(set(int(c) for c in cs))
            for dc, cs in (counts_by_class or {}).items()}
        for dc in self.classes:
            self._counts_by_class.setdefault(dc, self.counts)
        if self.hetero:
            self._keys = [(j, t, dc, g)
                          for (j, t, dc), c in self._curves.items()
                          for g in self._counts_by_class[dc]
                          if g in c.valid]
        else:
            self._keys = [(j, t, g)
                          for (j, t, dc), c in self._curves.items()
                          for g in self._counts_by_class[dc]
                          if g in c.valid]

    def counts_for(self, device_class: str = DEFAULT_CLASS) -> List[int]:
        return self._counts_by_class.get(device_class, self.counts)

    # --------------------------------------------------- Mapping contract
    def __getitem__(self, key: Tuple) -> Profile:
        if len(key) == 4:
            job, tech, dc, g = key
        elif len(key) == 3:
            (job, tech, g), dc = key, DEFAULT_CLASS
        else:
            raise KeyError(key)
        c = self._curves.get((job, tech, dc))
        if c is None:
            raise KeyError(key)
        return c.profile(int(g))

    def __iter__(self) -> Iterator[Tuple]:
        return iter(self._keys)

    def __len__(self) -> int:
        return len(self._keys)

    # ----------------------------------------------------- curve access
    def curve(self, job: str, technique: str,
              device_class: str = DEFAULT_CLASS) -> ThroughputCurve:
        return self._curves[(job, technique, device_class)]

    def curves_for(self, job: str,
                   device_class: Optional[str] = None
                   ) -> List[ThroughputCurve]:
        """All curves for one job; ``device_class`` filters to one
        class (single-class models hold everything under "default")."""
        return [c for (j, _, dc), c in self._curves.items()
                if j == job and (device_class is None
                                 or dc == device_class)]

    def step_time(self, job: str, technique: str, g: int,
                  device_class: str = DEFAULT_CLASS) -> float:
        return self._curves[(job, technique, device_class)].step_time(g)

    def mem(self, job: str, technique: str, g: int,
            device_class: str = DEFAULT_CLASS) -> float:
        return self._curves[(job, technique, device_class)].mem(g)

    def feasible(self, job: str, technique: str, g: int,
                 device_class: str = DEFAULT_CLASS) -> bool:
        c = self._curves.get((job, technique, device_class))
        return c.feasible(g) if c is not None else False

    # ------------------------------------------------------------ stats
    def anchor_keys(self) -> set:
        """The combos backed by real trials: (job, technique, g) on
        single-class models, (job, technique, device_class, g) on
        heterogeneous ones — matching the Mapping key shape."""
        if self.hetero:
            return {(c.job, c.technique, dc, g)
                    for (_, _, dc), c in self._curves.items()
                    for g in c.anchors}
        return {(c.job, c.technique, g)
                for c in self._curves.values() for g in c.anchors}

    def n_anchors(self) -> int:
        return sum(len(c.anchors) for c in self._curves.values())

    def real_anchor_keys(self) -> set:
        """Like :meth:`anchor_keys`, but only combos whose anchor came
        from a real trial — roofline predictions and interpolated points
        sit in ``anchors`` too (the curve serves them directly), and a
        held-out error measurement must not score a prediction against
        itself."""
        predicted = ("roofline", "interpolated")
        if self.hetero:
            return {(c.job, c.technique, dc, g)
                    for (_, _, dc), c in self._curves.items()
                    for g, p in c.anchors.items()
                    if p.source not in predicted}
        return {(c.job, c.technique, g)
                for c in self._curves.values()
                for g, p in c.anchors.items()
                if p.source not in predicted}

    def to_dict(self) -> Dict[Tuple, Profile]:
        """Materialize the full grid as a plain dict (legacy export)."""
        return {k: self[k] for k in self._keys}


class ObservedProfiles(Mapping):
    """A read-only overlay of MEASURED step times on top of a base
    profile representation (a plain dict or a :class:`PerfModel`).

    The real-execution backend records observed per-step wall times as
    launches run; introspection replans plan over this view, so the
    combos actually executing carry ground truth while everything else
    keeps its estimate — the paper's introspection loop closed over
    measured throughput.  This overlay is estimator-agnostic: roofline
    predictions (``source="roofline"``) are replaced by observations
    exactly like empirical or analytic profiles.  The base is never mutated, and the overlay
    enumerates exactly the base's keys (same Mapping contract every
    dict-shaped consumer already holds).  ``observed`` maps the base's
    own profile keys (see :func:`profile_key`) to measured seconds.
    """

    def __init__(self, base, observed: Dict[Tuple, float]):
        self._base = base
        self._observed = dict(observed)

    def _lookup(self, key: Tuple) -> Optional[float]:
        # bases accept both 3-tuple (job, tech, g) and default-class
        # 4-tuple keys for the same combo; normalize before matching
        o = self._observed.get(key)
        if o is not None:
            return o
        if len(key) == 4 and key[2] == DEFAULT_CLASS:
            return self._observed.get((key[0], key[1], key[3]))
        if len(key) == 3:
            return self._observed.get(
                (key[0], key[1], DEFAULT_CLASS, key[2]))
        return None

    def __getitem__(self, key: Tuple) -> Profile:
        p = self._base[key]
        o = self._lookup(key)
        if o is None:
            return p
        return dataclasses.replace(p, step_time_s=float(o),
                                   source="observed")

    def __iter__(self) -> Iterator[Tuple]:
        return iter(self._base)

    def __len__(self) -> int:
        return len(self._base)


class MergedProfiles(Mapping):
    """Several profile Mappings behind one read-only view, first hit
    wins.  The serving path needs this: training step times live in a
    :class:`PerfModel` (or dict) while serve-replica step times are a
    separate dict keyed ``(name, "serve", class, gpus)`` — merging keeps
    both answerable through the same adapters without mutating either.
    Note a wrapped :class:`PerfModel` is consulted through its
    enumerated grid keys here (the dict path), not its curve API."""

    def __init__(self, *parts):
        self._parts = parts

    def __getitem__(self, key: Tuple) -> Profile:
        for p in self._parts:
            try:
                return p[key]
            except KeyError:
                continue
        raise KeyError(key)

    def __iter__(self) -> Iterator[Tuple]:
        seen = set()
        for p in self._parts:
            for k in p:
                if k not in seen:
                    seen.add(k)
                    yield k

    def __len__(self) -> int:
        return sum(1 for _ in self)


# ------------------------------------------------- dict/model adapters
#
# Legacy dicts come in two shapes: 3-tuple keys (job, tech, g) for
# single-class clusters and 4-tuple keys (job, tech, device_class, g)
# for heterogeneous ones.  The adapters below accept both, plus
# PerfModels, so planners/runtime never branch on the representation.

def _dict_key(profiles, job: str, tech: str, g: int,
              device_class: Optional[str]) -> Tuple:
    """The key under which a plain dict holds this combo."""
    dc = device_class or DEFAULT_CLASS
    k4 = (job, tech, dc, g)
    if k4 in profiles:
        return k4
    return (job, tech, g)


def profile_key(profiles, job: str, tech: str, g: int,
                device_class: Optional[str] = None) -> Tuple:
    """The exact key ``profiles`` uses for this combo — the key the
    runtime's noise model is seeded under."""
    if isinstance(profiles, PerfModel):
        dc = device_class or DEFAULT_CLASS
        return (job, tech, dc, g) if profiles.hetero else (job, tech, g)
    return _dict_key(profiles, job, tech, g, device_class)


def iter_job_profiles(profiles, job_name: str,
                      device_class: Optional[str] = None
                      ) -> Iterator[Tuple[str, int, Profile]]:
    """Yield (technique, g, Profile) for one job on ONE device class
    (default: the "default" class) from either a profile dict or a
    :class:`PerfModel`."""
    dc = device_class or DEFAULT_CLASS
    if isinstance(profiles, PerfModel):
        for curve in profiles.curves_for(job_name, device_class=dc):
            for g in profiles.counts_for(dc):
                if g in curve.valid:
                    yield curve.technique, g, curve.profile(g)
        return
    for key, p in profiles.items():
        if len(key) == 4:
            jn, tech, kdc, g = key
            if jn == job_name and kdc == dc:
                yield tech, g, p
        else:
            jn, tech, g = key
            if jn == job_name and dc == DEFAULT_CLASS:
                yield tech, g, p


def iter_job_class_profiles(profiles, job_name: str
                            ) -> Iterator[Tuple[str, str, int, Profile]]:
    """Yield (technique, device_class, g, Profile) for one job across
    EVERY device class the profiles cover."""
    if isinstance(profiles, PerfModel):
        for dc in profiles.classes:
            for tech, g, p in iter_job_profiles(profiles, job_name, dc):
                yield tech, dc, g, p
        return
    for key, p in profiles.items():
        if len(key) == 4:
            jn, tech, dc, g = key
        else:
            (jn, tech, g), dc = key, DEFAULT_CLASS
        if jn == job_name:
            yield tech, dc, g, p


def step_time_of(profiles, job: str, tech: str, g: int,
                 device_class: Optional[str] = None) -> float:
    """Estimated step time from either representation; curve-backed
    models answer at any count, dicts only at profiled ones."""
    if isinstance(profiles, PerfModel):
        return profiles.step_time(job, tech, g,
                                  device_class or DEFAULT_CLASS)
    return profiles[_dict_key(profiles, job, tech, g,
                              device_class)].step_time_s


def lookup_profile(profiles, job: str, tech: str, g: int,
                   device_class: Optional[str] = None
                   ) -> Optional[Profile]:
    """Profile record from either representation (None if unknown)."""
    try:
        return profiles[profile_key(profiles, job, tech, g, device_class)]
    except KeyError:
        return None
