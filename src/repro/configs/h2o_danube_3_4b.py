"""H2O-Danube3-4B: llama/mistral-mix dense decoder with sliding-window
attention [arXiv:2401.16818]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", arch_type="dense",
    num_layers=24, d_model=3840, num_heads=32, num_kv_heads=8,
    d_ff=10240, vocab_size=32000, head_dim=120,
    block_pattern=("swa",), window_size=4096,
    tie_embeddings=False, long_context=True,
    source="llama+mistral mix, SWA [arXiv:2401.16818]",
)
