"""ClusterState + the backend-agnostic event-driven execution engine.

The engine (:func:`execute_runtime`) owns everything a *scheduler
runtime* owns — the event queue, job phases, placement, replans with
preemption diffs, Gantt + per-device-class GPU-second accounting — and
delegates everything an *execution substrate* owns to an
:class:`ExecutionBackend`: launching a (job, technique, device-set)
choice, polling its progress, preempting it with a checkpoint, and the
meaning of the clock.  Two backends implement the protocol:

- :class:`SimBackend` — virtual time.  Step times are profile estimates
  x seeded noise, completions are computed exactly at launch, and the
  clock simply follows event timestamps.  This is bit-exact with the
  historical ``simulate()`` loop: ``simulate_runtime`` (the compat
  entry point) constructs one by default, and the legacy equivalence
  tests pin the contract.
- :class:`~repro.core.local_backend.LocalJaxBackend` — real execution.
  Each launch starts an actual JAX training loop on the placement's
  device slice, completions are *predicted* events corrected against
  measured progress, preemption really checkpoints, and the clock is
  the wall clock.  Measured step times feed back into the profiles the
  policy replans over (the paper's introspection loop, for real).

Engine semantics (shared by both backends):

- jobs arrive at ``Job.arrival_s`` (online workloads) and policies
  replan on arrival batches;
- preempted jobs pay a REAL restart penalty: their GPUs are released at
  preemption time but the job is only admissible again when its
  :class:`RestartDone` event fires at ``t + restart_cost_s``;
- placement is pluggable (:mod:`.placement`): flat pool, node-aware, or
  per-device-class pools on heterogeneous clusters;
- every Gantt entry records the concrete device set (and device class)
  it occupied, and the engine asserts GPU-second conservation PER
  DEVICE CLASS before returning;
- replans are warm-start-capable: the engine hands the previous
  Schedule, the current time and the running set to
  :meth:`Policy.plan_incremental`;
- chaos (:mod:`.chaos`) injects cluster events through the same queue:
  failures/revocations shrink the elastic placement pool mid-run (a
  killed launch salvages its last periodic checkpoint), recoveries and
  spot grants grow it with fresh device ids, and each applied change
  triggers an incremental replan against a LIVE capacity view — with
  the same per-class conservation check holding throughout.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from .chaos import (CapacityChange, ChaosTrace, NodeFailure, NodeRecovery,
                    RetryPolicy, SpotGrant, SpotRevoke, WorkerFailure,
                    WorkerFault)
from .events import (ClusterEvent, EventQueue, IntrospectionTick,
                     JobArrival, JobCompletion, RestartDone)
from .job import DEFAULT_CLASS, SERVE_TECH, ClusterSpec, Job
from .perfmodel import ObservedProfiles, profile_key, step_time_of
from .placement import (ClassPool, PlacementBackend, PlacementError,
                        make_backend)
from .profiler import Profile
from .schedule import Placement, Policy, Schedule


@dataclasses.dataclass
class GanttEntry:
    job: str
    technique: str
    n_gpus: int
    start_s: float
    end_s: float
    kind: str = "run"          # run | restart
    devices: Tuple[int, ...] = ()
    device_class: str = DEFAULT_CLASS


@dataclasses.dataclass
class SimResult:
    policy: str
    makespan_s: float
    gantt: List[GanttEntry]
    replans: int = 0
    restarts: int = 0
    failures: int = 0          # chaos: NodeFailure events that took devices
    # execution-backend extras (LocalJaxBackend fills per-job segment
    # stats: losses, measured step times, compile costs); {} for sim
    stats: Dict[str, dict] = dataclasses.field(default_factory=dict)
    # supervision: detected worker failures (dead/hung workers, escaped
    # worker exceptions) routed through the retry machinery, and jobs
    # that exhausted their retry budget — quarantined with a recorded
    # reason instead of crashing or deadlocking the run
    worker_failures: int = 0
    quarantined: Dict[str, str] = dataclasses.field(default_factory=dict)

    def utilization(self, cluster: ClusterSpec) -> float:
        busy = sum((g.end_s - g.start_s) * g.n_gpus for g in self.gantt
                   if g.kind == "run")
        return busy / (self.makespan_s * cluster.total_gpus + 1e-9)


def _noise_factors(jobs, profiles, seed: int, sigma: float):
    """Seeded multiplicative drift between estimated and true step times.
    Iterates profiles in insertion order so legacy and runtime paths see
    identical factors."""
    rng = np.random.RandomState(seed)
    out = {}
    for key in profiles:
        out[key] = float(np.exp(rng.randn() * sigma))
    return out


@dataclasses.dataclass
class LaunchHandle:
    """One live launch: what the engine tracks between ``launch`` and
    completion/preemption.  Backends may subclass to carry substrate
    state (the sim keeps its true step time; the local backend keeps a
    worker thread)."""
    job: Job
    technique: str
    n_gpus: int
    placement: Placement
    start_s: float
    true_step_s: float
    steps_at_start: int
    token: int

    @property
    def device_class(self) -> str:
        return getattr(self.placement, "device_class", DEFAULT_CLASS)


# Backward-compat alias: the handle used to be the runtime-private
# ``_Running`` record.
_Running = LaunchHandle


class ExecutionBackend:
    """The launch / preempt-with-checkpoint / poll-progress / clock
    protocol between the engine and an execution substrate.

    ``exact_completions`` declares whether the :class:`JobCompletion`
    events this backend's launches schedule are exact (virtual time) or
    predictions the engine must verify against real progress when they
    fire.  ``virtual`` declares whether the clock is simulated (the
    engine never blocks) or real (``wait_until`` sleeps).
    """

    kind = "base"
    virtual = True
    exact_completions = True

    # ------------------------------------------------------------- setup
    def bind(self, jobs: List[Job], profiles, cluster: ClusterSpec) -> None:
        """Called once per run before any event is processed."""
        self._profiles = profiles
        self._cluster = cluster

    # ------------------------------------------------------------- clock
    def event_time(self, ev) -> float:
        """What the engine clock reads when ``ev`` is processed."""
        return ev.t

    def wait_until(self, t: float) -> None:
        """Block until the clock reaches ``t`` (real backends; may
        return early when a launch finishes).  Virtual clocks no-op."""

    def drain_finished(self) -> Tuple[LaunchHandle, ...]:
        """Launches that finished since the last drain (real backends
        deliver completions through here; exact backends through the
        events they scheduled at launch)."""
        return ()

    # --------------------------------------------------------- supervision
    def drain_failures(self) -> Tuple[Tuple[LaunchHandle, str], ...]:
        """``(handle, reason)`` pairs for launches whose workers failed
        since the last drain — a worker process that died, a worker
        that missed its heartbeat deadline, an exception that escaped a
        worker thread.  The engine synthesizes a
        :class:`~repro.core.chaos.WorkerFailure` event per record and
        routes it through salvage → backoff → relaunch (or quarantine
        once the :attr:`retry_policy` budget is exhausted)."""
        return ()

    # relaunch policy for failed workers; engine falls back to the
    # defaults when a backend leaves this None
    retry_policy: Optional[RetryPolicy] = None

    def salvage(self, handle: LaunchHandle) -> int:
        """Steps of a FAILED launch that are durable (checkpointed on
        disk and loadable at relaunch).  The base answers 0 — a worker
        that died without supervision salvages nothing beyond the
        checkpoint it was launched from; backends with periodic durable
        checkpoints answer from their checkpoint-ack records."""
        return 0

    def inject_fault(self, fault: WorkerFault,
                     running: Dict[str, LaunchHandle], t: float) -> None:
        """Really hurt a live worker (SIGKILL / stall heartbeats /
        truncate its checkpoint) per an injected
        :class:`~repro.core.chaos.WorkerFault`.  Only fault-capable
        backends (separate worker processes) support this."""
        raise RuntimeError(
            f"execution backend {self.kind!r} cannot inject worker "
            f"faults (kind={fault.kind!r}); use a process-isolated "
            f"backend such as ProcessJaxBackend")

    # ---------------------------------------------------------- estimates
    def est_step(self, job: str, tech: str, g: int,
                 device_class: Optional[str] = None) -> float:
        """Estimated step time (profiles / performance model).  Curve-
        backed models answer at ANY count, so introspection replans may
        pick counts nobody profiled."""
        return step_time_of(self._profiles, job, tech, g,
                            device_class=device_class)

    def planning_profiles(self):
        """The profile view policies plan over.  The sim returns the
        bound profiles untouched (identity matters: solver choice caches
        key on it); real backends overlay measured step times."""
        return self._profiles

    def serve_step_time(self, serve, device_class: Optional[str] = None
                        ) -> float:
        """Per-token engine step time of ONE serving replica of
        ``serve`` (a :class:`~repro.core.job.ServeJob`) on
        ``device_class`` — the serving counterpart of :meth:`est_step`.
        The base answers from the bound profiles; real backends measure
        an actual :class:`~repro.serving.engine.ContinuousBatchingEngine`."""
        return step_time_of(self._profiles, serve.name, SERVE_TECH,
                            serve.gpus_per_replica,
                            device_class=device_class)

    # ------------------------------------------------------ run lifecycle
    def launch(self, job: Job, entry, placement: Placement,
               device_class: str, remaining: int, t: float,
               token: int) -> LaunchHandle:
        raise NotImplementedError

    def eta(self, handle: LaunchHandle) -> float:
        """(Predicted) completion time of a launch."""
        raise NotImplementedError

    def steps_done(self, handle: LaunchHandle, upto_t: float) -> int:
        """Poll progress: steps finished since this launch started."""
        raise NotImplementedError

    def is_finished(self, handle: LaunchHandle) -> bool:
        """Whether the launch has really completed (real backends)."""
        return True

    def preempt(self, handle: LaunchHandle, t: float) -> int:
        """Stop a launch, checkpointing its state; returns the steps it
        completed.  The engine releases devices and charges the restart
        penalty."""
        raise NotImplementedError

    def complete(self, handle: LaunchHandle, t: float) -> None:
        """Normal-completion cleanup (join workers, record stats)."""

    def result_stats(self) -> Dict[str, dict]:
        """Per-job execution stats for :class:`SimResult` (may be {})."""
        return {}


class SimBackend(ExecutionBackend):
    """Virtual-time execution: estimate x seeded noise, exact completion
    events, instant clock.  Bit-exact with the historical ``simulate()``
    while-loop (the runtime/legacy equivalence tests pin this)."""

    kind = "sim"
    virtual = True
    exact_completions = True

    def __init__(self, noise_sigma: float = 0.1, noise_seed: int = 0):
        self.noise_sigma = noise_sigma
        self.noise_seed = noise_seed

    def bind(self, jobs, profiles, cluster) -> None:
        super().bind(jobs, profiles, cluster)
        self._noise = _noise_factors(jobs, profiles, self.noise_seed,
                                     self.noise_sigma)

    def _true_step(self, job: str, tech: str, g: int,
                   device_class: Optional[str]) -> float:
        key = profile_key(self._profiles, job, tech, g, device_class)
        return self.est_step(job, tech, g, device_class) * \
            self._noise.get(key, 1.0)

    def launch(self, job, entry, placement, device_class, remaining, t,
               token) -> LaunchHandle:
        st = self._true_step(job.name, entry.technique, entry.n_gpus,
                             device_class)
        return LaunchHandle(job, entry.technique, entry.n_gpus, placement,
                            t, st, remaining, token)

    def eta(self, handle: LaunchHandle) -> float:
        return handle.start_s + handle.steps_at_start * handle.true_step_s

    def steps_done(self, handle: LaunchHandle, upto_t: float) -> int:
        return int((upto_t - handle.start_s) / handle.true_step_s)

    def preempt(self, handle: LaunchHandle, t: float) -> int:
        return self.steps_done(handle, t)

    def serve_step_time(self, serve, device_class=None) -> float:
        """Serving step times drift with the same seeded noise training
        steps do — the "measured" value the fleet manager observes."""
        return self._true_step(serve.name, SERVE_TECH,
                               serve.gpus_per_replica, device_class)


def verify_conservation(state: "ClusterState") -> None:
    """GPU-second conservation, per device class.

    Reconciles the launch-side allocation bookkeeping (token -> launch
    time / size / class, written in ``start_fitting`` from the actual
    Placement) against the release-side Gantt segments (written from the
    :class:`LaunchHandle`), and both against the concrete device ids
    those segments claim.  A device double-booked within its class, a
    segment whose devices belong to a different class than recorded, a
    launch whose placement was never released, or busy-seconds leaking
    from one class to another all fail here — even when the GLOBAL
    totals happen to balance out.
    """
    if state._alloc_open:
        raise RuntimeError(
            f"conservation: {len(state._alloc_open)} allocation(s) never "
            f"released: {sorted(state._alloc_open)}")
    runs = [g for g in state.gantt if g.kind == "run"]
    per_class: Dict[str, float] = {}
    by_dev: Dict[int, List[Tuple[float, float, str, str]]] = {}
    for g in runs:
        if len(set(g.devices)) != g.n_gpus:
            raise RuntimeError(
                f"conservation: {g.job} records {g.n_gpus} GPUs but "
                f"{len(set(g.devices))} distinct devices")
        per_class[g.device_class] = per_class.get(g.device_class, 0.0) \
            + (g.end_s - g.start_s) * g.n_gpus
        for d in g.devices:
            dc = state.backend.class_of(d)
            if dc != g.device_class:
                raise RuntimeError(
                    f"conservation: {g.job} recorded class "
                    f"{g.device_class!r} but device {d} belongs to {dc!r}")
            by_dev.setdefault(d, []).append(
                (g.start_s, g.end_s, g.job, g.device_class))
    classes = set(per_class) | set(state.busy_gpu_s)
    for dc in classes:
        a = per_class.get(dc, 0.0)
        b = state.busy_gpu_s.get(dc, 0.0)
        if abs(a - b) > 1e-6 * max(1.0, a, b):
            raise RuntimeError(
                f"conservation: class {dc!r} gantt={a:.6f} GPU-s vs "
                f"accounted={b:.6f} GPU-s")
    for d, ivs in by_dev.items():
        ivs.sort()
        for (s1, e1, j1, _), (s2, e2, j2, _) in zip(ivs, ivs[1:]):
            if e1 > s2 + 1e-9:
                raise RuntimeError(
                    f"conservation: device {d} double-booked: "
                    f"{j1}[{s1},{e1}] overlaps {j2}[{s2},{e2}]")


class ClusterState:
    """Mutable runtime state: job phases, remaining work, live launch
    handles, the Gantt log under construction, and per-device-class
    GPU-second accounting (the runtime's conservation invariant)."""

    def __init__(self, jobs: List[Job], backend: PlacementBackend):
        self.by_name: Dict[str, Job] = {j.name: j for j in jobs}
        self.remaining: Dict[str, int] = {j.name: j.total_steps for j in jobs}
        self.arrived: set = set()
        self.waiting: List[str] = []
        self.restarting: set = set()
        self.quarantined: Dict[str, str] = {}    # job -> recorded reason
        self.running: Dict[str, LaunchHandle] = {}
        self.backend = backend
        self.gantt: List[GanttEntry] = []
        self.current_assign: Dict[str, Tuple] = {}
        self.busy_gpu_s: Dict[str, float] = {}   # device class -> GPU-seconds
        self._alloc_open: Dict[int, Tuple[float, int, str]] = {}
        self.t = 0.0

    def note_alloc(self, token: int, t: float, n_gpus: int,
                   device_class: str) -> None:
        """Record an allocation at LAUNCH time.  This bookkeeping is
        written on the launch path (start_fitting), independently of the
        Gantt entries written on the release paths, so the conservation
        check reconciles two genuinely distinct records."""
        self._alloc_open[token] = (t, n_gpus, device_class)

    def close_alloc(self, token: int, end_s: float) -> None:
        """Close an allocation at release time and charge its class."""
        t0, n, dc = self._alloc_open.pop(token)
        self.busy_gpu_s[dc] = self.busy_gpu_s.get(dc, 0.0) \
            + (end_s - t0) * n

    def log_run(self, name: str, r: LaunchHandle, end_s: float) -> None:
        """Close a run segment: Gantt entry + launch-side accounting."""
        self.close_alloc(r.token, end_s)
        self.gantt.append(GanttEntry(
            name, r.technique, r.n_gpus, r.start_s, end_s,
            devices=r.placement.devices, device_class=r.device_class))

    def live_jobs(self) -> List[Job]:
        """Arrived, unfinished jobs (running, waiting, or restarting) —
        what planners plan over.  Quarantined jobs are out of the
        workload: the rest of the sweep replans onto the surviving
        capacity without them."""
        return [self.by_name[n] for n in self.by_name
                if n in self.arrived and self.remaining[n] > 0
                and n not in self.quarantined]

    def all_done(self) -> bool:
        """Every job finished its budget or was quarantined (a
        quarantined job is RESOLVED, not silently dropped: its recorded
        reason rides ``SimResult.quarantined``)."""
        return all(v == 0 for n, v in self.remaining.items()
                   if n not in self.quarantined)


def execute_runtime(jobs: List[Job], policy: Policy,
                    profiles: Dict[Tuple[str, str, int], Profile],
                    cluster: ClusterSpec, *,
                    exec_backend: ExecutionBackend,
                    introspect_every_s: Optional[float] = None,
                    max_events: int = 100000,
                    backend: Optional[PlacementBackend] = None,
                    chaos: Optional[ChaosTrace] = None,
                    fleets=None) -> SimResult:
    """Run ``jobs`` under ``policy`` on the event-driven engine, with
    execution delegated to ``exec_backend`` (sim or real).

    ``chaos`` injects a :class:`~repro.core.chaos.ChaosTrace` of cluster
    events: failures/revocations shrink the placement pool mid-run
    (killing launches on dead devices, which salvage their last periodic
    checkpoint), recoveries/grants grow it with fresh device ids, and
    every applied change triggers an incremental replan for dynamic
    policies.  Requires an elastic placement backend (flat or per-class
    pools).  Per-class GPU-second conservation is verified at the end
    exactly as in the undisturbed case.

    ``fleets`` (a :class:`~repro.serving.fleet.FleetManager`) runs
    serving fleets alongside training: replicas hold real placement-pool
    device blocks (Gantt segments, conservation accounting), are resized
    at introspection ticks as the traffic trace shifts — growth may
    EVICT training launches, which pay the usual restart penalty —
    and measured replica step times feed back into the profile view
    replans plan over.  Per-fleet per-window latency/SLO stats land in
    ``SimResult.stats["serving"]``."""
    backend = backend or make_backend(cluster)
    if chaos is not None and not backend.supports_elasticity and \
            any(not isinstance(e, WorkerFault) for e in chaos):
        # WorkerFaults never touch the placement pool, so a trace made
        # only of them runs on any backend
        raise ValueError(
            f"chaos injection needs an elastic placement backend; "
            f"{backend.kind!r} does not support shrink/grow")
    if fleets is not None:
        if backend.kind == "node":
            raise ValueError("serving fleets require flat or class "
                             "placement (node-aware pools cannot carve "
                             "replica blocks)")
        if not introspect_every_s:
            introspect_every_s = fleets.window_s
        fleets.plans(profiles)
    exec_backend.bind(jobs, profiles, cluster)
    state = ClusterState(jobs, backend)
    q = EventQueue()
    for j in jobs:
        q.push(JobArrival(max(0.0, getattr(j, "arrival_s", 0.0)), j))
    if introspect_every_s:
        q.push(IntrospectionTick(introspect_every_s))
    if chaos is not None:
        for cev in chaos:
            q.push(cev)

    order = Schedule([])
    replans = 0
    restarts = 0
    failures = 0
    solver_log: List[dict] = []   # per-(re)plan telemetry -> stats["solver"]
    worker_failures = 0
    retry = getattr(exec_backend, "retry_policy", None) or RetryPolicy()
    fail_counts: Dict[str, int] = {}   # job -> detected failures so far
    launch_tokens = {}            # job -> token of its current launch
    next_token = [0]

    def settle(upto_t: float) -> None:
        """Account finished steps for running jobs up to ``upto_t``
        (sim: computed from true step times; real: polled counters)."""
        for name, h in state.running.items():
            done = exec_backend.steps_done(h, upto_t)
            state.remaining[name] = max(0, h.steps_at_start - done)

    # ------------------------------------------- serving-fleet plumbing
    def _fleet_free(dclass: str) -> int:
        if isinstance(backend, ClassPool):
            return backend.free_in(dclass)
        return backend.free_gpus

    def _fleet_evict(n_gpus: int, dclass: str, t: float) -> None:
        """Free capacity for fleet growth by preempting training
        launches (largest first, same class) — serving's SLO outranks
        sweep throughput, so training pays the restart penalty."""
        nonlocal restarts
        victims = sorted(
            (h for h in state.running.values()
             if not isinstance(backend, ClassPool)
             or h.device_class == dclass),
            key=lambda h: -h.n_gpus)
        for h in victims:
            if _fleet_free(dclass) >= n_gpus:
                break
            name = h.job.name
            state.running.pop(name)
            done = exec_backend.preempt(h, t)
            backend.release(h.placement)
            state.log_run(name, h, t)
            if done >= h.steps_at_start:
                state.remaining[name] = 0
                continue
            state.gantt.append(GanttEntry(
                name, "restart", 0, t, t + cluster.restart_cost_s,
                kind="restart", device_class=h.device_class))
            state.remaining[name] = max(1, h.steps_at_start - done)
            state.restarting.add(name)
            q.push(RestartDone(t + cluster.restart_cost_s, name))
            restarts += 1
            fleets.evictions += 1

    def _grow_replica(fs, t: float) -> bool:
        g = fs.serve.gpus_per_replica
        dclass = fs.device_class if isinstance(backend, ClassPool) else None
        pl = backend.allocate(g, device_class=dclass)
        if pl is None:
            _fleet_evict(g, fs.device_class, t)
            pl = backend.allocate(g, device_class=dclass)
            if pl is None:
                return False
        next_token[0] += 1
        tok = next_token[0]
        h = LaunchHandle(fs.serve, SERVE_TECH, g, pl, t, 0.0, 0, tok)
        state.note_alloc(tok, t, pl.n_gpus,
                         getattr(pl, "device_class", DEFAULT_CLASS))
        fs.handles.append(h)
        return True

    def _release_replica(fs, t: float) -> None:
        h = fs.handles.pop()
        backend.release(h.placement)
        state.log_run(fs.serve.name, h, t)

    def _measure_step_time(fs) -> float:
        return exec_backend.serve_step_time(fs.serve, fs.device_class)

    class _FleetHooks:
        pass

    hooks = _FleetHooks()
    hooks.grow_replica = _grow_replica
    hooks.release_replica = _release_replica
    hooks.measure_step_time = _measure_step_time
    hooks.profiles = profiles

    def planning_profiles():
        """What replans optimize over: the backend's view (measured
        training step times on real backends), plus the fleet manager's
        measured serve-replica step times when serving is live."""
        base = exec_backend.planning_profiles()
        if fleets is not None and fleets.observed:
            return ObservedProfiles(base, fleets.observed)
        return base

    def allocate_for(entry):
        """Place one entry: class-pinned entries draw from their class's
        pool; class-blind entries on a heterogeneous cluster take the
        first class with room where the config is actually runnable
        (finite estimated step time)."""
        if entry.device_class is None and isinstance(backend, ClassPool) \
                and len(backend.classes) > 1:
            for dc in backend.classes:
                try:
                    st = exec_backend.est_step(entry.job, entry.technique,
                                               entry.n_gpus, dc.name)
                except KeyError:
                    continue  # unprofiled on this class (e.g. count
                    #           exceeds the class's capacity grid)
                if not math.isfinite(st):
                    continue
                pl = backend.allocate(entry.n_gpus, device_class=dc.name)
                if pl is not None:
                    return pl
            return None
        return backend.allocate(entry.n_gpus,
                                preferred_nodes=entry.nodes,
                                device_class=entry.device_class)

    def start_fitting():
        """List scheduling: repeatedly start the first schedule entry
        whose job is admissible and whose GPU request fits."""
        progressed = True
        while progressed:
            progressed = False
            for entry in order.entries:
                name = entry.job
                if name not in state.waiting:
                    continue
                if not backend.feasible(entry.n_gpus,
                                        device_class=entry.device_class):
                    if chaos is not None:
                        # the pool shrank under this entry; capacity may
                        # return (recovery/grant), so wait instead of
                        # declaring the plan unhostable
                        continue
                    raise PlacementError(
                        f"{name}: {entry.n_gpus} GPUs "
                        f"(class {entry.device_class!r}) can never be "
                        f"placed on backend {backend.kind!r}")
                pl = allocate_for(entry)
                if pl is None:
                    continue
                dclass = getattr(pl, "device_class", DEFAULT_CLASS)
                next_token[0] += 1
                tok = next_token[0]
                h = exec_backend.launch(state.by_name[name], entry, pl,
                                        dclass, state.remaining[name],
                                        state.t, tok)
                state.note_alloc(tok, state.t, pl.n_gpus, dclass)
                state.running[name] = h
                launch_tokens[name] = tok
                state.current_assign[name] = entry.assignment
                state.waiting.remove(name)
                q.push(JobCompletion(exec_backend.eta(h), name, tok))
                progressed = True
                break

    def planning_cluster() -> ClusterSpec:
        """What policies plan over.  Without chaos or fleets: the static
        spec, verbatim (legacy paths stay bit-exact).  Under chaos: a
        live view whose per-class capacities track the elastic pools.
        With serving fleets: the fleet-held devices are subtracted too,
        so training replans only target what serving is not using."""
        if chaos is None and fleets is None:
            return cluster
        if isinstance(backend, ClassPool):
            caps = {dc.name: backend.capacity(dc.name)
                    for dc in cluster.device_classes}
            if fleets is not None:
                for name in caps:
                    caps[name] = max(0, caps[name] - fleets.held(name))
            if all(caps[dc.name] == dc.total_gpus
                   for dc in cluster.device_classes):
                return cluster
            dcs = tuple(dataclasses.replace(dc, nodes=1,
                                            gpus_per_node=caps[dc.name])
                        for dc in cluster.device_classes
                        if caps[dc.name] > 0)
            return dataclasses.replace(cluster, device_classes=dcs)
        cap = backend.capacity()
        if fleets is not None:
            cap = max(0, cap - fleets.held())
        if cap == cluster.total_gpus:
            return cluster
        return dataclasses.replace(cluster, nodes=1,
                                   gpus_per_node=max(1, cap),
                                   device_classes=())

    def replan(preempt: bool):
        nonlocal order, replans, restarts
        live = state.live_jobs()
        if not live:
            return
        if fleets is not None and \
                backend.capacity() - fleets.held() <= 0:
            return          # serving holds every device: nothing to plan
        # warm-start-capable policies get the previous schedule, the
        # current time and the running set and may re-solve only the
        # residual; the default delegates to plan() unchanged.  Real
        # backends hand over measured step times where observed.
        order = Schedule.coerce(policy.plan_incremental(
            live, dict(state.remaining), planning_profiles(),
            planning_cluster(), dict(state.current_assign), prev=order,
            now_s=state.t, running=frozenset(state.running)))
        replans += 1
        tel = getattr(order, "telemetry", None)
        if tel is not None:     # which engine planned, at what cost
            solver_log.append({**tel, "t": state.t})
        if preempt:
            new_assign = order.assignment_map()
            for name in list(state.running):
                if name in new_assign and \
                        new_assign[name] != state.current_assign.get(name):
                    h = state.running.pop(name)
                    done = exec_backend.preempt(h, state.t)
                    backend.release(h.placement)
                    state.log_run(name, h, state.t)
                    if done >= h.steps_at_start:
                        # a real worker can finish its whole budget
                        # while the replan solve was running: that is a
                        # completion, not a restart (unreachable in
                        # virtual time — a sim completion event always
                        # fires before its job reaches this branch)
                        state.remaining[name] = 0
                        continue
                    # checkpoint + relaunch penalty: the job is only
                    # admissible again when RestartDone fires
                    state.gantt.append(GanttEntry(
                        name, "restart", 0, state.t,
                        state.t + cluster.restart_cost_s, kind="restart",
                        device_class=h.device_class))
                    state.remaining[name] = max(1, h.steps_at_start - done)
                    state.restarting.add(name)
                    q.push(RestartDone(
                        state.t + cluster.restart_cost_s, name))
                    restarts += 1

    def kill_launches(victims: set, t: float) -> None:
        """Kill every launch touching a victim device, salvaging its
        last periodic checkpoint: progress since
        ``chaos.checkpoint_every_s`` (measured from launch start) is
        lost, progress up to the checkpoint — and everything from before
        this launch — survives.  The job pays the usual restart penalty
        before it is admissible again."""
        nonlocal restarts
        ck = chaos.checkpoint_every_s
        hit = [n for n, h in state.running.items()
               if victims & set(h.placement.devices)]
        for name in hit:
            h = state.running.pop(name)
            done = exec_backend.preempt(h, t)
            t_ck = h.start_s + math.floor(
                max(0.0, t - h.start_s) / ck) * ck
            done = min(done, exec_backend.steps_done(h, t_ck))
            backend.release(h.placement)
            state.log_run(name, h, t)
            if done >= h.steps_at_start:
                state.remaining[name] = 0
                continue
            state.gantt.append(GanttEntry(
                name, "restart", 0, t, t + cluster.restart_cost_s,
                kind="restart", device_class=h.device_class))
            state.remaining[name] = max(1, h.steps_at_start - done)
            state.restarting.add(name)
            q.push(RestartDone(t + cluster.restart_cost_s, name))
            restarts += 1

    def shrink(dclass: str, k: int, t: float, *,
               prefer_free: bool) -> int:
        """Remove up to ``k`` present devices of ``dclass``.  Failures
        (``prefer_free=False``) take the lowest present ids, busy or
        not; revocations/resizes drain the free pool first.  Returns how
        many devices actually left."""
        free = sorted(backend.free_devices(dclass))
        busy = sorted(d for h in state.running.values()
                      for d in h.placement.devices
                      if backend.class_of(d) == dclass)
        pool = (free + busy) if prefer_free else sorted(free + busy)
        victims = set(pool[:k])
        if not victims:
            return 0
        kill_launches(victims, t)
        backend.remove_devices(sorted(victims))
        return len(victims)

    def handle_worker_failure(e: WorkerFailure, t: float) -> bool:
        """Recover one detected worker failure: close the launch at its
        last DURABLE step (the backend's salvage answer — checkpointed
        on disk, loadable at relaunch), then relaunch under exponential
        backoff + jitter, or quarantine the job with a recorded reason
        once the retry budget is exhausted.  The run never deadlocks on
        a failed job and never silently drops one."""
        nonlocal restarts, worker_failures
        h = state.running.get(e.job)
        if h is None or h.token != e.token:
            return False            # stale: that launch is already gone
        worker_failures += 1
        state.running.pop(e.job)
        done = exec_backend.salvage(h)
        backend.release(h.placement)
        state.log_run(e.job, h, t)
        if done >= h.steps_at_start:
            # died AFTER its last step was durably checkpointed: the
            # work survived the worker
            state.remaining[e.job] = 0
            return True
        state.remaining[e.job] = max(1, h.steps_at_start - done)
        fail_counts[e.job] = attempt = fail_counts.get(e.job, 0) + 1
        if attempt > retry.budget:
            state.quarantined[e.job] = (
                f"retry budget exhausted after {attempt} failures; "
                f"last: {e.reason}")
            return True
        delay = max(cluster.restart_cost_s, retry.backoff_s(e.job, attempt))
        state.gantt.append(GanttEntry(
            e.job, "restart", 0, t, t + delay, kind="restart",
            device_class=h.device_class))
        state.restarting.add(e.job)
        q.push(RestartDone(t + delay, e.job))
        restarts += 1
        return True

    def apply_cluster_event(e: ClusterEvent, t: float) -> bool:
        """Mutate the pool for one chaos event; True if anything changed."""
        nonlocal failures
        if isinstance(e, WorkerFailure):
            return handle_worker_failure(e, t)
        if isinstance(e, WorkerFault):
            # injection only: the coordinator must DETECT the damage
            # through its supervision channel (process exit, missed
            # heartbeat, checksum) and synthesize the WorkerFailure —
            # never short-circuited here, so recovery is exercised for
            # real.  No pool change, no replan from this event.
            exec_backend.inject_fault(e, state.running, t)
            return False
        if isinstance(e, NodeFailure):
            removed = shrink(e.device_class, e.n_gpus, t,
                             prefer_free=False)
            if removed:
                failures += 1
                if e.recover_after_s is not None:
                    q.push(NodeRecovery(t + e.recover_after_s, removed,
                                        e.device_class))
            return removed > 0
        if isinstance(e, SpotRevoke):
            # voluntary capacity loss, not a failure: no failure count
            removed = shrink(e.device_class, e.n_gpus, t,
                             prefer_free=True)
            return removed > 0
        if isinstance(e, (NodeRecovery, SpotGrant)):
            backend.add_devices(e.n_gpus, device_class=e.device_class)
            return True
        if isinstance(e, CapacityChange):
            if e.delta > 0:
                backend.add_devices(e.delta, device_class=e.device_class)
                return True
            if e.delta < 0:
                removed = shrink(e.device_class, -e.delta, t,
                                 prefer_free=True)
                return removed > 0
        return False

    def finalize_if_done(t: float) -> bool:
        """When every job's remaining work hits zero, jobs still marked
        running finished at exactly this instant (their own completion
        events are queued at the same time): close their segments and
        release their devices instead of dropping them on the floor."""
        if not state.all_done():
            return False
        for name in list(state.running):
            h = state.running.pop(name)
            exec_backend.complete(h, t)
            backend.release(h.placement)
            state.log_run(name, h, t)
        return True

    if fleets is not None:
        # fleets come up before any training is placed: serving capacity
        # is carved first, the sweep schedules around it
        fleets.resize(hooks, 0.0, introspect_every_s)

    events = 0
    while q:
        if finalize_if_done(state.t) and not (
                fleets is not None and state.t < fleets.horizon_s):
            break
        ev = q.pop()
        events += 1
        if events > max_events:
            raise RuntimeError("execute_runtime: event cap hit")

        if not exec_backend.exact_completions:
            # real clock: sleep until the event's timestamp (interrupted
            # early if a launch finishes), then deliver real completions
            # at their actual finish time before the scheduled event
            exec_backend.wait_until(ev.t)
            finished = exec_backend.drain_finished()
            if finished:
                for h in finished:
                    q.push(JobCompletion(
                        exec_backend.event_time(ev) if h.finish_t is None
                        else h.finish_t, h.job.name, h.token))
                q.push(ev)
                continue

        failed = exec_backend.drain_failures()
        if failed:
            # synthesize detection events and requeue: WorkerFailure is
            # a ClusterEvent (priority above completions), so a failure
            # detected at the instant of a scheduled completion wins the
            # race — the stale completion is then dropped by its token.
            # The failure rides at ev.t, NOT the (possibly later) wall
            # clock: the requeued event keeps its original timestamp,
            # and a failure stamped later would lose to it on pop order
            # (a completion prediction that overran its timestamp would
            # then "complete" the dead worker).  The engine clock still
            # reads event_time() when the failure is processed.
            tf = ev.t
            for h, reason in failed:
                q.push(WorkerFailure(tf, job=h.job.name, token=h.token,
                                     reason=reason))
            q.push(ev)
            continue

        if isinstance(ev, JobArrival):
            state.t = exec_backend.event_time(ev)
            settle(state.t)   # replan must see observed progress
            batch = [ev] + q.pop_while(JobArrival, ev.t)
            for e in batch:
                state.arrived.add(e.job.name)
                state.waiting.append(e.job.name)
            # dynamic policies may preempt running jobs to make room for
            # the new arrival; static ones just extend the plan
            if state.t > 0 and not getattr(policy, "replan_on_arrival", True):
                pass
            else:
                replan(preempt=policy.dynamic and state.t > 0)
            start_fitting()

        elif isinstance(ev, JobCompletion):
            if launch_tokens.get(ev.job) != ev.token or \
                    ev.job not in state.running:
                continue                       # stale (preempted launch)
            h = state.running[ev.job]
            if not exec_backend.exact_completions and \
                    not exec_backend.is_finished(h):
                # the prediction fired early: re-aim at measured progress
                q.push(JobCompletion(exec_backend.eta(h), ev.job, ev.token))
                continue
            state.t = exec_backend.event_time(ev)
            settle(state.t)
            state.running.pop(ev.job)
            exec_backend.complete(h, state.t)
            state.remaining[ev.job] = 0
            backend.release(h.placement)
            state.log_run(ev.job, h, state.t)
            if finalize_if_done(state.t) and not (
                    fleets is not None and state.t < fleets.horizon_s):
                break
            if policy.dynamic and policy.replan_on_completion and \
                    state.waiting:
                replan(preempt=False)
            start_fitting()

        elif isinstance(ev, RestartDone):
            state.t = exec_backend.event_time(ev)
            state.restarting.discard(ev.job)
            state.waiting.append(ev.job)
            start_fitting()

        elif isinstance(ev, ClusterEvent):
            state.t = exec_backend.event_time(ev)
            settle(state.t)   # kills must charge observed progress
            # coalesce a same-instant burst (correlated failures, a
            # grant landing with a revoke) into ONE replan
            batch = [ev] + q.pop_while(ClusterEvent, ev.t)
            changed = False
            for e in batch:
                changed = apply_cluster_event(e, state.t) or changed
            if changed and policy.dynamic and backend.capacity() > 0:
                replan(preempt=True)
            start_fitting()

        elif isinstance(ev, IntrospectionTick):
            serving_live = fleets is not None and ev.t < fleets.horizon_s
            if state.all_done() and not serving_live:
                if fleets is not None:
                    # advance the clock to the traffic horizon so the
                    # final fleet teardown replays the full trace
                    state.t = max(state.t, min(exec_backend.event_time(ev),
                                               fleets.horizon_s))
                continue
            if fleets is not None:
                # rescale fleets to the coming interval's traffic FIRST:
                # growth may evict training launches, and the replan
                # below then plans around the new holdings
                state.t = exec_backend.event_time(ev)
                settle(state.t)
                fleets.plans(planning_profiles())
                fleets.resize(hooks, state.t, introspect_every_s)
            if not (state.running or state.waiting or state.restarting):
                # nothing in the system yet (future arrivals pending):
                # keep the tick chain alive, but there is nothing to
                # settle or replan
                q.push(IntrospectionTick(ev.t + introspect_every_s))
                continue
            state.t = exec_backend.event_time(ev)
            settle(state.t)
            if policy.dynamic:
                replan(preempt=True)
            # chain from the engine clock, not the event's timestamp:
            # on a real backend the tick's work (preempt joins, MILP
            # solves) may overrun ev.t by seconds, and chaining from
            # ev.t would fire a burst of back-to-back catch-up replans.
            # Virtual time has state.t == ev.t, so the sim is unchanged.
            q.push(IntrospectionTick(state.t + introspect_every_s))
            start_fitting()

        # deadlock: nothing running, nothing can ever start it (pending
        # cluster events count — a recovery/grant can restore capacity,
        # and a serving fleet whose traffic will drop can shrink at a
        # future introspection tick)
        if state.waiting and not state.running and not state.restarting \
                and not q.has_any((JobArrival, RestartDone, ClusterEvent)) \
                and not (fleets is not None and fleets.held() > 0
                         and fleets.can_shrink_later(state.t)
                         and q.has_any((IntrospectionTick,))):
            raise RuntimeError(
                f"deadlock: waiting={state.waiting} "
                f"free={backend.free_gpus} order={order.to_tuples()}")

    if not state.all_done():
        unfinished = [n for n, v in state.remaining.items()
                      if v > 0 and n not in state.quarantined]
        raise RuntimeError(f"runtime drained with unfinished jobs: "
                           f"{unfinished}")
    stats = exec_backend.result_stats()
    if fleets is not None:
        fleets.finish(hooks, state.t)
        stats = dict(stats)
        stats["serving"] = fleets.stats()
    if solver_log:
        stats = dict(stats)
        stats["solver"] = solver_log
    verify_conservation(state)
    return SimResult(policy.name, state.t, state.gantt, replans, restarts,
                     failures=failures, stats=stats,
                     worker_failures=worker_failures,
                     quarantined=dict(state.quarantined))


def simulate_runtime(jobs: List[Job], policy: Policy,
                     profiles: Dict[Tuple[str, str, int], Profile],
                     cluster: ClusterSpec, *,
                     introspect_every_s: Optional[float] = None,
                     noise_sigma: float = 0.1, noise_seed: int = 0,
                     max_events: int = 100000,
                     backend: Optional[PlacementBackend] = None,
                     exec_backend: Optional[ExecutionBackend] = None,
                     chaos: Optional[ChaosTrace] = None,
                     fleets=None) -> SimResult:
    """Run ``jobs`` under ``policy`` on the event-driven cluster runtime
    (default execution backend: :class:`SimBackend` in virtual time).
    ``chaos`` injects a :class:`~repro.core.chaos.ChaosTrace` of node
    failures / spot churn / capacity changes; ``fleets`` runs serving
    fleets alongside training (see :func:`execute_runtime`)."""
    exec_backend = exec_backend or SimBackend(noise_sigma=noise_sigma,
                                              noise_seed=noise_seed)
    return execute_runtime(jobs, policy, profiles, cluster,
                           exec_backend=exec_backend,
                           introspect_every_s=introspect_every_s,
                           max_events=max_events, backend=backend,
                           chaos=chaos, fleets=fleets)
