"""LocalJaxBackend — the Schedule IR drives REAL JAX training.

This is the second implementation of the engine's
:class:`~repro.core.runtime.ExecutionBackend` protocol (the first is
the virtual-time :class:`~repro.core.runtime.SimBackend`): every launch
starts an actual training loop for the job's reduced model on the
placement's device slice, preemption really checkpoints
(:mod:`repro.checkpoint.store`) and relaunch really resumes — state AND
data position — and measured per-step wall times feed back into the
profile view introspection replans plan over
(:class:`~repro.core.perfmodel.ObservedProfiles`).  The engine clock is
the wall clock; completion events are *predictions* from the profile
estimates that the engine corrects against measured progress, and
worker threads interrupt the engine's sleep the moment a launch really
finishes.

Device mapping: the placement pools hand out global GPU ids
``0..total_gpus-1``; this backend maps them 1:1 onto the process's JAX
devices.  On a CPU-only container, expose several host devices with

    XLA_FLAGS=--xla_force_host_platform_device_count=N

(set BEFORE jax is imported) so concurrent jobs really train on
disjoint device slices.
"""
from __future__ import annotations

import math
import os
import tempfile
import threading
import time
from typing import Dict, List, Optional, Tuple

from .job import ClusterSpec, Job
from .library import ParallelismLibrary
from .perfmodel import ObservedProfiles, profile_key
from .runtime import ExecutionBackend, LaunchHandle


class _Worker(threading.Thread):
    """One launched job segment: a real training loop on a device slice.

    The engine-facing surface is tiny and lock-free (reads of ints and
    floats under the GIL): ``steps_done`` advances as steps retire,
    ``stop_flag`` requests a checkpoint-and-exit, ``done`` flips when
    the segment is over (naturally or preempted).  The first step after
    (re)launch is the JIT compile and is timed separately — it must not
    poison the measured step rate (the profile-feedback channel).
    """

    def __init__(self, backend: "LocalJaxBackend", job: Job, technique,
                 devices: List, ckpt_path: str, steps_to_run: int):
        super().__init__(daemon=True,
                         name=f"saturn-local-{job.name}")
        self.backend = backend
        self.job = job
        self.technique = technique
        self.devices = devices
        self.ckpt_path = ckpt_path
        self.steps_to_run = int(steps_to_run)
        self.steps_done = 0
        self.start_step = 0            # absolute step resumed from
        self.stop_flag = threading.Event()
        self.done = threading.Event()
        self.error: Optional[BaseException] = None
        self.preempted = False
        self.compile_s = 0.0
        self.finish_clock: Optional[float] = None
        self.losses: List[Tuple[int, float]] = []   # (absolute step, loss)
        self._dt_sum = 0.0
        self._dt_n = 0

    @property
    def measured_step_s(self) -> Optional[float]:
        """Mean post-compile step time; None until 2 steps retired."""
        if self._dt_n < 1:
            return None
        return self._dt_sum / self._dt_n

    def run(self) -> None:
        try:
            self._train()
        except BaseException as e:          # surfaced by the engine
            self.error = e
        finally:
            self.finish_clock = self.backend.now()
            self.done.set()
            self.backend._on_worker_done(self)

    def _train(self) -> None:
        import jax

        from ..checkpoint.store import load_training_state, save_checkpoint
        from ..data.synthetic import SyntheticLM

        built = self.backend._built_job(self.job, self.technique,
                                        self.devices)
        params, opt = built.init(jax.random.PRNGKey(self.job.seed))
        params, opt, self.start_step = load_training_state(
            self.ckpt_path, params, opt)
        data = SyntheticLM(self.job.cfg, seed=self.job.seed).batches(
            self.job.batch_size, self.job.seq_len,
            num_batches=self.steps_to_run, skip=self.start_step)
        loss = float("nan")
        for b in data:
            if self.stop_flag.is_set():
                self.preempted = True
                break
            t0 = time.perf_counter()
            params, opt, m = built.step(params, opt, built.place_batch(b))
            loss = float(m.get("loss", float("nan")))   # forces sync
            dt = time.perf_counter() - t0
            if self.steps_done == 0:
                self.compile_s = dt
            else:
                self._dt_sum += dt
                self._dt_n += 1
            self.steps_done += 1
            self.losses.append((self.start_step + self.steps_done, loss))
        save_checkpoint(self.ckpt_path, {"params": params, "opt": opt},
                        {"step": self.start_step + self.steps_done,
                         "loss": loss})


class LocalHandle(LaunchHandle):
    """LaunchHandle + the worker thread executing it."""

    def __init__(self, worker: _Worker, *args):
        super().__init__(*args)
        self.worker = worker

    @property
    def finish_t(self) -> Optional[float]:
        return self.worker.finish_clock


class LocalJaxBackend(ExecutionBackend):
    """Execute schedules for real on this machine's JAX devices."""

    kind = "local-jax"
    virtual = False
    exact_completions = False

    def __init__(self, library: Optional[ParallelismLibrary] = None,
                 ckpt_dir: Optional[str] = None,
                 devices: Optional[List] = None,
                 min_requeue_s: float = 0.25,
                 fallback_step_s: float = 0.1,
                 resume: bool = False,
                 retry_policy=None):
        self.library = library or ParallelismLibrary()
        # relaunch policy for failed workers (None: engine defaults)
        self.retry_policy = retry_policy
        self.ckpt_dir = ckpt_dir
        self._devices = devices
        self.min_requeue_s = min_requeue_s
        self.fallback_step_s = fallback_step_s
        # resume=False (default): a run starts its workload from step 0,
        # clearing this workload's checkpoints at bind time — WITHIN-run
        # preempt/relaunch still resumes.  resume=True continues from
        # whatever checkpoints ckpt_dir already holds (crash recovery).
        self.resume = resume
        self.observed: Dict[Tuple, float] = {}
        self.job_stats: Dict[str, dict] = {}
        self._built_cache: Dict[Tuple, object] = {}

    # ------------------------------------------------------------- setup
    def bind(self, jobs, profiles, cluster: ClusterSpec) -> None:
        import jax

        from .compile_cache import enable_persistent_compilation_cache
        super().bind(jobs, profiles, cluster)
        enable_persistent_compilation_cache()
        self._jax_devices = list(self._devices or jax.devices())
        if cluster.total_gpus > len(self._jax_devices):
            raise RuntimeError(
                f"LocalJaxBackend: cluster asks for {cluster.total_gpus} "
                f"devices but only {len(self._jax_devices)} JAX devices "
                f"exist; set XLA_FLAGS=--xla_force_host_platform_"
                f"device_count={cluster.total_gpus} before importing jax "
                f"(or shrink the cluster)")
        if self.ckpt_dir is None:
            self.ckpt_dir = tempfile.mkdtemp(prefix="saturn_local_")
        os.makedirs(self.ckpt_dir, exist_ok=True)
        if not self.resume:
            # a stale checkpoint from a previous run would make a
            # "fresh" run silently continue a finished model
            for j in jobs:
                for suffix in (".npz", ".npz.prev", ".npz.meta.json"):
                    p = os.path.join(self.ckpt_dir, j.name + suffix)
                    if os.path.exists(p):
                        os.remove(p)
        self._t0 = time.monotonic()
        self._lock = threading.Lock()
        self._poke = threading.Event()
        self._finished: List[LocalHandle] = []
        self._failed: List[Tuple[LocalHandle, str]] = []
        self._by_worker: Dict[_Worker, LocalHandle] = {}
        self.observed.clear()
        self.job_stats.clear()

    def _built_job(self, job: Job, technique, devices: List):
        """Build (or reuse) the executable for one (job, technique,
        device-slice) choice.  Reuse keeps a job relaunched onto the
        SAME choice from paying the JIT compile twice; a changed
        assignment — the usual reason for a restart — still compiles
        for real."""
        from ..parallelism.build import BuiltJob
        key = (job.name, technique.name, tuple(id(d) for d in devices))
        with self._lock:
            built = self._built_cache.get(key)
        if built is None:
            plan = technique.plan(job.cfg, len(devices))
            built = BuiltJob(job.cfg, plan, job.opt_cfg, devices=devices)
            with self._lock:
                self._built_cache[key] = built
        return built

    # ------------------------------------------------------------- clock
    def now(self) -> float:
        return time.monotonic() - self._t0

    def event_time(self, ev) -> float:
        # real work may overrun its event's timestamp; the clock never
        # runs backwards
        return max(ev.t, self.now())

    def wait_until(self, t: float) -> None:
        # sleep until wall-clock t, but return the moment a launch
        # really finishes (its completion preempts the scheduled event);
        # spurious wake-ups loop — an event must never be processed
        # before its timestamp unless a real completion forces it
        while True:
            with self._lock:
                if self._finished or self._failed:
                    return
            dt = t - self.now()
            if dt <= 0:
                return
            self._poke.wait(timeout=dt)
            self._poke.clear()

    def _on_worker_done(self, worker: _Worker) -> None:
        # an exception escaping the worker goes to the FAILURE channel
        # (never _finished): the engine synthesizes a WorkerFailure,
        # salvages the durable checkpoint and retries/quarantines — the
        # scheduler is poked either way, so wait_until never sleeps on a
        # completion that will not come
        with self._lock:
            h = self._by_worker.get(worker)
            if h is not None and not worker.preempted:
                if worker.error is not None:
                    self._failed.append((h, f"worker thread died: "
                                         f"{type(worker.error).__name__}: "
                                         f"{worker.error}"))
                else:
                    self._finished.append(h)
        self._poke.set()

    def drain_finished(self) -> Tuple[LocalHandle, ...]:
        with self._lock:
            out, self._finished = tuple(self._finished), []
        return out

    def drain_failures(self) -> Tuple[Tuple[LocalHandle, str], ...]:
        with self._lock:
            out, self._failed = tuple(self._failed), []
        return out

    # ---------------------------------------------------------- feedback
    def _record(self, h: LocalHandle) -> None:
        m = h.worker.measured_step_s
        if m is None or not math.isfinite(m) or m <= 0:
            return
        key = profile_key(self._profiles, h.job.name, h.technique,
                          h.n_gpus, h.device_class)
        self.observed[key] = m

    def planning_profiles(self):
        """Measured step times overlaid on the estimates — what the
        introspection replans optimize over.  A fresh overlay per replan
        so the solver's choice cache (keyed on profile identity) never
        serves stale observations."""
        for h in list(self._by_worker.values()):
            self._record(h)
        if not self.observed:
            return self._profiles
        return ObservedProfiles(self._profiles, self.observed)

    def serve_step_time(self, serve, device_class=None) -> float:
        """REALLY measure a serving replica: run a saturated
        ContinuousBatchingEngine burst for this model (compile excluded)
        instead of reading the analytic serve profile.  Memoized per
        (model, device class, replica size) — fleets re-measure through
        replans, not per tick."""
        key = (serve.name, device_class, serve.gpus_per_replica)
        cache = getattr(self, "_serve_measured", None)
        if cache is None:
            cache = self._serve_measured = {}
        if key not in cache:
            from ..serving.profile import measure_serve_step_time
            cache[key] = measure_serve_step_time(
                serve.cfg, slots=min(serve.slots, 4), seed=0)
        return cache[key]

    # ------------------------------------------------------ run lifecycle
    def launch(self, job, entry, placement, device_class, remaining, t,
               token) -> LocalHandle:
        devs = [self._jax_devices[d] for d in placement.devices]
        ckpt = os.path.join(self.ckpt_dir, f"{job.name}.npz")
        worker = _Worker(self, job, self.library.get(entry.technique),
                         devs, ckpt, remaining)
        try:
            est = self.est_step(job.name, entry.technique, entry.n_gpus,
                                device_class)
        except KeyError:
            est = self.fallback_step_s
        if not math.isfinite(est) or est <= 0:
            est = self.fallback_step_s
        h = LocalHandle(worker, job, entry.technique, entry.n_gpus,
                        placement, t, est, remaining, token)
        with self._lock:
            self._by_worker[worker] = h
        worker.start()
        return h

    def eta(self, handle: LocalHandle) -> float:
        """Predicted completion: measured rate once observed, the
        profile estimate before that."""
        w = handle.worker
        if w.done.is_set():
            return w.finish_clock if w.finish_clock is not None \
                else self.now()
        rate = w.measured_step_s or handle.true_step_s
        left = max(0, handle.steps_at_start - w.steps_done)
        return max(self.now() + left * rate,
                   self.now() + self.min_requeue_s)

    def steps_done(self, handle: LocalHandle, upto_t: float) -> int:
        self._record(handle)
        return handle.worker.steps_done

    def is_finished(self, handle: LocalHandle) -> bool:
        return handle.worker.done.is_set()

    def _durable_steps(self, handle: LocalHandle) -> int:
        """Relative steps of this launch that are durably on disk —
        the checkpoint chain a relaunch will ACTUALLY load (current
        file, else last-known-good ``.prev``), measured against the
        absolute step the engine launched from.  This is what a failed
        launch salvages: nothing more than what recovery can resume."""
        from ..checkpoint.store import (CheckpointCorruptError,
                                        verify_checkpoint)
        ckpt = os.path.join(self.ckpt_dir, f"{handle.job.name}.npz")
        start_abs = handle.job.total_steps - handle.steps_at_start
        for p in (ckpt, ckpt + ".prev"):
            if not os.path.exists(p):
                continue
            try:
                meta = verify_checkpoint(p)
            except CheckpointCorruptError:
                continue
            return max(0, int(meta.get("step", 0)) - start_abs)
        return 0

    def salvage(self, handle: LocalHandle) -> int:
        w = handle.worker
        w.join()
        self._finish(handle, preempted=False,
                     error=(f"{type(w.error).__name__}: {w.error}"
                            if w.error is not None else "worker failed"))
        return self._durable_steps(handle)

    def preempt(self, handle: LocalHandle, t: float) -> int:
        """Checkpoint-and-stop, for real: the worker finishes its
        in-flight step, writes the checkpoint, and exits; relaunch
        resumes from it (the restart penalty the engine charges on top
        models the cluster's relaunch round-trip)."""
        w = handle.worker
        w.stop_flag.set()
        w.join()
        if w.error is not None:
            # the worker was already dead: report only the durable
            # progress a relaunch can really resume (its failure record
            # rides drain_failures, dropped as stale if this preemption
            # won the race) — never raise mid-replan
            self._finish(handle, preempted=False,
                         error=f"{type(w.error).__name__}: {w.error}")
            return self._durable_steps(handle)
        # w.preempted reflects what really happened: False if the
        # worker had already finished its budget before the stop landed
        self._finish(handle, preempted=w.preempted)
        return w.steps_done

    def complete(self, handle: LocalHandle, t: float) -> None:
        w = handle.worker
        w.join()
        self._finish(handle, preempted=False)
        if w.error is not None:
            raise RuntimeError(
                f"local launch of {handle.job.name} failed") from w.error

    def _finish(self, handle: LocalHandle, preempted: bool,
                error: Optional[str] = None) -> None:
        w = handle.worker
        self._record(handle)
        with self._lock:
            if self._by_worker.pop(w, None) is None and \
                    handle.job.name in self.job_stats:
                return    # already recorded (preempt/salvage race)
        seg = {
            "technique": handle.technique,
            "n_gpus": handle.n_gpus,
            "device_class": handle.device_class,
            # worker frame: start_step + steps = absolute step reached
            # (steps_done may additionally carry a resume pre-credit in
            # the engine frame)
            "start_step": w.start_step,
            "steps": getattr(w, "raw_steps", w.steps_done),
            "preempted": preempted,
            "failed": error,
            "compile_s": w.compile_s,
            "measured_step_s": w.measured_step_s,
            "first_loss": w.losses[0][1] if w.losses else None,
            "last_loss": w.losses[-1][1] if w.losses else None,
        }
        st = self.job_stats.setdefault(
            handle.job.name, {"segments": [], "losses": []})
        st["segments"].append(seg)
        st["losses"].extend(w.losses)

    def result_stats(self) -> Dict[str, dict]:
        return self.job_stats
