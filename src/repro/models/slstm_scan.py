"""sLSTM time scan with a batched-gradient backward (custom VJP).

Autodiff of the naive ``lax.scan`` accumulates the recurrent-weight
gradient dR inside the backward time loop; under data-parallel sharding
GSPMD then inserts an all-reduce of the (H, D, D) partial gradient at
EVERY time step (S x num_layers all-reduces per batch — the dominant
collective term of xlstm-125m train_4k in the dry-run).

This implementation (the cuDNN-RNN trick, TPU-adapted) instead:
  forward : plain scan, saving the h sequence
  backward: one recompute scan (elementwise, cheap) + one reverse scan
            that emits per-step pre-activation cotangents dpres as ys;
            dR is then a single post-loop einsum over (S, B) — ONE
            cross-data all-reduce per layer instead of S.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _step_core(pres, prev):
    """pres: (B,H,D,4) pre-activations (gx + h_prev @ R); prev: (c,n,m).
    Returns (c', n', m', h')."""
    c, n, m = prev
    z_pre, i_pre, f_pre, o_pre = [pres[..., i] for i in range(4)]
    z = jnp.tanh(z_pre).astype(jnp.float32)
    i_pre = i_pre.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    m_new = jnp.maximum(lf + m, i_pre)
    fg = jnp.exp(lf + m - m_new)
    ig = jnp.exp(i_pre - m_new)
    c_new = fg * c + ig * z
    n_new = jnp.maximum(fg * n + ig, 1e-6)
    h_new = (jax.nn.sigmoid(o_pre).astype(jnp.float32) * c_new / n_new)
    return c_new, n_new, m_new, h_new


def _pres(R, gx_t, h):
    """gx_t: (B,H,D,4); h: (B,H,D) in compute dtype."""
    hr = jnp.stack([
        jnp.einsum("bhd,hed->bhe", h, R["rz"]),
        jnp.einsum("bhd,hed->bhe", h, R["ri"]),
        jnp.einsum("bhd,hed->bhe", h, R["rf"]),
        jnp.einsum("bhd,hed->bhe", h, R["ro"]),
    ], axis=-1)
    return gx_t + hr


def _fwd_scan(R, gates, init, dtype):
    def step(carry, gx_t):
        c, n, m, h = carry
        pres = _pres(R, gx_t, h)
        c2, n2, m2, h2f = _step_core(pres, (c, n, m))
        h2 = h2f.astype(dtype)
        return (c2, n2, m2, h2), (h, c, n, m)  # save PREV h and states

    (cf, nf, mf, hf), saved = jax.lax.scan(step, init, gates)
    return (cf, nf, mf, hf), saved


@jax.custom_vjp
def slstm_scan(R, gates, init):
    """R: {rz,ri,rf,ro} each (H,D,D); gates: (S,B,H,D,4) pre-activations
    from x; init: (c,n,m,h).  Returns (final_carry, h_seq (S,B,H,D))."""
    dtype = init[3].dtype

    def step(carry, gx_t):
        c, n, m, h = carry
        pres = _pres(R, gx_t, h)
        c2, n2, m2, h2f = _step_core(pres, (c, n, m))
        h2 = h2f.astype(dtype)
        return (c2, n2, m2, h2), h2

    final, hs = jax.lax.scan(step, init, gates)
    return final, hs


def _slstm_fwd(R, gates, init):
    dtype = init[3].dtype
    final, saved = _fwd_scan(R, gates, init, dtype)
    h_prev_seq = saved[0]
    # keep only h_prev sequence; recompute (c,n,m) in bwd (elementwise)
    hs = jnp.concatenate([h_prev_seq[1:], final[3][None]], axis=0)
    return (final, hs), (R, gates, init, h_prev_seq)


def _slstm_bwd(res, cot):
    R, gates, init, h_prev_seq = res
    dtype = init[3].dtype
    (dcf, dnf, dmf, dhf), dhs = cot
    # recompute prev-state sequences (cheap elementwise scan)
    _, saved = _fwd_scan(R, gates, init, dtype)
    _, c_prev_seq, n_prev_seq, m_prev_seq = saved

    def rev_step(carry, xs):
        dc, dn, dm, dh = carry
        gx_t, hp, cp, np_, mp, dh_out = xs

        def f(pres, prev):
            return _step_core(pres, prev)

        pres = _pres(R, gx_t, hp)
        _, vjp = jax.vjp(f, pres, (cp, np_, mp))
        dh_total = dh + dh_out.astype(jnp.float32)
        dpres, (dcp, dnp, dmp) = vjp((dc, dn, dm, dh_total))
        # dh_prev: through pres = gx + h @ R
        dp32 = dpres.astype(jnp.float32)
        dhp = (jnp.einsum("bhe,hed->bhd", dp32[..., 0], R["rz"].astype(jnp.float32))
               + jnp.einsum("bhe,hed->bhd", dp32[..., 1], R["ri"].astype(jnp.float32))
               + jnp.einsum("bhe,hed->bhd", dp32[..., 2], R["rf"].astype(jnp.float32))
               + jnp.einsum("bhe,hed->bhd", dp32[..., 3], R["ro"].astype(jnp.float32)))
        return (dcp, dnp, dmp, dhp), dpres

    xs = (gates, h_prev_seq, c_prev_seq, n_prev_seq, m_prev_seq, dhs)
    init_carry = (dcf, dnf, dmf, dhf.astype(jnp.float32))
    (dc0, dn0, dm0, dh0), dpres_seq = jax.lax.scan(
        rev_step, init_carry, xs, reverse=True)
    # ---- the point of this file: ONE einsum (=> one all-reduce) for dR
    hp32 = h_prev_seq.astype(jnp.float32)
    dp32 = dpres_seq.astype(jnp.float32)
    dR = {
        "rz": jnp.einsum("sbhd,sbhe->hed", hp32, dp32[..., 0]).astype(R["rz"].dtype),
        "ri": jnp.einsum("sbhd,sbhe->hed", hp32, dp32[..., 1]).astype(R["ri"].dtype),
        "rf": jnp.einsum("sbhd,sbhe->hed", hp32, dp32[..., 2]).astype(R["rf"].dtype),
        "ro": jnp.einsum("sbhd,sbhe->hed", hp32, dp32[..., 3]).astype(R["ro"].dtype),
    }
    dgates = dpres_seq.astype(gates.dtype)
    dinit = (dc0, dn0, dm0, dh0.astype(dtype))
    return dR, dgates, dinit


slstm_scan.defvjp(_slstm_fwd, _slstm_bwd)
