"""Mixture-of-Experts FFN: top-k routing with sort-based, capacity-bounded
dispatch (megablocks-lite style — no (T, E, C) one-hot dispatch tensor, so
it lowers cheaply at 128-expert scale) and a load-balance aux loss.

Experts are sharded over the "experts" logical axis (expert parallelism);
the token gather/scatter across that axis lowers to all-to-all-like
collectives under pjit.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import rmsnorm_spec
from .params import P
from ..parallelism.context import shard


def moe_spec(cfg: ModelConfig):
    m = cfg.moe
    d, e, f = cfg.d_model, m.num_experts, m.d_ff_expert
    return {
        "norm": rmsnorm_spec(d),
        "router": P((d, e), ("embed", None), scale=0.1),
        "wi_gate": P((e, d, f), ("experts", "embed", "ffn")),
        "wi_up": P((e, d, f), ("experts", "embed", "ffn")),
        "wo": P((e, f, d), ("experts", "ffn", "embed")),
    }


def moe_capacity(cfg: ModelConfig, tokens_per_row: int) -> int:
    m = cfg.moe
    cap = int(math.ceil(tokens_per_row * m.top_k * m.capacity_factor
                        / m.num_experts))
    return max(4, (cap + 3) // 4 * 4)


def _route_row(p, xrow, cfg: ModelConfig, cap: int):
    """Sort-based capacity dispatch for ONE batch row.  xrow: (S, d).

    Per-row routing keeps the dispatch local to the data shard under
    vmap+pjit (no global sort across the sharded batch dim)."""
    m = cfg.moe
    s, d = xrow.shape
    k, e = m.top_k, m.num_experts
    logits = (xrow @ p["router"]).astype(jnp.float32)        # (S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, k)                 # (S, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (Switch-style)
    density = jnp.mean(
        jax.nn.one_hot(top_idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(density * jnp.mean(probs, axis=0)) * m.router_aux_weight

    flat_eid = top_idx.reshape(-1)                           # (S*k,)
    flat_tok = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_eid)
    s_tok, s_w = flat_tok[order], flat_w[order]
    group_sizes = jnp.bincount(flat_eid, length=e)           # (E,)
    starts = jnp.cumsum(group_sizes) - group_sizes

    slot = starts[:, None] + jnp.arange(cap)[None, :]        # (E, C)
    valid = jnp.arange(cap)[None, :] < group_sizes[:, None]
    slot = jnp.clip(slot, 0, s * k - 1)
    tok_of_slot = jnp.where(valid, s_tok[slot], 0)           # (E, C)
    w_of_slot = jnp.where(valid, s_w[slot], 0.0)
    xg = jnp.take(xrow, tok_of_slot.reshape(-1), axis=0).reshape(e, cap, d)
    return xg, tok_of_slot, w_of_slot, aux


def moe_ffn(p, x, cfg: ModelConfig):
    """x: (B, S, d) -> (out, aux_loss).  Dispatch is per batch row
    (data-parallel safe); expert matmuls shard over the experts axis
    (expert parallelism -> all-to-all under pjit)."""
    b, s, d = x.shape
    cap = moe_capacity(cfg, s)
    xg, tok_of_slot, w_of_slot, aux = jax.vmap(
        lambda xr: _route_row(p, xr, cfg, cap))(x)           # (B,E,C,d)
    xg = shard(xg, "batch", "experts", None, None)
    g = jax.nn.silu(jnp.einsum("becd,edf->becf", xg, p["wi_gate"]))
    u = jnp.einsum("becd,edf->becf", xg, p["wi_up"])
    y = jnp.einsum("becf,efd->becd", g * u, p["wo"])         # (B,E,C,d)
    y = shard(y, "batch", "experts", None, None)
    y = y * w_of_slot[..., None].astype(y.dtype)

    def combine_row(yr, tok):
        return jnp.zeros((s, d), yr.dtype).at[tok.reshape(-1)].add(
            yr.reshape(-1, d), mode="drop")

    out = jax.vmap(combine_row)(y, tok_of_slot)
    return out, jnp.mean(aux)
