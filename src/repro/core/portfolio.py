"""Solver portfolio: pluggable backends racing under one wall budget.

ROADMAP item 3 (and the opmed ADR-001 in SNIPPETS.md) calls for an
interval-variable solver next to the time-indexed MILP, raced per
replan — first engine to reach the gap target wins.  This module is
that seam:

- :class:`SolverBackend` is the protocol extracted from the
  ``solve_joint`` / ``solve_joint_classes`` call shape: jobs + per-job
  ``Choice`` lists + per-pool budgets + ``reserved=`` capacity triples
  + ``objective=`` in, a :class:`~repro.core.solver.Solution` (Schedule
  IR via ``to_schedule()``) with telemetry ``{backend, wall_s, gap,
  status}`` out.
- :class:`MilpRefinedBackend` wraps the existing coarse-to-fine
  time-indexed MILP; :class:`LnsBackend` wraps the interval-time LNS
  (:mod:`repro.core.lns`).
- :class:`CpSatBackend` is the OR-Tools CP-SAT interval-variable
  formulation, registered ONLY when ``ortools`` imports: the package
  cannot be installed in this environment (no network wheel), so it is
  an optional slot, never a dependency — the LNS delivers the
  interval-time representation with pure numpy.
- :func:`solve_portfolio` races backends in threads under a shared
  wall budget against the area/critical-path lower bound
  (:func:`makespan_lower_bound`): the first backend whose incumbent
  closes to ``gap_target`` wins and the rest are signalled to stop;
  otherwise the best incumbent at the deadline wins (deterministic
  tie-break on backend order).

scipy's HiGHS holds the GIL for the whole branch-and-bound, so inside
a race the MILP backend solves in a forked child process (see
:class:`MilpRefinedBackend`) — the LNS thread runs unstarved and a
losing MILP is actually killed, not abandoned.  Callers that measure
wall time back-to-back (the solver bench) still call
:func:`join_stragglers` between measurements to drain the watcher
threads.
"""
from __future__ import annotations

import dataclasses
import math
import multiprocessing
import threading
import time
import warnings
from typing import Dict, Iterable, List, Optional, Tuple, Union

from .job import Job
from .lns import lns_solve
from .solver import (Assignment, Choice, OBJECTIVES, Solution,
                     _solve_refined, greedy_schedule, objective_value)

try:                                   # optional: see module docstring
    from ortools.sat.python import cp_model
    HAVE_ORTOOLS = True
except Exception:                      # pragma: no cover - not installed
    cp_model = None
    HAVE_ORTOOLS = False


def makespan_lower_bound(jobs: List[Job],
                         choice_map: Dict[str, List[Choice]],
                         budgets: Dict[Optional[str], int]) -> float:
    """A valid makespan lower bound: max of the critical job (every job
    needs at least its fastest runtime) and the GPU-area bound (total
    minimum GPU-seconds over total capacity).  Reservations are ignored
    — they only shrink capacity, so this stays a true lower bound."""
    if not jobs:
        return 0.0
    t_min = max(min(c.runtime_s for c in choice_map[j.name])
                for j in jobs)
    area = sum(min(c.n_gpus * c.runtime_s for c in choice_map[j.name])
               for j in jobs)
    cap = max(sum(budgets.values()), 1)
    return max(t_min, area / cap)


class SolverBackend:
    """One engine in the portfolio.  Subclasses implement :meth:`solve`
    with the shared call shape; ``name`` keys the registry and the
    telemetry's ``backend`` field."""

    name = "base"

    def solve(self, jobs: List[Job],
              choice_map: Dict[str, List[Choice]],
              budgets: Dict[Optional[str], int], *,
              reserved: Iterable[Tuple] = (),
              objective: str = "makespan",
              time_limit_s: float = 10.0,
              gap_target: float = 0.05,
              seed: int = 0,
              warm_starts: Optional[Dict[str, float]] = None,
              incumbent: Optional[List[Assignment]] = None,
              lower_bound: Optional[float] = None,
              stop=None) -> Solution:
        raise NotImplementedError


SOLVER_BACKENDS: Dict[str, type] = {}


def register_backend(cls):
    """Class decorator: make a backend addressable by name in
    :func:`solve_portfolio`'s ``backends=`` list."""
    SOLVER_BACKENDS[cls.name] = cls
    return cls


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(SOLVER_BACKENDS))


def _milp_entry(payload: tuple) -> Solution:
    """The actual MILP solve — shared by the in-process and child-process
    paths, so both are bit-identical."""
    (jobs, choice_map, budgets, ub, n_slots, coarse_slots, time_limit_s,
     gap, objective, reserved, warm_starts) = payload
    if warm_starts:
        from .solver import _solve_time_indexed
        horizon = max(ub.makespan_s, 1e-6) * 1.05
        return _solve_time_indexed(
            jobs, choice_map, budgets, ub, "milp", n_slots=n_slots,
            time_limit_s=time_limit_s, mip_gap=gap, horizon=horizon,
            start_windows=warm_starts, window_pad_s=horizon / 8.0,
            reserved=reserved, objective=objective)
    return _solve_refined(
        jobs, choice_map, budgets, ub, "milp", n_slots=n_slots,
        coarse_slots=coarse_slots, time_limit_s=time_limit_s,
        mip_gap=gap, objective=objective, reserved=reserved)


def _milp_child(conn, payload) -> None:    # pragma: no cover - subprocess
    try:
        conn.send(("ok", _milp_entry(payload)))
    except Exception as e:
        conn.send(("err", repr(e)))
    finally:
        conn.close()


@register_backend
class MilpRefinedBackend(SolverBackend):
    """The existing coarse-to-fine time-indexed MILP as a portfolio
    engine.  ``warm_starts`` (job -> previous planned start) switches to
    the windowed single-grid solve the incremental replan uses.

    scipy's HiGHS wrapper holds the GIL for the whole branch-and-bound
    (measured: a 1 ms-sleep spinner thread gets ~3 ticks/s next to a
    grinding solve), so racing it in a thread would starve the LNS.
    When a ``stop`` event is supplied (i.e. inside a race) the solve
    runs in a forked child process instead: the GIL is uncontended and
    the race can actually *cancel* the MILP the moment another backend
    wins.  Direct calls (``stop=None``) solve in-process — no fork
    overhead, same answer (:func:`_milp_entry` is shared)."""

    name = "milp"

    def __init__(self, n_slots: int = 24, coarse_slots: int = 8):
        self.n_slots = n_slots
        self.coarse_slots = coarse_slots

    def solve(self, jobs, choice_map, budgets, *, reserved=(),
              objective="makespan", time_limit_s=10.0, gap_target=0.05,
              seed=0, warm_starts=None, incumbent=None,
              lower_bound=None, stop=None) -> Solution:
        t0 = time.perf_counter()
        reserved = list(reserved)
        ub = greedy_schedule(jobs, choice_map, budgets,
                             reserved=reserved, objective=objective)
        payload = (jobs, choice_map, budgets, ub, self.n_slots,
                   self.coarse_slots, time_limit_s, gap_target,
                   objective, reserved, warm_starts)
        status = None
        if stop is None:
            sol = _milp_entry(payload)
        else:
            sol, status = self._solve_forked(payload, ub, stop,
                                             t0 + time_limit_s + 5.0)
        sol.telemetry = {"backend": self.name,
                         "wall_s": time.perf_counter() - t0,
                         "gap": None, "status": status
                         or sol.milp_status or sol.solver,
                         "n_jobs": len(jobs)}
        return sol

    @staticmethod
    def _solve_forked(payload, ub: Solution, stop,
                      deadline: float) -> Tuple[Solution, Optional[str]]:
        """Run :func:`_milp_entry` in a forked child; fall back to the
        greedy bound if stopped/killed, to in-process if fork is
        unavailable (non-Linux)."""
        try:
            ctx = multiprocessing.get_context("fork")
        except ValueError:              # pragma: no cover - non-Linux
            return _milp_entry(payload), None
        parent, child = ctx.Pipe(duplex=False)
        proc = ctx.Process(target=_milp_child, args=(child, payload),
                           daemon=True)
        with warnings.catch_warnings():
            # JAX registers an at-fork hook that warns "os.fork() is
            # incompatible with multithreaded code" whenever it has been
            # imported (the launch layer imports it; this module never
            # does).  The warning does not apply here: the child runs
            # only numpy/scipy (_milp_entry) and never calls into
            # JAX/XLA, so its runtime threads' lock state is irrelevant.
            warnings.filterwarnings(
                "ignore", message=r".*os\.fork\(\).*",
                category=RuntimeWarning)
            proc.start()
        child.close()
        try:
            while True:
                if parent.poll(0.1):
                    try:
                        tag, obj = parent.recv()
                    except EOFError:    # child died without sending
                        return ub, "error"
                    return (obj, None) if tag == "ok" else (ub, "error")
                if stop.is_set():
                    proc.terminate()
                    return ub, "stopped"
                if time.perf_counter() > deadline:
                    proc.terminate()
                    return ub, "timeout"
        finally:
            parent.close()
            proc.join(1.0)
            if proc.is_alive():         # pragma: no cover
                proc.kill()
                proc.join(1.0)


@register_backend
class LnsBackend(SolverBackend):
    """The interval-time LNS (:func:`repro.core.lns.lns_solve`) as a
    portfolio engine.  ``incumbent`` seeds the search with the previous
    plan; ``stop`` aborts between iterations when another backend wins."""

    name = "lns"

    def __init__(self, max_iters: Optional[int] = None):
        self.max_iters = max_iters

    def solve(self, jobs, choice_map, budgets, *, reserved=(),
              objective="makespan", time_limit_s=10.0, gap_target=0.05,
              seed=0, warm_starts=None, incumbent=None,
              lower_bound=None, stop=None) -> Solution:
        return lns_solve(jobs, choice_map, budgets, reserved=reserved,
                         objective=objective, deadline_s=time_limit_s,
                         max_iters=self.max_iters, seed=seed,
                         incumbent=incumbent, gap_target=gap_target,
                         lower_bound=lower_bound, stop=stop)


class CpSatBackend(SolverBackend):
    """OR-Tools CP-SAT interval-variable formulation (opmed ADR-001):
    one optional interval per (job, choice) + ``AddCumulative`` per
    budget pool — no slot grid, exact integer starts at ``_SCALE``
    resolution.  Registered only when ``ortools`` imports; this
    environment cannot install it, so the class is exercised by CI only
    as a guarded-import skip (see tests/test_portfolio.py)."""

    name = "cpsat"
    _SCALE = 100          # integer time unit = 10 ms

    def solve(self, jobs, choice_map, budgets, *, reserved=(),
              objective="makespan", time_limit_s=10.0, gap_target=0.05,
              seed=0, warm_starts=None, incumbent=None,
              lower_bound=None, stop=None) -> Solution:
        if cp_model is None:            # pragma: no cover
            raise RuntimeError("ortools is not installed; the CP-SAT "
                               "backend is an optional slot")
        t0 = time.perf_counter()
        reserved = list(reserved)
        ub = greedy_schedule(jobs, choice_map, budgets,
                             reserved=reserved, objective=objective)
        horizon = int(math.ceil(max(
            [ub.makespan_s * 1.05] + [r for _, _, r in reserved
                                      if math.isfinite(r)]
        ) * self._SCALE)) + 1
        m = cp_model.CpModel()
        per_pool: Dict[Optional[str], list] = {p: [] for p in budgets}
        ends, lits_of = [], {}
        for j in jobs:
            lits, j_end = [], m.NewIntVar(0, horizon, f"end_{j.name}")
            for ci, c in enumerate(choice_map[j.name]):
                lit = m.NewBoolVar(f"x_{j.name}_{ci}")
                dur = max(1, int(round(c.runtime_s * self._SCALE)))
                s = m.NewIntVar(0, horizon, f"s_{j.name}_{ci}")
                iv = m.NewOptionalIntervalVar(
                    s, dur, s + dur, lit, f"iv_{j.name}_{ci}")
                pool = c.device_class if c.device_class in budgets \
                    else None
                per_pool[pool].append((iv, c.n_gpus))
                m.Add(j_end == s + dur).OnlyEnforceIf(lit)
                lits.append(lit)
            m.AddExactlyOne(lits)
            lits_of[j.name] = lits
            ends.append((j, j_end))
        for dc, g, release_s in reserved:
            pool = dc if dc in budgets else None
            until = horizon if not math.isfinite(release_s) \
                else max(1, int(round(release_s * self._SCALE)))
            per_pool[pool].append(
                (m.NewIntervalVar(0, until, until, f"res_{dc}_{g}"),
                 int(g)))
        for pool, ivs in per_pool.items():
            if ivs:
                m.AddCumulative([iv for iv, _ in ivs],
                                [g for _, g in ivs], budgets[pool])
        if objective in ("makespan", "fair_share"):
            M = m.NewIntVar(0, horizon, "M")
            if objective == "makespan":
                m.AddMaxEquality(M, [e for _, e in ends])
            else:
                per_ten: Dict[str, list] = {}
                for j, e in ends:
                    per_ten.setdefault(
                        getattr(j, "tenant", "default"), []).append(e)
                for es in per_ten.values():
                    m.Add(M * len(es) >= sum(es))
            m.Minimize(M)
        elif objective == "weighted_completion":
            m.Minimize(sum(int(round(getattr(j, "weight", 1.0) * 1000))
                           * e for j, e in ends))
        else:   # tardiness
            lates = []
            for j, e in ends:
                dl = getattr(j, "deadline_s", None)
                if dl is None:
                    continue
                late = m.NewIntVar(0, horizon, f"late_{j.name}")
                m.Add(late >= e - int(round(dl * self._SCALE)))
                lates.append(
                    int(round(getattr(j, "weight", 1.0) * 1000)) * late)
            m.Minimize(sum(lates) if lates else 0)
        solver = cp_model.CpSolver()
        solver.parameters.max_time_in_seconds = time_limit_s
        solver.parameters.relative_gap_limit = gap_target
        solver.parameters.random_seed = seed
        status = solver.Solve(m)
        if status not in (cp_model.OPTIMAL, cp_model.FEASIBLE):
            ub.telemetry = {"backend": self.name,
                            "wall_s": time.perf_counter() - t0,
                            "gap": None, "status": "infeasible",
                            "n_jobs": len(jobs)}
            return ub
        assignments = []
        for j in jobs:
            for ci, lit in enumerate(lits_of[j.name]):
                if solver.Value(lit):
                    c = choice_map[j.name][ci]
                    end = solver.Value(
                        [e for jj, e in ends if jj is j][0])
                    dur = max(1, int(round(c.runtime_s * self._SCALE)))
                    assignments.append(Assignment(
                        j.name, c.technique, c.n_gpus,
                        (end - dur) / self._SCALE, c.runtime_s,
                        device_class=c.device_class))
                    break
        mk = max(a.end_s for a in assignments)
        sol = Solution(assignments, mk, "cpsat",
                       milp_status=solver.StatusName(status))
        sol.telemetry = {"backend": self.name,
                         "wall_s": time.perf_counter() - t0,
                         "gap": None,
                         "status": solver.StatusName(status),
                         "n_jobs": len(jobs)}
        return sol


if HAVE_ORTOOLS:                       # pragma: no cover - optional dep
    register_backend(CpSatBackend)


# threads abandoned by an early-exiting race (HiGHS cannot be stopped
# mid-solve); join_stragglers() drains them before wall-sensitive work
_STRAGGLERS: List[threading.Thread] = []
_STRAGGLERS_LOCK = threading.Lock()


def join_stragglers(timeout: Optional[float] = None) -> None:
    """Wait for backend threads a finished race left running (bench
    hygiene: a grinding MILP thread would pollute the next tier's wall
    clock)."""
    with _STRAGGLERS_LOCK:
        pending, _STRAGGLERS[:] = _STRAGGLERS[:], []
    for t in pending:
        t.join(timeout)
        if t.is_alive():                # pragma: no cover
            with _STRAGGLERS_LOCK:
                _STRAGGLERS.append(t)


def solve_portfolio(jobs: List[Job],
                    choice_map: Dict[str, List[Choice]],
                    budgets: Dict[Optional[str], int], *,
                    reserved: Iterable[Tuple] = (),
                    objective: str = "makespan",
                    wall_budget_s: float = 10.0,
                    gap_target: float = 0.05,
                    seed: int = 0,
                    warm_starts: Optional[Dict[str, float]] = None,
                    incumbent: Optional[List[Assignment]] = None,
                    backends: Iterable[Union[str, SolverBackend]]
                    = ("milp", "lns")) -> Solution:
    """Race solver backends in threads under a shared wall budget.

    Every backend gets the full problem (jobs, choices, budgets,
    ``reserved`` triples, objective) plus the shared lower bound and a
    stop signal.  The first backend whose result closes to
    ``gap_target`` of :func:`makespan_lower_bound` wins immediately
    (the others are told to stop); otherwise the best finished incumbent
    under ``objective`` at the deadline wins, ties broken by backend
    order.  Falls back to the greedy bound if every backend errors.

    Returns the winning Solution renamed ``portfolio[<solver>]`` with
    ``telemetry = {backend, wall_s, gap, status, n_jobs, engines}``
    where ``engines`` holds each finisher's own telemetry.
    """
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"expected one of {OBJECTIVES}")
    t0 = time.perf_counter()
    reserved = list(reserved)
    if not jobs:
        return Solution([], 0.0, "portfolio[empty]",
                        telemetry={"backend": "none", "wall_s": 0.0,
                                   "gap": None, "status": "empty",
                                   "n_jobs": 0, "engines": {}})
    bes: List[SolverBackend] = []
    for b in backends:
        bes.append(SOLVER_BACKENDS[b]() if isinstance(b, str) else b)
    lb = makespan_lower_bound(jobs, choice_map, budgets) \
        if objective == "makespan" else None

    def gap_of(val: float) -> Optional[float]:
        if lb is None:
            return None
        return max(0.0, val - lb) / max(val, 1e-9)

    stop = threading.Event()
    done = threading.Condition()
    results: Dict[str, Solution] = {}
    failed: List[str] = []
    winner: List[str] = []

    def run(be: SolverBackend) -> None:
        try:
            sol = be.solve(jobs, choice_map, budgets, reserved=reserved,
                           objective=objective,
                           time_limit_s=wall_budget_s,
                           gap_target=gap_target, seed=seed,
                           warm_starts=warm_starts, incumbent=incumbent,
                           lower_bound=lb, stop=stop)
        except Exception:
            sol = None
        with done:
            if sol is None:
                failed.append(be.name)
            else:
                results[be.name] = sol
                g = gap_of(objective_value(sol.assignments, jobs,
                                           objective))
                if g is not None and g <= gap_target + 1e-12 \
                        and not winner:
                    winner.append(be.name)
                    stop.set()
            done.notify_all()

    threads = [threading.Thread(target=run, args=(be,), daemon=True,
                                name=f"portfolio-{be.name}")
               for be in bes]
    for t in threads:
        t.start()
    deadline = t0 + wall_budget_s + 2.0     # grace for thread overhead
    with done:
        while not winner and len(results) + len(failed) < len(bes):
            left = deadline - time.perf_counter()
            if left <= 0:
                break
            done.wait(timeout=min(left, 0.2))
    stop.set()
    with _STRAGGLERS_LOCK:
        _STRAGGLERS.extend(t for t in threads if t.is_alive())

    with done:
        got = dict(results)
    if not got:         # every backend failed or overran: greedy bound
        sol = greedy_schedule(jobs, choice_map, budgets,
                              reserved=reserved, objective=objective)
        got = {"greedy": sol}
    order = {be.name: i for i, be in enumerate(bes)}
    vals = {name: objective_value(s.assignments, jobs, objective)
            for name, s in got.items()}
    if winner:
        pick = winner[0]
    else:
        pick = min(got, key=lambda n: (vals[n], order.get(n, 99)))
    sol = got[pick]
    wall = time.perf_counter() - t0
    engines = {name: (s.telemetry or {"backend": name})
               for name, s in got.items()}
    tel = {"backend": pick, "wall_s": wall, "gap": gap_of(vals[pick]),
           "status": "gap_target" if winner else "deadline",
           "n_jobs": len(jobs), "engines": engines}
    out = dataclasses.replace(sol, solver=f"portfolio[{sol.solver}]")
    out.telemetry = tel
    return out
