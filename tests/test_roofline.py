"""Roofline profiling strategy: calibrated predictions must honour
every contract the real-trial strategies already hold (PerfModel keys,
class-qualified Profiles, cache versioning, ObservedProfiles overlay)
while spending only the calibration trials."""
import json
import math

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.job import DeviceClass, Job
from repro.core.library import ParallelismLibrary
from repro.core.perfmodel import ObservedProfiles, PerfModel
from repro.core.profiler import (CACHE_VERSION, HARDWARE, PROFILE_STRATEGIES,
                                 ClassCalibration, TrialRunner,
                                 fit_calibration)

CFG = get_config("xlstm-125m")


def _jobs(n=2):
    return [Job(name=f"j{i}", cfg=CFG, batch_size=16 * (i + 1),
                seq_len=512, total_steps=100, lr=1e-4, seed=i)
            for i in range(n)]


def _runner(**kw):
    return TrialRunner(ParallelismLibrary(), HARDWARE["a100"], **kw)


COUNTS = list(range(1, 17))


def test_roofline_returns_perfmodel_with_full_coverage():
    r = _runner()
    pm = r.profile_all(_jobs(), COUNTS, mode="napkin", strategy="roofline")
    assert isinstance(pm, PerfModel)
    ex = _runner().profile_all(_jobs(), COUNTS, mode="napkin",
                               strategy="exhaustive")
    assert set(pm) == set(ex)
    for key, p in ex.items():
        pr = pm[key]
        assert pr.feasible == p.feasible
        assert pr.n_devices == p.n_devices
        assert pr.device_class == p.device_class


def test_roofline_spends_only_calibration_trials():
    r = _runner()
    r.profile_all(_jobs(), COUNTS, mode="napkin", strategy="roofline",
                  calibration_trials=2)
    assert r.trials == 2 + r.roofline_stats["escalated"]
    assert r.roofline_stats["calibration_trials"] == 2
    assert r.roofline_stats["predicted"] > 20 * r.trials


def test_roofline_prediction_accuracy_vs_exhaustive():
    r = _runner()
    pm = r.profile_all(_jobs(), COUNTS, mode="napkin", strategy="roofline")
    ex = _runner().profile_all(_jobs(), COUNTS, mode="napkin",
                               strategy="exhaustive")
    errs = [abs(pm[k].step_time_s - p.step_time_s) / p.step_time_s
            for k, p in ex.items()
            if p.feasible and math.isfinite(p.step_time_s)]
    assert float(np.median(errs)) <= 0.15


def test_roofline_profiles_are_marked_and_real_anchors_tracked():
    r = _runner()
    pm = r.profile_all(_jobs(), COUNTS, mode="napkin", strategy="roofline")
    sources = {pm[k].source for k in pm}
    assert "roofline" in sources
    real = pm.real_anchor_keys()
    # exactly the calibration (and escalation) trials are real anchors
    assert len(real) == r.trials
    for key in real:
        assert pm[key].source != "roofline"
    predicted = [k for k in pm if pm[k].source == "roofline"]
    assert predicted and all(
        0.0 <= pm[k].terms["confidence"] <= 1.0 for k in predicted)


def test_confidence_threshold_one_escalates_everything():
    r = _runner()
    jobs = _jobs(1)
    r.profile_all(jobs, [1, 2, 4], mode="napkin", strategy="roofline",
                  confidence_threshold=1.1)
    assert r.roofline_stats["predicted"] == 0
    ex = _runner().profile_all(jobs, [1, 2, 4], mode="napkin",
                               strategy="exhaustive")
    assert r.trials == len(ex)


def test_roofline_hetero_keys_and_per_class_calibration():
    classes = [DeviceClass("a100", nodes=1, gpus_per_node=8),
               DeviceClass("v100", nodes=1, gpus_per_node=8,
                           hbm_per_gpu=16e9, speed_hint=0.5)]
    r = _runner()
    pm = r.profile_all(_jobs(1), list(range(1, 9)), mode="napkin",
                       strategy="roofline", classes=classes)
    key = next(iter(pm))
    assert len(key) == 4 and key[2] in ("a100", "v100")
    assert set(r.calibration) == {"a100", "v100"}
    # the slower class must predict slower steps at the same combo
    fast = pm[("j0", "ddp", "a100", 4)]
    slow = pm[("j0", "ddp", "v100", 4)]
    assert slow.step_time_s > fast.step_time_s


def test_calibration_persists_and_skips_trials_on_reload(tmp_path):
    path = str(tmp_path / "profiles.json")
    r1 = _runner(cache_path=path)
    r1.profile_all(_jobs(1), COUNTS, mode="napkin", strategy="roofline")
    assert r1.trials > 0
    data = json.loads(open(path).read())
    assert data["version"] == CACHE_VERSION
    assert "default" in data["calibration"]
    # a fresh runner loads the fit AND the cached real profiles: zero
    # new trials on a different workload of the same class
    r2 = _runner(cache_path=path)
    assert "default" in r2.calibration
    jobs2 = [Job(name="other", cfg=CFG, batch_size=8, seq_len=256,
                 total_steps=50, lr=1e-3, seed=9)]
    r2.profile_all(jobs2, COUNTS, mode="napkin", strategy="roofline")
    assert r2.trials == r2.roofline_stats["escalated"]
    assert r2.roofline_stats["calibration_trials"] == 0


def test_old_cache_version_discarded(tmp_path):
    path = str(tmp_path / "profiles.json")
    with open(path, "w") as f:
        json.dump({"version": CACHE_VERSION - 1, "profiles": [
            {"job": "j0", "technique": "ddp", "n_devices": 1,
             "step_time_s": 1.0, "mem_per_device": 1.0, "feasible": True,
             "source": "napkin"}],
            "calibration": {"default": {
                "device_class": "default", "coef": [1, 1, 1],
                "n_points": 2, "residual": 0.0, "mode": "napkin"}}}, f)
    r = _runner(cache_path=path)
    assert not r._cache and not r.calibration


def test_calibration_roundtrip_json():
    c = ClassCalibration("a100", (0.9, 1.1, 1.0), 3, 0.05, "napkin")
    c2 = ClassCalibration.from_json(c.to_json())
    assert c2 == c
    assert c2.predict((1.0, 0.0, 0.0)) == pytest.approx(0.9)


def test_fit_calibration_scalar_and_lstsq():
    # 2 points -> scalar fit recovers a global efficiency factor
    pts = [((1.0, 0.5, 0.1), 0.8 * 1.6), ((2.0, 1.0, 0.2), 0.8 * 3.2)]
    c = fit_calibration("default", pts, "napkin")
    assert c.coef[0] == pytest.approx(0.8, rel=1e-6)
    assert c.residual < 1e-9
    # >=4 points -> full least squares recovers distinct coefficients
    rng = np.random.default_rng(0)
    true = np.array([0.7, 1.3, 2.0])
    feats = rng.uniform(0.1, 2.0, size=(8, 3))
    pts = [(tuple(f), float(f @ true)) for f in feats]
    c = fit_calibration("default", pts, "napkin")
    np.testing.assert_allclose(c.coef, true, rtol=1e-6)


def test_observed_overlay_overrides_roofline():
    pm = _runner().profile_all(_jobs(1), COUNTS, mode="napkin",
                               strategy="roofline")
    key = next(k for k in pm if pm[k].source == "roofline")
    obs = ObservedProfiles(pm, {key: 123.0})
    assert obs[key].step_time_s == 123.0
    assert obs[key].source == "observed"
    other = next(k for k in pm if k != key)
    assert obs[other] == pm[other]


def test_unknown_strategy_names_all_strategies():
    with pytest.raises(ValueError) as e:
        _runner().profile_all(_jobs(1), [1, 2], strategy="nope")
    for s in PROFILE_STRATEGIES:
        assert s in str(e.value)


def test_unknown_device_class_raises():
    with pytest.raises(ValueError, match="unknown device class"):
        _runner()._class_hw("h900")


def test_roofline_analytic_mode_uses_compiled_hlo():
    """With a real (reduced) model the features must come from actual
    lowered HLO, not the napkin closed form."""
    cfg = CFG.reduced()
    job = Job(name="tiny", cfg=cfg, batch_size=4, seq_len=32,
              total_steps=10, lr=1e-4, seed=0)
    r = _runner()
    pm = r.profile_all([job], [1, 2], mode="analytic",
                       strategy="roofline", calibration_trials=1,
                       confidence_threshold=0.0)
    preds = [pm[k] for k in pm if pm[k].source == "roofline"]
    assert preds, "expected at least one roofline prediction"
    # techniques hostable at n=1 scale from a real n=1 compile; the
    # rest (fsdp/tp need n>=2, beyond this 1-device pool) legitimately
    # fall back to closed-form terms
    hlo_backed = [p for p in preds
                  if p.technique in ("ddp", "remat-offload")]
    assert hlo_backed
    assert all(p.terms.get("hlo_base_n") == 1.0 for p in hlo_backed)
    assert all(p.step_time_s > 0 and math.isfinite(p.step_time_s)
               for p in preds)


def test_compile_memoized_across_counts():
    """One lowering per (job-shape, technique, mesh): profiling the same
    combo twice must not grow the compile cache."""
    cfg = CFG.reduced()
    job = Job(name="tiny", cfg=cfg, batch_size=4, seq_len=32,
              total_steps=10, lr=1e-4, seed=0)
    r = _runner()
    r.profile_all([job], [1], mode="analytic", strategy="roofline",
                  confidence_threshold=0.0)
    n = len(r._compile_cache)
    assert n >= 1
    r.profile_all([job], [1], mode="analytic", strategy="roofline",
                  confidence_threshold=0.0)
    assert len(r._compile_cache) == n
