"""npz-based pytree checkpoint store.

Used by Saturn's introspection mechanism (checkpoint + relaunch when the
solver produces a new plan) and by the end-to-end training examples.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub" or arr.dtype.itemsize == 0 or \
                str(arr.dtype) == "bfloat16":
            arr = np.asarray(leaf, dtype=np.float32)  # bf16 etc: lossless up
        out[key] = arr
    return out


def save_checkpoint(path: str, tree: Any, metadata: Optional[dict] = None):
    """Atomic save of a pytree (+ JSON metadata) to ``path`` (.npz)."""
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    arrays = _flatten_with_paths(tree)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".npz.tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    if metadata is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(metadata, f)


def load_checkpoint(path: str, like: Any):
    """Restore into the structure of ``like`` (a pytree template)."""
    with np.load(path) as data:
        arrays = dict(data)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = "/".join(
            str(x.key) if hasattr(x, "key") else str(x.idx) for x in p)
        arr = arrays[key]
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_metadata(path: str) -> Optional[dict]:
    meta = path + ".meta.json"
    if os.path.exists(meta):
        with open(meta) as f:
            return json.load(f)
    return None


def load_training_state(path: str, params: Any, opt: Any):
    """Resume helper: restore ``(params, opt, start_step)`` from
    ``path`` if a checkpoint exists there (the step count comes from
    the metadata sidecar), else return the inputs unchanged at step 0.

    This is the single source of truth for the resume contract shared
    by ``LocalRunner.run_job`` and the LocalJaxBackend workers — the
    caller seeds fresh state, then continues from wherever the last
    run (or a preemption) checkpointed.
    """
    if not os.path.exists(path):
        return params, opt, 0
    meta = load_metadata(path) or {}
    state = load_checkpoint(path, {"params": params, "opt": opt})
    return state["params"], state["opt"], int(meta.get("step", 0))
