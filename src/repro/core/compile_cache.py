"""Persistent XLA compilation cache shared by every local execution
path.

BENCH_e2e spends ~17 s of a 39 s quick run recompiling (model,
technique, slice-size) combos that earlier runs already compiled; JAX's
persistent compilation cache keyed on the serialized HLO makes those
recompiles disk hits.  The cache directory is process-global JAX
config, so enabling is first-caller-wins: the TrialRunner keys it under
its profile cache, and the execution backends fall back to a stable
per-user default.
"""
from __future__ import annotations

import os
import threading
from typing import Optional

_lock = threading.Lock()
_enabled_dir: Optional[str] = None


def default_cache_dir() -> str:
    return os.environ.get(
        "SATURN_COMPILE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "saturn", "xla"))


def enable_persistent_compilation_cache(
        cache_dir: Optional[str] = None) -> Optional[str]:
    """Point JAX's persistent compilation cache at ``cache_dir``
    (default: :func:`default_cache_dir`).

    Idempotent and first-caller-wins — the dir is global JAX config and
    retargeting it mid-process would just split the cache.  Returns the
    active directory, or ``None`` when this JAX build has no persistent
    cache support (older versions: silently skipped, never a crash).
    """
    global _enabled_dir
    with _lock:
        if _enabled_dir is not None:
            return _enabled_dir
        import jax
        d = os.path.abspath(cache_dir or default_cache_dir())
        try:
            os.makedirs(d, exist_ok=True)
            jax.config.update("jax_compilation_cache_dir", d)
        except (AttributeError, ValueError, OSError):
            return None
        # Saturn's trial grids are hundreds of small jitted steps, each
        # well under the default 1 s / 0-byte thresholds — cache them all
        for knob, val in (
                ("jax_persistent_cache_min_compile_time_secs", 0.0),
                ("jax_persistent_cache_min_entry_size_bytes", -1)):
            try:
                jax.config.update(knob, val)
            except (AttributeError, ValueError):
                pass
        # JAX initializes its cache object at the FIRST compile; if one
        # already happened (e.g. profiling before the backend binds) the
        # dir update above is dead config until the cache is reset
        try:
            from jax.experimental.compilation_cache import (
                compilation_cache as cc)
            cc.reset_cache()
        except Exception:
            pass
        _enabled_dir = d
        return d


def enabled_dir() -> Optional[str]:
    return _enabled_dir
