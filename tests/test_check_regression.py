"""Unit tests for the CI bench regression gate
(benchmarks/check_regression.py): the relative gate, per-metric
tolerance overrides, absolute ceilings/floors (including on fresh-only
paths), and nested collect() flattening."""
import importlib.util
import json
import os
import sys


_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks", "check_regression.py")
_spec = importlib.util.spec_from_file_location("check_regression", _PATH)
cr = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(cr)


def _run(tmp_path, base, fresh, tolerance=None, monkeypatch=None):
    b = tmp_path / "base.json"
    f = tmp_path / "fresh.json"
    b.write_text(json.dumps(base))
    f.write_text(json.dumps(fresh))
    argv = ["check_regression", "--baseline", str(b), "--fresh", str(f)]
    if tolerance is not None:
        argv += ["--tolerance", str(tolerance)]
    monkeypatch.setattr(sys, "argv", argv)
    return cr.main()


def test_collect_flattens_nested_gated_metrics():
    doc = {"quick": True,
           "scenarios": {"flat": {"saturn_s": 10.0, "bench_wall_s": 3.0},
                         "deep": {"inner": {"current_practice_s": 5.0}}},
           "serve_attainment": 0.995}
    out = cr.collect(doc)
    assert out["scenarios.flat.saturn_s"] == ("saturn_s", 10.0)
    assert out["scenarios.deep.inner.current_practice_s"] == \
        ("current_practice_s", 5.0)
    assert out["serve_attainment"] == ("serve_attainment", 0.995)
    # ungated fields (wall clock, flags) never enter the gate
    assert not any("bench_wall_s" in k or "quick" in k for k in out)


def test_relative_gate_passes_within_tolerance(tmp_path, monkeypatch):
    base = {"s": {"saturn_s": 100.0}}
    assert _run(tmp_path, base, {"s": {"saturn_s": 109.0}},
                monkeypatch=monkeypatch) == 0
    assert _run(tmp_path, base, {"s": {"saturn_s": 112.0}},
                monkeypatch=monkeypatch) == 1
    # improvement is always fine
    assert _run(tmp_path, base, {"s": {"saturn_s": 50.0}},
                monkeypatch=monkeypatch) == 0


def test_missing_fresh_metric_fails(tmp_path, monkeypatch):
    base = {"s": {"saturn_s": 100.0}}
    assert _run(tmp_path, base, {"s": {}}, monkeypatch=monkeypatch) == 1
    # ...but a NEW fresh relative metric does not break the gate
    assert _run(tmp_path, base,
                {"s": {"saturn_s": 100.0, "makespan_aware_s": 1.0}},
                monkeypatch=monkeypatch) == 0


def test_tolerance_override_beats_cli_tolerance(tmp_path, monkeypatch):
    # wall_refined_over_dense has a 150% override: 2.4x the baseline
    # passes even with a tight --tolerance
    base = {"wall_refined_over_dense": 1.0}
    assert _run(tmp_path, base, {"wall_refined_over_dense": 2.4},
                tolerance=0.01, monkeypatch=monkeypatch) == 0
    assert _run(tmp_path, base, {"wall_refined_over_dense": 2.6},
                tolerance=0.01, monkeypatch=monkeypatch) == 1


def test_absolute_ceiling_and_floor(tmp_path, monkeypatch):
    base = {"roofline_err_median": 0.05, "serve_attainment": 1.0}
    ok = {"roofline_err_median": 0.10, "serve_attainment": 0.995}
    assert _run(tmp_path, base, ok, monkeypatch=monkeypatch) == 0
    # the ceiling is absolute: half the baseline's headroom is
    # irrelevant, 0.16 > 0.15 fails
    bad = {"roofline_err_median": 0.16, "serve_attainment": 1.0}
    assert _run(tmp_path, base, bad, monkeypatch=monkeypatch) == 1
    bad = {"roofline_err_median": 0.05, "serve_attainment": 0.98}
    assert _run(tmp_path, base, bad, monkeypatch=monkeypatch) == 1


def test_absolute_gates_apply_to_fresh_only_paths(tmp_path, monkeypatch):
    """A brand-new scenario cannot dodge its fixed floor just because
    the committed baseline predates it."""
    base = {"s": {"saturn_s": 10.0}}
    fresh = {"s": {"saturn_s": 10.0},
             "new_scenario": {"static_over_saturn_x": 1.1}}
    assert _run(tmp_path, base, fresh, monkeypatch=monkeypatch) == 1
    fresh["new_scenario"]["static_over_saturn_x"] = 1.3
    assert _run(tmp_path, base, fresh, monkeypatch=monkeypatch) == 0


def test_empty_baseline_skips(tmp_path, monkeypatch):
    assert _run(tmp_path, {"only": {"bench_wall_s": 1.0}},
                {"s": {"saturn_s": 5.0}}, monkeypatch=monkeypatch) == 0
