"""Quickstart: the Saturn workflow in ~40 lines (paper Fig. 1 API).

    PYTHONPATH=src python examples/quickstart.py

Registers a custom technique, submits a small model-selection workload,
profiles it (Trial Runner), solves the joint MILP, and simulates
execution vs Current Practice.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.core.api import SaturnSession
from repro.core.baselines import CurrentPractice
from repro.core.job import ClusterSpec, hpo_grid
from repro.parallelism.base import Plan, Technique


# -- users can extend the Parallelism Library with the 2-function API
class MyBatchShard(Technique):
    name = "my-batch-shard"

    def search_space(self, cfg, n):          # function 1: validity
        return n in (2, 4)

    def plan(self, cfg, n):                  # function 2: how to execute
        return Plan(self.name, n, (("data", n),), {"batch": "data"})


def main():
    cluster = ClusterSpec(nodes=1, gpus_per_node=8)
    sess = SaturnSession(cluster)
    sess.register_technique(MyBatchShard())

    jobs = hpo_grid(
        [("small-lm", get_config("xlstm-125m")),
         ("big-lm", get_config("h2o-danube-3-4b"))],
        lrs=[1e-4, 1e-3], batch_sizes=[16, 32],
        seq_len=1024, total_steps=1000)
    sess.submit(jobs)

    # Trial Runner: real trials at anchor counts only; the performance
    # model interpolates every other count for the Solver
    sess.profile(mode="analytic", strategy="interpolate")
    base = sess.run(policy=CurrentPractice())
    sat = sess.run()                         # Saturn: joint MILP + introspection

    print(f"\njobs: {len(jobs)}  cluster: {cluster.total_gpus} GPUs")
    print(f"current practice : {base.makespan_s / 3600:.2f} h")
    print(f"saturn           : {sat.makespan_s / 3600:.2f} h "
          f"({100 * (1 - sat.makespan_s / base.makespan_s):.0f}% lower, "
          f"{sat.replans} replans)")
    for a in sorted({(g.job, g.technique, g.n_gpus) for g in sat.gantt
                     if g.kind == 'run'}):
        print(f"  {a[0]:28s} -> {a[1]:>6s} x{a[2]} GPUs")


if __name__ == "__main__":
    main()
