"""Seeded request-traffic generators for serving fleets.

Traces are tuples of request arrival times (seconds over
``[0, duration_s)``) drawn from a nonhomogeneous Poisson process via
thinning, mirroring :func:`repro.core.chaos.poisson_node_failures`: the
candidate stream is generated ONCE at ``max_rps`` and each candidate
survives iff its uniform mark is below ``rate(t) / max_rps``.  Sweeping
the rate under a fixed ``max_rps`` and seed therefore yields NESTED
traces — every request in a lower-rate trace also appears, at the same
timestamp, in every higher-rate one.  That is what lets a load sweep
attribute SLO misses to the traffic level instead of to resampling
noise (and is pinned by tests/test_traffic.py).

Two shapes cover the paper-scale scenarios:

- :func:`diurnal_trace` — a day/night sinusoid around a mean rate, the
  steady-state production pattern fleet autoscaling must track;
- :func:`bursty_trace` — a low base rate punctuated by periodic square
  bursts, the flash-crowd pattern that punishes peak-provisioning.
"""
from __future__ import annotations

import math
import random
from typing import Callable, List, Tuple


def _thinned_arrivals(rate_fn: Callable[[float], float], duration_s: float,
                      max_rps: float, seed: int) -> Tuple[float, ...]:
    """Nonhomogeneous Poisson arrivals on ``[0, duration_s)`` by
    thinning a homogeneous ``max_rps`` stream.  ``rate_fn(t)`` must
    never exceed ``max_rps``."""
    if duration_s <= 0 or max_rps <= 0:
        return ()
    rng = random.Random(seed)
    out: List[float] = []
    t = 0.0
    while True:
        # draw the gap AND the thinning mark unconditionally so the
        # underlying stream is identical across rates (superset property)
        t += rng.expovariate(max_rps)
        keep = rng.random() * max_rps < rate_fn(t)
        if t >= duration_s:
            break
        if keep:
            out.append(t)
    return tuple(out)


def diurnal_trace(mean_rps: float, duration_s: float, *, seed: int = 0,
                  period_s: float = 3600.0, amplitude: float = 0.5,
                  phase: float = 0.0,
                  max_rps: float = None) -> Tuple[float, ...]:
    """Sinusoidal day/night traffic: rate(t) = ``mean_rps`` x
    ``(1 + amplitude * sin(2*pi*t/period_s + phase))``.

    ``max_rps`` is the thinning cap; traces generated with the same
    ``seed`` and ``max_rps`` nest across ``mean_rps`` (superset
    property).  The default cap is the trace's own peak, which keeps a
    single call efficient but opts out of nesting — sweeps must pin the
    cap to the highest rate swept, exactly like the chaos failure
    sweeps.
    """
    if not 0.0 <= amplitude <= 1.0:
        raise ValueError(f"amplitude must be in [0, 1], got {amplitude}")
    if mean_rps < 0:
        raise ValueError("mean_rps must be >= 0")
    peak = mean_rps * (1.0 + amplitude)
    cap = peak if max_rps is None else max_rps
    if peak > cap * (1 + 1e-12):
        raise ValueError(f"peak rate {peak} exceeds max_rps {cap}")
    w = 2.0 * math.pi / period_s

    def rate(t: float) -> float:
        return mean_rps * (1.0 + amplitude * math.sin(w * t + phase))

    return _thinned_arrivals(rate, duration_s, cap, seed)


def bursty_trace(base_rps: float, duration_s: float, *, seed: int = 0,
                 burst_rps: float = None, burst_every_s: float = 1800.0,
                 burst_len_s: float = 300.0,
                 max_rps: float = None) -> Tuple[float, ...]:
    """Flash-crowd traffic: ``base_rps`` everywhere, jumping to
    ``burst_rps`` (default ``4 * base_rps``) for ``burst_len_s`` at the
    start of every ``burst_every_s`` interval.

    Same thinning/nesting contract as :func:`diurnal_trace`: traces with
    the same ``seed`` and ``max_rps`` nest across rate scalings.
    """
    if base_rps < 0:
        raise ValueError("base_rps must be >= 0")
    if burst_rps is None:
        burst_rps = 4.0 * base_rps
    if burst_rps < base_rps:
        raise ValueError(f"burst_rps {burst_rps} below base_rps {base_rps}")
    cap = burst_rps if max_rps is None else max_rps
    if burst_rps > cap * (1 + 1e-12):
        raise ValueError(f"burst_rps {burst_rps} exceeds max_rps {cap}")

    def rate(t: float) -> float:
        return burst_rps if (t % burst_every_s) < burst_len_s else base_rps

    return _thinned_arrivals(rate, duration_s, cap, seed)


def window_rates(trace, window_s: float, duration_s: float
                 ) -> Tuple[float, ...]:
    """Mean arrival rate (req/s) per ``window_s`` window over
    ``[0, duration_s)`` — the planner's view of a trace."""
    if window_s <= 0:
        raise ValueError("window_s must be > 0")
    n = max(1, int(math.ceil(duration_s / window_s)))
    counts = [0] * n
    for t in trace:
        if 0.0 <= t < duration_s:
            counts[min(n - 1, int(t // window_s))] += 1
    return tuple(c / window_s for c in counts)
