"""EventQueue ordering contract: the runtime's total order over events.

Pins the tie-breaking the engine depends on — in particular that a
NodeFailure at the same instant as a JobCompletion processes FIRST (the
finishing job loses the race; conservative, see events.py docstring).
"""
import pytest

from repro.core.chaos import NodeFailure, SpotGrant, SpotRevoke
from repro.core.events import (ClusterEvent, Event, EventQueue,
                               IntrospectionTick, JobArrival,
                               JobCompletion, RestartDone)


def test_priority_total_order_at_equal_time():
    q = EventQueue()
    # push in WORST-case order; pop must follow the documented priority
    q.push(Event(5.0))
    q.push(IntrospectionTick(5.0))
    q.push(RestartDone(5.0, "r"))
    q.push(JobCompletion(5.0, "c", 1))
    q.push(NodeFailure(5.0))
    q.push(JobArrival(5.0, None))
    kinds = [type(q.pop()) for _ in range(6)]
    assert kinds == [JobArrival, NodeFailure, JobCompletion,
                     RestartDone, IntrospectionTick, Event]


def test_node_failure_beats_same_time_completion():
    # a job's devices dying at the very moment it would complete: the
    # failure processes first, so the job restarts from its checkpoint
    q = EventQueue()
    q.push(JobCompletion(100.0, "job", 7))
    q.push(NodeFailure(100.0, n_gpus=2))
    assert isinstance(q.pop(), NodeFailure)
    assert isinstance(q.pop(), JobCompletion)


def test_earlier_time_beats_priority():
    q = EventQueue()
    q.push(JobArrival(2.0, None))         # high priority, later
    q.push(IntrospectionTick(1.0))        # low priority, earlier
    assert isinstance(q.pop(), IntrospectionTick)


def test_fifo_among_equals():
    q = EventQueue()
    for name in ("a", "b", "c"):
        q.push(JobCompletion(3.0, name, 0))
    assert [q.pop().job for _ in range(3)] == ["a", "b", "c"]


def test_peek_does_not_pop():
    q = EventQueue()
    assert q.peek() is None
    q.push(RestartDone(1.0, "x"))
    assert q.peek().job == "x"
    assert len(q) == 1
    assert q.pop().job == "x"
    assert not q


def test_pop_while_epsilon_boundaries():
    eps = 1e-6
    q = EventQueue()
    q.push(JobArrival(1.0, "in0"))
    q.push(JobArrival(1.0 + 0.5 * eps, "in1"))  # within the tolerance
    q.push(JobArrival(1.0 + 10 * eps, "out"))   # beyond it
    got = q.pop_while(JobArrival, 1.0, eps=eps)
    assert [e.job for e in got] == ["in0", "in1"]
    assert q.peek().job == "out"


def test_pop_while_stops_at_other_kind():
    # a same-time event of another kind ends the scan even when more
    # matching events sit behind it (heap order interleaves them)
    q = EventQueue()
    q.push(NodeFailure(2.0))
    q.push(JobCompletion(2.0, "done", 0))
    q.push(SpotRevoke(2.0))
    got = q.pop_while(ClusterEvent, 2.0)
    assert [type(e) for e in got] == [NodeFailure, SpotRevoke]
    assert isinstance(q.peek(), JobCompletion)

    q2 = EventQueue()
    q2.push(JobArrival(2.0, "a"))      # higher priority than ClusterEvent
    q2.push(NodeFailure(2.0))
    assert q2.pop_while(ClusterEvent, 2.0) == []
    assert isinstance(q2.pop(), JobArrival)


def test_pop_while_different_time_excluded():
    q = EventQueue()
    q.push(NodeFailure(1.0))
    q.push(NodeFailure(1.5))
    got = q.pop_while(ClusterEvent, 1.0)
    assert len(got) == 1 and got[0].t == 1.0
    assert q.peek().t == 1.5


def test_has_any_mixed_kinds_at_identical_timestamps():
    q = EventQueue()
    q.push(JobCompletion(4.0, "j", 0))
    q.push(SpotGrant(4.0, n_gpus=2))
    q.push(IntrospectionTick(4.0))
    assert q.has_any((ClusterEvent,))
    assert q.has_any((JobCompletion, RestartDone))
    assert q.has_any((SpotGrant,))          # concrete subtype matches too
    assert not q.has_any((JobArrival, RestartDone))
    # drain; has_any reflects the live heap, not history
    while q:
        q.pop()
    assert not q.has_any((ClusterEvent, JobCompletion, IntrospectionTick))


@pytest.mark.parametrize("cls", [NodeFailure, SpotGrant, SpotRevoke])
def test_chaos_events_share_cluster_priority(cls):
    assert issubclass(cls, ClusterEvent)
    assert cls.PRIORITY == ClusterEvent.PRIORITY
    assert JobArrival.PRIORITY < cls.PRIORITY < JobCompletion.PRIORITY
