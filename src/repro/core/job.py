"""Job and cluster specifications for multi-large-model training."""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..models.config import ModelConfig
from ..optim.adamw import AdamWConfig


@dataclasses.dataclass(frozen=True)
class Job:
    """One model-selection trial: a model + hyperparameters + work amount.

    The paper's workload (Table 1) is a grid over {model} x {lr} x
    {batch size} for a fixed number of epochs; each grid point is a Job.

    ``weight``, ``deadline_s`` and ``tenant`` only matter under the
    alternative solver objectives (weighted completion time, tardiness,
    per-tenant fair share); the defaults make every job equivalent, so
    the makespan objective ignores them.
    """
    name: str
    cfg: ModelConfig
    batch_size: int
    seq_len: int
    total_steps: int
    lr: float = 1e-4
    seed: int = 0
    arrival_s: float = 0.0          # online workloads: submission time
    weight: float = 1.0             # objective weight (completion/tardiness)
    deadline_s: Optional[float] = None   # due time for the tardiness objective
    tenant: str = "default"         # owner for the fair-share objective

    @property
    def opt_cfg(self) -> AdamWConfig:
        return AdamWConfig(lr=self.lr, warmup_steps=min(100, self.total_steps // 10 + 1),
                           total_steps=self.total_steps)


# Profile-key technique under which serving (continuous-batching decode)
# throughput is recorded: a serve profile keyed (name, SERVE_TECH, class,
# gpus_per_replica) carries the per-token engine step time of ONE replica,
# exactly like a training profile carries a training step time.
SERVE_TECH = "serve"


@dataclasses.dataclass(frozen=True)
class ServeJob:
    """One serving fleet: a model behind a latency SLO fed by a request
    trace.  The inference-side sibling of :class:`Job` — it flows through
    the same profile → solve → execute → observe loop, but instead of a
    step budget it carries *traffic*: ``trace`` is a tuple of request
    arrival times (seconds, runtime clock; see :mod:`repro.data.traffic`
    for the seeded diurnal/bursty generators).

    A fleet is served by N replicas of ``gpus_per_replica`` GPUs, each
    running a :class:`~repro.serving.engine.ContinuousBatchingEngine`
    with ``slots`` concurrent sequences; a request occupies a slot for
    ``prompt_len + max_new_tokens`` engine steps.  The SLO is on p99
    request latency (arrival → last token) per traffic window.
    """
    name: str
    cfg: ModelConfig
    slo_p99_s: float                 # p99 latency SLO per window (seconds)
    trace: tuple = ()                # request arrival times (seconds)
    prompt_len: int = 32             # prompt tokens per request
    max_new_tokens: int = 96         # decode tokens per request
    slots: int = 8                   # concurrent sequences per replica
    gpus_per_replica: int = 1
    max_replicas: int = 64           # fleet-size cap for the planner
    arrival_s: float = 0.0           # when the fleet comes online
    weight: float = 1.0
    tenant: str = "default"

    def __post_init__(self):
        object.__setattr__(self, "trace", tuple(self.trace))

    @property
    def tokens_per_request(self) -> int:
        return self.prompt_len + self.max_new_tokens


DEFAULT_CLASS = "default"


@dataclasses.dataclass(frozen=True)
class DeviceClass:
    """One homogeneous slice of a (possibly mixed) cluster: a device
    generation / memory size, e.g. A100-40GB vs V100-16GB.

    ``speed_hint`` is the relative throughput vs the cluster's reference
    hardware (1.0 = reference): the profiler scales its roofline
    constants by it, so per-class trials land at realistic speeds even
    in the analytic/napkin backends.
    """
    name: str
    nodes: int = 1
    gpus_per_node: int = 8
    hbm_per_gpu: float = 40e9       # bytes
    speed_hint: float = 1.0         # relative throughput vs reference

    @property
    def total_gpus(self) -> int:
        return self.nodes * self.gpus_per_node


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """The GPU cluster: the paper evaluates 1 and 2 p4d.24xlarge nodes
    (8 GPUs each); the TPU adaptation treats a "node" as an ICI slice.

    Heterogeneous fleets pass ``device_classes`` — a tuple of
    :class:`DeviceClass` records (mixed generations / memory sizes).
    The legacy single-class constructor (``nodes`` x ``gpus_per_node``)
    is kept as a shim: it synthesizes one "default" class, and every
    class-aware code path reduces to the historical behavior.  When
    ``device_classes`` is given it is authoritative: the legacy fields
    are ignored and ``total_gpus`` sums over the classes.
    """
    nodes: int = 1
    gpus_per_node: int = 8
    hbm_per_gpu: float = 40e9       # bytes (A100-40GB on p4d.24xlarge)
    restart_cost_s: float = 30.0    # checkpoint + relaunch penalty
    placement: str = "flat"         # runtime placement backend: flat | node
    device_classes: tuple = ()      # Tuple[DeviceClass, ...]; () = legacy

    def __post_init__(self):
        if not self.device_classes:
            object.__setattr__(self, "device_classes", (DeviceClass(
                DEFAULT_CLASS, self.nodes, self.gpus_per_node,
                self.hbm_per_gpu),))
        else:
            object.__setattr__(self, "device_classes",
                               tuple(self.device_classes))
        names = [dc.name for dc in self.device_classes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate device-class names: {names}")

    @property
    def hetero(self) -> bool:
        """Class-aware paths required: more than one device class, or a
        single EXPLICIT class (anything not named "default" — the shim's
        synthesized class).  A lone explicit class still needs its own
        hardware constants (speed_hint, hbm_per_gpu) honored end to end;
        only the legacy shim reduces to the historical single-pool
        behavior."""
        return len(self.device_classes) > 1 or \
            self.device_classes[0].name != DEFAULT_CLASS

    @property
    def total_gpus(self) -> int:
        return sum(dc.total_gpus for dc in self.device_classes)

    def class_named(self, name: str) -> DeviceClass:
        for dc in self.device_classes:
            if dc.name == name:
                return dc
        raise KeyError(f"no device class {name!r} "
                       f"(have {[d.name for d in self.device_classes]})")

    def device_ranges(self):
        """Contiguous global device-id range per class, in declaration
        order: ``{class_name: (start, stop)}``."""
        out, off = {}, 0
        for dc in self.device_classes:
            out[dc.name] = (off, off + dc.total_gpus)
            off += dc.total_gpus
        return out

    def class_of_device(self, device: int) -> str:
        for name, (lo, hi) in self.device_ranges().items():
            if lo <= device < hi:
                return name
        raise KeyError(f"device {device} outside cluster "
                       f"(total {self.total_gpus})")


def hpo_grid(models, lrs, batch_sizes, *, seq_len: int, total_steps: int,
             steps_scale=None) -> list:
    """Build the paper-style model-selection workload (Table 1 grid)."""
    jobs = []
    for mname, cfg in models:
        for lr in lrs:
            for bs in batch_sizes:
                steps = total_steps
                if steps_scale:
                    steps = int(total_steps * steps_scale.get(mname, 1.0))
                jobs.append(Job(
                    name=f"{mname}-lr{lr:g}-bs{bs}", cfg=cfg,
                    batch_size=bs, seq_len=seq_len,
                    total_steps=steps, lr=lr, seed=len(jobs)))
    return jobs
