"""Model selection at paper scale: the Table-1 workload (2 workloads x
12-job HPO grids) under all five policies on 1- and 2-node clusters.

    PYTHONPATH=src python examples/model_selection.py [--nodes 1]
        [--placement flat|node] [--online] [--arrival-gap 600]

This is the runnable version of benchmarks.run:table2 with a Gantt dump
so the "unintuitive allocations" the paper describes are visible.

--placement node routes Saturn through the node-locality MILP and makes
the runtime's NodeAware backend enforce per-node capacity (single-node
configs never straddle nodes).  --online staggers job arrivals by
--arrival-gap seconds: the dynamic model-selection scenario the paper's
introspection mechanism is built for — policies replan as jobs arrive.
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.baselines import (CurrentPractice, Optimus, OptimusDynamic,
                                  RandomPolicy, SaturnPolicy)
from repro.core.executor import simulate
from repro.core.job import ClusterSpec
from repro.core.library import ParallelismLibrary
from repro.core.profiler import HARDWARE, TrialRunner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=1)
    ap.add_argument("--workload", default="wikitext",
                    choices=["wikitext", "imagenet"])
    ap.add_argument("--placement", default="flat", choices=["flat", "node"])
    ap.add_argument("--online", action="store_true",
                    help="stagger job arrivals (online model selection)")
    ap.add_argument("--arrival-gap", type=float, default=600.0,
                    help="seconds between successive arrivals with --online")
    ap.add_argument("--profile-strategy", default="interpolate",
                    choices=["interpolate", "exhaustive"],
                    help="interpolate: anchor trials + throughput curves "
                         "over the dense 1..G grid (paper's <5%% overhead "
                         "budget); exhaustive: profile every combo")
    args = ap.parse_args()

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.run import paper_workloads
    jobs = paper_workloads()[args.workload]
    if args.online:
        jobs = [dataclasses.replace(j, arrival_s=i * args.arrival_gap)
                for i, j in enumerate(jobs)]
    cluster = ClusterSpec(nodes=args.nodes, gpus_per_node=8,
                          placement=args.placement)
    lib = ParallelismLibrary()
    runner = TrialRunner(lib, HARDWARE["a100"])
    if args.profile_strategy == "interpolate":
        # dense solver grid, sparse (anchor-only) real profiling
        counts = list(range(1, cluster.total_gpus + 1))
    else:
        counts = [1, 2, 4, 8] + ([16] if args.nodes == 2 else [])
    profiles = runner.profile_all(jobs, counts, mode="analytic",
                                  strategy=args.profile_strategy)

    mode = "online" if args.online else "offline"
    print(f"{args.workload}: {len(jobs)} jobs, {cluster.total_gpus} GPUs, "
          f"{args.placement} placement, {mode}")
    results = {}
    for pol in (CurrentPractice(), RandomPolicy(0), Optimus(),
                OptimusDynamic(), SaturnPolicy(time_limit_s=15)):
        res = simulate(jobs, pol, profiles, cluster,
                       introspect_every_s=600 if pol.dynamic else None)
        results[pol.name] = res
        print(f"  {pol.name:18s} {res.makespan_s / 3600:6.2f} h   "
              f"util={res.utilization(cluster):.2f} "
              f"replans={res.replans} restarts={res.restarts}")

    sat = results["saturn"]
    print("\nSaturn Gantt (first 12 segments) — note the mixed"
          " parallelisms/allocations:")
    for g in sorted(sat.gantt, key=lambda g: g.start_s)[:12]:
        if g.kind == "run":
            devs = f" gpus={_ranges(g.devices)}" if g.devices else ""
            print(f"  t={g.start_s / 3600:6.2f}h..{g.end_s / 3600:6.2f}h  "
                  f"{g.job:26s} {g.technique:>6s} x{g.n_gpus}{devs}")


def _ranges(devices):
    """Collapse a device set to 'a-b,c-d' (NodeAware placements need not
    be contiguous)."""
    out, run = [], [devices[0], devices[0]]
    for d in devices[1:]:
        if d == run[1] + 1:
            run[1] = d
        else:
            out.append(run)
            run = [d, d]
    out.append(run)
    return ",".join(f"{a}-{b}" if a != b else f"{a}" for a, b in out)


if __name__ == "__main__":
    main()
