"""Benchmark harness — one function per paper table/figure plus the
roofline report for the dry-run deliverable.

  PYTHONPATH=src python -m benchmarks.run \\
      [table2|solver|kernels|roofline|schedule|profile|all] [--quick]

``schedule`` exercises the event-driven cluster runtime (flat vs
node-aware placement, offline vs online arrivals) and writes
BENCH_schedule.json at the repo root; ``profile`` benchmarks the
performance-model layer (anchor trials + interpolation vs exhaustive
profiling) and writes BENCH_profile.json; ``hetero`` compares
class-aware vs class-blind planning on a mixed A100+V100 fleet and
writes BENCH_hetero.json; ``e2e`` executes one Schedule IR on BOTH the
virtual-time SimBackend and the really-training LocalJaxBackend and
writes BENCH_e2e.json (sim-vs-real makespan fidelity + a real
checkpointed preempt/resume); ``chaos`` sweeps seeded failure rates
over the elastic runtime (Saturn-with-replanning vs static baselines,
plus spot churn on a mixed fleet and the non-makespan objectives) and
writes BENCH_chaos.json; ``recover`` injects real worker faults
(SIGKILL / stalled heartbeats / truncated checkpoints) into the
multi-process ProcessJaxBackend and gates bit-exact crash recovery,
writing BENCH_recover.json; ``--quick`` is the CI smoke variant.  Prints ``name,us_per_call,derived`` CSV rows (harness
contract) followed by human-readable tables.  Results also land in
results/*.json.
"""
from __future__ import annotations

import json
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

RESULTS = os.path.join(ROOT, "results")

CSV_ROWS = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.3f},{derived}"
    CSV_ROWS.append(row)
    print(row, flush=True)


# --------------------------------------------------------------- Table 2

def paper_workloads():
    """The paper's Table-1 model-selection grids, mapped onto the assigned
    architecture pool (GPT-2/GPT-J -> xlstm-125m/olmoe-1b-7b;
    ViT-G/ResNet-200 -> gemma3-4b/internvl2-1b).  Steps derive from
    10 epochs over WikiText-2 (~2.4M tokens) / ImageNet-100 subset."""
    from repro.configs import get_config
    from repro.core.job import hpo_grid

    wikitext = hpo_grid(
        [("xlstm-125m", get_config("xlstm-125m")),
         ("olmoe-1b-7b", get_config("olmoe-1b-7b"))],
        lrs=[1e-5, 1e-4, 1e-3], batch_sizes=[16, 32],
        seq_len=1024, total_steps=1500,
        steps_scale={"xlstm-125m": 1.0, "olmoe-1b-7b": 1.0})
    imagenet = hpo_grid(
        [("gemma3-4b", get_config("gemma3-4b")),
         ("internvl2-1b", get_config("internvl2-1b"))],
        lrs=[1e-5, 1e-4, 1e-3], batch_sizes=[64, 128],
        seq_len=256, total_steps=2000)
    return {"wikitext": wikitext, "imagenet": imagenet}


def bench_table2():
    """Reproduce paper Table 2: makespans for 5 policies x 2 cluster
    sizes x 2 workloads.  Paper claims SATURN cuts 39-49% vs Current
    Practice and beats Optimus/Optimus-Dynamic/Random."""
    from repro.core.baselines import (CurrentPractice, Optimus,
                                      OptimusDynamic, RandomPolicy,
                                      SaturnPolicy)
    from repro.core.executor import simulate
    from repro.core.job import ClusterSpec
    from repro.core.library import ParallelismLibrary
    from repro.core.profiler import HARDWARE, TrialRunner

    lib = ParallelismLibrary()
    runner = TrialRunner(lib, HARDWARE["a100"])
    out = {}
    for wname, jobs in paper_workloads().items():
        for nodes in (1, 2):
            cluster = ClusterSpec(nodes=nodes, gpus_per_node=8)
            counts = [1, 2, 4, 8] + ([16] if nodes == 2 else [])
            profiles = runner.profile_all(jobs, counts, mode="analytic")
            row = {}
            t0 = time.time()
            for pol in (CurrentPractice(), RandomPolicy(0), Optimus(),
                        OptimusDynamic(),
                        SaturnPolicy(n_slots=24, time_limit_s=15)):
                res = simulate(
                    jobs, pol, profiles, cluster,
                    introspect_every_s=600 if pol.dynamic else None,
                    noise_sigma=0.1)
                row[pol.name] = res.makespan_s / 3600.0
            out[f"{wname}_{nodes}node"] = row
            cp, sat = row["current-practice"], row["saturn"]
            emit(f"table2_{wname}_{nodes}node_saturn_hours",
                 (time.time() - t0) * 1e6,
                 f"saturn={sat:.2f}h cp={cp:.2f}h "
                 f"speedup={cp / sat:.2f}x reduction={100 * (1 - sat / cp):.0f}%")
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "table2.json"), "w") as f:
        json.dump(out, f, indent=1)
    # human-readable
    pols = ["current-practice", "random", "optimus", "optimus-dynamic",
            "saturn"]
    print("\n== Table 2 (makespan hours, 1-node/2-node) ==")
    print(f"{'workload':10s} " + " ".join(f"{p:>17s}" for p in pols))
    for wname in ("wikitext", "imagenet"):
        cells = []
        for p in pols:
            a = out[f"{wname}_1node"][p]
            b = out[f"{wname}_2node"][p]
            cells.append(f"{a:7.2f}/{b:<7.2f}")
        print(f"{wname:10s} " + " ".join(f"{c:>17s}" for c in cells))
    return out


# ----------------------------------------------- introspection ablation

def bench_introspection():
    """Ablation of the paper's introspection mechanism: makespan vs
    re-solve interval (static = never) under estimate noise."""
    from repro.core.baselines import SaturnPolicy, SaturnStatic
    from repro.core.executor import simulate
    from repro.core.job import ClusterSpec
    from repro.core.library import ParallelismLibrary
    from repro.core.profiler import HARDWARE, TrialRunner

    jobs = paper_workloads()["wikitext"]
    cluster = ClusterSpec(nodes=1, gpus_per_node=8)
    runner = TrialRunner(ParallelismLibrary(), HARDWARE["a100"])
    profiles = runner.profile_all(jobs, [1, 2, 4, 8], mode="analytic")
    rows = {}
    res = simulate(jobs, SaturnStatic(time_limit_s=10), profiles, cluster,
                   noise_sigma=0.2)
    rows["static"] = res.makespan_s / 3600
    emit("introspection_static", res.makespan_s * 1e6,
         f"makespan={res.makespan_s / 3600:.2f}h replans={res.replans}")
    for interval in (1800, 600, 300):
        res = simulate(jobs, SaturnPolicy(time_limit_s=10), profiles,
                       cluster, introspect_every_s=interval,
                       noise_sigma=0.2)
        rows[f"{interval}s"] = res.makespan_s / 3600
        emit(f"introspection_{interval}s", res.makespan_s * 1e6,
             f"makespan={res.makespan_s / 3600:.2f}h "
             f"replans={res.replans} restarts={res.restarts}")
    with open(os.path.join(RESULTS, "introspection.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


# ------------------------------------------------------- cluster runtime

def _synthetic_runtime_workload(n_jobs=8, seed=0, counts=(1, 2, 4, 8, 16)):
    """Synthetic profiles shaped like the paper-table workload (varied
    scaling efficiency), cheap enough for the CI smoke job."""
    import numpy as np

    from repro.configs import get_config
    from repro.core.job import Job
    from repro.core.profiler import Profile

    cfg = get_config("xlstm-125m").reduced()
    rng = np.random.RandomState(seed)
    jobs, profiles = [], {}
    for i in range(n_jobs):
        j = Job(f"j{i}", cfg, 8, 64, total_steps=int(rng.randint(150, 500)))
        jobs.append(j)
        base = rng.uniform(1.0, 4.0)
        eff = rng.uniform(0.5, 0.95)
        for g in counts:
            for tech, mult in (("ddp", 1.0), ("fsdp", 1.1), ("gpipe", 1.25)):
                profiles[(j.name, tech, g)] = Profile(
                    j.name, tech, g, base * mult / g ** eff, 1e9, True, "t")
    return jobs, profiles


def _node_capacity_violations(res, cluster):
    """Count (time, node) points where co-scheduled jobs exceed a node's
    GPU capacity — must be 0 under NodeAware placement."""
    gpn = cluster.gpus_per_node
    runs = [g for g in res.gantt if g.kind == "run"]
    bad = 0
    for t in sorted({g.start_s for g in runs}):
        live = [g for g in runs if g.start_s <= t < g.end_s - 1e-9]
        for nu in range(cluster.nodes):
            used = sum(len([d for d in g.devices if d // gpn == nu])
                       for g in live)
            if used > gpn:
                bad += 1
    return bad


def bench_schedule(quick=False):
    """The unified cluster-runtime benchmark: flat vs node-aware
    placement and offline vs online arrivals, Saturn-dynamic vs current
    practice.  Writes BENCH_schedule.json (repo root) so the perf
    trajectory accumulates across PRs."""
    import dataclasses

    from repro.core.baselines import CurrentPractice, SaturnPolicy
    from repro.core.executor import simulate
    from repro.core.job import ClusterSpec

    n_jobs = 6 if quick else 12
    tl = 5 if quick else 15
    jobs, profiles = _synthetic_runtime_workload(n_jobs=n_jobs, seed=0)
    out = {"quick": quick, "scenarios": {}}
    for placement in ("flat", "node"):
        cluster = ClusterSpec(nodes=2, gpus_per_node=8, placement=placement)
        for online in (False, True):
            key = f"{placement}_{'online' if online else 'offline'}"
            js = ([dataclasses.replace(j, arrival_s=120.0 * i)
                   for i, j in enumerate(jobs)] if online else jobs)
            t0 = time.time()
            cp = simulate(js, CurrentPractice(), profiles, cluster,
                          noise_sigma=0.1)
            sat = simulate(js, SaturnPolicy(time_limit_s=tl), profiles,
                           cluster, introspect_every_s=600, noise_sigma=0.1)
            wall = time.time() - t0
            viol = (_node_capacity_violations(sat, cluster)
                    + _node_capacity_violations(cp, cluster)
                    if placement == "node" else 0)
            row = {"current_practice_s": cp.makespan_s,
                   "saturn_s": sat.makespan_s,
                   "speedup": cp.makespan_s / sat.makespan_s,
                   "saturn_not_worse": sat.makespan_s
                   <= cp.makespan_s * 1.001,
                   "saturn_replans": sat.replans,
                   "saturn_restarts": sat.restarts,
                   "node_capacity_violations": viol,
                   "bench_wall_s": wall}
            out["scenarios"][key] = row
            emit(f"schedule_{key}", wall * 1e6,
                 f"saturn={sat.makespan_s:.0f}s cp={cp.makespan_s:.0f}s "
                 f"speedup={row['speedup']:.2f}x viol={viol}")
            # node capacity is enforced by construction -> hard failure;
            # the makespan comparison depends on MILP time limits, so it
            # is recorded (and tested under noise=0 in test_runtime.py)
            # rather than asserted on wall-clock-sensitive CI machines
            assert viol == 0, f"{key}: node capacity violated"
            if not row["saturn_not_worse"]:
                print(f"WARNING {key}: saturn ({sat.makespan_s:.0f}s) "
                      f"worse than current practice ({cp.makespan_s:.0f}s)")
    path = os.path.join(ROOT, "BENCH_schedule.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\nwrote {path}")
    return out


# ---------------------------------------------------- heterogeneous fleet

def _hetero_workload(n_jobs=8, seed=0, slow_factor=2.5,
                     counts=(1, 2, 4, 8)):
    """Synthetic per-class profiles on a mixed A100-40GB + V100-16GB
    fleet: every (job, tech, g) combo exists on both classes, the V100
    copy ``slow_factor`` x slower — so a class-blind planner that
    assumes reference-class speed everywhere pays a real price when its
    jobs land on the slow pool."""
    import numpy as np

    from repro.configs import get_config
    from repro.core.job import ClusterSpec, DeviceClass, Job
    from repro.core.profiler import Profile

    classes = (DeviceClass("a100-40g", nodes=1, gpus_per_node=8,
                           hbm_per_gpu=40e9, speed_hint=1.0),
               DeviceClass("v100-16g", nodes=1, gpus_per_node=8,
                           hbm_per_gpu=16e9, speed_hint=1.0 / slow_factor))
    cluster = ClusterSpec(restart_cost_s=30.0, device_classes=classes)
    cfg = get_config("xlstm-125m").reduced()
    rng = np.random.RandomState(seed)
    jobs, profiles = [], {}
    for i in range(n_jobs):
        j = Job(f"j{i}", cfg, 8, 64, total_steps=int(rng.randint(150, 500)))
        jobs.append(j)
        base = rng.uniform(1.0, 4.0)
        eff = rng.uniform(0.5, 0.95)
        for dc, slow in (("a100-40g", 1.0), ("v100-16g", slow_factor)):
            for g in counts:
                for tech, mult in (("ddp", 1.0), ("fsdp", 1.1),
                                   ("gpipe", 1.25)):
                    profiles[(j.name, tech, dc, g)] = Profile(
                        j.name, tech, g, base * mult * slow / g ** eff,
                        1e9, True, "t", device_class=dc)
    return cluster, jobs, profiles


def bench_hetero(quick=False):
    """Heterogeneous-cluster benchmark: class-AWARE joint planning (the
    class-dimension MILP + class-pinned placement) vs class-BLIND
    planning (the flat MILP on reference-class speeds, placement takes
    whatever class has room) on a mixed A100+V100 fleet.  Both plans
    execute against the same per-class ground-truth step times and the
    same noise.  Writes BENCH_hetero.json (repo root)."""
    from repro.core.baselines import CurrentPractice, SaturnPolicy
    from repro.core.executor import simulate
    from repro.core.job import Job
    from repro.core.schedule import Schedule
    from repro.core.solver import solve_joint

    n_jobs = 8 if quick else 12
    tl = 5 if quick else 15
    cluster, jobs, profiles = _hetero_workload(n_jobs=n_jobs, seed=0)

    # the class-blind planner's world view: every GPU runs at the best
    # class's speed, one big pool — capped at the largest class so its
    # plans remain placeable (no allocation can straddle classes)
    gmax = max(dc.total_gpus for dc in cluster.device_classes)
    blind_view = {}
    for (jn, tech, dc, g), p in profiles.items():
        if g > gmax:
            continue
        key = (jn, tech, g)
        if key not in blind_view or \
                p.step_time_s < blind_view[key].step_time_s:
            blind_view[key] = p

    class ClassBlindSaturn(SaturnPolicy):
        name = "saturn-class-blind"

        def __init__(self, **kw):
            # incremental replans consult the runtime's REAL profiles;
            # this policy must stay blind to them, so always replan from
            # scratch on its own class-blind world view
            kw["incremental"] = False
            super().__init__(**kw)

        def plan(self, jobs_, remaining, _profiles, cluster_, current):
            live = [Job(j.name, j.cfg, j.batch_size, j.seq_len,
                        remaining.get(j.name, j.total_steps), j.lr, j.seed)
                    for j in jobs_
                    if remaining.get(j.name, j.total_steps) > 0]
            if not live:
                return Schedule([], solver=self.name)
            sol = solve_joint(live, blind_view, cluster_.total_gpus,
                              n_slots=self.n_slots,
                              time_limit_s=self.time_limit_s, mip_gap=0.05)
            return sol.to_schedule()

    t0 = time.time()
    # from-scratch replans on BOTH sides: this bench is the end-to-end
    # coverage for cross-class migrations (an incremental replan fixes
    # well-placed running jobs and rarely migrates, which would leave
    # the migration accounting unexercised by any bench)
    aware = simulate(jobs, SaturnPolicy(n_slots=16, time_limit_s=tl,
                                        incremental=False),
                     profiles, cluster, introspect_every_s=600,
                     noise_sigma=0.1)
    blind = simulate(jobs, ClassBlindSaturn(n_slots=16, time_limit_s=tl),
                     profiles, cluster, introspect_every_s=600,
                     noise_sigma=0.1)
    cp = simulate(jobs, CurrentPractice(), profiles, cluster,
                  noise_sigma=0.1)
    wall = time.time() - t0

    # migrations: restarts whose surrounding run segments changed class
    runs_by_job = {}
    for g in aware.gantt:
        if g.kind == "run":
            runs_by_job.setdefault(g.job, []).append(g)
    migrations = 0
    for segs in runs_by_job.values():
        segs.sort(key=lambda g: g.start_s)
        migrations += sum(1 for a, b in zip(segs, segs[1:])
                          if a.device_class != b.device_class)

    out = {
        "quick": quick,
        "jobs": n_jobs,
        "classes": {dc.name: {"gpus": dc.total_gpus,
                              "speed_hint": dc.speed_hint}
                    for dc in cluster.device_classes},
        "makespan_aware_s": aware.makespan_s,
        "makespan_blind_s": blind.makespan_s,
        "current_practice_s": cp.makespan_s,
        "aware_vs_blind_speedup": blind.makespan_s / aware.makespan_s,
        "aware_replans": aware.replans,
        "aware_restarts": aware.restarts,
        "aware_class_migrations": migrations,
        "blind_restarts": blind.restarts,
        "bench_wall_s": wall,
    }
    emit("hetero_aware_vs_blind", wall * 1e6,
         f"aware={aware.makespan_s:.0f}s blind={blind.makespan_s:.0f}s "
         f"cp={cp.makespan_s:.0f}s "
         f"speedup={out['aware_vs_blind_speedup']:.2f}x "
         f"migrations={migrations}")
    # acceptance gate (ISSUE 3): class-aware planning must beat
    # class-blind planning on the mixed fleet.  (Per-class GPU-second
    # conservation is enforced inside the runtime for every run above.)
    assert aware.makespan_s < blind.makespan_s, \
        f"class-aware ({aware.makespan_s:.0f}s) did not beat " \
        f"class-blind ({blind.makespan_s:.0f}s)"
    path = os.path.join(ROOT, "BENCH_hetero.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\nwrote {path}")
    return out


# ----------------------------------------------------------- chaos engine

def _chaos_workload(n_jobs=6, base_steps=2500, counts=(1, 2, 4, 8, 16)):
    """Deterministic workload sized so the failure sweep's chaos window
    overlaps the whole run (makespans in the thousands of seconds):
    clean sub-linear speedups, job i ~30% slower per step and 300 steps
    longer than job i-1."""
    from repro.configs import get_config
    from repro.core.job import Job
    from repro.core.profiler import Profile

    cfg = get_config("xlstm-125m").reduced()
    jobs, profiles = [], {}
    for i in range(n_jobs):
        j = Job(f"job{i}", cfg, 8, 128, base_steps + 300 * i, seed=i)
        jobs.append(j)
        base = 1.0 + 0.3 * i
        for tech in ("ddp", "fsdp"):
            for g in counts:
                st = base / g ** 0.8 * (1.15 if tech == "fsdp" else 1.0)
                profiles[(j.name, tech, g)] = Profile(
                    j.name, tech, g, st, 1e9, True, "synthetic")
    return jobs, profiles


def bench_chaos(quick=False):
    """Chaos-engine benchmark (ISSUE 7): seeded node-failure sweeps over
    the elastic runtime, Saturn-with-replanning vs the static
    CurrentPractice / Optimus baselines, plus spot churn on a mixed
    fleet and the non-makespan solver objectives.  Writes
    BENCH_chaos.json (repo root).

    The headline gate: Saturn's makespan margin over the static
    full-node practice, AVERAGED over seeds, is monotonically
    non-decreasing as the failure rate rises.  Per-seed margins are
    noisy (one lucky failure can land in a baseline's idle tail), but
    the Poisson-thinned traces make each seed's failure sets nested
    across rates, so the seed-mean is a stable, monotone quantity.
    Optimus margins are reported, not gated — a static but
    packing-aware plan loses less to churn, and at high rates the two
    trade places seed by seed.  GPU-second conservation is verified
    inside the runtime for every simulation below."""
    from repro.core.baselines import CurrentPractice, Optimus, SaturnPolicy
    from repro.core.chaos import (ChaosTrace, poisson_node_failures,
                                  spot_capacity_trace)
    from repro.core.executor import simulate
    from repro.core.job import ClusterSpec, DeviceClass
    from repro.core.profiler import Profile
    from repro.core.solver import OBJECTIVES, objective_value, solve_joint

    def mean(xs):
        return sum(xs) / len(xs)

    # ---- scenario 1: failure-rate sweep (the monotone-margin gate).
    # Rates and seeds are fixed, noise is zero: the sweep is fully
    # deterministic, so the regression gate compares like with like.
    rates = (0.0, 4.0, 8.0) if quick else (0.0, 2.0, 4.0, 8.0)
    seeds = (7, 11, 23)
    jobs, profiles = _chaos_workload()
    cluster = ClusterSpec(nodes=2, gpus_per_node=8, restart_cost_s=30.0)
    sweep = {"rates_per_hour": list(rates), "seeds": list(seeds),
             "gpus_per_failure": 4, "recover_after_s": 1200.0,
             "checkpoint_every_s": 300.0, "levels": {}}
    margins_cp = []
    for rate in rates:
        t0 = time.time()
        sat_ms, cp_ms, op_ms, ratios_cp, ratios_op, fails = \
            [], [], [], [], [], []
        for seed in seeds:
            ev = poisson_node_failures(
                rate, 30000.0, seed=seed, n_gpus=4,
                recover_after_s=1200.0, max_rate_per_hour=max(rates))
            trace = ChaosTrace(ev, checkpoint_every_s=300.0)
            sat = simulate(jobs, SaturnPolicy(time_limit_s=3), profiles,
                           cluster, noise_sigma=0.0,
                           introspect_every_s=600.0, chaos=trace)
            cp = simulate(jobs, CurrentPractice(), profiles, cluster,
                          noise_sigma=0.0, chaos=trace)
            op = simulate(jobs, Optimus(), profiles, cluster,
                          noise_sigma=0.0, chaos=trace)
            sat_ms.append(sat.makespan_s)
            cp_ms.append(cp.makespan_s)
            op_ms.append(op.makespan_s)
            ratios_cp.append(cp.makespan_s / sat.makespan_s)
            ratios_op.append(op.makespan_s / sat.makespan_s)
            fails.append(sat.failures)
        wall = time.time() - t0
        row = {"saturn_s": mean(sat_ms),
               "current_practice_s": mean(cp_ms),
               "optimus_s": mean(op_ms),
               "margin_vs_current_practice": mean(ratios_cp),
               "margin_vs_optimus": mean(ratios_op),
               "failures_mean": mean(fails),
               "bench_wall_s": wall}
        sweep["levels"][f"rate_{rate:g}"] = row
        margins_cp.append(row["margin_vs_current_practice"])
        emit(f"chaos_rate_{rate:g}", wall * 1e6,
             f"saturn={row['saturn_s']:.0f}s "
             f"cp={row['current_practice_s']:.0f}s "
             f"margin={row['margin_vs_current_practice']:.3f}x "
             f"op_margin={row['margin_vs_optimus']:.3f}x "
             f"failures={row['failures_mean']:.1f}")
        # acceptance gate: replanning Saturn beats the static practice
        # at EVERY churn level, calm included
        assert row["saturn_s"] < row["current_practice_s"], \
            f"rate {rate}: saturn ({row['saturn_s']:.0f}s) did not " \
            f"beat current practice ({row['current_practice_s']:.0f}s)"
    # acceptance gate: the margin WIDENS with churn — monotone
    # non-decreasing across all >=3 levels, strictly wider at max churn
    assert all(b >= a - 0.02 for a, b in zip(margins_cp, margins_cp[1:])), \
        f"margin not monotone across failure rates: {margins_cp}"
    assert margins_cp[-1] > margins_cp[0], \
        f"margin did not widen with churn: {margins_cp}"

    # ---- scenario 2: spot churn on a mixed fleet (ClassPool path).
    # Half the v100 pool flaps per a seeded two-state availability
    # trace; revocations are voluntary (free-first, failures stay 0)
    # and every grant adds FRESH device ids.
    hetero = ClusterSpec(restart_cost_s=10.0, device_classes=(
        DeviceClass("a100", 1, 4), DeviceClass("v100", 1, 4)))
    sjobs, flat = _chaos_workload(4, base_steps=600, counts=(1, 2, 4))
    sprofiles = {(j, t, dc.name, g): Profile(j, t, g,
                                             p.step_time_s
                                             * (1.0 if dc.name == "a100"
                                                else 1.6),
                                             p.mem_per_device, True,
                                             "synthetic",
                                             device_class=dc.name)
                 for (j, t, g), p in flat.items()
                 for dc in hetero.device_classes}
    spot_ev = spot_capacity_trace(20000.0, seed=3, n_gpus=2,
                                  device_class="v100",
                                  mean_up_s=600.0, mean_down_s=300.0)
    spot_trace = ChaosTrace(spot_ev, checkpoint_every_s=120.0)
    t0 = time.time()
    spot = simulate(sjobs, SaturnPolicy(time_limit_s=3), sprofiles,
                    hetero, noise_sigma=0.0, introspect_every_s=300.0,
                    chaos=spot_trace)
    wall_spot = time.time() - t0
    out_spot = {"saturn_s": spot.makespan_s,
                "spot_events": len(spot_ev),
                "replans": spot.replans, "restarts": spot.restarts,
                "failures": spot.failures, "bench_wall_s": wall_spot}
    emit("chaos_spot", wall_spot * 1e6,
         f"makespan={spot.makespan_s:.0f}s events={len(spot_ev)} "
         f"restarts={spot.restarts} replans={spot.replans}")
    assert spot.failures == 0, \
        "spot revocations are voluntary, not failures"
    assert spot.makespan_s > 0

    # ---- scenario 3: deadline/fairness objectives.  Each specialized
    # solve must score at least as well as the makespan plan under its
    # own metric (deterministic MILPs, no simulation noise).
    ojobs, oprofiles = _chaos_workload(5, base_steps=300,
                                       counts=(1, 2, 4, 8))
    import dataclasses as _dc
    ojobs = [_dc.replace(j, weight=float(1 + i % 3),
                         deadline_s=400.0 + 150.0 * i,
                         tenant=f"t{i % 2}")
             for i, j in enumerate(ojobs)]
    base_plan = solve_joint(ojobs, oprofiles, 8, time_limit_s=5,
                            objective="makespan")
    out_obj = {}
    for obj in OBJECTIVES:
        t0 = time.time()
        sol = solve_joint(ojobs, oprofiles, 8, time_limit_s=5,
                          objective=obj)
        spec = objective_value(sol.assignments, ojobs, obj)
        under_makespan = objective_value(base_plan.assignments, ojobs, obj)
        out_obj[obj] = {"objective_value": spec,
                        "makespan_plan_value": under_makespan,
                        "bench_wall_s": time.time() - t0}
        emit(f"chaos_objective_{obj}", out_obj[obj]["bench_wall_s"] * 1e6,
             f"value={spec:.1f} makespan_plan={under_makespan:.1f}")
        assert spec <= under_makespan + 1e-6, \
            f"{obj}: specialized solve ({spec:.1f}) worse than the " \
            f"makespan plan's {under_makespan:.1f}"

    out = {"quick": quick, "failure_sweep": sweep, "spot": out_spot,
           "objectives": out_obj}
    path = os.path.join(ROOT, "BENCH_chaos.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\nwrote {path}")
    return out


# ------------------------------------------------------- end-to-end (e2e)

def bench_e2e(quick=False):
    """Unified-backend benchmark: the SAME Schedule IR executed by the
    virtual-time SimBackend (prediction) and by the LocalJaxBackend
    (really training the reduced models on this machine), gating how
    faithful the simulated makespan is to actually-executed wall clock
    — plus a forced mid-run introspection replan that preempts a
    really-training job, checkpoints it, and resumes it from the saved
    step.  Writes BENCH_e2e.json (repo root).

    Run standalone (``benchmarks/run.py e2e``) this forces 4 host
    devices via XLA_FLAGS so jobs train concurrently on disjoint
    slices; under ``all`` (jax already initialized) it falls back to
    whatever devices exist.
    """
    import sys as _sys
    if "jax" not in _sys.modules:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=4")
    import dataclasses
    import math

    import jax

    from repro.configs import get_config
    from repro.core.baselines import SaturnStatic
    from repro.core.executor import simulate
    from repro.core.job import ClusterSpec, Job
    from repro.core.library import ParallelismLibrary
    from repro.core.local_backend import LocalJaxBackend
    from repro.core.profiler import HARDWARE, Profile, TrialRunner
    from repro.core.schedule import Policy, Schedule, ScheduleEntry
    from repro.parallelism.techniques import DDP, RematOffload

    t_bench = time.time()
    n_dev = min(4, len(jax.devices()))
    cluster = ClusterSpec(nodes=1, gpus_per_node=n_dev, restart_cost_s=1.0)
    counts = [1, 2] if n_dev >= 2 else [1]
    cfg = dataclasses.replace(
        get_config("xlstm-125m").reduced(), d_model=64, num_heads=2,
        num_kv_heads=2, head_dim=32, name="xlstm-micro")
    lib = ParallelismLibrary([DDP(), RematOffload()])

    # ---- empirical Trial Runner: REAL minibatch timings on this
    # machine; one probe job profiles the shared (cfg, batch, seq)
    # shape, replicated per job name for the solver
    probe = Job("probe", cfg, 2, 32, total_steps=1)
    runner = TrialRunner(lib, HARDWARE["a100"])
    t0 = time.time()
    probes = {g: runner.profile(probe, "ddp", g, mode="empirical")
              for g in counts}
    if n_dev < 2:     # single device: restarts flip technique instead
        probes_rm = runner.profile(probe, "remat-offload", 1,
                                   mode="empirical")
    wall_profile = time.time() - t0
    est1 = probes[1].step_time_s
    emit("e2e_profile", wall_profile * 1e6,
         f"ddp1={est1 * 1e3:.1f}ms trials={runner.trials}")

    def mk_profiles(jobs):
        out = {}
        for j in jobs:
            for g, p in probes.items():
                out[(j.name, "ddp", g)] = Profile(
                    j.name, "ddp", g, p.step_time_s, p.mem_per_device,
                    p.feasible, p.source)
            if n_dev < 2:
                out[(j.name, "remat-offload", 1)] = Profile(
                    j.name, "remat-offload", 1, probes_rm.step_time_s,
                    probes_rm.mem_per_device, probes_rm.feasible,
                    probes_rm.source)
        return out

    scale = 1.0 if quick else 2.5
    # size workloads from the MEASURED rate so the training phase
    # dominates JIT compiles comparably on fast and slow machines
    def steps_for(seconds, lo):
        return max(lo, int(scale * seconds / max(est1, 1e-4)))

    # ---- scenario 1: fidelity.  One static plan, two backends.
    jobs = [Job(f"j{i}", cfg, 2, 32,
                total_steps=steps_for(s, 300), lr=lr, seed=i)
            for i, (s, lr) in enumerate([(16.0, 1e-3), (10.0, 3e-4),
                                         (10.0, 1e-3)])]
    profiles = mk_profiles(jobs)
    predicted = simulate(jobs, SaturnStatic(time_limit_s=10), profiles,
                         cluster, noise_sigma=0.0)
    be1 = LocalJaxBackend(library=lib)
    t0 = time.time()
    executed = simulate(jobs, SaturnStatic(time_limit_s=10), profiles,
                        cluster, noise_sigma=0.0, exec_backend=be1)
    wall_exec = time.time() - t0
    ratio = executed.makespan_s / predicted.makespan_s
    compile_total = sum(s["compile_s"] for st in executed.stats.values()
                        for s in st["segments"])
    emit("e2e_fidelity", wall_exec * 1e6,
         f"predicted={predicted.makespan_s:.1f}s "
         f"executed={executed.makespan_s:.1f}s ratio={ratio:.2f} "
         f"compile_total={compile_total:.1f}s")
    for j in jobs:
        segs = executed.stats[j.name]["segments"]
        assert sum(s["steps"] for s in segs) == j.total_steps, j.name
    # wide fidelity band: real compiles + CPU contention sit on top of
    # the per-step estimates; an order-of-magnitude miss means the sim
    # and the execution no longer describe the same system
    assert 0.1 <= ratio <= 8.0, f"fidelity ratio {ratio:.2f} out of band"

    # ---- scenario 2: a mid-run introspection replan preempts a
    # REALLY-training job; it checkpoints, pays the restart penalty,
    # and resumes from the saved step with the data stream continued
    class FlipWhenProgressed(Policy):
        name = "flip"
        dynamic = True
        replan_on_completion = False

        def __init__(self, target, total):
            self.target, self.total = target, total
            self.flipped = False

        def entry(self, name):
            if name == self.target and self.flipped:
                return ("ddp", 2) if n_dev >= 2 else ("remat-offload", 1)
            return ("ddp", 1)

        def plan(self, jobs_, remaining, _profiles, _cluster, current):
            if remaining.get(self.target, self.total) < self.total:
                self.flipped = True
            return Schedule([ScheduleEntry(j.name, *self.entry(j.name))
                             for j in jobs_])

    long_steps = steps_for(14.0, 800)
    jobs2 = [Job("j0", cfg, 2, 32, total_steps=long_steps, lr=1e-3,
                 seed=0)] + \
            [Job(f"j{i}", cfg, 2, 32, total_steps=steps_for(3.0, 150),
                 lr=1e-3, seed=i) for i in (1, 2)]
    profiles2 = mk_profiles(jobs2)
    be2 = LocalJaxBackend(library=lib)
    t0 = time.time()
    res2 = simulate(jobs2, FlipWhenProgressed("j0", long_steps),
                    profiles2, cluster, noise_sigma=0.0,
                    introspect_every_s=2.5, exec_backend=be2)
    wall_restart = time.time() - t0
    segs = res2.stats["j0"]["segments"]
    for a, b in zip(segs, segs[1:]):
        assert b["start_step"] == a["start_step"] + a["steps"], \
            "resume did not continue from the checkpointed step"
    assert res2.restarts >= 1, "no mid-run restart was exercised"
    assert segs[0]["steps"] > 0 and len(segs) >= 2
    assert sum(s["steps"] for s in segs) == long_steps
    losses = res2.stats["j0"]["losses"]
    assert all(math.isfinite(v) for _, v in losses)
    resumed_step = segs[1]["start_step"]
    loss_gap = abs(segs[1]["first_loss"] - segs[0]["last_loss"]) \
        if segs[0]["last_loss"] is not None else None
    emit("e2e_restart", wall_restart * 1e6,
         f"restarts={res2.restarts} resumed_step={resumed_step} "
         f"segments={len(segs)} loss_gap={loss_gap:.3f} "
         f"observed={len(be2.observed)}")
    assert be2.observed, \
        "measured step times must feed the introspection replans"

    out = {
        "quick": quick,
        "devices": n_dev,
        "jobs": len(jobs),
        "est_step_ddp1_s": est1,
        "profiling_wall_s": wall_profile,
        "predicted_makespan_s": predicted.makespan_s,
        "executed_makespan_s": executed.makespan_s,
        "makespan_executed_over_predicted": ratio,
        "compile_total_s": compile_total,
        "restart_scenario": {
            "long_steps": long_steps,
            "restarts": res2.restarts,
            "replans": res2.replans,
            "resumed_step": resumed_step,
            "segments_j0": len(segs),
            "loss_gap_at_resume": loss_gap,
            "observed_combos": len(be2.observed),
            "executed_makespan_s": res2.makespan_s,
        },
        "bench_wall_s": time.time() - t_bench,
    }
    path = os.path.join(ROOT, "BENCH_e2e.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\nwrote {path}")
    return out


# ------------------------------------------------------- crash recovery

def bench_recover(quick=False):
    """Fault-tolerant execution benchmark: really training worker
    PROCESSES are really hurt (SIGKILL mid-step, stalled heartbeats, a
    truncated checkpoint file) and the ProcessJaxBackend's supervision
    must detect each fault, salvage the durable checkpoint, relaunch
    under backoff, and finish the job with the EXACT loss trajectory of
    an uninterrupted run — recovery that drops or perturbs steps cannot
    hide.  A zero-budget scenario checks the quarantine path: the run
    completes with the failure recorded instead of deadlocking.

    Gates (check_regression): ``recover_traj_err`` (absolute ceiling —
    the resumed trajectory must match the uninterrupted one),
    ``recover_overhead_x`` (recovery makespan over baseline, bounded),
    ``recover_completes`` / ``quarantine_recorded`` (absolute floors).
    Writes BENCH_recover.json (repo root)."""
    import dataclasses
    import tempfile

    from repro.configs import get_config
    from repro.core.baselines import CurrentPractice
    from repro.core.chaos import ChaosTrace, RetryPolicy, WorkerFault
    from repro.core.executor import simulate
    from repro.core.job import ClusterSpec, Job
    from repro.core.process_backend import ProcessJaxBackend
    from repro.core.profiler import Profile

    t_bench = time.time()
    cfg = dataclasses.replace(
        get_config("xlstm-125m").reduced(), d_model=64, num_heads=2,
        num_kv_heads=2, head_dim=32, name="xlstm-micro")
    steps = 400 if quick else 1000
    # the fault event arrives early and DEFERS (WorkerFault.min_step)
    # until the worker's first durable checkpoint at/past min_step:
    # a mid-run strike is guaranteed regardless of machine-load-
    # dependent worker startup time (spawn + jax import + compile)
    fault_t = 1.0
    min_step = 20     # the SECOND durable commit (ckpt_every_steps=10):
                      # the corrupt fault then has a `.prev`
                      # last-known-good to fall back to
    cluster = ClusterSpec(nodes=1, gpus_per_node=1, restart_cost_s=0.5)
    jobs = [Job("j0", cfg, 2, 32, total_steps=steps, lr=1e-3, seed=0)]
    profiles = {("j0", "ddp", 1): Profile("j0", "ddp", 1, 0.01, 1e9,
                                          True, "t")}

    def run(chaos=None, **backend_kw):
        be = ProcessJaxBackend(ckpt_dir=tempfile.mkdtemp(),
                               ckpt_every_steps=10, **backend_kw)
        t0 = time.time()
        res = simulate(jobs, CurrentPractice(), profiles, cluster,
                       exec_backend=be, chaos=chaos)
        return res, time.time() - t0

    def trajectory(res):
        d = {}   # absolute step -> loss; replayed steps overwrite
        for s, v in res.stats["j0"]["losses"]:
            d[s] = v
        return d

    base, wall_base = run()
    t_base = trajectory(base)
    assert base.worker_failures == 0 and not base.quarantined
    emit("recover_baseline", wall_base * 1e6,
         f"steps={steps} makespan={base.makespan_s:.1f}s")

    scenarios = {}
    worst_err, worst_overhead, completed = 0.0, 0.0, 0
    for kind in ("sigkill", "hang", "corrupt"):
        res, wall = run(ChaosTrace((WorkerFault(fault_t, kind, "j0",
                                                min_step=min_step),)))
        t_f = trajectory(res)
        ok = (res.worker_failures >= 1 and not res.quarantined
              and set(t_f) == set(t_base))
        err = max(abs(t_base[s] - t_f[s]) for s in t_base) if ok \
            else float("inf")
        overhead = res.makespan_s / base.makespan_s
        completed += int(ok)
        worst_err = max(worst_err, err)
        worst_overhead = max(worst_overhead, overhead)
        segs = res.stats["j0"]["segments"]
        scenarios[kind] = {
            "worker_failures": res.worker_failures,
            "restarts": res.restarts,
            "segments": len(segs),
            "resumed_step": segs[-1]["start_step"],
            "makespan_s": res.makespan_s,
            "overhead_x": overhead,
            "traj_max_err": err,
        }
        emit(f"recover_{kind}", wall * 1e6,
             f"failures={res.worker_failures} restarts={res.restarts} "
             f"resumed_step={segs[-1]['start_step']} "
             f"overhead={overhead:.2f}x traj_err={err:.1e}")

    # quarantine: a zero retry budget turns the first failure terminal —
    # the run must COMPLETE with the reason recorded, never deadlock
    resq, wallq = run(ChaosTrace((WorkerFault(fault_t, "sigkill", "j0",
                                              min_step=min_step),)),
                      retry_policy=RetryPolicy(budget=0))
    quarantined_ok = ("j0" in resq.quarantined
                      and "retry budget exhausted" in resq.quarantined["j0"])
    emit("recover_quarantine", wallq * 1e6,
         f"quarantined={quarantined_ok} "
         f"reason={resq.quarantined.get('j0', '')[:40]!r}")

    out = {
        "quick": quick,
        "steps": steps,
        "fault_t_s": fault_t,
        "fault_min_step": min_step,
        "baseline_makespan_s": base.makespan_s,
        "scenarios": scenarios,
        # gated acceptance criteria
        "recover_traj_err": worst_err,
        "recover_overhead_x": worst_overhead,
        "recover_completes": completed / 3.0,
        "quarantine_recorded": float(quarantined_ok),
        "bench_wall_s": time.time() - t_bench,
    }
    path = os.path.join(ROOT, "BENCH_recover.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\nwrote {path}")
    assert completed == 3, f"recovery incomplete: {scenarios}"
    assert quarantined_ok, f"quarantine not recorded: {resq.quarantined}"
    return out


# -------------------------------------------------------------- serving

def bench_serve(quick=False):
    """Mixed cluster: a model-selection sweep sharing GPUs with a live
    SLO-bound serving fleet.  Saturn's adaptive fleets return off-peak
    GPUs to the sweep (and evict training when bursts land, paying real
    restart penalties); the baseline is today's practice — a static GPU
    partition peak-provisioned for the worst traffic window.  Gates:
    BOTH runs hold >= 99% SLO attainment, and the adaptive run finishes
    the sweep >= 1.2x faster.  Writes BENCH_serve.json."""
    from repro.configs import get_config
    from repro.core.baselines import (CurrentPractice, SaturnPolicy,
                                      static_partition_fleets)
    from repro.core.executor import simulate
    from repro.core.job import (SERVE_TECH, ClusterSpec, DeviceClass, Job,
                                ServeJob)
    from repro.core.profiler import Profile
    from repro.data.traffic import bursty_trace
    from repro.serving.fleet import FleetManager, serve_profiles

    import numpy as np

    cluster = ClusterSpec(device_classes=(
        DeviceClass("a100", nodes=1, gpus_per_node=8,
                    hbm_per_gpu=40e9, speed_hint=1.0),))
    cfg = get_config("xlstm-125m").reduced()
    n_jobs = 4 if quick else 6
    steps = 2000 if quick else 4000
    horizon = 900.0 if quick else 1800.0
    tl = 5 if quick else 10
    rng = np.random.RandomState(0)
    jobs, profiles = [], {}
    for i in range(n_jobs):
        j = Job(f"t{i}", cfg, 8, 64, total_steps=steps, seed=i)
        jobs.append(j)
        base = rng.uniform(0.3, 0.5)
        eff = rng.uniform(0.8, 0.95)
        for g in (1, 2, 4):
            profiles[(j.name, "ddp", "a100", g)] = Profile(
                j.name, "ddp", g, base / g ** eff, 1e9, True, "t",
                device_class="a100")

    # diurnal-ish bursty service: quiet base load, 15x bursts the
    # static partition must be provisioned for at ALL times
    trace = bursty_trace(2.0, horizon, seed=1, burst_rps=30.0,
                         burst_every_s=horizon / 3.0, burst_len_s=120.0)
    serve = ServeJob(name="svc", cfg=cfg, slo_p99_s=1.0, trace=trace,
                     slots=4, gpus_per_replica=1, prompt_len=32,
                     max_new_tokens=96)
    merged = dict(profiles)
    merged.update(serve_profiles([serve], cluster, base_step_s=0.004))

    def sweep_makespan(res):
        # training may finish before the traffic horizon keeps the run
        # alive: the sweep's makespan is the last TRAINING segment end
        return max(e.end_s for e in res.gantt
                   if e.kind == "run" and e.technique != SERVE_TECH)

    out = {"quick": quick, "scenarios": {}}
    t_bench = time.time()
    runs = {
        "saturn_adaptive": (
            SaturnPolicy(time_limit_s=tl),
            FleetManager([serve], cluster, window_s=60.0,
                         horizon_s=horizon)),
        "static_partition": (
            CurrentPractice(),
            static_partition_fleets([serve], cluster, window_s=60.0,
                                    horizon_s=horizon)),
    }
    rows = {}
    for label, (policy, fm) in runs.items():
        t0 = time.time()
        res = simulate(jobs, policy, merged, cluster,
                       introspect_every_s=60.0, fleets=fm)
        wall = time.time() - t0
        sv = res.stats["serving"]
        svc = sv["svc"]
        worst = min((w["attainment"] for w in svc["windows"]
                     if w["requests"]), default=1.0)
        rows[label] = {
            "sweep_makespan_s": sweep_makespan(res),
            "serve_attainment": svc["attainment"],
            "worst_window_attainment": worst,
            "requests": svc["requests"],
            "peak_replicas": svc["peak_replicas"],
            "evictions": sv["evictions"],
            "restarts": res.restarts,
            "bench_wall_s": wall,
        }
        emit(f"serve_{label}", wall * 1e6,
             f"sweep={rows[label]['sweep_makespan_s']:.0f}s "
             f"attain={svc['attainment']:.3f} "
             f"evict={sv['evictions']}")
    sat, stat = rows["saturn_adaptive"], rows["static_partition"]
    ratio = stat["sweep_makespan_s"] / sat["sweep_makespan_s"]
    out["scenarios"] = rows
    out["makespan_saturn_serve_s"] = sat["sweep_makespan_s"]
    out["makespan_static_partition_s"] = stat["sweep_makespan_s"]
    out["serve_attainment"] = min(sat["serve_attainment"],
                                  stat["serve_attainment"])
    out["static_over_saturn_x"] = ratio
    out["bench_wall_s"] = time.time() - t_bench
    emit("serve_static_over_saturn", out["bench_wall_s"] * 1e6,
         f"{ratio:.2f}x attain={out['serve_attainment']:.3f}")
    # acceptance gates: serving never misses its SLO under EITHER
    # policy, and sharing beats the static partition by a real margin
    assert out["serve_attainment"] >= 0.99, \
        f"SLO attainment {out['serve_attainment']:.3f} < 0.99"
    assert ratio >= 1.2, \
        f"adaptive sharing won only {ratio:.2f}x (< 1.2x) over static"
    path = os.path.join(ROOT, "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\nwrote {path}")
    return out


# ------------------------------------------------------ performance model

def bench_profile(quick=False):
    """Profiling-strategy benchmark (paper §2's <5% profiling-overhead
    budget): exhaustive profiling of a dense GPU-count grid vs anchor
    trials + throughput-curve interpolation vs the calibrated roofline
    predictor.  Reports the real-trial reduction, profiling wall-clock,
    held-out step-time error, and the end-to-end makespan delta when
    the Solver plans on estimated instead of exhaustive profiles.
    Writes BENCH_profile.json (repo root) so the trajectory accumulates
    across PRs."""
    import math

    import numpy as np

    from repro.configs import get_config
    from repro.core.executor import simulate
    from repro.core.job import ClusterSpec, hpo_grid
    from repro.core.library import ParallelismLibrary
    from repro.core.profiler import HARDWARE, TrialRunner
    from repro.core.schedule import Policy
    from repro.core.solver import solve_joint

    lib = ParallelismLibrary()
    models = [("xlstm-125m", get_config("xlstm-125m")),
              ("gemma3-4b", get_config("gemma3-4b"))]
    jobs = hpo_grid(models, lrs=[1e-4] if quick else [1e-4, 1e-3],
                    batch_sizes=[16, 32], seq_len=512, total_steps=1500)
    G = 32
    counts = list(range(1, G + 1))
    cluster = ClusterSpec(nodes=4, gpus_per_node=8)

    runner_ex = TrialRunner(lib, HARDWARE["a100"])
    t0 = time.time()
    ex = runner_ex.profile_all(jobs, counts, mode="napkin")
    wall_ex = time.time() - t0

    runner_in = TrialRunner(lib, HARDWARE["a100"])
    t0 = time.time()
    pm = runner_in.profile_all(jobs, counts, mode="napkin",
                               strategy="interpolate", workers=4)
    wall_in = time.time() - t0
    reduction = runner_ex.trials / max(runner_in.trials, 1)

    # held-out interpolation error: every combo the exhaustive sweep
    # profiled but the interpolating runner did not
    anchored = pm.anchor_keys()
    errs = []
    for key, p in ex.items():
        if key in anchored or not p.feasible or \
                not math.isfinite(p.step_time_s):
            continue
        errs.append(abs(pm.step_time(*key) - p.step_time_s)
                    / p.step_time_s)
    err_med = float(np.median(errs))
    err_p90 = float(np.percentile(errs, 90))
    err_max = float(np.max(errs))

    # roofline: 2 calibration trials fit the class coefficients, every
    # other combo is predicted from op counts (napkin ground truth, so
    # the predictor sees the same cost surface the "real" trials do)
    runner_rf = TrialRunner(lib, HARDWARE["a100"])
    t0 = time.time()
    pm_rf = runner_rf.profile_all(jobs, counts, mode="napkin",
                                  strategy="roofline", workers=4)
    wall_rf = time.time() - t0
    reduction_rf = runner_ex.trials / max(runner_rf.trials, 1)

    anchored_rf = pm_rf.real_anchor_keys()
    errs_rf = []
    for key, p in ex.items():
        if key in anchored_rf or not p.feasible or \
                not math.isfinite(p.step_time_s):
            continue
        errs_rf.append(abs(pm_rf.step_time(*key) - p.step_time_s)
                       / p.step_time_s)
    rf_err_med = float(np.median(errs_rf))
    rf_err_p90 = float(np.percentile(errs_rf, 90))
    rf_err_max = float(np.max(errs_rf))

    # solver on estimated vs exhaustive profiles; makespans compared
    # end-to-end by replaying ALL plans against the exhaustive
    # ("ground truth") step times.  The MILPs must reach (gap-)optimality
    # — a time-limit incumbent is machine-speed-dependent and would make
    # the CI regression gate flaky — so: few slots, generous limit.
    sol_ex = solve_joint(jobs, ex, G, n_slots=10, time_limit_s=120)
    sol_in = solve_joint(jobs, pm, G, n_slots=10, time_limit_s=120)
    sol_rf = solve_joint(jobs, pm_rf, G, n_slots=10, time_limit_s=120)

    class _Replay(Policy):
        dynamic = False

        def __init__(self, name, schedule):
            self.name = name
            self._schedule = schedule

        def plan(self, jobs, remaining, profiles, cluster, current):
            return self._schedule

    res_ex = simulate(jobs, _Replay("replay-exhaustive",
                                    sol_ex.to_schedule()),
                      ex, cluster, noise_sigma=0.0)
    res_in = simulate(jobs, _Replay("replay-interpolated",
                                    sol_in.to_schedule()),
                      ex, cluster, noise_sigma=0.0)
    res_rf = simulate(jobs, _Replay("replay-roofline",
                                    sol_rf.to_schedule()),
                      ex, cluster, noise_sigma=0.0)
    delta = res_in.makespan_s / res_ex.makespan_s - 1.0
    delta_rf = res_rf.makespan_s / res_ex.makespan_s - 1.0

    out = {
        "quick": quick,
        "jobs": len(jobs),
        "gpu_counts": G,
        "combos_exhaustive": runner_ex.trials,
        "combos_interpolated": runner_in.trials,
        "trial_reduction_x": reduction,
        "profiling_wall_exhaustive_s": wall_ex,
        "profiling_wall_interpolated_s": wall_in,
        "held_out_points": len(errs),
        "interp_err_median": err_med,
        "interp_err_p90": err_p90,
        "interp_err_max": err_max,
        "solver_exhaustive": sol_ex.solver,
        "solver_interpolated": sol_in.solver,
        "solver_est_makespan_exhaustive_s": sol_ex.makespan_s,
        "solver_est_makespan_interpolated_s": sol_in.makespan_s,
        "makespan_exhaustive_s": res_ex.makespan_s,
        "makespan_interpolated_s": res_in.makespan_s,
        "makespan_delta_pct": 100.0 * delta,
        "combos_roofline": runner_rf.trials,
        "roofline_trial_reduction_x": reduction_rf,
        "roofline_calibration_trials":
            runner_rf.roofline_stats["calibration_trials"],
        "roofline_escalated": runner_rf.roofline_stats["escalated"],
        "profiling_wall_roofline_s": wall_rf,
        "roofline_err_median": rf_err_med,
        "roofline_err_p90": rf_err_p90,
        "roofline_err_max": rf_err_max,
        "solver_roofline": sol_rf.solver,
        "makespan_roofline_s": res_rf.makespan_s,
        "makespan_roofline_delta_pct": 100.0 * delta_rf,
    }
    emit("profile_trials", wall_in * 1e6,
         f"real={runner_in.trials} exhaustive={runner_ex.trials} "
         f"reduction={reduction:.1f}x")
    emit("profile_interp_err", err_med * 1e6,
         f"median={err_med:.3f} p90={err_p90:.3f} max={err_max:.3f} "
         f"held_out={len(errs)}")
    emit("profile_makespan_delta", abs(delta) * 1e6,
         f"interp={res_in.makespan_s:.0f}s exhaustive="
         f"{res_ex.makespan_s:.0f}s delta={100 * delta:+.2f}%")
    emit("profile_roofline", wall_rf * 1e6,
         f"real={runner_rf.trials} reduction={reduction_rf:.0f}x "
         f"err_med={rf_err_med:.3f} delta={100 * delta_rf:+.2f}%")
    # acceptance gates (ISSUE 2): >=4x fewer real trials, <=15% median
    # interpolation error, and planning on interpolated profiles costs
    # no more than 5% makespan vs exhaustive (one-sided: slot-rounding
    # luck can make the interpolated plan strictly better)
    assert sol_ex.solver == sol_in.solver == sol_rf.solver, \
        f"asymmetric solver fallback: {sol_ex.solver} vs " \
        f"{sol_in.solver} vs {sol_rf.solver}"
    assert reduction >= 4.0, f"trial reduction {reduction:.2f}x < 4x"
    assert err_med <= 0.15, f"median interp error {err_med:.3f} > 0.15"
    assert delta <= 0.05, f"makespan delta {100 * delta:.2f}% > +5%"
    # roofline gates (ISSUE 6): >=20x fewer real trials than exhaustive,
    # <=15% median held-out step-time error, and the solver's plan on
    # roofline profiles costs at most 10% makespan vs the
    # exhaustively-profiled plan (one-sided, like the interpolate gate:
    # slot-rounding luck can make the roofline plan strictly better)
    assert reduction_rf >= 20.0, \
        f"roofline trial reduction {reduction_rf:.1f}x < 20x"
    assert rf_err_med <= 0.15, \
        f"median roofline error {rf_err_med:.3f} > 0.15"
    assert delta_rf <= 0.10, \
        f"roofline makespan delta {100 * delta_rf:.2f}% > +10%"
    path = os.path.join(ROOT, "BENCH_profile.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\nwrote {path}")
    return out


# ---------------------------------------------------------- solver scaling

def _solver_workload(n_jobs, total_gpus, seed=0):
    """Synthetic workload for the scheduling-core benchmark: varied
    scaling efficiency, geometric count grid up to the cluster size."""
    import numpy as np

    from repro.configs import get_config
    from repro.core.job import Job
    from repro.core.profiler import Profile

    cfg = get_config("xlstm-125m").reduced()
    rng = np.random.RandomState(seed)
    counts, c = [], 1
    while c <= total_gpus:
        counts.append(c)
        c *= 2
    jobs, profiles = [], {}
    for i in range(n_jobs):
        j = Job(f"j{i}", cfg, 8, 64, total_steps=int(rng.randint(150, 500)))
        jobs.append(j)
        base = rng.uniform(1.0, 4.0)
        eff = rng.uniform(0.5, 0.95)
        for g in counts:
            for tech, mult in (("ddp", 1.0), ("fsdp", 1.1), ("gpipe", 1.25)):
                profiles[(j.name, tech, g)] = Profile(
                    j.name, tech, g, base * mult / g ** eff, 1e9, True, "t")
    return jobs, profiles


def _replan_state(jobs, prev, frac=0.3):
    """A mid-flight snapshot at ``frac`` of the plan's makespan: which
    jobs are running (and how far along), which are still waiting —
    exactly what an introspection replan sees."""
    import math

    from repro.core.job import Job

    T = frac * prev.makespan_s
    by = {j.name: j for j in jobs}
    remaining, current, running, live = {}, {}, set(), []
    for a in prev.order():
        j = by[a.job]
        if a.end_s <= T:
            continue                       # already finished
        if a.start_s <= T:                 # running at T
            done = (T - a.start_s) / a.runtime_s
            rem = max(1, int(math.ceil(j.total_steps * (1.0 - done))))
            running.add(j.name)
            current[j.name] = (a.technique, a.n_gpus)
        else:                              # not started yet
            rem = j.total_steps
        remaining[j.name] = rem
        live.append(Job(j.name, j.cfg, j.batch_size, j.seq_len, rem,
                        j.lr, j.seed))
    return T, live, remaining, current, running


def bench_solver(quick=False):
    """The scheduling-core benchmark: solver wall time and makespan
    quality at {8, 32, 64} jobs for the dense time-indexed MILP vs the
    coarse-to-fine refined solve vs the warm-started incremental replan
    (vs a from-scratch replan of the same mid-flight state), plus the
    solver PORTFOLIO (ISSUE 10): at 64 jobs the MILP-vs-LNS race on a
    fifth of the MILP's wall budget must match the capped-dense
    incumbent (headline gate: <= dense makespan at >=4x less wall), and
    128/256-job tiers — beyond what the dense MILP can touch — must
    come back conservation-clean inside a fixed 40 s budget.  Writes
    BENCH_solver.json (repo root).

    Dense solves at the larger tiers hit the time limit (that is the
    point — the dense formulation stops scaling); their wall is the
    limit and their makespan the best incumbent.  The speedup gate
    therefore accepts either a measured >=3x ratio or a dense solve
    still capped while the refined pass finished well under it.
    """
    from repro.core.lns import lns_solve, validate_capacity
    from repro.core.portfolio import (join_stragglers,
                                      makespan_lower_bound,
                                      solve_portfolio)
    from repro.core.solver import (choices_from_profiles,
                                   pooled_choice_map, solve_joint,
                                   solve_residual, split_fixed_running)

    tl = 40.0 if quick else 90.0
    gap = 0.02
    out = {"quick": quick, "time_limit_s": tl, "mip_gap": gap, "tiers": {}}
    for n_jobs in (8, 32, 64):
        jobs, profiles = _solver_workload(n_jobs, total_gpus=64, seed=0)
        t0 = time.time()
        dense = solve_joint(jobs, profiles, 64, n_slots=24,
                            time_limit_s=tl, mip_gap=gap)
        wall_dense = time.time() - t0
        t0 = time.time()
        refined = solve_joint(jobs, profiles, 64, n_slots=24,
                              time_limit_s=tl, mip_gap=gap, refine=True)
        wall_refined = time.time() - t0

        # ---- replan the refined plan's mid-flight state, both ways
        T, live, remaining, current, running = _replan_state(jobs, refined)
        t0 = time.time()
        scratch = solve_joint(live, profiles, 64, n_slots=24,
                              time_limit_s=tl, mip_gap=gap)
        wall_scratch = time.time() - t0
        t0 = time.time()
        cm = {j.name: choices_from_profiles(j, profiles) for j in live}
        fixed, residual = split_fixed_running(
            live, remaining, current, running, cm, profiles,
            restart_cost_s=30.0)
        warm = {a.job: max(0.0, a.start_s - T) for a in refined.order()
                if any(j.name == a.job for j in residual)}
        incr = solve_residual(residual,
                              {j.name: cm[j.name] for j in residual},
                              {None: 64}, fixed, n_slots=24,
                              time_limit_s=tl, mip_gap=gap,
                              warm_starts=warm)
        wall_incr = time.time() - t0

        row = {
            "jobs": n_jobs,
            "wall_dense_s": wall_dense,
            "wall_refined_s": wall_refined,
            "wall_replan_scratch_s": wall_scratch,
            "wall_replan_incremental_s": wall_incr,
            "refined_speedup_x": wall_dense / wall_refined,
            "replan_speedup_x": wall_scratch / wall_incr,
            "makespan_dense_s": dense.makespan_s,
            "makespan_refined_s": refined.makespan_s,
            "makespan_replan_scratch_s": scratch.makespan_s,
            "makespan_replan_incremental_s": incr.makespan_s,
            "solver_dense": dense.solver,
            "solver_refined": refined.solver,
            "solver_incremental": incr.solver,
            "replan_live": len(live),
            "replan_fixed": len(fixed),
            "dense_capped": wall_dense >= 0.95 * tl,
            "scratch_capped": wall_scratch >= 0.95 * tl,
        }
        # lower-is-better wall ratios for the CI regression gate — only
        # where the slow side hit its time limit, so the denominator is
        # a machine-independent constant and the ratio scales purely
        # with the fast path's cost.  Uncapped tiers mix solver search
        # (machine-proportional) with fixed assembly overhead, making
        # the ratio meaningless to gate across runners.
        if row["dense_capped"]:
            row["wall_refined_over_dense"] = wall_refined / wall_dense
        if row["scratch_capped"]:
            row["wall_incremental_over_scratch"] = wall_incr / wall_scratch

        if n_jobs == 64:
            # ---- the portfolio race (headline gate): a fifth of the
            # MILP's budget, must match the capped-dense incumbent
            cm = pooled_choice_map(jobs, profiles)
            budgets = {None: 64}
            t0 = time.time()
            port = solve_portfolio(jobs, cm, budgets,
                                   wall_budget_s=tl / 5.0,
                                   gap_target=gap, seed=0)
            wall_port = time.time() - t0
            join_stragglers()
            tel = port.telemetry
            assert validate_capacity(port.assignments, budgets), \
                "64-job portfolio plan violates capacity"
            row["wall_portfolio_s"] = wall_port
            row["makespan_portfolio_s"] = port.makespan_s
            row["portfolio_winner"] = tel["backend"]
            row["portfolio_gap"] = tel["gap"]
            if row["dense_capped"]:
                row["portfolio_wall_over_dense"] = wall_port / wall_dense
                # ISSUE 10 headline: <= capped-dense incumbent makespan
                # at >= 4x less wall
                assert port.makespan_s <= dense.makespan_s + 1e-6, \
                    f"portfolio makespan {port.makespan_s:.0f}s > " \
                    f"capped dense {dense.makespan_s:.0f}s"
                assert wall_port <= 0.25 * wall_dense + 1.0, \
                    f"portfolio wall {wall_port:.1f}s not >=4x under " \
                    f"dense {wall_dense:.1f}s"
            # satellite: one LNS destroy/repair round at 64 jobs stays
            # under ~50 ms (vectorized objective + event-sweep inserts)
            lsol = lns_solve(jobs, cm, budgets, deadline_s=3.0, seed=0)
            lt = lsol.telemetry
            round_ms = lt["wall_s"] / max(lt["iters"], 1) * 1e3
            row["lns_round_ms_64"] = round_ms
            assert round_ms < 50.0, \
                f"64-job LNS round {round_ms:.1f}ms >= 50ms"
            emit("solver_portfolio_64race", wall_port * 1e6,
                 f"mk={port.makespan_s:.0f}s vs dense "
                 f"{dense.makespan_s:.0f}s wall={wall_port:.1f}s vs "
                 f"{wall_dense:.1f}s winner={tel['backend']} "
                 f"lns_round={round_ms:.1f}ms")
        out["tiers"][str(n_jobs)] = row
        emit(f"solver_{n_jobs}jobs", wall_dense * 1e6,
             f"dense={wall_dense:.1f}s refined={wall_refined:.1f}s "
             f"({row['refined_speedup_x']:.1f}x) "
             f"replan scratch={wall_scratch:.1f}s "
             f"incr={wall_incr:.1f}s ({row['replan_speedup_x']:.1f}x) "
             f"mk_ratio={refined.makespan_s / dense.makespan_s:.3f}")
        # quality: the refined pass must stay within 5% of dense, and
        # the warm-started replan must not trade its speed for plan
        # quality vs the from-scratch re-solve
        assert refined.makespan_s <= dense.makespan_s * 1.05 + 1e-6, \
            f"{n_jobs} jobs: refined makespan " \
            f"{refined.makespan_s:.0f}s > 1.05x dense " \
            f"{dense.makespan_s:.0f}s"
        assert incr.makespan_s <= scratch.makespan_s * 1.2 + 1e-6, \
            f"{n_jobs} jobs: incremental replan makespan " \
            f"{incr.makespan_s:.0f}s > 1.2x scratch " \
            f"{scratch.makespan_s:.0f}s"

    # ---- portfolio-only tiers (ISSUE 10): job counts the dense MILP
    # cannot touch, on a FIXED 40 s budget (same in quick and nightly —
    # the budget is the contract, not a share of the MILP's limit)
    port_budget = 40.0
    for n_jobs in (128, 256):
        jobs, profiles = _solver_workload(n_jobs, total_gpus=64, seed=0)
        cm = pooled_choice_map(jobs, profiles)
        budgets = {None: 64}
        lb = makespan_lower_bound(jobs, cm, budgets)
        t0 = time.time()
        port = solve_portfolio(jobs, cm, budgets,
                               wall_budget_s=port_budget,
                               gap_target=gap, seed=0)
        wall_port = time.time() - t0
        join_stragglers()
        tel = port.telemetry
        ok = validate_capacity(port.assignments, budgets)
        complete = (ok and len(port.assignments) == n_jobs
                    and wall_port <= port_budget * 1.2)
        row = {
            "jobs": n_jobs,
            "wall_portfolio_s": wall_port,
            "makespan_portfolio_s": port.makespan_s,
            "portfolio_winner": tel["backend"],
            "portfolio_gap": tel["gap"],
            "lower_bound_s": lb,
            "conservation_ok": ok,
        }
        if n_jobs == 256:
            # ISSUE 10 headline: a feasible, conservation-clean plan for
            # 256 jobs inside the 40 s budget (absolute-floor gated)
            row["portfolio_completes_256"] = 1.0 if complete else 0.0
            assert complete, \
                f"256-job portfolio incomplete: conservation_ok={ok} " \
                f"n_assigned={len(port.assignments)} " \
                f"wall={wall_port:.1f}s (budget {port_budget:.0f}s)"
        out["tiers"][str(n_jobs)] = row
        emit(f"solver_portfolio_{n_jobs}jobs", wall_port * 1e6,
             f"mk={port.makespan_s:.0f}s lb={lb:.0f}s "
             f"gap={tel['gap']:.3f} winner={tel['backend']} "
             f"wall={wall_port:.1f}s conservation_ok={ok}")

    # acceptance gates (ISSUE 4), at the 64-job tier.  When the dense
    # solve is still grinding at its time limit its true cost is only
    # bounded below, so a capped dense + a refined pass well under the
    # cap also proves the reduction (and keeps the gate meaningful on
    # slower CI machines where wall_refined stretches but the capped
    # wall_dense cannot).
    r64 = out["tiers"]["64"]
    assert r64["refined_speedup_x"] >= 3.0 or (
        r64["dense_capped"]
        and r64["wall_refined_s"] <= 0.6 * r64["wall_dense_s"]), \
        f"refined speedup {r64['refined_speedup_x']:.2f}x < 3x at 64 jobs"
    assert r64["replan_speedup_x"] >= 1.5 or (
        r64["scratch_capped"]
        and r64["wall_replan_incremental_s"]
        <= 0.6 * r64["wall_replan_scratch_s"]), \
        f"incremental replan not measurably cheaper: " \
        f"{r64['replan_speedup_x']:.2f}x"
    path = os.path.join(ROOT, "BENCH_solver.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\nwrote {path}")
    return out


# --------------------------------------------------------------- kernels

def bench_kernels():
    """Kernel micro-bench: pure-jnp reference vs Pallas(interpret) — the
    derived column reports correctness deltas; wall-times on CPU are NOT
    TPU perf (interpret mode runs the kernel body in Python)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.mlstm_chunk import mlstm_chunk
    from repro.kernels.rglru_scan import rglru_scan

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    B, S, H, D = 1, 512, 4, 64

    def timeit(f, *a, n=3):
        f(*a)  # compile
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(f(*a))
        return (time.time() - t0) / n * 1e6

    q = jax.random.normal(ks[0], (B, S, H, D)) * D ** -0.5
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    t_ref = timeit(jax.jit(lambda *a: ref.blockwise_attention_ref(*a)),
                   q, k, v)
    err = float(jnp.max(jnp.abs(
        flash_attention(q, k, v, interpret=True)
        - ref.attention_ref(q, k, v))))
    emit("kernel_flash_attention_ref_jnp", t_ref,
         f"pallas_interpret_maxerr={err:.2e}")

    a = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, 256))) * .2 + .8
    b = jax.random.normal(ks[4], (B, S, 256)) * .1
    t_ref = timeit(jax.jit(ref.rglru_scan_ref), a, b)
    err = float(jnp.max(jnp.abs(rglru_scan(a, b, interpret=True)
                                - ref.rglru_scan_ref(a, b))))
    emit("kernel_rglru_scan_ref_jnp", t_ref,
         f"pallas_interpret_maxerr={err:.2e}")

    ip = jax.random.normal(ks[3], (B, S, H))
    fp = jax.random.normal(ks[4], (B, S, H)) * 2 + 2
    t_ref = timeit(jax.jit(lambda *x: ref.mlstm_chunked_ref(*x)),
                   q, k, v, ip, fp)
    err = float(jnp.max(jnp.abs(
        mlstm_chunk(q, k, v, ip, fp, interpret=True)
        - ref.mlstm_ref(q, k, v, ip, fp))))
    emit("kernel_mlstm_chunk_ref_jnp", t_ref,
         f"pallas_interpret_maxerr={err:.2e}")


# --------------------------------------------------------------- roofline

HW = {"flops": 197e12, "hbm": 819e9, "ici": 50e9}  # TPU v5e per chip


def model_flops_per_step(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode D=batch
    tokens; train adds backward (x3)."""
    from repro.configs import get_config
    from repro.models.config import INPUT_SHAPES
    from repro.models.params import param_count
    from repro.models.transformer import model_spec

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n = param_count(model_spec(cfg))
    if cfg.is_moe:
        m = cfg.moe
        expert_params = (3 * cfg.d_model * m.d_ff_expert
                         * cfg.num_layers * m.num_experts)
        n = n - expert_params + expert_params * (m.top_k / m.num_experts)
    tokens = shape.global_batch * (shape.seq_len if shape.mode == "train"
                                   else (shape.seq_len if shape.mode ==
                                         "prefill" else 1))
    per_token = 2.0 * n
    mult = 3.0 if shape.mode == "train" else 1.0  # fwd+bwd
    return per_token * tokens * mult


def bench_roofline(dryrun_dir=os.path.join(RESULTS, "dryrun")):
    """Three-term roofline per (arch x shape) from the dry-run artifacts
    (single-pod mesh).  Writes results/roofline.json."""
    rows = []
    if not os.path.isdir(dryrun_dir):
        print("no dryrun results; run repro.launch.dryrun first")
        return []
    for fn in sorted(os.listdir(dryrun_dir)):
        if not fn.endswith("_pod.json"):
            continue
        with open(os.path.join(dryrun_dir, fn)) as f:
            rec = json.load(f)
        if rec["status"] != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "status": rec["status"],
                         "reason": rec.get("reason", rec.get("error"))})
            continue
        n_dev = 256
        compute_s = rec["flops"] / HW["flops"]
        memory_s = rec["bytes_written"] / HW["hbm"]
        coll_s = rec["collectives"]["total"] / HW["ici"]
        dominant = max((compute_s, "compute"), (memory_s, "memory"),
                       (coll_s, "collective"))[1]
        mf = model_flops_per_step(rec["arch"], rec["shape"])
        useful = mf / (rec["flops"] * n_dev) if rec["flops"] else 0.0
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s, "dominant": dominant,
            "model_flops": mf, "hlo_flops_global": rec["flops"] * n_dev,
            "useful_ratio": useful,
            "peak_bytes_per_device": rec.get("memory", {}).get(
                "peak_per_device"),
        })
    with open(os.path.join(RESULTS, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1)
    print("\n== Roofline (single pod, 256 chips; seconds per step) ==")
    hdr = (f"{'arch':22s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'collect':>9s} {'bound':>10s} {'useful':>7s}")
    print(hdr)
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']:22s} {r['shape']:12s} {'skip':>9s}")
            continue
        print(f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:9.3f} "
              f"{r['memory_s']:9.3f} {r['collective_s']:9.3f} "
              f"{r['dominant']:>10s} {r['useful_ratio']:7.2f}")
        emit(f"roofline_{r['arch']}_{r['shape']}",
             max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
             f"bound={r['dominant']} useful={r['useful_ratio']:.2f}")
    return rows


def bench_preset_compare(base_dir=os.path.join(RESULTS, "dryrun"),
                         opt_dir=os.path.join(RESULTS, "dryrun_opt")):
    """Baseline vs optimized-preset dominant roofline term per pair."""
    if not os.path.isdir(opt_dir):
        print("no optimized dry-run results; run "
              "repro.launch.dryrun --preset optimized first")
        return
    print("\n== Baseline vs optimized preset (dominant term, s/step) ==")
    print(f"{'arch':22s} {'shape':12s} {'base':>8s} {'opt':>8s} {'x':>6s}"
          f"  {'base bound':>10s} -> {'opt bound':>10s}")
    rows = []
    for fn in sorted(os.listdir(opt_dir)):
        if not fn.endswith("_pod.json"):
            continue
        bpath = os.path.join(base_dir, fn)
        if not os.path.exists(bpath):
            continue
        with open(os.path.join(opt_dir, fn)) as f:
            o = json.load(f)
        with open(bpath) as f:
            b = json.load(f)
        if o["status"] != "ok" or b["status"] != "ok":
            continue

        def terms(r):
            return {"compute": r["flops"] / HW["flops"],
                    "memory": r["bytes_written"] / HW["hbm"],
                    "collective": r["collectives"]["total"] / HW["ici"]}
        tb, to = terms(b), terms(o)
        db, do_ = max(tb, key=tb.get), max(to, key=to.get)
        speed = tb[db] / max(to[do_], 1e-12)
        rows.append({"arch": o["arch"], "shape": o["shape"],
                     "base_dominant_s": tb[db], "opt_dominant_s": to[do_],
                     "speedup": speed, "base_bound": db, "opt_bound": do_})
        print(f"{o['arch']:22s} {o['shape']:12s} {tb[db]:8.3f} "
              f"{to[do_]:8.3f} {speed:6.2f}  {db:>10s} -> {do_:>10s}")
        emit(f"preset_{o['arch']}_{o['shape']}", to[do_] * 1e6,
             f"speedup={speed:.2f}x {db}->{do_}")
    with open(os.path.join(RESULTS, "preset_compare.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("which", nargs="?", default="all",
                    choices=["all", "roofline", "kernels", "solver",
                             "introspection", "table2", "schedule",
                             "profile", "hetero", "chaos", "e2e",
                             "serve", "recover"])
    ap.add_argument("--quick", action="store_true",
                    help="reduced workloads (CI smoke job)")
    args = ap.parse_args()
    which = args.which
    if which in ("roofline", "all"):
        bench_roofline()
        bench_preset_compare()
    if which in ("kernels", "all"):
        bench_kernels()
    if which in ("solver", "all"):
        bench_solver(quick=args.quick)
    if which in ("schedule", "all"):
        bench_schedule(quick=args.quick)
    if which in ("profile", "all"):
        bench_profile(quick=args.quick)
    if which in ("hetero", "all"):
        bench_hetero(quick=args.quick)
    if which in ("chaos", "all"):
        bench_chaos(quick=args.quick)
    if which in ("e2e", "all"):
        bench_e2e(quick=args.quick)
    if which in ("serve", "all"):
        bench_serve(quick=args.quick)
    if which in ("recover", "all"):
        bench_recover(quick=args.quick)
    if which in ("introspection", "all"):
        bench_introspection()
    if which in ("table2", "all"):
        bench_table2()
    print("\n== CSV summary ==")
    print("name,us_per_call,derived")
    for row in CSV_ROWS:
        print(row)


if __name__ == "__main__":
    main()
