"""Parallelism-equivalence checker.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(so the main pytest process keeps 1 device): for each technique, one real
train step on 8 virtual devices must match the single-device baseline.

Usage: python -m repro.testing.parallel_check [arch_id]
"""
import os
import sys

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def check(arch_id: str = "h2o-danube-3-4b", n_devices: int = 8,
          tol: float = 2e-2) -> int:
    from repro.configs import concrete_batch, get_config
    from repro.models.transformer import init_model
    from repro.optim.adamw import AdamWConfig, init_opt_state
    from repro.parallelism.build import BuiltJob
    from repro.parallelism.techniques import DEFAULT_TECHNIQUES
    from repro.train.steps import make_train_step

    cfg = get_config(arch_id).reduced(num_layers=4)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    key = jax.random.PRNGKey(42)
    batch = concrete_batch(cfg, 8, 32)

    # single-device baseline
    params0 = init_model(cfg, key)
    opt0 = init_opt_state(params0)
    p_ref, o_ref, m_ref = jax.jit(make_train_step(cfg, opt_cfg))(
        params0, opt0, batch)
    ref_loss = float(m_ref["loss"])
    print(f"[baseline] {arch_id} loss={ref_loss:.6f}")

    failures = 0
    for tech in DEFAULT_TECHNIQUES:
        if not tech.search_space(cfg, n_devices):
            print(f"[{tech.name}] not in search space for {arch_id}@{n_devices} — skipped")
            continue
        plan = tech.plan(cfg, n_devices)
        job = BuiltJob(cfg, plan, opt_cfg)
        params, opt = job.init(key)
        b = job.place_batch(batch)
        p1, o1, m1 = job.step(params, opt, b)
        loss = float(m1["loss"])
        # compare updated params against baseline update
        diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32) - b_.astype(jnp.float32))))
                 for a, b_ in zip(jax.tree.leaves(p1), jax.tree.leaves(p_ref))]
        max_diff = max(diffs)
        ok = abs(loss - ref_loss) < tol and max_diff < tol
        print(f"[{tech.name}] loss={loss:.6f} dloss={abs(loss-ref_loss):.2e} "
              f"max_param_diff={max_diff:.2e} {'OK' if ok else 'FAIL'}")
        if not ok:
            failures += 1
    return failures


if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "h2o-danube-3-4b"
    sys.exit(check(arch))
