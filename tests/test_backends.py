"""Execution backends: the ExecutionBackend protocol, SimBackend
equivalence with the default path, the ObservedProfiles feedback
overlay, the LocalJaxBackend really training through the Schedule IR
(checkpointed preemption + resume), and the strict library load."""
import dataclasses
import os

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.baselines import CurrentPractice, OptimusDynamic
from repro.core.executor import simulate
from repro.core.job import ClusterSpec, Job
from repro.core.library import ParallelismLibrary
from repro.core.local_backend import LocalJaxBackend
from repro.core.perfmodel import ObservedProfiles
from repro.core.profiler import Profile
from repro.core.runtime import SimBackend
from repro.core.schedule import Policy, Schedule, ScheduleEntry

CFG = get_config("xlstm-125m").reduced()
# micro same-family variant: small enough that real CPU training steps
# are milliseconds and JIT compiles are a couple of seconds
MICRO = dataclasses.replace(CFG, d_model=64, num_heads=2, num_kv_heads=2,
                            head_dim=32, name="xlstm-micro")


def mk_workload(n_jobs=6, seed=0, total_gpus=8):
    rng = np.random.RandomState(seed)
    jobs, profiles = [], {}
    for i in range(n_jobs):
        j = Job(f"j{i}", CFG, 8, 64, total_steps=int(rng.randint(100, 400)))
        jobs.append(j)
        base = rng.uniform(1.0, 4.0)
        eff = rng.uniform(0.5, 0.95)
        g = 1
        while g <= total_gpus:
            for tech, mult in (("ddp", 1.0), ("fsdp", 1.1), ("gpipe", 1.25)):
                profiles[(j.name, tech, g)] = Profile(
                    j.name, tech, g, base * mult / g ** eff, 1e9, True, "t")
            g *= 2
    return jobs, profiles


CLUSTER = ClusterSpec(nodes=1, gpus_per_node=8, restart_cost_s=10.0)


# ------------------------------------------------ protocol / sim backend

def test_explicit_sim_backend_is_the_default():
    """simulate(exec_backend=SimBackend(...)) must be bit-identical to
    the default path (same noise seeding, same event semantics)."""
    jobs, profiles = mk_workload(n_jobs=6, seed=3)
    a = simulate(jobs, OptimusDynamic(), profiles, CLUSTER,
                 introspect_every_s=120, noise_sigma=0.3, noise_seed=7)
    b = simulate(jobs, OptimusDynamic(), profiles, CLUSTER,
                 introspect_every_s=120,
                 exec_backend=SimBackend(noise_sigma=0.3, noise_seed=7))
    assert a.makespan_s == b.makespan_s
    assert a.restarts == b.restarts
    assert a.replans == b.replans
    assert len(a.gantt) == len(b.gantt)


def test_sim_result_stats_empty_for_sim():
    jobs, profiles = mk_workload(n_jobs=3, seed=1)
    res = simulate(jobs, CurrentPractice(), profiles, CLUSTER)
    assert res.stats == {}


# ------------------------------------------------- observed-profile view

def test_observed_profiles_overlay():
    _, profiles = mk_workload(n_jobs=2, seed=0)
    key = ("j0", "ddp", 2)
    obs = ObservedProfiles(profiles, {key: 123.0})
    assert obs[key].step_time_s == 123.0
    assert obs[key].source == "observed"
    # untouched combos pass through, the base is not mutated
    other = ("j1", "ddp", 2)
    assert obs[other].step_time_s == profiles[other].step_time_s
    assert profiles[key].step_time_s != 123.0
    # Mapping contract: same keys, same size
    assert set(obs) == set(profiles)
    assert len(obs) == len(profiles)


def test_observed_profiles_key_normalization():
    """Default-class 4-tuple and 3-tuple keys hit the same observation
    (single-class PerfModels answer both shapes)."""
    _, profiles = mk_workload(n_jobs=1, seed=0)
    obs = ObservedProfiles(profiles, {("j0", "ddp", 1): 9.0})
    assert obs[("j0", "ddp", 1)].step_time_s == 9.0


# --------------------------------------------------- local JAX execution

def _local_workload(n_jobs, steps, est=0.01):
    jobs = [Job(f"j{i}", MICRO, 2, 32, total_steps=steps, lr=1e-3, seed=i)
            for i in range(n_jobs)]
    profiles = {}
    for j in jobs:
        for tech in ("ddp", "remat-offload"):
            profiles[(j.name, tech, 1)] = Profile(
                j.name, tech, 1, est, 1e9, True, "t")
    return jobs, profiles


LOCAL_CLUSTER = ClusterSpec(nodes=1, gpus_per_node=1, restart_cost_s=0.5)


@pytest.mark.slow
def test_local_backend_trains_schedule_for_real(tmp_path):
    """A 3-job workload really trains through the Schedule IR: every
    job runs its exact step budget, checkpoints land on disk, and
    measured step times feed the observation channel."""
    jobs, profiles = _local_workload(n_jobs=3, steps=12)
    be = LocalJaxBackend(ckpt_dir=str(tmp_path))
    res = simulate(jobs, CurrentPractice(), profiles, LOCAL_CLUSTER,
                   exec_backend=be)
    assert {g.job for g in res.gantt if g.kind == "run"} == \
        {j.name for j in jobs}
    assert res.makespan_s > 0
    for j in jobs:
        st = res.stats[j.name]
        assert sum(s["steps"] for s in st["segments"]) == j.total_steps
        # the loss trajectory is real numbers from real training
        assert all(np.isfinite(loss) for _, loss in st["losses"])
        assert os.path.exists(tmp_path / f"{j.name}.npz")
        # compile time is kept out of the measured step rate
        seg = st["segments"][0]
        assert seg["compile_s"] > seg["measured_step_s"]
    assert be.observed, "measured step times must reach the feedback dict"
    for v in be.observed.values():
        assert 0 < v < 10


class FlipWhenProgressed(Policy):
    """Dynamic policy that changes j0's technique at the first replan
    that observes real progress — guaranteeing a mid-run
    preempt/checkpoint/restart with a non-trivial resume point."""

    name = "flip"
    dynamic = True
    replan_on_completion = False

    def __init__(self, total_steps):
        self.total = total_steps
        self.flipped = False

    def plan(self, jobs, remaining, profiles, cluster, current):
        if remaining.get("j0", self.total) < self.total:
            self.flipped = True
        tech = "remat-offload" if self.flipped else "ddp"
        return Schedule([ScheduleEntry(
            j.name, tech if j.name == "j0" else "ddp", 1) for j in jobs])


@pytest.mark.slow
def test_local_backend_preempt_checkpoint_resume(tmp_path):
    """An introspection replan preempts the running job; it must
    checkpoint, pay the restart penalty, resume from the saved step
    with the data stream continued, and finish its exact budget."""
    steps = 1500
    jobs, profiles = _local_workload(n_jobs=1, steps=steps)
    be = LocalJaxBackend(ckpt_dir=str(tmp_path))
    res = simulate(jobs, FlipWhenProgressed(steps), profiles,
                   LOCAL_CLUSTER, introspect_every_s=1.0, exec_backend=be)
    assert res.restarts >= 1
    segs = res.stats["j0"]["segments"]
    assert len(segs) >= 2 and segs[0]["preempted"]
    # resume continuity: each segment starts exactly where the previous
    # one checkpointed, and the budget is met in total
    for a, b in zip(segs, segs[1:]):
        assert b["start_step"] == a["start_step"] + a["steps"]
    assert sum(s["steps"] for s in segs) == steps
    assert segs[0]["steps"] > 0, "flip fired before any observed progress"
    assert segs[0]["technique"] == "ddp"
    assert segs[-1]["technique"] == "remat-offload"
    # the run segments around the restart respect the real penalty
    restarts = [g for g in res.gantt if g.kind == "restart"]
    assert len(restarts) == res.restarts
    for r in restarts:
        assert abs((r.end_s - r.start_s)
                   - LOCAL_CLUSTER.restart_cost_s) < 1e-9
    # losses were recorded across the boundary and stayed finite
    losses = res.stats["j0"]["losses"]
    assert len(losses) == steps
    assert all(np.isfinite(loss) for _, loss in losses)
    steps_logged = [s for s, _ in losses]
    assert steps_logged == sorted(steps_logged)
    assert steps_logged[0] == 1 and steps_logged[-1] == steps


def test_local_worker_failure_surfaces_and_quarantines(tmp_path):
    """An exception escaping a worker thread must reach the engine as a
    detected worker failure (never a silent hang in wait_until): the
    job is retried under its budget, then quarantined with the reason,
    and the run completes."""
    from repro.core.chaos import RetryPolicy

    class Boom:
        name = "boom"

        def search_space(self, cfg, n):
            return n == 1

        def plan(self, cfg, n):
            raise RuntimeError("poisoned technique")

    lib = ParallelismLibrary()
    lib.register(Boom())
    jobs = [Job("j0", MICRO, 2, 32, total_steps=50, lr=1e-3, seed=0)]
    # the only profile j0 has is the poisoned technique: every launch
    # of it dies inside the worker thread
    profiles = {("j0", "boom", 1): Profile("j0", "boom", 1, 0.01, 1e9,
                                           True, "t")}
    be = LocalJaxBackend(
        library=lib, ckpt_dir=str(tmp_path),
        retry_policy=RetryPolicy(budget=1, base_s=0.1, cap_s=0.2,
                                 jitter=0.0))
    res = simulate(jobs, CurrentPractice(), profiles, LOCAL_CLUSTER,
                   exec_backend=be)
    # budget 1: original + one retry fail, then quarantine
    assert res.worker_failures == 2
    assert res.restarts == 1
    assert "j0" in res.quarantined
    assert "retry budget exhausted" in res.quarantined["j0"]
    assert "poisoned technique" in res.quarantined["j0"]
    seg = res.stats["j0"]["segments"][0]
    assert seg["failed"] and "poisoned technique" in seg["failed"]


# ------------------------------------------------------ session plumbing

def test_session_rejects_unknown_backend():
    from repro.core.api import SaturnSession
    sess = SaturnSession(CLUSTER)
    with pytest.raises(ValueError):
        sess.run(backend="remote")
    with pytest.raises(ValueError):
        sess.run(backend="sim", ckpt_dir="/tmp/x")


# ------------------------------------------------------ library loading

def test_library_load_strict_raises_on_missing(tmp_path):
    lib = ParallelismLibrary()

    class Custom:
        name = "my-custom"

        def search_space(self, cfg, n):
            return n == 1

        def plan(self, cfg, n):
            raise NotImplementedError

    lib.register(Custom())
    p = str(tmp_path / "lib.json")
    lib.save(p)
    # default pool lacks "my-custom": strict load must name it
    with pytest.raises(KeyError, match="my-custom"):
        ParallelismLibrary.load(p)
    lax = ParallelismLibrary.load(p, strict=False)
    assert "my-custom" not in lax.names()
    assert "ddp" in lax.names()
    full = ParallelismLibrary.load(p, available=list(
        dict(lib.items()).values()))
    assert "my-custom" in full.names()
