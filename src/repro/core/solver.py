"""Saturn's joint Solver (paper §2): parallelism selection + GPU
allocation + scheduling as one mixed-integer linear program.

Time-indexed formulation (the tech-report formulation, Gurobi swapped
for HiGHS via ``scipy.optimize.milp`` — same MILP, different solver):

  binaries  x[j,c,t]  — job j starts config c = (technique, g, duration)
                         at time slot t
  continuous M         — makespan

  min  M + eps * sum t*x                    (eps tie-breaks earlier starts)
  s.t. sum_{c,t} x[j,c,t] = 1               for every job j
       sum_{j,c} g_c * sum_{t in (tau-d_c, tau]} x[j,c,t] <= G   for all tau
       (t + d_jc) * delta * x[j,c,t] <= M   for all j,c,t

The flat MILP (``solve_joint``) and the node-locality MILP
(``solve_joint_nodes``) share one constraint builder (:class:`_MilpBuilder`)
and both emit Schedule IR via :meth:`Solution.to_schedule` — the
node-aware solution carries per-job node assignments the runtime's
NodeAware placement backend honors.

A greedy list-scheduling fallback guards against solver timeouts (and is
also used to compute an upper bound that sizes the horizon).
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import os
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np
from scipy import sparse
from scipy.optimize import LinearConstraint, milp, Bounds

from .job import Job
from .profiler import Profile
from .schedule import Schedule, ScheduleEntry


@contextlib.contextmanager
def _quiet_stdout():
    """HiGHS prints C-level debug lines; mute fd 1 during the solve."""
    try:
        saved = os.dup(1)
        devnull = os.open(os.devnull, os.O_WRONLY)
        os.dup2(devnull, 1)
        yield
    finally:
        os.dup2(saved, 1)
        os.close(saved)
        os.close(devnull)


@dataclasses.dataclass(frozen=True)
class Choice:
    """One point in a job's config space."""
    technique: str
    n_gpus: int
    runtime_s: float          # total remaining runtime under this config
    device_class: Optional[str] = None   # class-qualified (hetero) choices


@dataclasses.dataclass
class Assignment:
    job: str
    technique: str
    n_gpus: int
    start_s: float
    runtime_s: float
    nodes: Optional[Tuple[int, ...]] = None   # node-aware MILP only
    device_class: Optional[str] = None        # class-aware MILP only

    @property
    def end_s(self) -> float:
        return self.start_s + self.runtime_s


@dataclasses.dataclass
class Solution:
    assignments: List[Assignment]
    makespan_s: float
    solver: str               # "milp" | "milp-nodes" | "milp-classes" | "greedy"
    milp_status: Optional[str] = None

    def order(self) -> List[Assignment]:
        return sorted(self.assignments, key=lambda a: (a.start_s, a.job))

    def to_schedule(self) -> Schedule:
        """Emit Schedule IR: the typed contract the runtime executes."""
        entries = [ScheduleEntry(a.job, a.technique, a.n_gpus,
                                 start_s=a.start_s, runtime_s=a.runtime_s,
                                 nodes=a.nodes, device_class=a.device_class)
                   for a in self.order()]
        return Schedule(entries, solver=self.solver,
                        makespan_s=self.makespan_s)


# ------------------------------------------------- shared MILP machinery

class _MilpBuilder:
    """Accumulates sparse linear constraints + runs the HiGHS MILP.

    Both joint formulations are "binary start variables + one continuous
    makespan var"; this builder owns the shared mechanics (sparse
    triplets, row bounds, bounds/integrality vectors, solver call) so
    the two solvers only differ in which constraints they emit.
    """

    def __init__(self, n_binary: int):
        self.n_binary = n_binary
        self.nvar = n_binary + 1          # + makespan, always last
        self.M_idx = n_binary
        self._rows: List[int] = []
        self._cols: List[int] = []
        self._vals: List[float] = []
        self._lbs: List[float] = []
        self._ubs: List[float] = []
        self._r = 0

    def add(self, terms: Iterable[Tuple[int, float]],
            lb: float, ub: float) -> None:
        """One constraint row: lb <= sum coef*x[col] <= ub."""
        for col, coef in terms:
            self._rows.append(self._r)
            self._cols.append(col)
            self._vals.append(coef)
        self._lbs.append(lb)
        self._ubs.append(ub)
        self._r += 1

    def add_makespan(self, var: int, end_s: float) -> None:
        """end_s * x[var] - M <= 0."""
        self.add([(var, end_s), (self.M_idx, -1.0)], -np.inf, 0.0)

    def solve(self, cvec: np.ndarray, *, time_limit_s: float,
              mip_gap: float):
        """Run HiGHS; returns the scipy result or None on failure."""
        A = sparse.coo_matrix(
            (self._vals, (self._rows, self._cols)),
            shape=(self._r, self.nvar)).tocsc()
        cons = LinearConstraint(A, np.array(self._lbs), np.array(self._ubs))
        integrality = np.ones(self.nvar)
        integrality[self.M_idx] = 0
        bounds = Bounds(np.zeros(self.nvar),
                        np.concatenate([np.ones(self.n_binary), [np.inf]]))
        try:
            with _quiet_stdout():
                res = milp(c=cvec, constraints=cons,
                           integrality=integrality, bounds=bounds,
                           options={"time_limit": time_limit_s,
                                    "mip_rel_gap": mip_gap,
                                    "presolve": True})
        except Exception:
            return None
        if not res.success or res.x is None:
            return None
        return res


def choices_from_profiles(job: Job, profiles, *, prune: bool = True,
                          device_class: Optional[str] = None
                          ) -> List[Choice]:
    """Feasible (technique, g) choices with total runtimes for one job.

    ``profiles`` is either the legacy exhaustive dict or a
    :class:`~repro.core.perfmodel.PerfModel` — with a model, choices are
    evaluated straight off the throughput curves, so the MILP optimizes
    over every count in the model's grid even though only the anchor
    counts were actually profiled.  Enumeration goes through
    ``iter_job_profiles`` so the solver sees exactly the grid the
    policies see.

    prune=True drops Pareto-dominated choices (same or more GPUs, same or
    worse runtime) — a large constant-factor MILP size reduction that
    does not change the optimum.
    """
    from .perfmodel import iter_job_profiles
    out = [Choice(tech, g, p.step_time_s * job.total_steps,
                  device_class=device_class)
           for tech, g, p in iter_job_profiles(profiles, job.name,
                                               device_class=device_class)
           if p.feasible]
    if prune and out:
        out.sort(key=lambda c: (c.n_gpus, c.runtime_s))
        kept: List[Choice] = []
        best_rt = math.inf
        for c in out:
            if c.runtime_s < best_rt - 1e-9:
                kept.append(c)
                best_rt = c.runtime_s
        out = kept
    return out


def greedy_schedule(jobs: List[Job], choices: Dict[str, List[Choice]],
                    total_gpus) -> Solution:
    """List scheduling: longest-remaining-work first, each job on its
    best-throughput feasible choice that fits when it starts.

    ``total_gpus`` is either a single pooled budget (int — the legacy
    flat cluster) or per-device-class budgets (``{class_name: gpus}``);
    with budgets, each Choice draws from its own class's pool.
    """
    if isinstance(total_gpus, dict):
        free = dict(total_gpus)
    else:
        free = {None: int(total_gpus)}

    def pool(c: Choice):
        return c.device_class if c.device_class in free else None

    # rank jobs by their best-possible runtime, longest first
    ranked = sorted(
        jobs, key=lambda j: -min((c.runtime_s for c in choices[j.name]),
                                 default=0.0))
    t = 0.0
    running: List[Tuple[float, Assignment]] = []
    out: List[Assignment] = []
    queue = list(ranked)
    while queue or running:
        progressed = True
        while progressed and queue:
            progressed = False
            for job in list(queue):
                fits = [c for c in choices[job.name]
                        if c.n_gpus <= free[pool(c)]]
                if fits:
                    c = min(fits, key=lambda c: c.runtime_s)
                    a = Assignment(job.name, c.technique, c.n_gpus, t,
                                   c.runtime_s, device_class=c.device_class)
                    out.append(a)
                    running.append((a.end_s, a))
                    free[pool(c)] -= c.n_gpus
                    queue.remove(job)
                    progressed = True
        if not running:
            if queue:  # nothing fits at all — infeasible choice sets
                raise RuntimeError("greedy: no feasible choice fits cluster")
            break
        running.sort(key=lambda x: x[0])
        t_end, done = running.pop(0)
        t = t_end
        key = done.device_class if done.device_class in free else None
        free[key] += done.n_gpus
    makespan = max((a.end_s for a in out), default=0.0)
    return Solution(out, makespan, "greedy")


def _solve_time_indexed(jobs: List[Job],
                        choice_map: Dict[str, List[Choice]],
                        budgets: Dict[Optional[str], int],
                        ub: Solution, solver_name: str, *,
                        n_slots: int, time_limit_s: float,
                        mip_gap: float) -> Solution:
    """The shared time-indexed MILP core behind ``solve_joint`` (one
    pooled budget under the ``None`` key) and ``solve_joint_classes``
    (one budget per device class): binary start variables x[j, c, t],
    capacity rows per (budget pool, slot), a continuous makespan var,
    and an eps tie-break toward earlier starts.  Falls back to the
    greedy upper bound ``ub`` on infeasibility/timeout."""
    horizon = max(ub.makespan_s, 1e-6) * 1.05
    delta = horizon / n_slots

    def pool(c: Choice) -> Optional[str]:
        return c.device_class if c.device_class in budgets else None

    # variable layout: x[j, c, t] flattened, then M last
    index: List[Tuple[int, Choice, int]] = []   # (job_idx, choice, slot)
    var_of: Dict[Tuple[int, int, int], int] = {}
    dur_of: Dict[int, int] = {}
    for ji, j in enumerate(jobs):
        for ci, c in enumerate(choice_map[j.name]):
            dur = max(1, math.ceil(c.runtime_s / delta - 1e-9))
            if dur > n_slots:
                continue
            for t in range(n_slots - dur + 1):
                var_of[(ji, ci, t)] = len(index)
                dur_of[len(index)] = dur
                index.append((ji, c, t))
    nx = len(index)

    b = _MilpBuilder(nx)
    # (1) each job picks exactly one (choice, start)
    for ji in range(len(jobs)):
        terms = [(vi, 1.0) for (ji2, ci, t), vi in var_of.items()
                 if ji2 == ji]
        if not terms:
            return ub          # some job's every choice outlasts horizon
        b.add(terms, 1.0, 1.0)
    # (2) capacity per (budget pool, slot)
    for pkey, cap in budgets.items():
        for tau in range(n_slots):
            terms = []
            for (ji, ci, t), vi in var_of.items():
                c = choice_map[jobs[ji].name][ci]
                if pool(c) == pkey and t <= tau < t + dur_of[vi]:
                    terms.append((vi, float(c.n_gpus)))
            if terms:
                b.add(terms, -np.inf, float(cap))
    # (3) makespan: (t + dur)*delta * x - M <= 0
    for (ji, ci, t), vi in var_of.items():
        b.add_makespan(vi, (t + dur_of[vi]) * delta)

    cvec = np.zeros(b.nvar)
    cvec[b.M_idx] = 1.0
    eps = delta * 1e-4
    for key, vi in var_of.items():
        cvec[vi] = eps * key[2]
    res = b.solve(cvec, time_limit_s=time_limit_s, mip_gap=mip_gap)
    if res is None:
        return ub
    x = res.x
    key_of = {vi: key for key, vi in var_of.items()}
    assignments = []
    for ji, j in enumerate(jobs):
        best_vi, best_val = None, 0.5
        for (ji2, ci, t), vi in var_of.items():
            if ji2 == ji and x[vi] > best_val:
                best_vi, best_val = vi, x[vi]
        if best_vi is None:
            return ub
        _, ci, t = key_of[best_vi]
        c = choice_map[j.name][ci]
        assignments.append(Assignment(j.name, c.technique, c.n_gpus,
                                      t * delta, c.runtime_s,
                                      device_class=c.device_class))
    makespan = max(a.end_s for a in assignments)
    sol = Solution(assignments, makespan, solver_name,
                   milp_status=res.message)
    # keep whichever is better (slot rounding can make MILP worse)
    return sol if makespan <= ub.makespan_s + 1e-6 else ub


def solve_joint(jobs: List[Job],
                profiles: Dict[Tuple[str, str, int], Profile],
                total_gpus: int, *,
                n_slots: int = 24,
                time_limit_s: float = 30.0,
                mip_gap: float = 0.02) -> Solution:
    """The joint MILP.  Falls back to greedy on infeasibility/timeout."""
    choice_map = {j.name: choices_from_profiles(j, profiles) for j in jobs}
    for j in jobs:
        if not choice_map[j.name]:
            raise ValueError(f"job {j.name}: no feasible (technique, g)")
    ub = greedy_schedule(jobs, choice_map, total_gpus)
    return _solve_time_indexed(jobs, choice_map, {None: int(total_gpus)},
                               ub, "milp", n_slots=n_slots,
                               time_limit_s=time_limit_s, mip_gap=mip_gap)


def solve_joint_classes(jobs: List[Job], profiles, cluster, *,
                        n_slots: int = 20,
                        time_limit_s: float = 30.0,
                        mip_gap: float = 0.05) -> Solution:
    """Device-class-aware joint MILP for heterogeneous clusters.

    A job's config space is the union over device classes of its
    feasible (technique, g) choices ON that class — each evaluated
    against the class's own throughput curve, so a V100 choice carries a
    genuinely longer runtime than its A100 twin.  The flat capacity
    constraint becomes one capacity row per (class, slot): apportionment
    now picks *which* class as well as *how many* GPUs.  Assignments
    carry the chosen class, which the runtime's ClassPool placement pins.

    Falls back to a per-class-budget greedy on infeasibility/timeout.
    """
    classes = list(cluster.device_classes)
    budgets: Dict[Optional[str], int] = {dc.name: dc.total_gpus
                                         for dc in classes}
    choice_map: Dict[str, List[Choice]] = {}
    for j in jobs:
        cs: List[Choice] = []
        for dc in classes:
            cs.extend(choices_from_profiles(j, profiles,
                                            device_class=dc.name))
        cs = [c for c in cs if c.n_gpus <= budgets[c.device_class]]
        if not cs:
            raise ValueError(
                f"job {j.name}: no feasible (technique, g, class)")
        choice_map[j.name] = cs
    ub = greedy_schedule(jobs, choice_map, budgets)
    return _solve_time_indexed(jobs, choice_map, budgets, ub,
                               "milp-classes", n_slots=n_slots,
                               time_limit_s=time_limit_s, mip_gap=mip_gap)


def solve_joint_nodes(jobs: List[Job],
                      profiles: Dict[Tuple[str, str, int], Profile],
                      nodes: int, gpus_per_node: int, *,
                      n_slots: int = 16,
                      time_limit_s: float = 30.0,
                      mip_gap: float = 0.05) -> Solution:
    """Node-locality-aware joint MILP.

    Single-node configs (g <= gpus_per_node) additionally choose a node;
    larger configs must be whole-node multiples (you allocate whole
    p4d/ICI-slice nodes) and pick which nodes via binaries y[j,c,t,nu].
    Per-(node, slot) capacity replaces the flat pool constraint, so two
    5-GPU jobs can NOT share a single 8-GPU node with a third.  The
    solution's assignments carry the chosen node sets, which the
    runtime's NodeAware placement backend uses as placement hints.
    """
    G = nodes * gpus_per_node
    choice_map = {j.name: choices_from_profiles(j, profiles) for j in jobs}
    for j in jobs:
        kept = []
        for c in choice_map[j.name]:
            if c.n_gpus <= gpus_per_node or c.n_gpus % gpus_per_node == 0:
                kept.append(c)
        choice_map[j.name] = kept
        if not kept:
            raise ValueError(f"job {j.name}: no node-feasible choice")
    ub = greedy_schedule(jobs, choice_map, G)  # node-UNaware (optimistic)
    seq_total = sum(min(c.runtime_s for c in choice_map[j.name])
                    for j in jobs)  # sequential = always node-feasible
    return _solve_nodes_at_horizon(
        jobs, choice_map, ub, nodes, gpus_per_node,
        horizons=[max(ub.makespan_s, 1e-6) * 1.3, seq_total * 1.05],
        n_slots=n_slots, time_limit_s=time_limit_s, mip_gap=mip_gap)


def _solve_nodes_at_horizon(jobs, choice_map, ub, nodes, gpus_per_node, *,
                            horizons, n_slots, time_limit_s, mip_gap):
    best = None
    for horizon in horizons:
        sol = _solve_nodes_once(jobs, choice_map, nodes, gpus_per_node,
                                horizon=horizon, n_slots=n_slots,
                                time_limit_s=time_limit_s, mip_gap=mip_gap)
        if sol is not None and (best is None
                                or sol.makespan_s < best.makespan_s):
            best = sol
        if best is not None:
            break  # first feasible horizon wins (tighter delta)
    return best if best is not None else ub


def _solve_nodes_once(jobs, choice_map, nodes, gpus_per_node, *,
                      horizon, n_slots, time_limit_s, mip_gap):
    delta = horizon / n_slots

    # variables: x[j,c,t,nu] for single-node; for whole-node configs one
    # x[j,c,t] plus y[j,c,t,nu] node-occupancy binaries
    xvars: List[Tuple] = []   # (kind, ji, ci, t, nu_or_None)
    var_of: Dict[Tuple, int] = {}

    def add(key):
        var_of[key] = len(xvars)
        xvars.append(key)

    dur_of: Dict[Tuple[int, int], int] = {}
    for ji, j in enumerate(jobs):
        for ci, c in enumerate(choice_map[j.name]):
            dur = max(1, math.ceil(c.runtime_s / delta - 1e-9))
            dur_of[(ji, ci)] = dur
            if dur > n_slots:
                continue
            for t in range(n_slots - dur + 1):
                if c.n_gpus <= gpus_per_node:
                    for nu in range(nodes):
                        add(("x1", ji, ci, t, nu))
                else:
                    add(("xm", ji, ci, t, None))
                    for nu in range(nodes):
                        add(("y", ji, ci, t, nu))
    nx = len(xvars)

    b = _MilpBuilder(nx)
    # (1) one (choice, start[, node-set]) per job
    for ji in range(len(jobs)):
        terms = [(vi, 1.0) for key, vi in var_of.items()
                 if key[0] in ("x1", "xm") and key[1] == ji]
        if not terms:
            return None
        b.add(terms, 1.0, 1.0)
    # (2) whole-node jobs: sum_nu y == k * x
    for key, vi in var_of.items():
        if key[0] != "xm":
            continue
        _, ji, ci, t, _ = key
        c = choice_map[jobs[ji].name][ci]
        k = c.n_gpus // gpus_per_node
        terms = [(vi, -float(k))]
        for nu in range(nodes):
            terms.append((var_of[("y", ji, ci, t, nu)], 1.0))
        b.add(terms, 0.0, 0.0)
    # (3) per-(node, slot) capacity
    for nu in range(nodes):
        for tau in range(n_slots):
            terms = []
            for key, vi in var_of.items():
                kind, ji, ci, t = key[0], key[1], key[2], key[3]
                if kind == "x1" and key[4] == nu:
                    c = choice_map[jobs[ji].name][ci]
                    if t <= tau < t + dur_of[(ji, ci)]:
                        terms.append((vi, float(c.n_gpus)))
                elif kind == "y" and key[4] == nu:
                    if t <= tau < t + dur_of[(ji, ci)]:
                        terms.append((vi, float(gpus_per_node)))
            if terms:
                b.add(terms, -np.inf, float(gpus_per_node))
    # (4) makespan
    for key, vi in var_of.items():
        if key[0] not in ("x1", "xm"):
            continue
        _, ji, ci, t = key[0], key[1], key[2], key[3]
        b.add_makespan(vi, (t + dur_of[(ji, ci)]) * delta)

    cvec = np.zeros(b.nvar)
    cvec[b.M_idx] = 1.0
    for key, vi in var_of.items():
        if key[0] in ("x1", "xm"):
            cvec[vi] = delta * 1e-4 * key[3]
    res = b.solve(cvec, time_limit_s=time_limit_s, mip_gap=mip_gap)
    if res is None:
        return None
    x = res.x
    assignments = []
    for ji, j in enumerate(jobs):
        pick = None
        for key, vi in var_of.items():
            if key[0] in ("x1", "xm") and key[1] == ji and x[vi] > 0.5:
                pick = key
                break
        if pick is None:
            return None
        kind, _, ci, t, nu = pick
        c = choice_map[j.name][ci]
        if kind == "x1":
            node_set: Tuple[int, ...] = (nu,)
        else:
            node_set = tuple(sorted(
                n2 for n2 in range(nodes)
                if x[var_of[("y", ji, ci, t, n2)]] > 0.5))
        assignments.append(Assignment(j.name, c.technique, c.n_gpus,
                                      t * delta, c.runtime_s,
                                      nodes=node_set))
    makespan = max(a.end_s for a in assignments)
    return Solution(assignments, makespan, "milp-nodes",
                    milp_status=res.message)
