"""Memory-efficient full-sequence primitives for long contexts (pure jnp,
lowered for the dry-run; the Pallas kernels in ``repro.kernels`` are the
TPU-optimized versions of the same math).

- ``blockwise_attention``: online-softmax attention, scan over q-chunks
  with an inner scan over kv-chunks.  Never materializes (S, S).
- ``mlstm_chunked``: chunkwise-parallel mLSTM — quadratic only within a
  chunk, recurrent (C, n, m) state across chunks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def blockwise_attention(q, k, v, *, window: int = 0, q_chunk: int = 512,
                        kv_chunk: int = 512):
    """Causal (optionally sliding-window) GQA attention.

    q: (B, S, H, D) pre-scaled; k, v: (B, S, Kv, D).  Returns (B, S, H, D).
    """
    b, s, h, d = q.shape
    kvh = k.shape[2]
    qpk = h // kvh
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, s)
    assert s % q_chunk == 0 and s % kv_chunk == 0
    nq, nk = s // q_chunk, s // kv_chunk

    # (nq, B, Kv, Q, qc, D) / (nk, B, Kv, kc, D)
    qr = q.reshape(b, nq, q_chunk, kvh, qpk, d).transpose(1, 0, 3, 4, 2, 5)
    kr = k.reshape(b, nk, kv_chunk, kvh, d).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(b, nk, kv_chunk, kvh, d).transpose(1, 0, 3, 2, 4)

    def q_step(_, qi_qc):
        qi, qc = qi_qc  # qc: (B, Kv, Q, qchunk, D)
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kj_kc):
            m, l, acc = carry
            kj, kc, vc = kj_kc
            k_pos = kj * kv_chunk + jnp.arange(kv_chunk)
            scores = jnp.einsum("bkqcd,bked->bkqce", qc, kc).astype(jnp.float32)
            mask = k_pos[None, :] <= q_pos[:, None]
            if window:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
            m_new = jnp.maximum(m, scores.max(-1))
            p = jnp.exp(scores - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            # NOTE: a bf16 p·v (flash-kernel practice) was tried and
            # REFUTED on the HLO-write instrument: XLA materializes both
            # the f32 p (for l) and its bf16 copy, so measured traffic
            # rose 22.3->25.8 s on qwen3 train_4k (EXPERIMENTS.md §Perf)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkqce,bked->bkqcd", p, vc.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, kvh, qpk, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((b, kvh, qpk, q_chunk), jnp.float32),
                jnp.zeros((b, kvh, qpk, q_chunk, d), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(nk), kr, vr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qr))
    # outs: (nq, B, Kv, Q, qc, D) -> (B, S, H, D)
    return outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, h, d)


def mlstm_chunked(q, k, v, i_pre, f_pre, *, chunk: int = 256,
                  return_final: bool = False):
    """Chunkwise-parallel mLSTM (matches ``mlstm_parallel_ref``).

    q,k,v: (B,S,H,D); i_pre,f_pre: (B,S,H).  Returns (B,S,H,D), or
    ((B,S,H,D), (C, n, m)) when ``return_final`` (prefill -> decode)."""
    b, s, h, d = q.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    n_chunks = s // chunk
    scale = d ** -0.5

    def to_chunks(x):
        return x.reshape(b, n_chunks, chunk, *x.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    ic = to_chunks(i_pre.astype(jnp.float32))
    lfc = to_chunks(jax.nn.log_sigmoid(f_pre.astype(jnp.float32)))

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(carry, xs):
        C, n, m = carry                       # (B,H,D,D), (B,H,D), (B,H)
        qt, kt, vt, it, lft = xs              # (B,L,H,*)
        cum = jnp.cumsum(lft, axis=1)         # (B,L,H) inclusive
        g = cum[:, -1]                        # (B,H) total decay
        # intra-chunk log decay matrix: cum_i - cum_j + i_j for j <= i
        logd = cum[:, :, None, :] - cum[:, None, :, :] + it[:, None, :, :]
        logd = jnp.where(tri[None, :, :, None], logd, -jnp.inf)
        m_intra = jnp.max(logd, axis=2)                       # (B,L,H)
        m_inter = cum + m[:, None, :]                         # (B,L,H)
        m_i = jnp.maximum(jnp.maximum(m_intra, m_inter), -1e30)
        dmat = jnp.exp(logd - m_i[:, :, None, :])
        scores = jnp.einsum("blhd,bjhd->bljh", qt, kt) * scale
        cmat = scores.astype(jnp.float32) * dmat              # (B,L,L,H)
        inter_w = jnp.exp(m_inter - m_i)                      # (B,L,H)
        q32 = qt.astype(jnp.float32) * scale
        h_inter = jnp.einsum("blhk,bhkv->blhv", q32, C) * inter_w[..., None]
        n_inter = jnp.einsum("blhk,bhk->blh", q32, n) * inter_w
        h_intra = jnp.einsum("bljh,bjhv->blhv", cmat, vt.astype(jnp.float32))
        n_total = jnp.sum(cmat, axis=2) + n_inter
        denom = jnp.maximum(jnp.abs(n_total), jnp.exp(-m_i))
        h_out = ((h_intra + h_inter) / denom[..., None]).astype(qt.dtype)
        # ---- state update
        m_next = jnp.maximum(g + m, jnp.max(it + g[:, None] - cum, axis=1))
        decay_state = jnp.exp(g + m - m_next)                 # (B,H)
        w_in = jnp.exp(it + g[:, None] - cum - m_next[:, None])  # (B,L,H)
        k32 = kt.astype(jnp.float32)
        C_new = decay_state[..., None, None] * C + jnp.einsum(
            "blh,blhk,blhv->bhkv", w_in, k32, vt.astype(jnp.float32))
        n_new = decay_state[..., None] * n + jnp.einsum(
            "blh,blhk->bhk", w_in, k32)
        return (C_new, n_new, m_next), h_out

    init = (jnp.zeros((b, h, d, d), jnp.float32),
            jnp.zeros((b, h, d), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32))
    final, hs = jax.lax.scan(step, init, (qc, kc, vc, ic, lfc))
    out = hs.swapaxes(0, 1).reshape(b, s, h, d)
    return (out, final) if return_final else out
