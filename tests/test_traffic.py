"""Traffic generators: seed-determinism and the Poisson-thinning
superset property (mirroring chaos.poisson_node_failures) — at a shared
seed and rate cap, a higher-rate trace contains every arrival of a
lower-rate one, so rate sweeps are paired comparisons, not re-rolls."""
import pytest

from _hypothesis_compat import given, settings, st

from repro.data.traffic import bursty_trace, diurnal_trace, window_rates


def test_seed_determinism():
    a = diurnal_trace(3.0, 3600.0, seed=7)
    b = diurnal_trace(3.0, 3600.0, seed=7)
    assert a == b
    c = bursty_trace(2.0, 3600.0, seed=7, burst_rps=10.0)
    d = bursty_trace(2.0, 3600.0, seed=7, burst_rps=10.0)
    assert c == d
    assert diurnal_trace(3.0, 3600.0, seed=8) != a


def test_traces_sorted_in_range():
    for tr in (diurnal_trace(5.0, 1800.0, seed=0),
               bursty_trace(2.0, 1800.0, seed=0)):
        assert list(tr) == sorted(tr)
        assert all(0.0 <= t < 1800.0 for t in tr)
        assert len(tr) > 0


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 50), lo=st.integers(1, 5), hi=st.integers(6, 12))
def test_diurnal_rate_superset(seed, lo, hi):
    """Same seed + same cap: every arrival at mean rate ``lo`` appears
    at mean rate ``hi`` too."""
    cap = 2.0 * hi          # shared cap >= both peaks (amplitude 0.5)
    a = set(diurnal_trace(float(lo), 1800.0, seed=seed, max_rps=cap))
    b = set(diurnal_trace(float(hi), 1800.0, seed=seed, max_rps=cap))
    assert a <= b


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 50), base=st.integers(1, 4))
def test_bursty_rate_superset(seed, base):
    cap = 40.0
    a = set(bursty_trace(float(base), 1800.0, seed=seed, burst_rps=10.0,
                         max_rps=cap))
    b = set(bursty_trace(float(base + 2), 1800.0, seed=seed,
                         burst_rps=30.0, max_rps=cap))
    assert a <= b


def test_diurnal_validates_amplitude_and_cap():
    with pytest.raises(ValueError):
        diurnal_trace(3.0, 600.0, amplitude=1.5)
    with pytest.raises(ValueError):
        # peak 4.5 rps exceeds the declared cap
        diurnal_trace(3.0, 600.0, max_rps=4.0)


def test_bursty_mean_rates_land_in_windows():
    """Burst windows must carry visibly more arrivals than quiet ones."""
    tr = bursty_trace(1.0, 3600.0, seed=3, burst_rps=20.0,
                      burst_every_s=1800.0, burst_len_s=300.0)
    rates = window_rates(tr, 300.0, 3600.0)
    assert len(rates) == 12
    # bursts occupy windows 0 and 6 (t in [0,300) and [1800,2100))
    quiet = [r for i, r in enumerate(rates) if i not in (0, 6)]
    assert min(rates[0], rates[6]) > 3 * max(quiet)


def test_window_rates_conserves_requests():
    tr = diurnal_trace(4.0, 1200.0, seed=5)
    rates = window_rates(tr, 100.0, 1200.0)
    assert sum(r * 100.0 for r in rates) == pytest.approx(len(tr))
