"""Node-locality MILP (solve_joint_nodes) + brute-force optimality
checks for the flat MILP on tiny instances."""
import itertools
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.job import Job
from repro.core.profiler import Profile
from repro.core.solver import solve_joint, solve_joint_nodes

CFG = get_config("xlstm-125m").reduced()


def mk(name, steps=100):
    return Job(name, CFG, 8, 64, steps)


def prof(times):
    return {(j, t, g): Profile(j, t, g, s, 1e9, True, "t")
            for (j, t, g), s in times.items()}


def test_node_locality_prevents_fragmentation():
    """Three 5-GPU jobs, two 8-GPU nodes: flat pool fits all three
    concurrently (15<=16); node-local scheduling can only run two."""
    jobs = [mk(f"j{i}") for i in range(3)]
    times = {(j.name, "fsdp", 5): 1.0 for j in jobs}
    p = prof(times)
    flat = solve_joint(jobs, p, total_gpus=16, n_slots=12)
    local = solve_joint_nodes(jobs, p, nodes=2, gpus_per_node=8,
                              n_slots=12)
    assert flat.makespan_s < 1.3 * 100          # all concurrent
    assert local.makespan_s >= 1.9 * 100 * 0.9  # two waves
    # validate node capacity: at any time <= 2 jobs running
    events = sorted({a.start_s for a in local.assignments})
    for t in events:
        running = [a for a in local.assignments
                   if a.start_s <= t < a.end_s - 1e-9]
        assert len(running) <= 2


def test_whole_node_jobs():
    """A 16-GPU job must take both nodes; an 8-GPU job one node."""
    jobs = [mk("big"), mk("small")]
    p = prof({("big", "fsdp", 16): 1.0, ("small", "ddp", 8): 1.0})
    sol = solve_joint_nodes(jobs, p, nodes=2, gpus_per_node=8, n_slots=10)
    big = next(a for a in sol.assignments if a.job == "big")
    small = next(a for a in sol.assignments if a.job == "small")
    # they cannot overlap (big takes the whole cluster)
    assert big.end_s <= small.start_s + 1e-6 or \
        small.end_s <= big.start_s + 1e-6


def test_non_multiple_multi_node_excluded():
    jobs = [mk("odd")]
    p = prof({("odd", "tp", 12): 1.0})  # 12 > 8 and 12 % 8 != 0
    with pytest.raises(ValueError):
        solve_joint_nodes(jobs, p, nodes=2, gpus_per_node=8)


# ---------------------------------------------------- brute-force check

def _brute_force_makespan(jobs, choices, total_gpus):
    """Exhaustive: every config pick x every permutation, list-scheduled
    greedily — a true upper bound baseline for tiny instances."""
    best = math.inf
    names = [j.name for j in jobs]
    for picks in itertools.product(*(choices[n] for n in names)):
        for perm in itertools.permutations(range(len(jobs))):
            free, t = total_gpus, 0.0
            running = []  # (end, g)
            makespan = 0.0
            ok = True
            for idx in perm:
                c = picks[idx]
                if c.n_gpus > total_gpus:
                    ok = False
                    break
                while c.n_gpus > free:
                    running.sort()
                    end, g = running.pop(0)
                    t = end
                    free += g
                running.append((t + c.runtime_s, c.n_gpus))
                free -= c.n_gpus
                makespan = max(makespan, t + c.runtime_s)
            if ok:
                best = min(best, makespan)
    return best


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 200))
def test_milp_near_bruteforce_optimum(seed):
    rng = np.random.RandomState(seed)
    jobs = [mk(f"b{i}", steps=100) for i in range(3)]
    times = {}
    for j in jobs:
        base = rng.uniform(0.5, 3.0)
        for g in (1, 2, 4):
            times[(j.name, "fsdp", g)] = base / g ** rng.uniform(0.5, 1.0)
    p = prof(times)
    from repro.core.solver import choices_from_profiles
    choices = {j.name: choices_from_profiles(j, p) for j in jobs}
    bf = _brute_force_makespan(jobs, choices, total_gpus=4)
    sol = solve_joint(jobs, p, total_gpus=4, n_slots=20, time_limit_s=10)
    # MILP may beat list-scheduling (true optimum <= bf) but must not be
    # worse than bf by more than slot-rounding slack
    assert sol.makespan_s <= bf * 1.12 + 1e-6
