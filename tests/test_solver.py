"""Saturn Solver tests: MILP correctness + hypothesis property tests on
schedule invariants (capacity, completeness, makespan bounds)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.job import Job
from repro.core.profiler import Profile
from repro.core.solver import (choices_from_profiles, greedy_schedule,
                               solve_joint)

CFG = get_config("xlstm-125m").reduced()


def mk_job(name, steps=100):
    return Job(name, CFG, batch_size=8, seq_len=64, total_steps=steps)


def mk_profiles(jobs, step_times):
    """step_times: {(job, tech, g): seconds}."""
    out = {}
    for (jn, tech, g), t in step_times.items():
        out[(jn, tech, g)] = Profile(jn, tech, g, t, 1e9, True, "test")
    return out


def _validate(sol, jobs, total_gpus):
    names = {a.job for a in sol.assignments}
    assert names == {j.name for j in jobs}, "every job scheduled exactly once"
    assert len(sol.assignments) == len(jobs)
    # capacity at every start/end event
    events = sorted({a.start_s for a in sol.assignments}
                    | {a.end_s for a in sol.assignments})
    for t in events:
        used = sum(a.n_gpus for a in sol.assignments
                   if a.start_s <= t < a.end_s - 1e-9)
        assert used <= total_gpus + 1e-9, f"capacity violated at t={t}"
    assert sol.makespan_s >= max(a.runtime_s for a in sol.assignments) - 1e-6


def test_milp_beats_or_matches_greedy_simple():
    jobs = [mk_job(f"j{i}") for i in range(4)]
    st_times = {}
    for j in jobs:
        for g in (1, 2, 4, 8):
            st_times[(j.name, "ddp", g)] = 100.0 / g  # perfect scaling
    profiles = mk_profiles(jobs, st_times)
    sol = solve_joint(jobs, profiles, total_gpus=8, n_slots=16)
    _validate(sol, jobs, 8)
    choices = {j.name: choices_from_profiles(j, profiles) for j in jobs}
    g = greedy_schedule(jobs, choices, 8)
    assert sol.makespan_s <= g.makespan_s + 1e-6


def test_joint_choice_matters():
    """Two jobs, 4 GPUs: job A scales perfectly, job B not at all.  The
    joint optimum gives B 1 GPU and A 3 (or serializes) — check the MILP
    does not naively split 2/2."""
    a, b = mk_job("a", 100), mk_job("b", 100)
    times = {("a", "tp", g): 120.0 / g for g in (1, 2, 3, 4)}
    times.update({("b", "ddp", g): 100.0 for g in (1, 2, 3, 4)})
    profiles = mk_profiles([a, b], times)
    sol = solve_joint([a, b], profiles, total_gpus=4, n_slots=20)
    _validate(sol, [a, b], 4)
    b_assign = next(x for x in sol.assignments if x.job == "b")
    assert b_assign.n_gpus == 1, "no point giving B more than 1 GPU"


def test_infeasible_job_raises():
    j = mk_job("x")
    profiles = mk_profiles([j], {})
    profiles[("x", "ddp", 8)] = Profile("x", "ddp", 8, 1.0, 1e20, False,
                                        "test")
    with pytest.raises(ValueError):
        solve_joint([j], profiles, total_gpus=8)


def test_pareto_pruning():
    j = mk_job("p")
    profiles = mk_profiles([j], {
        ("p", "ddp", 1): 10.0,
        ("p", "ddp", 2): 12.0,   # dominated: more gpus, slower
        ("p", "fsdp", 2): 6.0,
        ("p", "tp", 4): 6.0,     # dominated by fsdp@2
    })
    ch = choices_from_profiles(j, profiles)
    got = {(c.technique, c.n_gpus) for c in ch}
    assert ("ddp", 2) not in got
    assert ("tp", 4) not in got
    assert ("ddp", 1) in got and ("fsdp", 2) in got


@settings(max_examples=25, deadline=None)
@given(
    n_jobs=st.integers(2, 6),
    total_gpus=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 1000),
)
def test_schedule_invariants_random_workloads(n_jobs, total_gpus, seed):
    rng = np.random.RandomState(seed)
    jobs = [mk_job(f"r{i}", steps=int(rng.randint(50, 500)))
            for i in range(n_jobs)]
    times = {}
    for j in jobs:
        base = rng.uniform(0.5, 5.0)
        eff = rng.uniform(0.4, 1.0)  # scaling efficiency
        g = 1
        while g <= total_gpus:
            times[(j.name, "fsdp", g)] = base / (g ** eff)
            g *= 2
    profiles = mk_profiles(jobs, times)
    sol = solve_joint(jobs, profiles, total_gpus, n_slots=12,
                      time_limit_s=5.0)
    _validate(sol, jobs, total_gpus)
    # lower bounds: max single-job best runtime; total-work / capacity
    best = {j.name: min(t for (jn, _, g), t in times.items()
                        if jn == j.name) * j.total_steps for j in jobs}
    assert sol.makespan_s >= max(best.values()) * 0.999
    work_lb = sum(min((t * g for (jn, _, g), t in times.items()
                       if jn == j.name)) * j.total_steps
                  for j in jobs) / total_gpus
    assert sol.makespan_s >= work_lb * 0.999
