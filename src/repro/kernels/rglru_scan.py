"""Pallas TPU kernel for the RG-LRU linear recurrence
h_t = a_t * h_{t-1} + b_t  (Griffin / RecurrentGemma temporal mixing).

Grid: (batch, num_r_blocks, num_s_blocks) with the sequence dimension
minor-most: each (b, ir) program walks its sequence blocks in order,
carrying h in VMEM scratch.  Inside a block the recurrence runs as a
``fori_loop`` over time steps on (1, block_r) vectors — elementwise VPU
work; there is no MXU component, so the kernel's job is purely to keep
the carry resident in VMEM and stream a/b through HBM exactly once
(the associative-scan reference does log(S) passes over HBM instead).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, y_ref, h_ref, *, block_s: int):
    isb = pl.program_id(2)

    @pl.when(isb == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)       # (block_s, block_r)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]                # (block_r,)
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h0 = h_ref[0]
    h_final = jax.lax.fori_loop(0, block_s, step, h0)
    h_ref[0, :] = h_final


@functools.partial(
    jax.jit, static_argnames=("block_s", "block_r", "interpret"))
def rglru_scan(a, b, *, block_s: int = 256, block_r: int = 128,
               interpret: bool = False):
    """a, b: (B, S, R) -> h: (B, S, R) with h_t = a_t h_{t-1} + b_t."""
    bsz, s, r = a.shape
    block_s = min(block_s, s)
    block_r = min(block_r, r)
    assert s % block_s == 0 and r % block_r == 0
    grid = (bsz, r // block_r, s // block_s)
    return pl.pallas_call(
        functools.partial(_rglru_kernel, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_r),
                         lambda b_, ir, isb: (b_, isb, ir)),
            pl.BlockSpec((1, block_s, block_r),
                         lambda b_, ir, isb: (b_, isb, ir)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_r),
                               lambda b_, ir, isb: (b_, isb, ir)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, r), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_r), jnp.float32)],
        interpret=interpret,
    )(a, b)
