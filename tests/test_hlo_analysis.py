"""Loop-aware HLO analyzer: the roofline instrument must be exact on
known workloads (scan trip counts, nested loops, in-place DUS)."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import (analyze, collective_link_factor,
                                       computation_multipliers,
                                       link_seconds, parse_computations,
                                       scale_analysis)


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile().as_text()


def test_scan_flops_multiplied_by_trip_count():
    def g(x):
        def body(c, _):
            return c @ x, None
        return jax.lax.scan(body, x, None, length=10)[0]
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    r = analyze(_compile(g, a))
    np.testing.assert_allclose(r["flops"], 10 * 2 * 512 ** 3, rtol=0.02)


def test_nested_scan_flops():
    def h(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ x, None
            return jax.lax.scan(inner, c, None, length=5)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    r = analyze(_compile(h, a))
    np.testing.assert_allclose(r["flops"], 15 * 2 * 256 ** 3, rtol=0.02)


def test_inplace_dus_not_overcounted():
    """A scan writing one row per step into an (S, D) buffer must count
    ~S*D bytes, not S^2*D."""
    S, D = 256, 512

    def g(x):
        def body(c, i):
            buf, v = c
            v = v * 1.0001
            buf = jax.lax.dynamic_update_index_in_dim(buf, v, i, 0)
            return (buf, v), None
        init = (jnp.zeros((S, D)), x)
        (buf, _), _ = jax.lax.scan(body, init, jnp.arange(S))
        return buf
    a = jax.ShapeDtypeStruct((D,), jnp.float32)
    r = analyze(_compile(g, a))
    written = r["bytes_written"]
    assert written < 6 * S * D * 4, f"DUS overcounted: {written:.2e}"
    assert written >= S * D * 4 * 0.5


def test_flops_scan_vs_unrolled_agree():
    def body_fn(c, x):
        return jnp.tanh(c @ x), None

    def scanned(x):
        return jax.lax.scan(body_fn, x, jnp.stack([x] * 6))[0]

    def unrolled(x):
        c = x
        for _ in range(6):
            c, _ = body_fn(c, x)
        return c
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    r1 = analyze(_compile(scanned, a))
    r2 = analyze(_compile(unrolled, a))
    np.testing.assert_allclose(r1["flops"], r2["flops"], rtol=0.05)


def test_collective_parse_smoke():
    hlo = """
ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %ar = f32[8]{0} all-reduce(%p), to_apply=%add
  ROOT %r = f32[8]{0} add(%ar, %p)
}
"""
    r = analyze(hlo)
    assert r["collectives"].get("all-reduce") == 32.0


# ------------------------------------------------ hand-written HLO edges

_WHILE_HLO = """
%cond (c: (s32[], f32[16])) -> pred[] {
  %c = (s32[], f32[16]{0}) parameter(0)
  %i = s32[] get-tuple-element(%c), index=0
  %k = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

%body (b: (s32[], f32[16])) -> (s32[], f32[16]) {
  %b = (s32[], f32[16]{0}) parameter(0)
  %i2 = s32[] get-tuple-element(%b), index=0
  %v = f32[16]{0} get-tuple-element(%b), index=1
  %one = s32[] constant(1)
  %i3 = s32[] add(%i2, %one)
  %v2 = f32[16]{0} multiply(%v, %v)
  ROOT %t = (s32[], f32[16]{0}) tuple(%i3, %v2)
}

ENTRY %main (p: f32[16]) -> f32[16] {
  %p = f32[16]{0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[16]{0}) tuple(%z, %p)
  %w = (s32[], f32[16]{0}) while(%init), condition=%cond, body=%body
  ROOT %out = f32[16]{0} get-tuple-element(%w), index=1
}
"""


def test_while_trip_count_from_condition_constant():
    """The loop bound lives in the CONDITION computation's integer
    constant — body instructions must be multiplied by it."""
    comps = parse_computations(_WHILE_HLO)
    mult = computation_multipliers(comps)
    assert mult["body"] == (7.0, 7.0)
    assert mult["main"] == (1.0, 1.0)
    r = analyze(_WHILE_HLO)
    # body writes one f32[16] multiply per trip (64 B x 7); the add on
    # the s32 counter adds 4 B x 7
    assert r["bytes_written"] >= 7 * 64


def test_dus_effective_write_bytes_bare_instruction():
    hlo = """
ENTRY %main (buf: f32[256,64], v: f32[1,64]) -> f32[256,64] {
  %buf = f32[256,64]{1,0} parameter(0)
  %v = f32[1,64]{1,0} parameter(1)
  %z = s32[] constant(0)
  ROOT %d = f32[256,64]{1,0} dynamic-update-slice(%buf, %v, %z, %z)
}
"""
    r = analyze(hlo)
    # in-place: only the (1, 64) update slice hits HBM, not the
    # (256, 64) buffer
    assert r["bytes_written"] == 1 * 64 * 4


def test_fusion_multiplier_flops_but_no_bytes():
    """A fusion callee inherits the caller's FLOPs multiplier but its
    instruction outputs stay in registers — zero bytes multiplier; the
    fusion's own output is the only HBM write."""
    hlo = """
%fused (a: f32[32,32], b: f32[32,32]) -> f32[32,32] {
  %a = f32[32,32]{1,0} parameter(0)
  %b = f32[32,32]{1,0} parameter(1)
  %d = f32[32,32]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = f32[32,32]{1,0} tanh(%d)
}

ENTRY %main (x: f32[32,32]) -> f32[32,32] {
  %x = f32[32,32]{1,0} parameter(0)
  ROOT %f = f32[32,32]{1,0} fusion(%x, %x), kind=kOutput, calls=%fused
}
"""
    comps = parse_computations(hlo)
    mult = computation_multipliers(comps)
    assert mult["fused"] == (1.0, 0.0)
    r = analyze(hlo)
    assert r["flops"] == 2 * 32 ** 3          # the fused dot still counts
    assert r["bytes_written"] == 32 * 32 * 4  # only the fusion output


def test_unknown_collective_kind_is_unfit():
    secs, unfit = link_seconds({"ragged-all-to-all": 1e6, "total": 1e6},
                               8, 1e9)
    assert unfit == ["ragged-all-to-all"]
    assert secs > 0      # still charged conservatively at 1x


def test_link_factor_units():
    assert collective_link_factor("all-reduce", 4) == 2.0 * 3 / 4
    assert collective_link_factor("all-gather", 4) == 3 / 4
    assert collective_link_factor("reduce-scatter", 8) == 7 / 8
    assert collective_link_factor("collective-permute", 8) == 1.0
    assert collective_link_factor("all-reduce", 1) == 0.0
    assert collective_link_factor("all-reduce-start", 4) == \
        collective_link_factor("all-reduce", 4)
    assert collective_link_factor("ragged-all-to-all", 4) is None


def test_scale_analysis_work_and_payload():
    a = {"flops": 8e9, "bytes_written": 4e9,
         "collectives": {"all-reduce": 1e6, "total": 1e6}}
    s = scale_analysis(a, 2, 8)
    assert s["flops"] == 2e9                  # same work over 4x devices
    assert s["bytes_written"] == 1e9
    assert s["collectives"]["all-reduce"] == 1e6   # payload constant
    assert (s["scaled_from"], s["scaled_to"]) == (2.0, 8.0)
    f = scale_analysis(a, 2, 8, work_scales=False)
    assert f["flops"] == 8e9


# ------------------------------------------------------ golden transformer

_GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                       "golden_transformer_step.hlo.txt")
# analyze() of the committed HLO text; FLOPs are exactly fwd+bwd of the
# L=4-layer scan (3 x L x 2 x 2*B*S*D*F with B*S=64, D=64, F=128)
_GOLDEN_FLOPS = 25165824.0
_GOLDEN_BYTES = 3244144.0
_GOLDEN_N_COMPS = 22


def test_golden_file_parser_pinned():
    """The committed HLO text must analyze to the recorded op counts
    EXACTLY — any parser regression (instruction regex, multiplier
    propagation, DUS handling) fails here first."""
    r = analyze(open(_GOLDEN).read())
    assert r["flops"] == _GOLDEN_FLOPS
    assert r["bytes_written"] == _GOLDEN_BYTES
    assert r["n_computations"] == _GOLDEN_N_COMPS
    assert r["collectives"]["total"] == 0


def test_golden_recompile_matches_committed_analysis():
    """Recompiling the same step TODAY must analyze to the same FLOPs:
    if XLA's HLO text format drifts in a way the parser cannot read,
    this fails loudly instead of silently under-counting."""
    L, D, F, S, B = 4, 64, 128, 32, 2

    def loss(params, x):
        def layer(h, p):
            w1, w2 = p
            h = jnp.tanh(h @ w1) @ w2
            return h, None
        h, _ = jax.lax.scan(layer, x, params)
        return (h ** 2).mean()

    def train_step(params, x):
        g = jax.grad(loss)(params, x)
        return jax.tree_util.tree_map(lambda p, gg: p - 0.1 * gg,
                                      params, g)

    params = (jax.ShapeDtypeStruct((L, D, F), jnp.float32),
              jax.ShapeDtypeStruct((L, F, D), jnp.float32))
    x = jax.ShapeDtypeStruct((B * S, D), jnp.float32)
    txt = jax.jit(train_step).lower(params, x).compile().as_text()
    r = analyze(txt)
    np.testing.assert_allclose(r["flops"], _GOLDEN_FLOPS, rtol=0.02)
    # bytes depend on fusion decisions and may move a little across
    # XLA versions, but an order-of-magnitude jump means the DUS /
    # fusion write logic no longer understands the text
    assert 0.3 * _GOLDEN_BYTES < r["bytes_written"] < 3 * _GOLDEN_BYTES
