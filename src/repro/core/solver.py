"""Saturn's joint Solver (paper §2): parallelism selection + GPU
allocation + scheduling as one mixed-integer linear program.

Time-indexed formulation (the tech-report formulation, Gurobi swapped
for HiGHS via ``scipy.optimize.milp`` — same MILP, different solver):

  binaries  x[j,c,t]  — job j starts config c = (technique, g, duration)
                         at time slot t
  continuous M         — makespan

  min  M + eps * sum t*x                    (eps tie-breaks earlier starts)
  s.t. sum_{c,t} x[j,c,t] = 1               for every job j
       sum_{j,c} g_c * sum_{t in (tau-d_c, tau]} x[j,c,t] <= cap(pool, tau)
       sum_{c,t} (t + d_jc) * delta * x[j,c,t] <= M     for every job j

(The makespan rows are aggregated per job: with the assignment equality
in place the weighted sum equals the chosen end exactly, and the LP
relaxation is *tighter* than one big-M row per binary — n_jobs rows
instead of one per variable.)

The scheduling core is built for scale:

- Constraint assembly is fully vectorized: per-variable attributes live
  in flat numpy arrays and every constraint family is emitted as one
  bulk COO block (``_MilpBuilder.add_block``) — no per-term Python
  loops, so assembly stays negligible next to the solve itself.
- ``refine=True`` runs a coarse-to-fine pass: solve on a coarse slot
  grid first, then re-solve on the fine grid with each job's start
  variables restricted to a window around the coarse incumbent's start
  — cutting the binary count roughly ``n_slots / coarse_slots``-fold.
- :func:`solve_residual` is the warm-started incremental replan: jobs
  that are running and provably not worth preempting become capacity
  *reservations* instead of variables, the previous solution's start
  times seed per-job refinement windows, and the greedy bound is
  installed as an upper bound on the makespan variable so HiGHS can
  early-exit on gap.

The flat MILP (``solve_joint``), the class-aware MILP
(``solve_joint_classes``) and the node-locality MILP
(``solve_joint_nodes``) share the one builder and all emit Schedule IR
via :meth:`Solution.to_schedule`.

Beyond the paper's makespan objective, the flat/class/incremental
solvers accept ``objective=`` (see ``OBJECTIVES``): weighted completion
time, weighted tardiness against per-job deadlines, and per-tenant fair
share (minimize the worst tenant's mean completion) — all linear in the
same start binaries, so no extra variables are introduced.

A greedy list-scheduling fallback guards against solver timeouts (and is
also used to compute an upper bound that sizes the horizon).
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import os
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np
from scipy import sparse
from scipy.optimize import LinearConstraint, milp, Bounds

from .job import Job
from .profiler import Profile
from .schedule import Schedule, ScheduleEntry


@contextlib.contextmanager
def _quiet_stdout():
    """HiGHS prints C-level debug lines; mute fd 1 during the solve."""
    try:
        saved = os.dup(1)
        devnull = os.open(os.devnull, os.O_WRONLY)
        os.dup2(devnull, 1)
        yield
    finally:
        os.dup2(saved, 1)
        os.close(saved)
        os.close(devnull)


@dataclasses.dataclass(frozen=True)
class Choice:
    """One point in a job's config space."""
    technique: str
    n_gpus: int
    runtime_s: float          # total remaining runtime under this config
    device_class: Optional[str] = None   # class-qualified (hetero) choices


@dataclasses.dataclass
class Assignment:
    job: str
    technique: str
    n_gpus: int
    start_s: float
    runtime_s: float
    nodes: Optional[Tuple[int, ...]] = None   # node-aware MILP only
    device_class: Optional[str] = None        # class-aware MILP only

    @property
    def end_s(self) -> float:
        return self.start_s + self.runtime_s


@dataclasses.dataclass
class Solution:
    assignments: List[Assignment]
    makespan_s: float
    solver: str     # "milp" | "milp-nodes" | "milp-classes" |
    #                 "milp-incremental" | "greedy" | "greedy-incremental" |
    #                 "lns" | "portfolio[...]"
    milp_status: Optional[str] = None
    # solver telemetry {backend, wall_s, gap, status, ...} — filled by the
    # portfolio/LNS backends and surfaced via Schedule into
    # SimResult.stats["solver"] so callers stop re-deriving which engine
    # won and whether it capped
    telemetry: Optional[dict] = None

    def order(self) -> List[Assignment]:
        return sorted(self.assignments, key=lambda a: (a.start_s, a.job))

    def to_schedule(self) -> Schedule:
        """Emit Schedule IR: the typed contract the runtime executes."""
        entries = [ScheduleEntry(a.job, a.technique, a.n_gpus,
                                 start_s=a.start_s, runtime_s=a.runtime_s,
                                 nodes=a.nodes, device_class=a.device_class)
                   for a in self.order()]
        return Schedule(entries, solver=self.solver,
                        makespan_s=self.makespan_s,
                        telemetry=self.telemetry)


def _pool_of(choice: Choice, budgets) -> Optional[str]:
    """Which budget pool a choice draws from: its device class when that
    class has its own budget, else the pooled ``None`` key."""
    return choice.device_class if choice.device_class in budgets else None


# ------------------------------------------------- alternative objectives

# Every objective is linear in the start binaries (each binary encodes a
# complete (config, start) decision, so its end time — and therefore its
# completion cost or lateness — is a CONSTANT coefficient), which is why
# none of them needs extra MILP variables:
#
# - "makespan"             min M,             M >= end_j            (paper)
# - "weighted_completion"  min sum w_j * end_j
# - "tardiness"            min sum w_j * max(0, end_j - deadline_j)
# - "fair_share"           min M,  M >= avg end over each tenant's jobs
#                          (minimize the WORST tenant's mean completion)
OBJECTIVES = ("makespan", "weighted_completion", "tardiness", "fair_share")


def _weight(j) -> float:
    return float(getattr(j, "weight", 1.0))


def _deadline(j) -> float:
    d = getattr(j, "deadline_s", None)
    return math.inf if d is None else float(d)


def objective_value(assignments: Iterable[Assignment], jobs: List[Job],
                    objective: str = "makespan") -> float:
    """Score a plan under an objective (lower is better).  Jobs absent
    from ``assignments`` contribute nothing — callers compare plans over
    the same job set."""
    ends = {a.job: a.end_s for a in assignments}
    if objective == "makespan":
        return max(ends.values(), default=0.0)
    if objective == "weighted_completion":
        return sum(_weight(j) * ends[j.name] for j in jobs
                   if j.name in ends)
    if objective == "tardiness":
        tot = 0.0
        for j in jobs:
            if j.name in ends and math.isfinite(_deadline(j)):
                tot += _weight(j) * max(0.0, ends[j.name] - _deadline(j))
        return tot
    if objective == "fair_share":
        per: Dict[str, List[float]] = {}
        for j in jobs:
            if j.name in ends:
                per.setdefault(getattr(j, "tenant", "default"),
                               []).append(ends[j.name])
        return max((sum(v) / len(v) for v in per.values()), default=0.0)
    raise ValueError(f"unknown objective {objective!r}; "
                     f"expected one of {OBJECTIVES}")


def objective_arrays(jobs: List[Job]) -> Dict[str, np.ndarray]:
    """Per-job numpy arrays (weights, deadlines, tenant one-hot) for
    :func:`objective_values_batch` — precompute once, score many
    candidate plans.  Row order follows ``jobs``."""
    n = len(jobs)
    w = np.array([_weight(j) for j in jobs], dtype=np.float64)
    dl = np.array([_deadline(j) for j in jobs], dtype=np.float64)
    tenants = sorted({getattr(j, "tenant", "default") for j in jobs})
    tix = {t: i for i, t in enumerate(tenants)}
    onehot = np.zeros((n, max(len(tenants), 1)), dtype=np.float64)
    for i, j in enumerate(jobs):
        onehot[i, tix[getattr(j, "tenant", "default")]] = 1.0
    counts = np.maximum(onehot.sum(axis=0), 1.0)
    return {"weight": w, "deadline": dl, "tenant_onehot": onehot,
            "tenant_counts": counts}


def objective_values_batch(ends, jobs: Optional[List[Job]] = None,
                           objective: str = "makespan", *,
                           arrays: Optional[Dict[str, np.ndarray]] = None):
    """Vectorized :func:`objective_value` over candidate plans.

    ``ends`` is the per-job completion-time array — shape ``(n_jobs,)``
    for one plan (returns a float) or ``(n_plans, n_jobs)`` for a batch
    (returns a ``(n_plans,)`` array), column order following ``jobs``.
    Pass ``arrays=`` (from :func:`objective_arrays`) to amortize the
    per-job attribute extraction across calls — the LNS hot loop scores
    every destroy/repair candidate through here, so the per-plan cost is
    pure numpy with no Python per-job iteration.
    """
    arrs = arrays if arrays is not None else objective_arrays(jobs)
    E = np.atleast_2d(np.asarray(ends, dtype=np.float64))
    if E.shape[1] == 0:
        vals = np.zeros(E.shape[0])
    elif objective == "makespan":
        vals = E.max(axis=1)
    elif objective == "weighted_completion":
        vals = E @ arrs["weight"]
    elif objective == "tardiness":
        fin = np.isfinite(arrs["deadline"])
        late = np.maximum(0.0, E[:, fin] - arrs["deadline"][fin])
        vals = late @ arrs["weight"][fin]
    elif objective == "fair_share":
        vals = (E @ arrs["tenant_onehot"] / arrs["tenant_counts"]) \
            .max(axis=1)
    else:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"expected one of {OBJECTIVES}")
    return vals if np.ndim(ends) == 2 else float(vals[0])


# ------------------------------------------------- shared MILP machinery

class _MilpBuilder:
    """Accumulates sparse linear constraints + runs the HiGHS MILP.

    Both joint formulations are "binary start variables + one continuous
    makespan var"; this builder owns the shared mechanics (COO blocks,
    row bounds, bounds/integrality vectors, solver call) so the solvers
    only differ in which constraints they emit.  Constraints arrive as
    whole numpy blocks (:meth:`add_block`) — per-term Python loops are
    the scaling killer the vectorized assembly replaces.
    """

    def __init__(self, n_binary: int):
        self.n_binary = n_binary
        self.nvar = n_binary + 1          # + makespan, always last
        self.M_idx = n_binary
        self._row_chunks: List[np.ndarray] = []
        self._col_chunks: List[np.ndarray] = []
        self._val_chunks: List[np.ndarray] = []
        self._lb_chunks: List[np.ndarray] = []
        self._ub_chunks: List[np.ndarray] = []
        self._r = 0

    def add_block(self, rows, cols, vals, lbs, ubs) -> None:
        """Bulk-append constraint rows.  ``rows`` holds LOCAL row ids
        0..len(lbs)-1 (offset internally); ``cols``/``vals`` are the COO
        triplets, one entry per nonzero."""
        lbs = np.atleast_1d(np.asarray(lbs, dtype=np.float64))
        self._row_chunks.append(
            np.asarray(rows, dtype=np.int64) + self._r)
        self._col_chunks.append(np.asarray(cols, dtype=np.int64))
        self._val_chunks.append(np.asarray(vals, dtype=np.float64))
        self._lb_chunks.append(lbs)
        self._ub_chunks.append(np.atleast_1d(np.asarray(ubs, np.float64)))
        self._r += len(lbs)

    def add(self, terms: Iterable[Tuple[int, float]],
            lb: float, ub: float) -> None:
        """One constraint row: lb <= sum coef*x[col] <= ub."""
        terms = list(terms)
        self.add_block(np.zeros(len(terms), dtype=np.int64),
                       [c for c, _ in terms], [v for _, v in terms],
                       [lb], [ub])

    def solve(self, cvec: np.ndarray, *, time_limit_s: float,
              mip_gap: float, m_upper: float = np.inf):
        """Run HiGHS; returns the scipy result or None on failure.

        ``m_upper`` bounds the makespan variable — installing a known
        feasible makespan (e.g. the greedy incumbent's) lets the solver
        prune and exit early on gap."""
        A = sparse.coo_matrix(
            (np.concatenate(self._val_chunks),
             (np.concatenate(self._row_chunks),
              np.concatenate(self._col_chunks))),
            shape=(self._r, self.nvar)).tocsc()
        cons = LinearConstraint(A, np.concatenate(self._lb_chunks),
                                np.concatenate(self._ub_chunks))
        integrality = np.ones(self.nvar)
        integrality[self.M_idx] = 0
        bounds = Bounds(np.zeros(self.nvar),
                        np.concatenate([np.ones(self.n_binary),
                                        [m_upper]]))
        try:
            with _quiet_stdout():
                res = milp(c=cvec, constraints=cons,
                           integrality=integrality, bounds=bounds,
                           options={"time_limit": time_limit_s,
                                    "mip_rel_gap": mip_gap,
                                    "presolve": True})
        except Exception:
            return None
        # status 0 = optimal, 1 = iteration/time limit: a limit-hit run
        # still carries its best integral incumbent in res.x — keep it
        # (callers fall back to the greedy bound when it's worse anyway)
        if res.x is None or res.status not in (0, 1):
            return None
        return res


# --------------------------------------------------------- choice cache

class _ChoiceCache:
    """Memoizes the per-step-time (technique, g, step_time) sweep behind
    :func:`choices_from_profiles`, keyed on profiles-object identity.

    Replans re-derive the same choice lists on every introspection
    event; with a curve-backed PerfModel each derivation walks the whole
    dense count grid.  The cache pins a strong reference to each
    profiles object it has seen (so ``id()`` cannot be recycled
    underneath it) and invalidates on ``len()`` change — the way test
    fixtures and planners actually mutate profile dicts (adding keys).
    Replacing a value in place for an existing key is NOT detected;
    nothing in the repo does that.
    """

    def __init__(self, max_entries: int = 8):
        self.max_entries = max_entries
        self._store: "OrderedDict[int, tuple]" = OrderedDict()

    def per_step(self, profiles, job_name: str,
                 device_class: Optional[str]) -> List[Tuple[str, int, float]]:
        from .perfmodel import iter_job_profiles
        key = id(profiles)
        n = len(profiles)
        ent = self._store.get(key)
        if ent is None or ent[0] is not profiles or ent[1] != n:
            ent = (profiles, n, {})
            self._store[key] = ent
            while len(self._store) > self.max_entries:
                self._store.popitem(last=False)
        else:
            self._store.move_to_end(key)   # LRU: hits refresh recency
        sub = ent[2]
        k = (job_name, device_class)
        if k not in sub:
            sub[k] = [(tech, g, p.step_time_s)
                      for tech, g, p in iter_job_profiles(
                          profiles, job_name, device_class=device_class)
                      if p.feasible]
        return sub[k]

    def clear(self) -> None:
        self._store.clear()


_CHOICE_CACHE = _ChoiceCache()


def clear_choice_cache() -> None:
    """Drop all memoized choice lists (test hook)."""
    _CHOICE_CACHE.clear()


def choices_from_profiles(job: Job, profiles, *, prune: bool = True,
                          device_class: Optional[str] = None
                          ) -> List[Choice]:
    """Feasible (technique, g) choices with total runtimes for one job.

    ``profiles`` is either the legacy exhaustive dict or a
    :class:`~repro.core.perfmodel.PerfModel` — with a model, choices are
    evaluated straight off the throughput curves, so the MILP optimizes
    over every count in the model's grid even though only the anchor
    counts were actually profiled.  Enumeration goes through
    ``iter_job_profiles`` so the solver sees exactly the grid the
    policies see — and is memoized per (profiles identity, job, class),
    so introspection replans stop re-walking the curve grid.

    prune=True drops Pareto-dominated choices (same or more GPUs, same or
    worse runtime) — a large constant-factor MILP size reduction that
    does not change the optimum.
    """
    per_step = _CHOICE_CACHE.per_step(profiles, job.name, device_class)
    out = [Choice(tech, g, st * job.total_steps,
                  device_class=device_class)
           for tech, g, st in per_step]
    if prune and out:
        out.sort(key=lambda c: (c.n_gpus, c.runtime_s))
        kept: List[Choice] = []
        best_rt = math.inf
        for c in out:
            if c.runtime_s < best_rt - 1e-9:
                kept.append(c)
                best_rt = c.runtime_s
        out = kept
    return out


def pooled_choice_map(jobs: List[Job], profiles
                      ) -> Dict[str, List[Choice]]:
    """Per-job pruned choice lists on the single pooled budget; raises
    when a job has no feasible config (shared by the flat MILP and the
    incremental replan so both optimize over the same space)."""
    cm = {j.name: choices_from_profiles(j, profiles) for j in jobs}
    for j in jobs:
        if not cm[j.name]:
            raise ValueError(f"job {j.name}: no feasible (technique, g)")
    return cm


def class_choice_map(jobs: List[Job], profiles, classes
                     ) -> Tuple[Dict[str, List[Choice]],
                                Dict[Optional[str], int]]:
    """Per-job class-qualified choice lists + per-class budgets: each
    job's space is the union over device classes of its feasible
    choices ON that class, budget-filtered (shared by the class MILP
    and the incremental replan)."""
    budgets: Dict[Optional[str], int] = {dc.name: dc.total_gpus
                                         for dc in classes}
    cm: Dict[str, List[Choice]] = {}
    for j in jobs:
        cs: List[Choice] = []
        for dc in classes:
            cs.extend(choices_from_profiles(j, profiles,
                                            device_class=dc.name))
        cs = [c for c in cs if c.n_gpus <= budgets[c.device_class]]
        if not cs:
            raise ValueError(
                f"job {j.name}: no feasible (technique, g, class)")
        cm[j.name] = cs
    return cm, budgets


def _rank_jobs(jobs: List[Job], choices: Dict[str, List[Choice]],
               objective: str) -> List[Job]:
    """Greedy dispatch order per objective: longest-first for makespan
    and fair share, WSPT (weight over best runtime, densest first) for
    weighted completion, EDF for tardiness (deadline-free jobs last,
    longest first among them)."""
    best_rt = {j.name: min((c.runtime_s for c in choices[j.name]),
                           default=0.0) for j in jobs}
    if objective == "weighted_completion":
        return sorted(jobs, key=lambda j: -_weight(j)
                      / max(best_rt[j.name], 1e-9))
    if objective == "tardiness":
        return sorted(jobs,
                      key=lambda j: (_deadline(j), -best_rt[j.name]))
    return sorted(jobs, key=lambda j: -best_rt[j.name])


def greedy_schedule(jobs: List[Job], choices: Dict[str, List[Choice]],
                    total_gpus, reserved: Iterable[Tuple] = (),
                    objective: str = "makespan") -> Solution:
    """List scheduling: objective-ranked jobs (see :func:`_rank_jobs`),
    each on its best-throughput feasible choice that fits when it starts.

    ``total_gpus`` is either a single pooled budget (int — the legacy
    flat cluster) or per-device-class budgets (``{class_name: gpus}``);
    with budgets, each Choice draws from its own class's pool.

    ``reserved`` pre-loads running allocations the schedule must work
    around: ``(device_class_or_None, n_gpus, release_s)`` triples whose
    GPUs only free up at ``release_s`` — the incremental replan's view
    of jobs it decided not to preempt.
    """
    if isinstance(total_gpus, dict):
        free = dict(total_gpus)
    else:
        free = {None: int(total_gpus)}

    # (release time, gpus, pool) for everything currently holding GPUs
    running: List[Tuple[float, int, Optional[str]]] = []
    for dc, g, release_s in reserved:
        key = dc if dc in free else None
        free[key] -= int(g)
        running.append((float(release_s), int(g), key))

    ranked = _rank_jobs(jobs, choices, objective)
    t = 0.0
    out: List[Assignment] = []
    queue = list(ranked)
    while queue:
        progressed = True
        while progressed and queue:
            progressed = False
            for job in list(queue):
                fits = [c for c in choices[job.name]
                        if c.n_gpus <= free[_pool_of(c, free)]]
                if fits:
                    c = min(fits, key=lambda c: c.runtime_s)
                    a = Assignment(job.name, c.technique, c.n_gpus, t,
                                   c.runtime_s, device_class=c.device_class)
                    out.append(a)
                    running.append((a.end_s, c.n_gpus, _pool_of(c, free)))
                    free[_pool_of(c, free)] -= c.n_gpus
                    queue.remove(job)
                    progressed = True
        if not queue:
            break
        if not running:
            raise RuntimeError("greedy: no feasible choice fits cluster")
        running.sort(key=lambda x: x[0])
        t_end, g_rel, key = running.pop(0)
        t = t_end
        free[key] += g_rel
    makespan = max((a.end_s for a in out), default=0.0)
    return Solution(out, makespan, "greedy")


def _solve_time_indexed(jobs: List[Job],
                        choice_map: Dict[str, List[Choice]],
                        budgets: Dict[Optional[str], int],
                        ub: Solution, solver_name: str, *,
                        n_slots: int, time_limit_s: float,
                        mip_gap: float,
                        horizon: Optional[float] = None,
                        start_windows: Optional[Dict[str, float]] = None,
                        window_pad_s: float = 0.0,
                        reserved: Iterable[Tuple] = (),
                        m_upper: float = np.inf,
                        objective: str = "makespan") -> Solution:
    """The shared time-indexed MILP core behind ``solve_joint`` (one
    pooled budget under the ``None`` key), ``solve_joint_classes`` (one
    budget per device class) and ``solve_residual``.

    Assembly is vectorized: variables are described by flat arrays
    (job index, slot, duration, GPUs, pool) built once, and every
    constraint family — assignment, per-(pool, slot) capacity,
    per-job makespan — lands as one bulk COO block.

    ``start_windows`` restricts a job's start slots to
    ``center ± window_pad_s`` (seconds) — the coarse-to-fine refinement
    and the warm-started replan both ride on it; a job whose window
    admits no start falls back to the full range.  ``reserved`` entries
    ``(pool, gpus, until_s)`` shrink capacity rows for the slots they
    cover (running jobs the incremental replan keeps in place).
    ``m_upper`` bounds the makespan variable (a known-feasible
    incumbent's value) so HiGHS can early-exit on gap.

    Falls back to the upper bound ``ub`` on infeasibility/timeout.
    """
    if horizon is None:
        horizon = max(ub.makespan_s, 1e-6) * 1.05
    delta = horizon / n_slots
    pools = list(budgets.keys())
    pool_idx = {p: i for i, p in enumerate(pools)}
    n_jobs = len(jobs)

    # ---- variable layout: one flat array per attribute, then M last
    ji_ch, ci_ch, t_ch, dur_ch, g_ch, pool_ch = [], [], [], [], [], []
    for ji, j in enumerate(jobs):
        win = (start_windows or {}).get(j.name)
        for ci, c in enumerate(choice_map[j.name]):
            dur = max(1, math.ceil(c.runtime_s / delta - 1e-9))
            if dur > n_slots:
                continue
            tmax = n_slots - dur
            if win is not None:
                lo = max(0, int(math.floor((win - window_pad_s) / delta)))
                hi = min(tmax, int(math.ceil((win + window_pad_s) / delta)))
                ts = np.arange(lo, hi + 1) if lo <= hi \
                    else np.arange(tmax + 1)
            else:
                ts = np.arange(tmax + 1)
            ji_ch.append(np.full(ts.size, ji))
            ci_ch.append(np.full(ts.size, ci))
            t_ch.append(ts)
            dur_ch.append(np.full(ts.size, dur))
            g_ch.append(np.full(ts.size, c.n_gpus))
            pool_ch.append(np.full(ts.size, pool_idx[_pool_of(c, budgets)]))
    if not t_ch:
        return ub
    ji_all = np.concatenate(ji_ch)
    ci_all = np.concatenate(ci_ch)
    t_all = np.concatenate(t_ch)
    dur_all = np.concatenate(dur_ch)
    g_all = np.concatenate(g_ch).astype(np.float64)
    pool_all = np.concatenate(pool_ch)
    nx = ji_all.size
    end_all = (t_all + dur_all) * delta

    if (np.bincount(ji_all, minlength=n_jobs) == 0).any():
        return ub                 # some job's every choice outlasts horizon

    b = _MilpBuilder(nx)
    # (1) each job picks exactly one (choice, start)
    b.add_block(ji_all, np.arange(nx), np.ones(nx),
                np.ones(n_jobs), np.ones(n_jobs))
    # (2) capacity per (budget pool, slot), minus reservations
    cap_ub = np.repeat(np.array([float(budgets[p]) for p in pools]),
                       n_slots)
    for dc, g_res, until_s in reserved:
        p = pool_idx[dc if dc in budgets else None]
        k = n_slots if not math.isfinite(until_s) else \
            min(n_slots, max(0, int(math.ceil(until_s / delta - 1e-9))))
        cap_ub[p * n_slots:p * n_slots + k] -= float(g_res)
    np.maximum(cap_ub, 0.0, out=cap_ub)
    reps = dur_all
    occ_var = np.repeat(np.arange(nx), reps)    # var of each occupancy
    offs = np.repeat(np.cumsum(reps) - reps, reps)
    taus = np.repeat(t_all, reps) + (np.arange(int(reps.sum())) - offs)
    b.add_block(pool_all[occ_var] * n_slots + taus, occ_var,
                g_all[occ_var],
                np.full(len(pools) * n_slots, -np.inf), cap_ub)
    # (3) the continuous variable M + cost vector, per objective.  For
    # makespan M bounds per-job ends (sum end*x - M <= 0, exact under
    # the assignment equality, and a tighter relaxation than per-var);
    # for fair_share M bounds per-TENANT mean ends instead; the two sum
    # objectives need no M rows at all (cost rides on the binaries).
    cvec = np.zeros(b.nvar)
    if objective == "fair_share":
        tenants = sorted({getattr(j, "tenant", "default") for j in jobs})
        tix = {name: i for i, name in enumerate(tenants)}
        ten_of = np.array([tix[getattr(j, "tenant", "default")]
                           for j in jobs])
        n_ten = np.bincount(ten_of, minlength=len(tenants)) \
            .astype(np.float64)
        b.add_block(
            np.concatenate([ten_of[ji_all], np.arange(len(tenants))]),
            np.concatenate([np.arange(nx),
                            np.full(len(tenants), b.M_idx)]),
            np.concatenate([end_all / n_ten[ten_of[ji_all]],
                            -np.ones(len(tenants))]),
            np.full(len(tenants), -np.inf), np.zeros(len(tenants)))
        cvec[b.M_idx] = 1.0
        cvec[:nx] = (delta * 1e-4) * t_all
    else:
        b.add_block(np.concatenate([ji_all, np.arange(n_jobs)]),
                    np.concatenate([np.arange(nx),
                                    np.full(n_jobs, b.M_idx)]),
                    np.concatenate([end_all, -np.ones(n_jobs)]),
                    np.full(n_jobs, -np.inf), np.zeros(n_jobs))
        if objective == "makespan":
            cvec[b.M_idx] = 1.0
            cvec[:nx] = (delta * 1e-4) * t_all
        else:
            w_all = np.array([_weight(j) for j in jobs])[ji_all]
            if objective == "weighted_completion":
                cost = w_all * end_all
            elif objective == "tardiness":
                dl = np.array([_deadline(j) for j in jobs])
                cost = w_all * np.maximum(0.0, end_all - dl[ji_all])
            else:
                raise ValueError(f"unknown objective {objective!r}; "
                                 f"expected one of {OBJECTIVES}")
            cvec[:nx] = cost + (delta * 1e-4) * t_all
    res = b.solve(cvec, time_limit_s=time_limit_s, mip_gap=mip_gap,
                  m_upper=m_upper)
    if res is None:
        return ub
    xb = res.x[:nx]
    pick: Dict[int, int] = {}
    for vi in np.flatnonzero(xb > 0.5):
        ji = int(ji_all[vi])
        if ji not in pick or xb[vi] > xb[pick[ji]]:
            pick[ji] = int(vi)
    if len(pick) != n_jobs:
        return ub
    assignments = []
    for ji, j in enumerate(jobs):
        vi = pick[ji]
        c = choice_map[j.name][int(ci_all[vi])]
        assignments.append(Assignment(j.name, c.technique, c.n_gpus,
                                      float(t_all[vi]) * delta,
                                      c.runtime_s,
                                      device_class=c.device_class))
    makespan = max(a.end_s for a in assignments)
    sol = Solution(assignments, makespan, solver_name,
                   milp_status=res.message)
    # keep whichever plan is better UNDER THE OBJECTIVE (slot rounding
    # can make the MILP's integral plan worse than the greedy bound)
    if objective == "makespan":
        return sol if makespan <= ub.makespan_s + 1e-6 else ub
    sv = objective_value(sol.assignments, jobs, objective)
    uv = objective_value(ub.assignments, jobs, objective)
    return sol if sv <= uv + 1e-6 else ub


# below this estimated binary count the dense MILP is already cheap and
# exact — refinement would only risk quality for no wall-time win
_REFINE_MIN_BINARIES = 1000


def _solve_refined(jobs, choice_map, budgets, ub, solver_name, *,
                   n_slots, coarse_slots, time_limit_s, mip_gap,
                   objective="makespan", reserved=()):
    """Coarse-to-fine: solve on ``coarse_slots`` first, then on the full
    ``n_slots`` grid with each job's starts windowed one coarse slot
    around the incumbent's start — roughly a
    ``n_slots / coarse_slots``-fold binary-count cut.

    Small instances (estimated binaries below ``_REFINE_MIN_BINARIES``)
    skip the refinement and solve dense: they are fast anyway and the
    dense answer is exact."""
    est_binaries = sum(len(choice_map[j.name]) for j in jobs) * n_slots
    if n_slots <= coarse_slots or est_binaries < _REFINE_MIN_BINARIES:
        return _solve_time_indexed(
            jobs, choice_map, budgets, ub, solver_name, n_slots=n_slots,
            time_limit_s=time_limit_s, mip_gap=mip_gap,
            reserved=reserved, objective=objective)
    horizon = max(ub.makespan_s, 1e-6) * 1.05
    # budget split keeps the refined path's TOTAL wall under the dense
    # path's single time limit even when both stages hit their caps
    coarse = _solve_time_indexed(
        jobs, choice_map, budgets, ub, solver_name,
        n_slots=coarse_slots, time_limit_s=0.3 * time_limit_s,
        mip_gap=mip_gap, horizon=horizon, reserved=reserved,
        objective=objective)
    windows = {a.job: a.start_s for a in coarse.assignments}
    ub2 = coarse if objective_value(coarse.assignments, jobs, objective) \
        < objective_value(ub.assignments, jobs, objective) else ub
    return _solve_time_indexed(
        jobs, choice_map, budgets, ub2, solver_name, n_slots=n_slots,
        time_limit_s=0.7 * time_limit_s, mip_gap=mip_gap,
        horizon=horizon, start_windows=windows,
        window_pad_s=horizon / coarse_slots, reserved=reserved,
        objective=objective)


def solve_joint(jobs: List[Job],
                profiles: Dict[Tuple[str, str, int], Profile],
                total_gpus: int, *,
                n_slots: int = 24,
                time_limit_s: float = 30.0,
                mip_gap: float = 0.02,
                refine: bool = False,
                coarse_slots: int = 8,
                objective: str = "makespan",
                reserved: Iterable[Tuple] = ()) -> Solution:
    """The joint MILP.  Falls back to greedy on infeasibility/timeout.

    ``refine=True`` enables the coarse-to-fine pass (solve on
    ``coarse_slots``, re-solve on ``n_slots`` restricted to windows
    around the incumbent) — the fast path for large job counts.

    ``objective`` selects what the MILP minimizes (see ``OBJECTIVES``);
    the default reproduces the paper's makespan formulation.

    ``reserved`` pre-loads ``(class_or_None, gpus, release_s)`` capacity
    reservations the plan must schedule around — running jobs an
    incremental replan keeps, or serving-fleet allocations (see
    :func:`repro.serving.fleet.fleet_reservations`).
    """
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"expected one of {OBJECTIVES}")
    reserved = list(reserved)
    choice_map = pooled_choice_map(jobs, profiles)
    ub = greedy_schedule(jobs, choice_map, total_gpus,
                         reserved=reserved, objective=objective)
    budgets = {None: int(total_gpus)}
    if refine:
        return _solve_refined(jobs, choice_map, budgets, ub, "milp",
                              n_slots=n_slots, coarse_slots=coarse_slots,
                              time_limit_s=time_limit_s, mip_gap=mip_gap,
                              reserved=reserved, objective=objective)
    return _solve_time_indexed(jobs, choice_map, budgets,
                               ub, "milp", n_slots=n_slots,
                               time_limit_s=time_limit_s, mip_gap=mip_gap,
                               reserved=reserved, objective=objective)


def solve_joint_classes(jobs: List[Job], profiles, cluster, *,
                        n_slots: int = 20,
                        time_limit_s: float = 30.0,
                        mip_gap: float = 0.05,
                        refine: bool = False,
                        coarse_slots: int = 8,
                        objective: str = "makespan",
                        reserved: Iterable[Tuple] = ()) -> Solution:
    """Device-class-aware joint MILP for heterogeneous clusters.

    A job's config space is the union over device classes of its
    feasible (technique, g) choices ON that class — each evaluated
    against the class's own throughput curve, so a V100 choice carries a
    genuinely longer runtime than its A100 twin.  The flat capacity
    constraint becomes one capacity row per (class, slot): apportionment
    now picks *which* class as well as *how many* GPUs.  Assignments
    carry the chosen class, which the runtime's ClassPool placement pins.

    ``reserved`` pre-loads ``(class, gpus, release_s)`` reservations —
    running jobs kept by a replan, or serving-fleet holdings.

    Falls back to a per-class-budget greedy on infeasibility/timeout.
    """
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"expected one of {OBJECTIVES}")
    reserved = list(reserved)
    choice_map, budgets = class_choice_map(jobs, profiles,
                                           cluster.device_classes)
    ub = greedy_schedule(jobs, choice_map, budgets, reserved=reserved,
                         objective=objective)
    if refine:
        return _solve_refined(jobs, choice_map, budgets, ub,
                              "milp-classes", n_slots=n_slots,
                              coarse_slots=coarse_slots,
                              time_limit_s=time_limit_s, mip_gap=mip_gap,
                              reserved=reserved, objective=objective)
    return _solve_time_indexed(jobs, choice_map, budgets, ub,
                               "milp-classes", n_slots=n_slots,
                               time_limit_s=time_limit_s, mip_gap=mip_gap,
                               reserved=reserved, objective=objective)


def solve_joint_serving(jobs: List[Job], serves, profiles, cluster, *,
                        window_s: float, horizon_s: float,
                        util_cap: float = 0.7,
                        **solver_kw) -> Tuple[Solution, dict]:
    """The joint train+serve plan: size every serving fleet under its
    latency SLO first (device class + per-window replica counts from the
    measured throughput curves — :func:`repro.serving.fleet.plan_fleets`),
    convert the fleets into capacity reservations, and solve the
    training MILP around them.

    Returns ``(solution, fleet_plans)``.  ``profiles`` must answer both
    training keys and ``(name, "serve", class, gpus)`` serve keys (see
    :func:`repro.serving.fleet.serve_profiles` and
    :class:`repro.core.perfmodel.MergedProfiles`).
    """
    from ..serving.fleet import fleet_reservations, plan_fleets
    plans = plan_fleets(serves, profiles, cluster, window_s=window_s,
                        horizon_s=horizon_s, util_cap=util_cap)
    reserved = fleet_reservations(plans)
    if cluster.hetero:
        sol = solve_joint_classes(jobs, profiles, cluster,
                                  reserved=reserved, **solver_kw)
    else:
        sol = solve_joint(jobs, profiles, cluster.total_gpus,
                          reserved=reserved, **solver_kw)
    return sol, plans


# --------------------------------------------- warm-started incremental

def split_fixed_running(jobs: List[Job], remaining: Dict[str, int],
                        current: Dict[str, Tuple], running,
                        choice_map: Dict[str, List[Choice]], profiles,
                        restart_cost_s: float
                        ) -> Tuple[List[Assignment], List[Job]]:
    """Partition live jobs for the incremental replan.

    A job that is RUNNING under assignment ``(tech, g[, class])`` is
    *fixed* — kept in place, modeled as a capacity reservation — when
    switching provably cannot pay off on current estimates:
    ``remaining_runtime(current) <= best_remaining_runtime +
    restart_cost_s``.  Everything else (waiting, restarting, and running
    jobs a better config might rescue) lands in the residual the MILP
    actually re-solves.
    """
    from .perfmodel import step_time_of
    fixed: List[Assignment] = []
    residual: List[Job] = []
    for j in jobs:
        asn = current.get(j.name)
        if j.name in running and asn:
            tech, g = asn[0], int(asn[1])
            dc = asn[2] if len(asn) > 2 else None
            rem = remaining.get(j.name, j.total_steps)
            try:
                st = step_time_of(profiles, j.name, tech, g,
                                  device_class=dc)
            except KeyError:
                st = float("inf")
            cur_rt = st * rem
            best_rt = min((c.runtime_s for c in choice_map[j.name]),
                          default=float("inf"))
            if math.isfinite(cur_rt) and \
                    cur_rt <= best_rt + restart_cost_s:
                fixed.append(Assignment(j.name, tech, g, 0.0, cur_rt,
                                        device_class=dc))
                continue
        residual.append(j)
    return fixed, residual


def solve_residual(residual_jobs: List[Job],
                   choice_map: Dict[str, List[Choice]],
                   budgets: Dict[Optional[str], int],
                   fixed: List[Assignment], *,
                   n_slots: int = 24,
                   time_limit_s: float = 10.0,
                   mip_gap: float = 0.05,
                   warm_starts: Optional[Dict[str, float]] = None,
                   objective: str = "makespan") -> Solution:
    """Warm-started incremental replan: solve only the residual jobs.

    ``fixed`` assignments (running jobs not worth preempting) become
    per-pool capacity reservations until their estimated ends instead of
    MILP variables; ``warm_starts`` (job -> previous planned start, in
    seconds from now) windows each residual job's start variables around
    the previous solution.  The reservation-aware greedy bound both
    sizes the horizon and is installed as an upper bound on the makespan
    variable, so the solve early-exits once within gap of it.

    Returns the merged Solution: fixed assignments (start 0) plus the
    residual plan.
    """
    fixed = list(fixed)
    if not residual_jobs:
        mk = max((a.end_s for a in fixed), default=0.0)
        return Solution(fixed, mk, "fixed")
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"expected one of {OBJECTIVES}")
    reserved = [(a.device_class, a.n_gpus, a.runtime_s) for a in fixed]
    ub = greedy_schedule(residual_jobs, choice_map, budgets,
                         reserved=reserved, objective=objective)
    horizon = max([ub.makespan_s] + [a.end_s for a in fixed]
                  + [1e-6]) * 1.05
    delta = horizon / n_slots
    # provably safe incumbent bound: any schedule at least as good as
    # the greedy ub stays slot-representable within one slot per job
    # in a delay chain (+ one per reservation release it waits on).
    # Only valid when M IS the makespan — the other objectives leave M
    # unbounded (fair_share's M tracks tenant means, not the horizon).
    m_upper = min(horizon, ub.makespan_s
                  + delta * (len(residual_jobs) + len(fixed))) \
        if objective == "makespan" else np.inf
    sol = _solve_time_indexed(
        residual_jobs, choice_map, budgets, ub, "milp-incremental",
        n_slots=n_slots, time_limit_s=time_limit_s, mip_gap=mip_gap,
        horizon=horizon, start_windows=warm_starts,
        window_pad_s=horizon / 8.0, reserved=reserved, m_upper=m_upper,
        objective=objective)
    assignments = fixed + list(sol.assignments)
    mk = max(a.end_s for a in assignments)
    name = sol.solver if sol.solver.startswith("milp") \
        else "greedy-incremental"
    return Solution(assignments, mk, name, milp_status=sol.milp_status)


# ------------------------------------------------------ node-aware MILP

def solve_joint_nodes(jobs: List[Job],
                      profiles: Dict[Tuple[str, str, int], Profile],
                      nodes: int, gpus_per_node: int, *,
                      n_slots: int = 16,
                      time_limit_s: float = 30.0,
                      mip_gap: float = 0.05) -> Solution:
    """Node-locality-aware joint MILP.

    Single-node configs (g <= gpus_per_node) additionally choose a node;
    larger configs must be whole-node multiples (you allocate whole
    p4d/ICI-slice nodes) and pick which nodes via binaries y[j,c,t,nu].
    Per-(node, slot) capacity replaces the flat pool constraint, so two
    5-GPU jobs can NOT share a single 8-GPU node with a third.  The
    solution's assignments carry the chosen node sets, which the
    runtime's NodeAware placement backend uses as placement hints.
    """
    G = nodes * gpus_per_node
    choice_map = {j.name: choices_from_profiles(j, profiles) for j in jobs}
    for j in jobs:
        kept = []
        for c in choice_map[j.name]:
            if c.n_gpus <= gpus_per_node or c.n_gpus % gpus_per_node == 0:
                kept.append(c)
        choice_map[j.name] = kept
        if not kept:
            raise ValueError(f"job {j.name}: no node-feasible choice")
    ub = greedy_schedule(jobs, choice_map, G)  # node-UNaware (optimistic)
    seq_total = sum(min(c.runtime_s for c in choice_map[j.name])
                    for j in jobs)  # sequential = always node-feasible
    return _solve_nodes_at_horizon(
        jobs, choice_map, ub, nodes, gpus_per_node,
        horizons=[max(ub.makespan_s, 1e-6) * 1.3, seq_total * 1.05],
        n_slots=n_slots, time_limit_s=time_limit_s, mip_gap=mip_gap)


def _solve_nodes_at_horizon(jobs, choice_map, ub, nodes, gpus_per_node, *,
                            horizons, n_slots, time_limit_s, mip_gap):
    best = None
    for horizon in horizons:
        sol = _solve_nodes_once(jobs, choice_map, nodes, gpus_per_node,
                                horizon=horizon, n_slots=n_slots,
                                time_limit_s=time_limit_s, mip_gap=mip_gap)
        if sol is not None and (best is None
                                or sol.makespan_s < best.makespan_s):
            best = sol
        if best is not None:
            break  # first feasible horizon wins (tighter delta)
    return best if best is not None else ub


# variable kinds in the node MILP's flat arrays
_X1, _XM, _Y = 0, 1, 2


def _solve_nodes_once(jobs, choice_map, nodes, gpus_per_node, *,
                      horizon, n_slots, time_limit_s, mip_gap):
    """One node-MILP solve at a fixed horizon, vectorized like
    ``_solve_time_indexed``: variables are x1[j,c,t,nu] (single-node
    configs pick a node), xm[j,c,t] + y[j,c,t,nu] (whole-node configs
    pick a node SET), all described by flat attribute arrays, with each
    constraint family emitted as one bulk COO block."""
    delta = horizon / n_slots

    kind_ch, ji_ch, ci_ch, t_ch, nu_ch = [], [], [], [], []
    dur_ch, g_ch, parent_ch = [], [], []
    nvar = 0
    for ji, j in enumerate(jobs):
        for ci, c in enumerate(choice_map[j.name]):
            dur = max(1, math.ceil(c.runtime_s / delta - 1e-9))
            if dur > n_slots:
                continue
            nst = n_slots - dur + 1
            if c.n_gpus <= gpus_per_node:
                n = nst * nodes
                kind_ch.append(np.full(n, _X1))
                t_ch.append(np.repeat(np.arange(nst), nodes))
                nu_ch.append(np.tile(np.arange(nodes), nst))
                parent_ch.append(np.full(n, -1))
            else:
                # per start slot: one xm var then its `nodes` y vars
                n = nst * (1 + nodes)
                kinds = np.full(n, _Y)
                kinds[::1 + nodes] = _XM
                kind_ch.append(kinds)
                t_ch.append(np.repeat(np.arange(nst), 1 + nodes))
                nus = np.tile(np.arange(-1, nodes), nst)
                nu_ch.append(nus)
                xm_pos = nvar + np.arange(0, n, 1 + nodes)
                parents = np.repeat(xm_pos, 1 + nodes)
                parents[::1 + nodes] = -1     # xm vars have no parent
                parent_ch.append(parents)
            ji_ch.append(np.full(n, ji))
            ci_ch.append(np.full(n, ci))
            dur_ch.append(np.full(n, dur))
            g_ch.append(np.full(n, c.n_gpus))
            nvar += n
    if not t_ch:
        return None
    kind_all = np.concatenate(kind_ch)
    ji_all = np.concatenate(ji_ch)
    ci_all = np.concatenate(ci_ch)
    t_all = np.concatenate(t_ch)
    nu_all = np.concatenate(nu_ch)
    dur_all = np.concatenate(dur_ch)
    g_all = np.concatenate(g_ch)
    parent_all = np.concatenate(parent_ch)
    nx = kind_all.size
    starts = kind_all != _Y                   # x1 and xm: "start" vars
    n_jobs = len(jobs)
    if (np.bincount(ji_all[starts], minlength=n_jobs) == 0).any():
        return None

    b = _MilpBuilder(nx)
    # (1) one (choice, start[, node-set]) per job
    sv = np.flatnonzero(starts)
    b.add_block(ji_all[sv], sv, np.ones(sv.size),
                np.ones(n_jobs), np.ones(n_jobs))
    # (2) whole-node jobs: sum_nu y - k * xm == 0, one row per xm var
    xm_vars = np.flatnonzero(kind_all == _XM)
    if xm_vars.size:
        xm_row = np.full(nx, -1)
        xm_row[xm_vars] = np.arange(xm_vars.size)
        y_vars = np.flatnonzero(kind_all == _Y)
        k_of = g_all[xm_vars] // gpus_per_node
        b.add_block(
            np.concatenate([np.arange(xm_vars.size),
                            xm_row[parent_all[y_vars]]]),
            np.concatenate([xm_vars, y_vars]),
            np.concatenate([-k_of.astype(np.float64),
                            np.ones(y_vars.size)]),
            np.zeros(xm_vars.size), np.zeros(xm_vars.size))
    # (3) per-(node, slot) capacity: x1 vars weigh their GPU count, y
    # vars a whole node; expand each var over its occupied slots
    occ = np.flatnonzero(kind_all != _XM)
    reps = dur_all[occ]
    occ_var = np.repeat(occ, reps)
    offs = np.repeat(np.cumsum(reps) - reps, reps)
    taus = np.repeat(t_all[occ], reps) + (np.arange(int(reps.sum())) - offs)
    weights = np.where(kind_all[occ_var] == _X1,
                       g_all[occ_var], gpus_per_node).astype(np.float64)
    b.add_block(nu_all[occ_var] * n_slots + taus, occ_var, weights,
                np.full(nodes * n_slots, -np.inf),
                np.full(nodes * n_slots, float(gpus_per_node)))
    # (4) makespan, aggregated per job over its start vars
    end_all = (t_all + dur_all) * delta
    b.add_block(np.concatenate([ji_all[sv], np.arange(n_jobs)]),
                np.concatenate([sv, np.full(n_jobs, b.M_idx)]),
                np.concatenate([end_all[sv], -np.ones(n_jobs)]),
                np.full(n_jobs, -np.inf), np.zeros(n_jobs))

    cvec = np.zeros(b.nvar)
    cvec[b.M_idx] = 1.0
    cvec[sv] = (delta * 1e-4) * t_all[sv]
    res = b.solve(cvec, time_limit_s=time_limit_s, mip_gap=mip_gap)
    if res is None:
        return None
    xb = res.x[:nx]
    pick: Dict[int, int] = {}
    for vi in np.flatnonzero((xb > 0.5) & starts):
        ji = int(ji_all[vi])
        if ji not in pick or xb[vi] > xb[pick[ji]]:
            pick[ji] = int(vi)
    if len(pick) != n_jobs:
        return None
    assignments = []
    for ji, j in enumerate(jobs):
        vi = pick[ji]
        c = choice_map[j.name][int(ci_all[vi])]
        if kind_all[vi] == _X1:
            node_set: Tuple[int, ...] = (int(nu_all[vi]),)
        else:
            ys = np.flatnonzero((parent_all == vi) & (xb > 0.5))
            node_set = tuple(sorted(int(nu_all[y]) for y in ys))
        assignments.append(Assignment(j.name, c.technique, c.n_gpus,
                                      float(t_all[vi]) * delta,
                                      c.runtime_s, nodes=node_set))
    makespan = max(a.end_s for a in assignments)
    return Solution(assignments, makespan, "milp-nodes",
                    milp_status=res.message)
