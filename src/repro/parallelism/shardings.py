"""Build NamedShardings for params / opt state / batches from a Plan (or
from explicit logical->mesh rules for the production dry-run meshes).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..models.params import P, is_spec
from .base import Plan, largest_divisible_axis
from .context import spec_for


def make_mesh_from_plan(plan: Plan, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()[: plan.n_devices]
    import numpy as np
    devs = np.asarray(devices).reshape(plan.mesh_shape)
    return Mesh(devs, plan.mesh_axis_names)


def param_pspec(spec: P, plan: Plan) -> PartitionSpec:
    """PartitionSpec for one parameter under the plan's policy."""
    if plan.param_policy == "replicate":
        return PartitionSpec()
    if plan.param_policy == "fsdp":
        n = dict(plan.mesh_axes)["data"]
        idx = largest_divisible_axis(spec.shape, n)
        if idx is None:
            return PartitionSpec()
        entries = [None] * len(spec.shape)
        entries[idx] = "data"
        return PartitionSpec(*entries)
    if plan.param_policy == "rules":
        return spec_for(spec.axes, plan.rules)
    if plan.param_policy == "stage":
        # stacked-layer ("layers") axis sharded over the stage axis
        entries = ["stage" if a == "layers" else None for a in spec.axes]
        return PartitionSpec(*entries)
    raise ValueError(plan.param_policy)


def param_shardings(spec_tree, plan: Plan, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, param_pspec(s, plan)),
        spec_tree, is_leaf=is_spec)


def param_shardings_from_rules(spec_tree, rules: Dict[str, Optional[str]],
                               mesh: Mesh):
    """Production-mesh path: map logical param axes through ``rules``."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_for(s.axes, rules)),
        spec_tree, is_leaf=is_spec)


def opt_state_shardings(spec_tree, plan_or_rules, mesh: Mesh):
    """mu/nu mirror param shardings; step is replicated."""
    if isinstance(plan_or_rules, Plan):
        ps = param_shardings(spec_tree, plan_or_rules, mesh)
    else:
        ps = param_shardings_from_rules(spec_tree, plan_or_rules, mesh)
    return {"mu": ps, "nu": ps,
            "step": NamedSharding(mesh, PartitionSpec())}


def batch_shardings(batch_tree, mesh: Mesh, batch_axes) -> dict:
    """Shard dim 0 (batch) of every input over ``batch_axes``."""
    def mk(x):
        nd = x.ndim if hasattr(x, "ndim") else len(x.shape)
        if nd == 0:
            return NamedSharding(mesh, PartitionSpec())
        return NamedSharding(mesh, PartitionSpec(batch_axes))
    return jax.tree.map(mk, batch_tree)
