"""Training and serving step functions.

``train_step`` — causal-LM loss (next-token CE; audio archs use provided
codec labels; VLM masks the patch prefix), AdamW update, MoE aux loss.
``serve_step`` — single-token decode against a KV/recurrent-state cache
(this is what the decode_32k / long_500k dry-run shapes lower).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.transformer import decode_step, forward
from ..optim.adamw import AdamWConfig, adamw_update


def _ce_from_logits(cfg: ModelConfig, logits, batch):
    """Mean next-token cross-entropy.  Audio archs use provided codec
    labels (aligned); others shift tokens; VLM skips the patch prefix."""
    if cfg.frontend == "audio":
        targets = batch["labels"]
        pred = logits
    else:
        tokens = batch["tokens"]
        n_prefix = logits.shape[1] - tokens.shape[1]  # VLM patch prefix
        pred = logits[:, n_prefix:][:, :-1]
        targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(pred.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    return loss, {"loss": loss,
                  "perplexity": jnp.exp(jnp.minimum(loss, 20.0))}


def lm_loss(params, cfg: ModelConfig, batch, *, opts=None, remat=False):
    """Mean next-token cross-entropy (+ MoE aux).  Returns (loss, metrics)."""
    logits, aux = forward(params, cfg, batch, opts=opts, remat=remat)
    loss, metrics = _ce_from_logits(cfg, logits, batch)
    metrics["aux_loss"] = aux
    return loss + aux, metrics


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    opts: Optional[dict] = None, remat: bool = False,
                    microbatches: int = 1, loss_fn=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    microbatches > 1 accumulates gradients over batch slices (gradient
    accumulation; the GPipe technique instead passes its own pipelined
    ``loss_fn`` and keeps microbatches=1 here).
    """

    if loss_fn is None:
        def loss_fn(params, batch):
            return lm_loss(params, cfg, batch, opts=opts, remat=remat)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            grads, metrics = jax.grad(
                lambda p: loss_fn(p, batch), has_aux=True)(params)
        else:
            def split(x):
                b = x.shape[0]
                mb = b // microbatches
                return x.reshape(microbatches, mb, *x.shape[1:])
            mbatch = jax.tree.map(split, batch)

            def body(carry, mb):
                acc = carry
                g, m = jax.grad(
                    lambda p: loss_fn(p, mb), has_aux=True)(params)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, m
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, ms = jax.lax.scan(body, zero, mbatch)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda x: x.mean(), ms)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics.update(opt_metrics)
        return new_params, new_opt, metrics

    return train_step


def make_serve_step(cfg: ModelConfig, *, opts: Optional[dict] = None,
                    greedy: bool = True):
    """Returns serve_step(params, tokens (B,1), state) ->
    (next_tokens (B,1), logits, new_state)."""

    def serve_step(params, tokens, state):
        logits, new_state = decode_step(params, cfg, tokens, state, opts=opts)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, logits, new_state

    return serve_step
