"""Batched-gradient sLSTM scan (custom VJP): forward and gradients must
match the naive autodiff scan exactly (the §Perf pair-1 optimization)."""
import jax
import numpy as np
import pytest

from repro.configs import concrete_batch, get_config
from repro.models.transformer import forward, init_model
from repro.train.steps import lm_loss


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("xlstm-125m").reduced(num_layers=4)
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, 2, 32)
    return cfg, params, batch


def test_forward_matches(setup):
    cfg, params, batch = setup
    a, _ = forward(params, cfg, batch)
    b, _ = forward(params, cfg, batch, opts={"slstm_batched_grad": True})
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-5, rtol=1e-5)


def test_grads_match_autodiff(setup):
    cfg, params, batch = setup
    g1 = jax.grad(lambda p: lm_loss(p, cfg, batch)[0])(params)
    g2 = jax.grad(lambda p: lm_loss(
        p, cfg, batch, opts={"slstm_batched_grad": True})[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-6, rtol=1e-3)


def test_unroll_equivalent(setup):
    cfg, params, batch = setup
    a, _ = forward(params, cfg, batch)
    b, _ = forward(params, cfg, batch, opts={"slstm_unroll": 4})
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-5, rtol=1e-5)
