"""Fast scheduling core: vectorized assembly parity, coarse-to-fine
refinement, warm-started incremental replans, the choice cache, and the
runtime plumbing that feeds them.

Exact-equivalence tests use Optimus/CurrentPractice per repo
convention — MILP policies are time-limit-nondeterministic.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.baselines import (CurrentPractice, OptimusDynamic,
                                  SaturnPolicy)
from repro.core.executor import simulate, simulate_legacy
from repro.core.job import ClusterSpec, Job
from repro.core.profiler import Profile
from repro.core.solver import (Assignment, choices_from_profiles,
                               clear_choice_cache, greedy_schedule,
                               solve_joint, solve_residual,
                               split_fixed_running)

CFG = get_config("xlstm-125m").reduced()
CLUSTER = ClusterSpec(nodes=1, gpus_per_node=8, restart_cost_s=10.0)


def mk_job(name, steps=100):
    return Job(name, CFG, batch_size=8, seq_len=64, total_steps=steps)


def mk_profiles(step_times):
    return {(jn, tech, g): Profile(jn, tech, g, t, 1e9, True, "test")
            for (jn, tech, g), t in step_times.items()}


def random_workload(n_jobs, total_gpus, seed):
    rng = np.random.RandomState(seed)
    jobs, times = [], {}
    for i in range(n_jobs):
        j = mk_job(f"r{i}", steps=int(rng.randint(50, 500)))
        jobs.append(j)
        base = rng.uniform(0.5, 5.0)
        eff = rng.uniform(0.4, 1.0)
        g = 1
        while g <= total_gpus:
            times[(j.name, "fsdp", g)] = base / g ** eff
            g *= 2
    return jobs, mk_profiles(times)


def validate_capacity(assignments, budget):
    events = sorted({a.start_s for a in assignments}
                    | {a.end_s for a in assignments})
    for t in events:
        used = sum(a.n_gpus for a in assignments
                   if a.start_s <= t < a.end_s - 1e-9)
        assert used <= budget + 1e-9, f"capacity violated at t={t}"


# ------------------------------------------------- coarse-to-fine refine

@pytest.mark.parametrize("seed", [0, 3, 11])
def test_refined_small_instances_match_dense(seed):
    """Below the refinement threshold refine=True takes the dense path:
    identical quality, nothing to trade."""
    jobs, profiles = random_workload(6, 16, seed)
    dense = solve_joint(jobs, profiles, 16, n_slots=24, time_limit_s=10,
                        mip_gap=0.02)
    fine = solve_joint(jobs, profiles, 16, n_slots=24, time_limit_s=10,
                       mip_gap=0.02, refine=True)
    assert {a.job for a in fine.assignments} == {j.name for j in jobs}
    validate_capacity(fine.assignments, 16)
    assert fine.makespan_s <= dense.makespan_s * 1.01 + 1e-6


@pytest.mark.parametrize("seed", [2, 7])
def test_refined_within_gap_of_dense(seed):
    """Above the threshold the coarse-to-fine windows engage; quality
    must stay near the dense solve (a heuristic, hence the slack)."""
    jobs, profiles = random_workload(12, 16, seed)
    dense = solve_joint(jobs, profiles, 16, n_slots=24, time_limit_s=15,
                        mip_gap=0.02)
    fine = solve_joint(jobs, profiles, 16, n_slots=24, time_limit_s=15,
                       mip_gap=0.02, refine=True)
    assert {a.job for a in fine.assignments} == {j.name for j in jobs}
    validate_capacity(fine.assignments, 16)
    assert fine.makespan_s <= dense.makespan_s * 1.10 + 1e-6


def test_refine_noop_on_coarse_grids():
    jobs, profiles = random_workload(4, 8, 2)
    a = solve_joint(jobs, profiles, 8, n_slots=8, time_limit_s=5)
    b = solve_joint(jobs, profiles, 8, n_slots=8, time_limit_s=5,
                    refine=True, coarse_slots=8)
    assert b.makespan_s == pytest.approx(a.makespan_s, rel=1e-9)


# ------------------------------------------------------- greedy reserved

def test_greedy_reserved_delays_start():
    j = mk_job("a", steps=100)
    choices = {"a": choices_from_profiles(
        j, mk_profiles({("a", "ddp", 8): 1.0}))}
    free = greedy_schedule([j], choices, 8)
    assert free.assignments[0].start_s == 0.0
    held = greedy_schedule([j], choices, 8,
                           reserved=[(None, 8, 50.0)])
    assert held.assignments[0].start_s == pytest.approx(50.0)
    partial = greedy_schedule([j], choices, 16,
                              reserved=[(None, 8, 50.0)])
    assert partial.assignments[0].start_s == 0.0


# ----------------------------------------------------------- choice cache

def test_choice_cache_consistent_and_invalidated():
    clear_choice_cache()
    j1, j2 = mk_job("x", steps=100), mk_job("x", steps=200)
    profiles = mk_profiles({("x", "ddp", 1): 10.0, ("x", "fsdp", 2): 6.0})
    first = choices_from_profiles(j1, profiles)
    again = choices_from_profiles(j1, profiles)
    assert [(c.technique, c.n_gpus, c.runtime_s) for c in first] == \
        [(c.technique, c.n_gpus, c.runtime_s) for c in again]
    # runtimes scale with the job's remaining steps, off the same cache
    doubled = choices_from_profiles(j2, profiles)
    by_key = {(c.technique, c.n_gpus): c.runtime_s for c in first}
    for c in doubled:
        assert c.runtime_s == pytest.approx(
            2.0 * by_key[(c.technique, c.n_gpus)])
    # mutating the dict (new key) invalidates the cached enumeration
    profiles[("x", "tp", 4)] = Profile("x", "tp", 4, 1.0, 1e9, True, "t")
    fresh = choices_from_profiles(j1, profiles)
    assert ("tp", 4) in {(c.technique, c.n_gpus) for c in fresh}


# --------------------------------------------- warm incremental residual

def test_solve_residual_respects_reservations():
    """A fixed 6-GPU job holds the pool until t=50; the residual job
    needs 4 GPUs and must wait for the release."""
    j = mk_job("res", steps=100)
    choices = {"res": choices_from_profiles(
        j, mk_profiles({("res", "ddp", 4): 1.0}))}
    fixed = [Assignment("fix", "fsdp", 6, 0.0, 50.0)]
    sol = solve_residual([j], choices, {None: 8}, fixed,
                         n_slots=20, time_limit_s=5)
    by_job = {a.job: a for a in sol.assignments}
    assert set(by_job) == {"fix", "res"}
    assert by_job["res"].start_s >= 50.0 - 1e-6
    assert sol.makespan_s == pytest.approx(by_job["res"].end_s)


def test_solve_residual_no_residual_keeps_fixed():
    fixed = [Assignment("a", "ddp", 4, 0.0, 30.0),
             Assignment("b", "fsdp", 4, 0.0, 80.0)]
    sol = solve_residual([], {}, {None: 8}, fixed)
    assert sol.solver == "fixed"
    assert sol.makespan_s == pytest.approx(80.0)
    assert len(sol.assignments) == 2


def test_split_fixed_running_criterion():
    """Fix a running job iff switching provably cannot pay off:
    remaining(current) <= best remaining + restart cost."""
    a, b = mk_job("a", steps=100), mk_job("b", steps=100)
    profiles = mk_profiles({("a", "ddp", 1): 1.0, ("a", "ddp", 2): 0.5,
                            ("b", "ddp", 1): 1.0, ("b", "ddp", 2): 0.99})
    cm = {j.name: choices_from_profiles(j, profiles) for j in (a, b)}
    remaining = {"a": 100, "b": 100}
    current = {"a": ("ddp", 1), "b": ("ddp", 1)}
    fixed, residual = split_fixed_running(
        [a, b], remaining, current, {"a", "b"}, cm, profiles,
        restart_cost_s=10.0)
    # a: current 100s vs best 50s + 10s restart -> worth preempting
    # b: current 100s vs best 99s + 10s restart -> fixed in place
    assert [f.job for f in fixed] == ["b"]
    assert [j.name for j in residual] == ["a"]
    assert fixed[0].runtime_s == pytest.approx(100.0)


def test_incremental_close_to_scratch_when_fixing_is_right():
    """Running jobs already on their best configs (and a physically
    consistent running state — their GPUs fit together): the
    incremental replan (fix + residual) must match a from-scratch
    re-solve."""
    rng = np.random.RandomState(7)
    jobs, times = [], {}
    for i in range(5):
        j = mk_job(f"r{i}", steps=int(rng.randint(50, 300)))
        jobs.append(j)
        base = rng.uniform(0.5, 5.0)
        # scaling saturates at 4 GPUs (8 is strictly worse, so it gets
        # pruned): g=4 is every job's best choice, and two running jobs
        # fit the 8-GPU pool together
        for g, speed in ((1, 1.0), (2, 1.9), (4, 3.6), (8, 3.5)):
            times[(j.name, "fsdp", g)] = base / speed
    profiles = mk_profiles(times)
    cm = {j.name: choices_from_profiles(j, profiles) for j in jobs}
    running = {jobs[0].name, jobs[1].name}
    current, remaining = {}, {}
    for j in jobs:
        remaining[j.name] = j.total_steps
        if j.name in running:
            best = min(cm[j.name], key=lambda c: c.runtime_s)
            current[j.name] = (best.technique, best.n_gpus)
            assert best.n_gpus == 4
    fixed, residual = split_fixed_running(
        jobs, remaining, current, running, cm, profiles,
        restart_cost_s=10.0)
    assert {f.job for f in fixed} == running
    scratch = solve_joint(jobs, profiles, 8, n_slots=20, time_limit_s=10,
                          mip_gap=0.02)
    incr = solve_residual(residual,
                          {j.name: cm[j.name] for j in residual},
                          {None: 8}, fixed, n_slots=20, time_limit_s=10,
                          mip_gap=0.02)
    assert {a.job for a in incr.assignments} == {j.name for j in jobs}
    validate_capacity(incr.assignments, 8)
    assert incr.makespan_s <= scratch.makespan_s * 1.10 + 1e-6


# ------------------------------------------------------- runtime plumbing

def test_runtime_incremental_saturn_completes_and_conserves():
    """SaturnPolicy with warm-started replans drives the runtime end to
    end: every job finishes and (simulate's built-in) per-class
    GPU-second conservation holds under heavy introspection."""
    jobs, profiles = random_workload(6, 8, seed=5)
    res = simulate(jobs, SaturnPolicy(time_limit_s=5, incremental=True),
                   profiles, CLUSTER, introspect_every_s=100,
                   noise_sigma=0.3)
    assert {g.job for g in res.gantt if g.kind == "run"} == \
        {j.name for j in jobs}
    assert res.replans > 1


def test_incremental_vs_scratch_policy_same_workload():
    """Warm-started and from-scratch Saturn replans both finish the
    workload; the incremental path must not collapse in quality."""
    jobs, profiles = random_workload(6, 8, seed=9)
    warm = simulate(jobs, SaturnPolicy(time_limit_s=5, incremental=True),
                    profiles, CLUSTER, introspect_every_s=150,
                    noise_sigma=0.2)
    cold = simulate(jobs, SaturnPolicy(time_limit_s=5, incremental=False),
                    profiles, CLUSTER, introspect_every_s=150,
                    noise_sigma=0.2)
    assert warm.makespan_s <= cold.makespan_s * 1.25 + 1e-6


def _equiv_workload():
    rng = np.random.RandomState(17)
    jobs, times = [], {}
    for i in range(7):
        j = mk_job(f"j{i}", steps=int(rng.randint(100, 400)))
        jobs.append(j)
        base, eff = rng.uniform(1, 4), rng.uniform(0.5, 0.95)
        for g in (1, 2, 4, 8):
            for tech, mult in (("ddp", 1.0), ("fsdp", 1.1)):
                times[(j.name, tech, g)] = base * mult / g ** eff
    return jobs, mk_profiles(times)


def _segments(res):
    return sorted((g.job, g.technique, g.n_gpus,
                   round(g.start_s, 9), round(g.end_s, 9))
                  for g in res.gantt if g.kind == "run")


def test_warm_replan_plumbing_keeps_gantt_accounting_static():
    """The plan_incremental plumbing must be invisible to policies that
    do not opt in: for a static policy the runtime's Gantt must match
    the legacy loop's SEGMENT FOR SEGMENT, not just on makespan."""
    jobs, profiles = _equiv_workload()
    new = simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                   noise_sigma=0.35)
    old = simulate_legacy(jobs, CurrentPractice(), profiles, CLUSTER,
                          noise_sigma=0.35)
    assert _segments(new) == _segments(old)
    assert new.restarts == old.restarts == 0


def test_warm_replan_plumbing_keeps_gantt_accounting_dynamic():
    """Dynamic non-incremental policies keep the established
    runtime/legacy equivalence contract through the new replan path:
    exact makespan, restart count and run-segment count.  (Segment
    shapes may differ: legacy replans at completions also when only a
    RESTARTING job is pending — a pre-existing nuance, not part of the
    contract.)"""
    jobs, profiles = _equiv_workload()
    new = simulate(jobs, OptimusDynamic(), profiles, CLUSTER,
                   introspect_every_s=120.0, noise_sigma=0.35)
    old = simulate_legacy(jobs, OptimusDynamic(), profiles, CLUSTER,
                          introspect_every_s=120.0, noise_sigma=0.35)
    assert new.makespan_s == pytest.approx(old.makespan_s, rel=1e-12)
    assert new.restarts == old.restarts > 0
    assert len(_segments(new)) == len(_segments(old))


def test_session_solver_knobs():
    from repro.core.api import SaturnSession
    sess = SaturnSession(ClusterSpec(nodes=1, gpus_per_node=4))
    jobs = [mk_job("s0", steps=40), mk_job("s1", steps=60)]
    sess.submit(jobs)
    res = sess.run(n_slots=10, time_limit_s=2, mip_gap=0.1, refine=True,
                   introspect_every_s=None)
    assert {g.job for g in res.gantt if g.kind == "run"} == {"s0", "s1"}
    with pytest.raises(ValueError):
        sess.run(policy=CurrentPractice(), n_slots=10)


def test_saturn_refine_policy_runs():
    jobs, profiles = random_workload(5, 8, seed=13)
    res = simulate(jobs, SaturnPolicy(time_limit_s=5, refine=True),
                   profiles, CLUSTER, introspect_every_s=200,
                   noise_sigma=0.1)
    assert {g.job for g in res.gantt if g.kind == "run"} == \
        {j.name for j in jobs}
