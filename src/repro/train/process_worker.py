"""Child entry point for the ProcessJaxBackend: one training worker in
its own OS process.

The coordinator (:class:`~repro.core.process_backend.ProcessJaxBackend`)
spawns this module's :func:`_worker_main` with a duplex pipe and a plain
``spec`` dict, and the two speak a small message protocol:

child -> parent
  ``{"msg": "hello", "start_step", "steps_to_run"}``
      sent once the checkpoint is loaded: the ABSOLUTE step the worker
      really resumed from (the durable checkpoint is the source of
      truth; the coordinator reconciles its own step accounting against
      this).
  ``{"msg": "hb", "steps", "t"}``
      heartbeat with the worker's step counter, from a dedicated thread
      that runs independently of the (possibly JIT-blocked) training
      thread — a multi-second compile never looks like a hang.
  ``{"msg": "ckpt", "step"}``
      checkpoint-ack: the checkpoint for ABSOLUTE ``step`` is durably
      committed on disk (atomic, checksummed).
  ``{"msg": "exit", "steps", "preempted", "losses", ...}``
      clean end of the segment (budget done or stop honored), after the
      final checkpoint commit.
  ``{"msg": "error", "reason"}``
      the training loop raised; the process exits 3 without
      checkpointing (recovery salvages the last durable commit).

parent -> child
  ``{"cmd": "stop"}``  checkpoint-and-exit (preemption);
  ``{"cmd": "hang"}``  fault injection: wedge — stop heartbeating AND
  stop making progress, but stay alive (the coordinator must detect the
  missed heartbeat deadline and kill the process).

This module is deliberately import-lean: it pulls the model/optimizer/
data/checkpoint stacks but NEVER ``repro.core`` (the scheduler), so a
spawned child does not pay the coordinator's import bill.
"""
from __future__ import annotations

import os
import threading
import time


def _enable_compile_cache() -> None:
    """Persistent XLA cache (same dir contract as
    ``repro.core.compile_cache``, inlined so the child skips the heavy
    ``repro.core`` import): relaunching onto a previously compiled
    (model, technique, slice) choice is a disk hit, not a recompile."""
    import jax
    d = os.environ.get(
        "SATURN_COMPILE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "saturn", "xla"))
    try:
        os.makedirs(d, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", os.path.abspath(d))
    except (AttributeError, ValueError, OSError):
        return
    for knob, val in (
            ("jax_persistent_cache_min_compile_time_secs", 0.0),
            ("jax_persistent_cache_min_entry_size_bytes", -1)):
        try:
            jax.config.update(knob, val)
        except (AttributeError, ValueError):
            pass


def _worker_main(conn, spec: dict) -> None:
    """Process target.  Any exception is reported over the pipe as an
    ``error`` message and the process exits 3 — the coordinator treats
    both the message and the bare death as the same failure."""
    try:
        _run(conn, spec)
    except BaseException as e:  # noqa: BLE001 — report, then die
        try:
            conn.send({"msg": "error",
                       "reason": f"{type(e).__name__}: {e}"})
        except Exception:
            pass
        os._exit(3)


def _run(conn, spec: dict) -> None:
    send_lock = threading.Lock()

    def send(m: dict) -> None:
        with send_lock:
            try:
                conn.send(m)
            except (BrokenPipeError, OSError):
                pass    # coordinator gone; nothing useful left to do

    state = {"steps": 0, "sent_losses": 0}
    losses: list = []           # (absolute step, loss), append-only
    loss_lock = threading.Lock()
    stop = threading.Event()
    hang = threading.Event()

    def send_with_losses(base: dict) -> None:
        # cursor + send under one lock so chunks from the sidecar and
        # the training thread (checkpoint acks) never reorder
        with loss_lock:
            chunk = losses[state["sent_losses"]:]
            state["sent_losses"] += len(chunk)
            base["losses"] = chunk
            send(base)

    def sidecar() -> None:
        # heartbeats + command listening, independent of the training
        # thread: a JIT compile blocking the main thread for many
        # seconds still heartbeats.  A wedged ("hang") worker goes
        # silent for real — no heartbeats, no command responses.
        # Heartbeats stream the loss records accrued since the last one,
        # so even a SIGKILLed segment leaves its trajectory behind
        # (append-only list + cursor: safe against the training thread
        # under the GIL).
        while not stop.is_set():
            try:
                if conn.poll(spec["heartbeat_every_s"]):
                    cmd = conn.recv()
                    if not hang.is_set():
                        if cmd.get("cmd") == "stop":
                            stop.set()
                        elif cmd.get("cmd") == "hang":
                            hang.set()
            except (EOFError, OSError):
                return
            if not hang.is_set() and not stop.is_set():
                send_with_losses({"msg": "hb", "steps": state["steps"],
                                  "t": time.monotonic()})

    # start heartbeating BEFORE the heavy setup (jax import, mesh
    # build, init) so the coordinator's startup grace only has to cover
    # process spawn, not compilation
    threading.Thread(target=sidecar, daemon=True,
                     name="saturn-hb").start()

    import jax

    _enable_compile_cache()
    from repro.checkpoint.store import load_training_state, save_checkpoint
    from repro.data.synthetic import SyntheticLM
    from repro.optim.adamw import AdamWConfig
    from repro.parallelism.build import BuiltJob
    from repro.parallelism.techniques import DEFAULT_TECHNIQUES

    cfg = spec["model_cfg"]
    devices = [jax.devices()[i] for i in spec["device_ids"]]
    tech = {t.name: t for t in DEFAULT_TECHNIQUES}[spec["technique"]]
    plan = tech.plan(cfg, len(devices))
    total = spec["total_steps"]
    opt_cfg = AdamWConfig(lr=spec["lr"],
                          warmup_steps=min(100, total // 10 + 1),
                          total_steps=total)
    built = BuiltJob(cfg, plan, opt_cfg, devices=devices)
    params, opt = built.init(jax.random.PRNGKey(spec["seed"]))
    params, opt, start_step = load_training_state(
        spec["ckpt_path"], params, opt)
    # the durable checkpoint is authoritative: never run past the job's
    # total budget even when the coordinator's view lagged behind it
    steps_to_run = max(0, min(spec["steps_to_run"], total - start_step))
    send({"msg": "hello", "start_step": start_step,
          "steps_to_run": steps_to_run})

    data = SyntheticLM(cfg, seed=spec["seed"]).batches(
        spec["batch_size"], spec["seq_len"],
        num_batches=steps_to_run, skip=start_step)
    ckpt_every = int(spec.get("ckpt_every_steps", 0))
    loss = float("nan")
    compile_s = 0.0
    dt_sum, dt_n = 0.0, 0
    preempted = False
    for b in data:
        if stop.is_set():
            preempted = True
            break
        while hang.is_set():        # wedged for real: silent AND stuck
            time.sleep(0.05)
        t0 = time.perf_counter()
        params, opt, m = built.step(params, opt, built.place_batch(b))
        loss = float(m.get("loss", float("nan")))   # forces sync
        dt = time.perf_counter() - t0
        if state["steps"] == 0:
            compile_s = dt
        else:
            dt_sum += dt
            dt_n += 1
        state["steps"] += 1
        losses.append((start_step + state["steps"], loss))
        if ckpt_every and state["steps"] % ckpt_every == 0 \
                and state["steps"] < steps_to_run:
            step_abs = start_step + state["steps"]
            save_checkpoint(spec["ckpt_path"],
                            {"params": params, "opt": opt},
                            {"step": step_abs, "loss": loss})
            # the ack flushes pending loss records: every step at or
            # below a durable checkpoint is then recorded parent-side,
            # so a later crash loses no trajectory (steps PAST the
            # checkpoint are replayed from it on resume)
            send_with_losses({"msg": "ckpt", "step": step_abs})
    step_abs = start_step + state["steps"]
    save_checkpoint(spec["ckpt_path"], {"params": params, "opt": opt},
                    {"step": step_abs, "loss": loss})
    send_with_losses({"msg": "ckpt", "step": step_abs})
    stop.set()
    send({"msg": "exit", "steps": state["steps"], "preempted": preempted,
          "losses": losses, "compile_s": compile_s,
          "measured_step_s": (dt_sum / dt_n) if dt_n else None})
    conn.close()
