"""SaturnSession — the user-facing facade (paper Fig. 1B API):

    sess = SaturnSession(cluster)
    sess.register_technique(MyTechnique())     # Parallelism Library
    sess.submit(jobs)                          # model selection workload
    sess.submit(more_jobs, arrival_s=3600.0)   # ...or staggered arrivals
    sess.profile()                             # Trial Runner
    result = sess.run()                        # Solver + cluster runtime

Execution goes through the event-driven cluster runtime: placement is
chosen by ``ClusterSpec.placement`` ("flat" pool or "node"-aware), jobs
with ``arrival_s > 0`` enter the system online, and dynamic policies
replan on arrivals and introspection ticks with real restart penalties.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

from .baselines import SaturnPolicy
from .executor import simulate
from .job import ClusterSpec, Job, ServeJob
from .library import ParallelismLibrary
from .profiler import HARDWARE, HardwareSpec, TrialRunner
from .runtime import SimResult
from .schedule import Policy


class SaturnSession:
    def __init__(self, cluster: ClusterSpec,
                 hardware: HardwareSpec = HARDWARE["a100"],
                 cache_path: Optional[str] = None,
                 library: Optional[ParallelismLibrary] = None):
        self.cluster = cluster
        self.library = library or ParallelismLibrary()
        self.runner = TrialRunner(self.library, hardware, cache_path)
        # mixed fleets: derive per-class hardware (speed_hint-scaled
        # rates, per-class HBM) so trials land at realistic speeds
        for dc in cluster.device_classes:
            self.runner.register_class(dc)
        self.jobs: List[Job] = []
        self.serves: List[ServeJob] = []
        # a PerfModel (strategy="interpolate") or legacy profile dict
        self.profiles = {}

    # ------------------------------------------------- Parallelism Library
    def register_technique(self, technique):
        return self.library.register(technique)

    # ----------------------------------------------------------- workload
    def submit(self, jobs: Sequence[Job],
               arrival_s: Optional[Union[float, Sequence[float]]] = None):
        """Add jobs to the workload.

        ``arrival_s`` stamps submission times for online scenarios: a
        scalar applies to every job in this batch, a sequence gives one
        arrival per job.  Omitted, each job keeps its own ``arrival_s``
        (0.0 for offline workloads).
        """
        jobs = list(jobs)
        if arrival_s is not None:
            if isinstance(arrival_s, (int, float)):
                arrivals = [float(arrival_s)] * len(jobs)
            else:
                arrivals = [float(a) for a in arrival_s]
                if len(arrivals) != len(jobs):
                    raise ValueError(
                        f"{len(arrivals)} arrivals for {len(jobs)} jobs")
            jobs = [dataclasses.replace(j, arrival_s=a)
                    for j, a in zip(jobs, arrivals)]
        self.jobs.extend(jobs)
        return jobs

    def submit_serving(self, serves: Sequence[ServeJob]):
        """Add serving workloads: each :class:`~repro.core.job.ServeJob`
        is a model with a p99 latency SLO and a request-arrival trace
        (see :mod:`repro.data.traffic`).  ``run()`` sizes a
        continuous-batching replica fleet per serve job — device class
        and per-window replica count — and trains the sweep around the
        capacity the fleets hold."""
        serves = list(serves)
        self.serves.extend(serves)
        return serves

    def gpu_counts(self, dense: bool = False):
        """Candidate GPU counts: the geometric ladder (what gets real
        trials), or with ``dense`` every count 1..G (what the
        performance model evaluates for free).  On heterogeneous
        clusters G is the LARGEST class (a single allocation never
        straddles classes); profiling truncates per class."""
        if self.cluster.hetero:
            g = max(dc.total_gpus for dc in self.cluster.device_classes)
        else:
            g = self.cluster.total_gpus
        if dense:
            return list(range(1, g + 1))
        counts, c = [], 1
        while c <= g:
            counts.append(c)
            c *= 2
        if g not in counts:
            counts.append(g)
        return counts

    # --------------------------------------------------------- Trial Runner
    def profile(self, mode: str = "analytic",
                strategy: str = "interpolate",
                workers: Optional[int] = None,
                calibration_trials: int = 2,
                confidence_threshold: float = 0.3):
        """Run the Trial Runner over the submitted workload.

        ``strategy="interpolate"`` (default, the paper's <5%-overhead
        mechanism) runs real trials only at the geometric anchor counts
        and returns a curve-backed
        :class:`~repro.core.perfmodel.PerfModel` covering EVERY count
        1..G — the Solver gets the dense allocation grid at the sparse
        profiling price.  ``strategy="exhaustive"`` profiles the
        geometric ladder directly and returns the legacy dict.
        ``strategy="roofline"`` predicts every count from compiled-HLO
        op counts, spending only ``calibration_trials`` real trials per
        device class (none at all when the profile cache already holds
        this class's fit); combos whose prediction confidence falls
        below ``confidence_threshold`` escalate to real trials.
        Real trials fan out across ``workers`` threads (auto by default;
        empirical trials always run serially).
        """
        self.profiles = self.runner.profile_all(
            self.jobs,
            self.gpu_counts(dense=(strategy in ("interpolate",
                                                "roofline"))),
            mode=mode, strategy=strategy, workers=workers,
            calibration_trials=calibration_trials,
            confidence_threshold=confidence_threshold,
            classes=(self.cluster.device_classes if self.cluster.hetero
                     else None))
        return self.profiles

    # ------------------------------------------------------ Solver + exec
    def run(self, policy: Optional[Policy] = None,
            introspect_every_s: Optional[float] = 600.0,
            noise_sigma: float = 0.1,
            placement: Optional[str] = None,
            n_slots: Optional[int] = None,
            time_limit_s: Optional[float] = None,
            mip_gap: Optional[float] = None,
            refine: Optional[bool] = None,
            incremental: Optional[bool] = None,
            objective: Optional[str] = None,
            solver: Optional[str] = None,
            backend: str = "sim",
            ckpt_dir: Optional[str] = None,
            chaos=None,
            serve_window_s: float = 60.0,
            serve_util_cap: float = 0.7,
            serve_adaptive: bool = True) -> SimResult:
        """Solve + execute on the cluster runtime.

        ``backend`` selects the execution substrate the one Schedule IR
        drives: ``"sim"`` (default) runs in virtual time on the
        :class:`~repro.core.runtime.SimBackend`; ``"local"`` REALLY
        trains the models on this machine's JAX devices via
        :class:`~repro.core.local_backend.LocalJaxBackend` —
        checkpointed preemption, wall-clock introspection intervals, and
        measured step times fed back into the replans; ``"process"``
        additionally isolates every job in a supervised worker process
        (:class:`~repro.core.process_backend.ProcessJaxBackend`) with
        heartbeat-based failure detection, retry/backoff and verified
        crash recovery.  ``ckpt_dir`` (local/process) pins where
        checkpoints land.

        ``placement`` overrides ``cluster.placement`` for this run.

        The solver knobs (``n_slots``, ``time_limit_s``, ``mip_gap``,
        ``refine``, ``incremental``, ``objective``, ``solver``)
        configure the
        default :class:`SaturnPolicy` this call constructs; passing them
        together with an explicit ``policy`` is an error — configure
        the policy directly instead of having knobs silently ignored.
        ``objective`` selects what the MILP minimizes ("makespan",
        "weighted_completion", "tardiness" or "fair_share" — see
        ``repro.core.solver.OBJECTIVES``).  ``solver="portfolio"``
        races the MILP against the interval-time LNS per (re)plan
        (first to the ``mip_gap`` target wins) — per-plan engine
        telemetry lands in ``result.stats["solver"]``.

        ``chaos`` injects a :class:`~repro.core.chaos.ChaosTrace` —
        seeded node failures, spot revocations/grants and capacity
        resizes — into the run; killed launches salvage their last
        periodic checkpoint and dynamic policies replan on the new
        capacity.

        Serving (``submit_serving``): each serve job gets an SLO-sized
        continuous-batching fleet re-planned every ``serve_window_s``
        (``serve_adaptive=False`` holds peak provisioning — the static
        partition baseline); fleet growth may evict training launches,
        and per-window p50/p99/attainment land in
        ``result.stats["serving"]``.  ``serve_util_cap`` is the target
        utilization headroom per replica.
        """
        knobs = {k: v for k, v in (("n_slots", n_slots),
                                   ("time_limit_s", time_limit_s),
                                   ("mip_gap", mip_gap),
                                   ("refine", refine),
                                   ("incremental", incremental),
                                   ("objective", objective),
                                   ("solver", solver))
                 if v is not None}
        if policy is not None and knobs:
            raise ValueError(
                f"solver knobs {sorted(knobs)} only apply to the default "
                f"SaturnPolicy; configure your policy directly")
        if backend not in ("sim", "local", "process"):
            raise ValueError(f"unknown execution backend {backend!r}; "
                             f"expected 'sim', 'local' or 'process'")
        if ckpt_dir is not None and backend == "sim":
            raise ValueError(
                "ckpt_dir only applies to backend='local'/'process'")
        if not self.profiles:
            self.profile()
        policy = policy or SaturnPolicy(**knobs)
        cluster = self.cluster
        if placement is not None and placement != cluster.placement:
            # the policy must see the same placement the runtime enforces
            # (node-aware Saturn switches MILPs on it)
            cluster = dataclasses.replace(cluster, placement=placement)
        exec_backend = None
        if backend == "local":
            from .local_backend import LocalJaxBackend
            exec_backend = LocalJaxBackend(self.library, ckpt_dir=ckpt_dir)
        elif backend == "process":
            from .process_backend import ProcessJaxBackend
            exec_backend = ProcessJaxBackend(self.library,
                                             ckpt_dir=ckpt_dir)
        profiles, fleets = self.profiles, None
        if self.serves:
            from ..serving.fleet import FleetManager, serve_profiles
            from .perfmodel import MergedProfiles
            sp = serve_profiles(self.serves, cluster)
            profiles = (MergedProfiles(sp, profiles)
                        if not isinstance(profiles, dict)
                        else {**profiles, **sp})
            fleets = FleetManager(self.serves, cluster,
                                  window_s=serve_window_s,
                                  util_cap=serve_util_cap,
                                  adaptive=serve_adaptive)
        return simulate(self.jobs, policy, profiles, cluster,
                        introspect_every_s=introspect_every_s
                        if policy.dynamic else None,
                        noise_sigma=noise_sigma,
                        exec_backend=exec_backend, chaos=chaos,
                        fleets=fleets)
