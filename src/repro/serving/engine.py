"""Continuous-batching serving engine.

Token-granularity continuous batching over a fixed pool of batch slots:
every engine step runs ONE batched `decode_step`; a slot that still has
unconsumed prompt tokens is fed the next prompt token (inline chunk-1
prefill), otherwise its last sampled token.  Finished slots are refilled
from the request queue immediately — no lockstep barriers, exactly the
Orca/vLLM scheduling idea expressed in JAX (per-slot cache positions via
the batched-``pos`` decode path; the recurrent-state archs work
unchanged because their state is position-free).

This is the serving-side counterpart to Saturn's training orchestration
and what the decode_32k / long_500k dry-run shapes exercise at scale.
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..models.transformer import decode_step, init_decode_state


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    arrival_s: float = 0.0
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    ttft_s: Optional[float] = None
    done_s: Optional[float] = None


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    prompt_left: int = 0

    @property
    def free(self) -> bool:
        return self.req is None


class ContinuousBatchingEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 512, dtype=jnp.float32,
                 opts: Optional[dict] = None, eos_id: Optional[int] = None):
        self.cfg, self.params = cfg, params
        self.n_slots, self.max_len = slots, max_len
        self.eos_id = eos_id
        self.state = init_decode_state(cfg, slots, max_len, dtype=dtype,
                                       per_row_pos=True)
        self.slots = [_Slot() for _ in range(slots)]
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.steps = 0
        self._t0: Optional[float] = None   # engine epoch: first run() call
        opts = opts or {}
        # exact per-leaf batch axis: diff the state spec at two batch
        # sizes (a leading layer-stack dim can coincide with `slots`)
        from ..models.transformer import decode_state_spec
        s_a = decode_state_spec(cfg, slots, max_len, dtype)
        s_b = decode_state_spec(cfg, slots + 1, max_len, dtype)
        self._batch_axis = jax.tree.map(
            lambda a, b: next((i for i, (x, y) in
                               enumerate(zip(a.shape, b.shape)) if x != y),
                              None) if a.shape else None,
            s_a, s_b)
        self._batch_axis["pos"] = 0
        batch_axes = self._batch_axis

        def step_fn(params, tokens, state, active):
            logits, new_state = decode_step(params, cfg, tokens, state,
                                            opts=opts)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

            def splice(new, old, ax):
                # frozen slots keep their previous state
                if new.ndim == 0 or ax is None:
                    return new
                shape = [1] * new.ndim
                shape[ax] = -1
                return jnp.where(jnp.reshape(active, shape), new, old)

            spliced = jax.tree.map(splice, new_state, state, batch_axes)
            return nxt, spliced

        self._step = jax.jit(step_fn)

    # ------------------------------------------------------------ public
    def submit(self, req: Request):
        """Queue ``req`` for admission.  Requests are admitted in
        ``arrival_s`` order, ties broken by submission order — so a
        batch of same-timestamp requests drains FIFO instead of in
        whatever order a caller's dict happened to iterate.  An
        infeasible request (prompt + generation budget beyond the cache)
        is rejected HERE, not mid-run when its turn comes up and the
        engine has already served everything admitted before it."""
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(f"request {req.rid} exceeds max_len "
                             f"({len(req.prompt)} + {req.max_new_tokens} "
                             f"> {self.max_len})")
        # insort_right keeps equal-arrival requests in submission order
        bisect.insort_right(self.queue, req, key=lambda r: r.arrival_s)

    def run(self, max_steps: int = 10000) -> List[Request]:
        """Run until queue + slots drain.  Returns finished requests.

        The engine clock starts at the FIRST ``run()`` call and persists
        across calls: a request finishing in a second ``run()`` gets a
        ``done_s`` after everything from the first, instead of the clock
        silently restarting at zero."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        while (self.queue or any(not s.free for s in self.slots)) \
                and self.steps < max_steps:
            self._admit()
            self._engine_step(self._t0)
        return self.finished

    def throughput(self) -> Dict[str, float]:
        toks = sum(len(r.output) for r in self.finished)
        lat = [r.done_s - r.arrival_s for r in self.finished
               if r.done_s is not None]
        ttft = [r.ttft_s for r in self.finished if r.ttft_s is not None]
        return {"requests": len(self.finished), "tokens": toks,
                "steps": self.steps,
                "mean_latency_s": float(np.mean(lat)) if lat else 0.0,
                "p50_latency_s": float(np.percentile(lat, 50)) if lat else 0.0,
                "p99_latency_s": float(np.percentile(lat, 99)) if lat else 0.0,
                "mean_ttft_s": float(np.mean(ttft)) if ttft else 0.0}

    # ----------------------------------------------------------- private
    def _admit(self):
        for b, slot in enumerate(self.slots):
            if slot.free and self.queue:
                req = self.queue.pop(0)
                slot.req = req
                slot.prompt_left = len(req.prompt)
                # reset this slot's cache position
                self.state["pos"] = self.state["pos"].at[b].set(0)
                self._reset_slot_state(b)

    def _reset_slot_state(self, b: int):
        """Zero the recurrent states of slot b (KV entries are masked by
        pos, but recurrent archs carry state that must clear)."""
        axes = self._batch_axis["layers"]

        def reset(path, leaf, ax):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if leaf.ndim == 0 or ax is None or name in ("k", "v"):
                return leaf
            fill = -1e30 if name == "m" else 0
            idx = tuple([slice(None)] * ax + [b])
            return leaf.at[idx].set(fill)

        layers = jax.tree_util.tree_map_with_path(
            reset, self.state["layers"], axes)
        self.state = {"layers": layers, "pos": self.state["pos"]}

    def _engine_step(self, t0: float):
        tokens = np.zeros((self.n_slots, 1), np.int32)
        active = np.zeros((self.n_slots,), bool)
        for b, slot in enumerate(self.slots):
            if slot.free:
                continue
            req = slot.req
            active[b] = True
            if slot.prompt_left > 0:
                idx = len(req.prompt) - slot.prompt_left
                tokens[b, 0] = req.prompt[idx]
            else:
                tokens[b, 0] = req.output[-1]
        nxt, self.state = self._step(
            self.params, jnp.asarray(tokens), self.state,
            jnp.asarray(active))
        self.steps += 1
        now = time.perf_counter() - t0
        nxt = np.asarray(nxt)
        for b, slot in enumerate(self.slots):
            if slot.free:
                continue
            req = slot.req
            if slot.prompt_left > 0:
                slot.prompt_left -= 1
                if slot.prompt_left == 0:
                    # this step consumed the last prompt token => its
                    # output is the first generated token
                    req.output.append(int(nxt[b]))
                    req.ttft_s = now
            else:
                req.output.append(int(nxt[b]))
            done = len(req.output) >= req.max_new_tokens or (
                self.eos_id is not None and req.output
                and req.output[-1] == self.eos_id)
            if done:
                req.done_s = now
                self.finished.append(req)
                self.slots[b] = _Slot()
