"""xLSTM-125M: alternating mLSTM/sLSTM blocks [arXiv:2405.04517]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", arch_type="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=192,
    block_pattern=("mlstm", "slstm"),
    tie_embeddings=True, long_context=True,
    source="sLSTM + mLSTM blocks [arXiv:2405.04517]",
)
