"""Pure-jnp oracles for every Pallas kernel (single source of truth —
these re-export the model-layer reference implementations the kernels
must match bit-for-bit up to fp32 reassociation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.blockwise import blockwise_attention as _blockwise_attention
from ..models.blockwise import mlstm_chunked as _mlstm_chunked
from ..models.recurrent import mlstm_parallel_ref as _mlstm_parallel
from ..models.recurrent import rglru_scan_ref as _rglru_scan


def attention_ref(q, k, v, window: int = 0):
    """Naive causal GQA attention.  q pre-scaled: (B,S,H,D)."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    qr = q.reshape(b, s, kvh, h // kvh, d)
    scores = jnp.einsum("bskqd,blkd->bkqsl", qr, k).astype(jnp.float32)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = j <= i
    if window:
        mask &= (i - j) < window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkqsl,blkd->bskqd", p, v)
    return out.reshape(b, s, h, d)


def blockwise_attention_ref(q, k, v, window: int = 0):
    return _blockwise_attention(q, k, v, window=window)


def rglru_scan_ref(a, b):
    """h_t = a_t h_{t-1} + b_t via associative scan."""
    return _rglru_scan(a, b)


def mlstm_ref(q, k, v, i_pre, f_pre):
    """Quadratic-form mLSTM."""
    return _mlstm_parallel(q, k, v, i_pre, f_pre)


def mlstm_chunked_ref(q, k, v, i_pre, f_pre, chunk: int = 256):
    return _mlstm_chunked(q, k, v, i_pre, f_pre, chunk=chunk)
