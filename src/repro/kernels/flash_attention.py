"""Pallas TPU flash attention (causal, GQA, optional sliding window).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the kv dimension is
minor-most, so each (b, h, iq) program visits its kv blocks sequentially
and accumulates the online softmax in VMEM scratch (acc, m, l).  Blocks
whose entire kv range is masked (beyond causal front or outside the
sliding window) are skipped with ``pl.when``.

TPU-native adaptation notes (vs the CUDA algorithm): tile shapes are
chosen for the 128x128 MXU and 8x128 VPU lanes; m/l statistics are kept
as (block_q, 128) lane-replicated tiles (TPU has no warp shuffles — the
reduction lives in VMEM vectors); kv tiles stream HBM->VMEM via BlockSpec
index maps rather than cp.async.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  block_q: int, block_k: int, window: int, seq_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    # skip blocks fully outside the causal (and window) band
    causal_live = k_start <= q_start + block_q - 1
    window_live = True
    if window:
        window_live = (k_start + block_k - 1) >= (q_start - window + 1)

    @pl.when(causal_live & window_live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())))            # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = k_pos <= q_pos
        if window:
            mask &= (q_pos - k_pos) < window
        scores = jnp.where(mask, scores, NEG_INF)
        m_prev = m_ref[:, :1]                          # (bq, 1)
        m_cur = jnp.max(scores, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(scores - m_new)                    # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                 # (bq, 1)
        l_new = l_ref[:, :1] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())))
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[:, :1]
        o_ref[0, 0, :, :] = (acc_ref[...] /
                             jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, window: int = 0, *, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, S, H, D) pre-scaled; k, v: (B, S, Kv, D) -> (B, S, H, D).

    GQA: query head h reads kv head h // (H // Kv).
    """
    b, s, h, d = q.shape
    kvh = k.shape[2]
    qpk = h // kvh
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    nq, nk = s // block_q, s // block_k
    # layout: (B, H, S, D) for clean tiling
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, h, nq, nk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                          window=window, seq_len=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, iq, ik, _qpk=qpk: (b_, h_ // _qpk, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, iq, ik, _qpk=qpk: (b_, h_ // _qpk, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),      # acc
            pltpu.VMEM((block_q, _LANES), jnp.float32), # m
            pltpu.VMEM((block_q, _LANES), jnp.float32), # l
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
