"""Composable decoder transformer covering all assigned arch families.

Layers follow ``cfg.block_pattern``; repeats of the pattern execute as one
``lax.scan`` over stacked per-position params (small HLO at 94 layers /
512 devices), with an unrolled remainder.  Supports:

- full-sequence forward (train / prefill), returning logits (+ MoE aux)
- single-token decode against per-layer caches/recurrent states
- audio/VLM frontends: precomputed frame/patch embeddings (stub per the
  assignment carve-out) consumed alongside / instead of token embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .config import ATTN, MLSTM, RGLRU, SLSTM, SWA, ModelConfig
from .layers import (attention, attn_cache_spec, ffn, ffn_spec, rmsnorm,
                     rmsnorm_spec)
from .moe import moe_ffn, moe_spec
from .params import P, init_params
from .recurrent import (mlstm_block, mlstm_block_spec, mlstm_state_spec,
                        rglru_block, rglru_block_spec, rglru_state_spec,
                        slstm_block, slstm_block_spec, slstm_state_spec)
from ..parallelism.context import shard


# ------------------------------------------------------------------ specs

def block_spec(cfg: ModelConfig, kind: str):
    spec: Dict[str, Any] = {}
    if kind in (ATTN, SWA):
        from .layers import attention_spec
        spec["mixer"] = attention_spec(cfg)
    elif kind == RGLRU:
        spec["mixer"] = rglru_block_spec(cfg)
    elif kind == MLSTM:
        spec["mixer"] = mlstm_block_spec(cfg)
    elif kind == SLSTM:
        spec["mixer"] = slstm_block_spec(cfg)
    else:
        raise ValueError(kind)
    if cfg.d_ff or cfg.is_moe:
        spec["ffn"] = moe_spec(cfg) if cfg.is_moe else ffn_spec(cfg)
    return spec


def _stack_spec_tree(tree, n):
    from .params import stack_specs
    return stack_specs(tree, n)


def model_spec(cfg: ModelConfig):
    d = cfg.d_model
    spec: Dict[str, Any] = {
        "embed": P((cfg.vocab_size, d), ("vocab", "embed"), init="embed"),
        "final_norm": rmsnorm_spec(d),
    }
    if not cfg.tie_embeddings:
        spec["unembed"] = P((d, cfg.vocab_size), ("embed", "vocab"))
    groups = []
    for mode, pattern, n in cfg.layer_plan():
        g = {}
        for i, kind in enumerate(pattern):
            bs = block_spec(cfg, kind)
            g[f"pos{i}_{kind}"] = _stack_spec_tree(bs, n) if mode == "scan" else bs
        groups.append(g)
    spec["groups"] = groups
    return spec


def init_model(cfg: ModelConfig, key, dtype=jnp.float32):
    return init_params(model_spec(cfg), key, dtype)


# ----------------------------------------------------------------- caches

def _block_cache_spec(cfg: ModelConfig, kind: str, batch: int, length: int,
                      dtype):
    if kind in (ATTN, SWA):
        return attn_cache_spec(cfg, batch, length, dtype)
    if kind == RGLRU:
        return rglru_state_spec(cfg, batch, dtype)
    if kind == MLSTM:
        return mlstm_state_spec(cfg, batch, dtype)
    if kind == SLSTM:
        return slstm_state_spec(cfg, batch, dtype)
    raise ValueError(kind)


def decode_state_spec(cfg: ModelConfig, batch: int, length: int,
                      dtype=jnp.bfloat16):
    """Abstract (ShapeDtypeStruct) decode state for the whole stack."""
    groups = []
    for mode, pattern, n in cfg.layer_plan():
        g = {}
        for i, kind in enumerate(pattern):
            c = _block_cache_spec(cfg, kind, batch, length, dtype)
            if mode == "scan":
                c = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), c)
            g[f"pos{i}_{kind}"] = c
        groups.append(g)
    return {"layers": groups, "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def init_decode_state(cfg: ModelConfig, batch: int, length: int,
                      dtype=jnp.bfloat16, per_row_pos: bool = False):
    """Concrete zero-initialized decode state (m-stabilizers at -1e30).
    per_row_pos=True gives ``pos`` shape (batch,) — each batch slot
    tracks its own cache position (continuous batching)."""
    spec = decode_state_spec(cfg, batch, length, dtype)
    if per_row_pos:
        spec = dict(spec)
        spec["pos"] = jax.ShapeDtypeStruct((batch,), jnp.int32)

    def mk(path, s):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name == "m":
            return jnp.full(s.shape, -1e30, s.dtype)
        return jnp.zeros(s.shape, s.dtype)

    return jax.tree_util.tree_map_with_path(mk, spec)


# ---------------------------------------------------------------- forward

def _block_apply(p, x, *, kind, cfg: ModelConfig, cache=None, positions=None,
                 pos=None, opts=None, prefill=False):
    opts = opts or {}
    h = rmsnorm(p["mixer"]["norm"], x, cfg.norm_eps)
    if kind in (ATTN, SWA):
        window = cfg.window_size if kind == SWA else 0
        y, nc = attention(p["mixer"], h, cfg, window=window, cache=cache,
                          positions=positions, pos=pos,
                          attn_fn=opts.get("attn_fn"), return_cache=prefill)
    elif kind == RGLRU:
        y, nc = rglru_block(p["mixer"], h, cfg, state=cache,
                            scan_fn=opts.get("rglru_scan"),
                            return_state=prefill)
    elif kind == MLSTM:
        y, nc = mlstm_block(p["mixer"], h, cfg, state=cache,
                            parallel_fn=opts.get("mlstm_fn"),
                            return_state=prefill)
    elif kind == SLSTM:
        y, nc = slstm_block(p["mixer"], h, cfg, state=cache,
                            return_state=prefill,
                            unroll=opts.get("slstm_unroll", 1),
                            batched_grad=opts.get("slstm_batched_grad",
                                                  False))
    else:
        raise ValueError(kind)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in p:
        h2 = rmsnorm(p["ffn"]["norm"], x, cfg.norm_eps)
        if cfg.is_moe:
            y2, aux = moe_ffn(p["ffn"], h2, cfg)
        else:
            y2 = ffn(p["ffn"], h2)
        x = x + y2
    return x, nc, aux


def _run_groups(params, cfg: ModelConfig, x, *, caches=None, positions=None,
                pos=None, opts=None, remat=False, prefill=False):
    """Run all layer groups.  Returns (x, new_caches, aux).

    prefill=True: caches are None on input but every block *returns* its
    decode-ready state (KV cache / recurrent state)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_groups = []
    for gi, (mode, pattern, n) in enumerate(cfg.layer_plan()):
        gparams = params["groups"][gi]
        gcaches = caches[gi] if caches is not None else None
        if mode == "unroll":
            new_g = {}
            for i, kind in enumerate(pattern):
                key = f"pos{i}_{kind}"
                c = gcaches[key] if gcaches is not None else None
                fn = lambda p_, x_, c_: _block_apply(
                    p_, x_, kind=kind, cfg=cfg, cache=c_, positions=positions,
                    pos=pos, opts=opts, prefill=prefill)
                if remat:
                    fn = jax.checkpoint(fn)
                x, nc, a = fn(gparams[key], x, c)
                new_g[key] = nc
                aux_total = aux_total + a
            new_groups.append(new_g)
        else:
            def body(carry, xs):
                x_, aux_ = carry
                lp, lc = xs
                ncs = {}
                for i, kind in enumerate(pattern):
                    key = f"pos{i}_{kind}"
                    c = lc[key] if lc is not None else None
                    x_, nc, a = _block_apply(
                        lp[key], x_, kind=kind, cfg=cfg, cache=c,
                        positions=positions, pos=pos, opts=opts,
                        prefill=prefill)
                    ncs[key] = nc
                    aux_ = aux_ + a
                x_ = shard(x_, "batch", "seq", None)
                return (x_, aux_), ncs

            body_fn = jax.checkpoint(body) if remat else body
            if gcaches is None and not prefill:
                def body_noc(carry, lp):
                    out_carry, _ = body_fn(carry, (lp, None))
                    return out_carry, None
                (x, aux_total), _ = jax.lax.scan(
                    body_noc, (x, aux_total), gparams)
                new_groups.append(None)
            elif gcaches is None:  # prefill: collect per-layer states
                def body_pre(carry, lp):
                    return body_fn(carry, (lp, None))
                (x, aux_total), new_c = jax.lax.scan(
                    body_pre, (x, aux_total), gparams)
                new_groups.append(new_c)
            else:
                (x, aux_total), new_c = jax.lax.scan(
                    body_fn, (x, aux_total), (gparams, gcaches))
                new_groups.append(new_c)
    return x, new_groups, aux_total


def embed_inputs(params, cfg: ModelConfig, batch: Dict[str, Any]):
    """Token / frontend embedding.  batch keys: tokens (B,S) int32 and/or
    embeds (B,S,d) float (audio frames / vision patches, stubbed)."""
    parts = []
    if "embeds" in batch and batch["embeds"] is not None:
        parts.append(batch["embeds"].astype(params["embed"].dtype))
    if "tokens" in batch and batch["tokens"] is not None:
        parts.append(jnp.take(params["embed"], batch["tokens"], axis=0))
    if not parts:
        raise ValueError("batch must contain tokens and/or embeds")
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return shard(x, "batch", "seq", None)


def unembed(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    return shard(logits, "batch", "seq", "vocab")


def forward(params, cfg: ModelConfig, batch: Dict[str, Any], *,
            opts: Optional[dict] = None, remat: bool = False):
    """Full-sequence forward.  Returns (logits, aux_loss)."""
    x = embed_inputs(params, cfg, batch)
    x, _, aux = _run_groups(params, cfg, x, opts=opts, remat=remat)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params, cfg, x), aux


def prefill_forward(params, cfg: ModelConfig, batch: Dict[str, Any], *,
                    opts: Optional[dict] = None):
    """Serving prefill: full-sequence forward that returns ONLY the
    last-position logits plus a decode-ready state (KV caches of length
    seq / recurrent states) — never materializes (B, S, vocab)."""
    x = embed_inputs(params, cfg, batch)
    s = x.shape[1]
    x, new_caches, _ = _run_groups(params, cfg, x, opts=opts, prefill=True)
    x = rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = unembed(params, cfg, x)
    return logits, {"layers": new_caches,
                    "pos": jnp.asarray(s, jnp.int32)}


def decode_step(params, cfg: ModelConfig, tokens, state, *,
                opts: Optional[dict] = None):
    """One decode step.  tokens: (B, 1) int32; state from
    ``init_decode_state``.  Returns (logits (B,1,V), new_state)."""
    pos = state["pos"]
    x = jnp.take(params["embed"], tokens, axis=0)
    x, new_caches, _ = _run_groups(
        params, cfg, x, caches=state["layers"], pos=pos, opts=opts)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params, cfg, x)
    return logits, {"layers": new_caches, "pos": pos + 1}
