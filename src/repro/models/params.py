"""Parameter-spec system: single source of truth for shapes, init and
logical sharding axes.

Modules define a pytree of ``P`` specs; ``init_params`` materializes
arrays, ``logical_axes`` extracts the axis names, and the parallelism
layer maps logical axes -> mesh axes to build NamedShardings.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    """Spec for one parameter tensor."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names, len == ndim
    init: str = "normal"              # normal | zeros | ones | embed
    scale: float = 1.0

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} / axes {self.axes} mismatch")


def is_spec(x) -> bool:
    return isinstance(x, P)


def _materialize(spec: P, key, dtype):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "const":
        return jnp.full(spec.shape, spec.scale, dtype)
    if spec.init in ("normal", "embed"):
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)
    raise ValueError(f"unknown init {spec.init}")


def init_params(spec_tree, key, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_materialize(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(spec_tree, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree — no allocation (dry-run path)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        spec_tree, is_leaf=is_spec)


def logical_axes(spec_tree):
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=is_spec)


def param_count(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def stack_specs(spec_tree, n: int, axis_name: Optional[str] = "layers"):
    """Add a leading 'stacked layers' dim of size n to every spec
    (params for a scanned group of n pattern-repeats)."""
    return jax.tree.map(
        lambda s: P((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale),
        spec_tree, is_leaf=is_spec)
