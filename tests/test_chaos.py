"""Chaos engine: fault injection, elastic placement, checkpoint salvage,
and the deadline/fairness solver objectives."""
import pytest

from repro.configs import get_config
from repro.core.baselines import CurrentPractice, SaturnPolicy
from repro.core.chaos import (CapacityChange, ChaosTrace, NodeFailure,
                              SpotGrant, SpotRevoke, merge_events,
                              poisson_node_failures, spot_capacity_trace)
from repro.core.executor import simulate
from repro.core.job import ClusterSpec, DeviceClass, Job
from repro.core.placement import ClassPool, FlatPool, PlacementError
from repro.core.profiler import Profile
from repro.core.solver import (OBJECTIVES, Assignment, objective_value,
                               solve_joint)

CFG = get_config("xlstm-125m").reduced()


def mk_workload(n_jobs=4, steps=300, counts=(1, 2, 4, 8), **job_kw):
    """Jobs + synthetic profiles with clean sub-linear speedups.

    ``steps`` is either a scalar (job i gets ``steps + 40*i``) or a
    per-job sequence."""
    jobs, profiles = [], {}
    for i in range(n_jobs):
        per_job = {k: (v[i] if isinstance(v, (list, tuple)) else v)
                   for k, v in job_kw.items()}
        n_steps = (steps[i] if isinstance(steps, (list, tuple))
                   else steps + 40 * i)
        j = Job(f"job{i}", CFG, 8, 128, n_steps, seed=i, **per_job)
        jobs.append(j)
        base = 1.0 + 0.3 * i
        for tech in ("ddp", "fsdp"):
            for g in counts:
                st = base / (g ** 0.8) * (1.15 if tech == "fsdp" else 1.0)
                profiles[(j.name, tech, g)] = Profile(
                    j.name, tech, g, st, 1e9, True, "synthetic")
    return jobs, profiles


# ------------------------------------------------------------ ChaosTrace

def test_trace_sorts_and_validates():
    tr = ChaosTrace((NodeFailure(50.0), NodeFailure(10.0)),
                    checkpoint_every_s=60.0)
    assert [e.t for e in tr] == [10.0, 50.0] and len(tr) == 2
    with pytest.raises(ValueError):
        ChaosTrace((NodeFailure(1.0),), checkpoint_every_s=0.0)
    with pytest.raises(ValueError):
        ChaosTrace((NodeFailure(-1.0),))
    with pytest.raises(TypeError):
        ChaosTrace(("not-an-event",))


def test_poisson_thinning_superset():
    # same seed + max rate: the failures at rate r are a strict subset
    # of those at any higher rate — the property the bench's
    # monotone-margin gate rests on
    kw = dict(seed=7, max_rate_per_hour=8.0)
    times = {r: {e.t for e in poisson_node_failures(r, 36000.0, **kw)}
             for r in (0.0, 2.0, 4.0, 8.0)}
    assert times[0.0] == set()
    assert times[2.0] <= times[4.0] <= times[8.0]
    assert len(times[8.0]) > len(times[2.0])
    # deterministic in the seed
    again = {e.t for e in poisson_node_failures(4.0, 36000.0, **kw)}
    assert again == times[4.0]
    with pytest.raises(ValueError):
        poisson_node_failures(9.0, 100.0, max_rate_per_hour=8.0)


def test_spot_trace_alternates_and_merge_sorts():
    tr = spot_capacity_trace(20000.0, seed=3, n_gpus=2)
    kinds = [type(e) for e in tr]
    assert kinds[0] is SpotRevoke          # capacity starts granted
    assert all(a is not b for a, b in zip(kinds, kinds[1:]))
    merged = merge_events(tr, poisson_node_failures(4.0, 20000.0, seed=1))
    assert list(merged) == sorted(merged, key=lambda e: e.t)


# --------------------------------------------------- elastic placements

def test_flatpool_elastic_fresh_ids():
    p = FlatPool(4)
    held = p.allocate(2)                       # devices (0, 1) busy
    with pytest.raises(PlacementError):
        p.remove_devices([0])                  # busy: caller must kill first
    p.remove_devices([2, 3])
    assert p.total_gpus == 2 and p.free_devices() == ()
    fresh = p.add_devices(2)
    assert fresh == (4, 5)                     # never reuses 2, 3
    assert p.total_gpus == 4 and p.capacity() == 4
    p.release(held)
    assert p.free_devices() == (0, 1, 4, 5)


def test_classpool_elastic_per_class():
    p = ClassPool((DeviceClass("a100", 1, 2), DeviceClass("v100", 1, 2)))
    assert p.capacity("a100") == 2 and p.capacity() == 4
    p.remove_devices([0])
    assert p.capacity("a100") == 1 and p.total_gpus == 3
    assert p.class_of(0) == "a100"             # persists for removed ids
    with pytest.raises(PlacementError):
        p.add_devices(1)                       # multi-class: class required
    fresh = p.add_devices(2, device_class="v100")
    assert fresh == (4, 5) and p.capacity("v100") == 4
    assert all(p.class_of(d) == "v100" for d in fresh)
    assert not p.feasible(2, device_class="a100")
    assert p.feasible(4, device_class="v100")


def test_chaos_rejects_non_elastic_backend():
    jobs, profiles = mk_workload(2)
    cluster = ClusterSpec(nodes=2, gpus_per_node=4, placement="node")
    trace = ChaosTrace((NodeFailure(10.0),))
    with pytest.raises(ValueError, match="elastic"):
        simulate(jobs, SaturnPolicy(time_limit_s=2), profiles, cluster,
                 chaos=trace)


# ------------------------------------------------------ runtime effects

CLUSTER = ClusterSpec(nodes=1, gpus_per_node=8, restart_cost_s=10.0)


def test_failure_recovery_conservation_and_count():
    jobs, profiles = mk_workload(4)
    pol = SaturnPolicy(time_limit_s=2)
    calm = simulate(jobs, pol, profiles, CLUSTER, noise_sigma=0.0,
                    introspect_every_s=200.0)
    trace = ChaosTrace((NodeFailure(60.0, n_gpus=4, recover_after_s=150.0),
                        NodeFailure(300.0, n_gpus=2, recover_after_s=150.0)),
                       checkpoint_every_s=50.0)
    churn = simulate(jobs, SaturnPolicy(time_limit_s=2), profiles, CLUSTER,
                     noise_sigma=0.0, introspect_every_s=200.0, chaos=trace)
    # conservation is asserted inside the runtime; reaching here means it
    # held under shrink + grow.  Churn can only cost time.
    assert churn.failures == 2
    assert churn.makespan_s >= calm.makespan_s - 1e-6
    assert churn.restarts >= 1


def test_checkpoint_salvage_bounds_lost_work():
    # identical failure, identical policy/noise: a finer checkpoint
    # cadence salvages more progress, so it can only finish sooner
    jobs, profiles = mk_workload(3)
    def run(ck):
        trace = ChaosTrace((NodeFailure(100.0, n_gpus=8,
                                        recover_after_s=50.0),),
                           checkpoint_every_s=ck)
        return simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                        noise_sigma=0.0, chaos=trace)
    fine, coarse = run(20.0), run(1e6)
    assert fine.failures == coarse.failures == 1
    assert fine.makespan_s <= coarse.makespan_s + 1e-6


def test_spot_revoke_prefers_free_devices():
    # one 4-GPU job on an 8-GPU cluster: revoking 4 GPUs takes the free
    # ones, the launch survives and no restart is paid
    jobs, profiles = mk_workload(1, counts=(4,))
    trace = ChaosTrace((SpotRevoke(50.0, n_gpus=4),))
    r = simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                 noise_sigma=0.0, chaos=trace)
    assert r.restarts == 0 and r.failures == 0
    assert all(g.kind != "restart" for g in r.gantt)


def test_capacity_change_grow_and_shrink():
    jobs, profiles = mk_workload(4)
    trace = ChaosTrace((CapacityChange(80.0, delta=-6),
                        CapacityChange(200.0, delta=6)))
    r = simulate(jobs, SaturnPolicy(time_limit_s=2), profiles, CLUSTER,
                 noise_sigma=0.0, introspect_every_s=150.0, chaos=trace)
    assert r.makespan_s > 0 and r.failures == 0


def test_static_policy_survives_failure():
    # non-dynamic policies never replan; recovery still lets the fixed
    # plan finish (jobs wait for capacity instead of erroring out)
    jobs, profiles = mk_workload(3)
    trace = ChaosTrace((NodeFailure(60.0, n_gpus=8,
                                    recover_after_s=100.0),),
                       checkpoint_every_s=30.0)
    r = simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                 noise_sigma=0.0, chaos=trace)
    assert r.failures == 1 and r.makespan_s > 0


def test_chaos_on_class_pool_cluster():
    jobs, profiles = mk_workload(3, counts=(1, 2, 4))
    hetero = ClusterSpec(restart_cost_s=10.0, device_classes=(
        DeviceClass("a100", 1, 4), DeviceClass("v100", 1, 4)))
    per_class = {(j, t, dc.name, g): p for (j, t, g), p in profiles.items()
                 for dc in hetero.device_classes}
    trace = ChaosTrace((NodeFailure(50.0, n_gpus=2, device_class="a100",
                                    recover_after_s=120.0),
                        SpotRevoke(90.0, n_gpus=1, device_class="v100"),
                        SpotGrant(250.0, n_gpus=1, device_class="v100")),
                       checkpoint_every_s=40.0)
    r = simulate(jobs, SaturnPolicy(time_limit_s=2), per_class, hetero,
                 noise_sigma=0.0, introspect_every_s=150.0, chaos=trace)
    assert r.failures == 1 and r.makespan_s > 0


# ------------------------------------------------------------ objectives

def test_objective_value_known_plans():
    jobs = [Job("a", CFG, 8, 128, 100, weight=2.0, deadline_s=50.0,
                tenant="t1"),
            Job("b", CFG, 8, 128, 100, weight=1.0, tenant="t2")]
    asn = [Assignment("a", "ddp", 1, 0.0, 60.0),
           Assignment("b", "ddp", 1, 0.0, 40.0)]
    assert objective_value(asn, jobs, "makespan") == 60.0
    assert objective_value(asn, jobs, "weighted_completion") == \
        pytest.approx(2.0 * 60.0 + 1.0 * 40.0)
    # only job a has a deadline; 10s late at weight 2
    assert objective_value(asn, jobs, "tardiness") == pytest.approx(20.0)
    # per-tenant means: t1 -> 60, t2 -> 40; worst tenant is t1
    assert objective_value(asn, jobs, "fair_share") == pytest.approx(60.0)
    with pytest.raises(ValueError):
        objective_value(asn, jobs, "nope")


def test_specialized_objectives_dominate_makespan_plan():
    jobs, profiles = mk_workload(
        5, weight=[1.0, 2.0, 3.0, 4.0, 5.0],
        deadline_s=[400.0, 500.0, 600.0, 700.0, 800.0],
        tenant=["t1", "t2", "t1", "t2", "t1"])
    base = solve_joint(jobs, profiles, 8, time_limit_s=5,
                       objective="makespan")
    for obj in OBJECTIVES:
        sol = solve_joint(jobs, profiles, 8, time_limit_s=5, objective=obj)
        assert {a.job for a in sol.assignments} == {j.name for j in jobs}
        assert objective_value(sol.assignments, jobs, obj) <= \
            objective_value(base.assignments, jobs, obj) + 1e-6


def test_objective_validation():
    with pytest.raises(ValueError, match="unknown objective"):
        SaturnPolicy(objective="latency")
    jobs, profiles = mk_workload(2)
    with pytest.raises(ValueError, match="unknown objective"):
        solve_joint(jobs, profiles, 8, objective="latency")
    # the node-aware MILP only supports makespan
    pol = SaturnPolicy(time_limit_s=2, objective="fair_share")
    node_cluster = ClusterSpec(nodes=1, gpus_per_node=8, placement="node")
    with pytest.raises(ValueError, match="makespan"):
        pol.plan(jobs, {j.name: j.total_steps for j in jobs}, profiles,
                 node_cluster, {})


def test_objectives_run_end_to_end_under_chaos():
    jobs, profiles = mk_workload(
        4, weight=[1.0, 2.0, 1.0, 3.0],
        deadline_s=[500.0, 600.0, 700.0, 800.0],
        tenant=["t1", "t1", "t2", "t2"])
    trace = ChaosTrace((NodeFailure(80.0, n_gpus=4,
                                    recover_after_s=120.0),),
                       checkpoint_every_s=40.0)
    for obj in OBJECTIVES:
        r = simulate(jobs, SaturnPolicy(time_limit_s=2, objective=obj),
                     profiles, CLUSTER, noise_sigma=0.0,
                     introspect_every_s=200.0, chaos=trace)
        assert r.failures == 1 and r.makespan_s > 0


@pytest.mark.slow
def test_margin_widens_with_churn():
    # mini version of the BENCH_chaos gate: Saturn's advantage over the
    # static full-node practice is non-decreasing across failure rates.
    # Per-seed margins are noisy (a lucky failure can land in CP's idle
    # tail), so the gated quantity is the margin AVERAGED over seeds —
    # the thinned traces make each seed's failure sets nested across
    # rates, and the mean is monotone.
    jobs, profiles = mk_workload(
        6, steps=[2500 + 300 * i for i in range(6)],
        counts=(1, 2, 4, 8, 16))
    cluster = ClusterSpec(nodes=2, gpus_per_node=8, restart_cost_s=30.0)
    rates, seeds = (0.0, 4.0, 8.0), (7, 11, 23)
    margins = []
    for rate in rates:
        per_seed = []
        for seed in seeds:
            ev = poisson_node_failures(rate, 30000.0, seed=seed,
                                       n_gpus=4, recover_after_s=1200.0,
                                       max_rate_per_hour=max(rates))
            trace = ChaosTrace(ev, checkpoint_every_s=300.0)
            sat = simulate(jobs, SaturnPolicy(time_limit_s=3), profiles,
                           cluster, noise_sigma=0.0,
                           introspect_every_s=600.0, chaos=trace)
            cp = simulate(jobs, CurrentPractice(), profiles, cluster,
                          noise_sigma=0.0, chaos=trace)
            per_seed.append(cp.makespan_s / sat.makespan_s)
        margins.append(sum(per_seed) / len(per_seed))
    assert all(b >= a - 0.02 for a, b in zip(margins, margins[1:])), \
        f"mean margin not monotone: {margins}"
    assert margins[-1] > margins[0] > 1.0, margins
