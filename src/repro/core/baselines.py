"""The paper's four baselines (§3) plus the SATURN policy itself.

- Current Practice: all GPUs of a node to one job, jobs in sequence,
  task parallelism across nodes.
- Random: random parallelism, allocation and order (seeded).
- Optimus (Peng et al., EuroSys'18): greedy marginal-gain GPU allocation.
- Optimus-Dynamic: Optimus + the introspection mechanism.
- Saturn: the joint MILP (+ introspection); under a node-aware cluster
  (``ClusterSpec(placement="node")``) it runs the node-locality MILP
  and emits node placement hints the runtime honors; on a heterogeneous
  cluster (multiple :class:`~repro.core.job.DeviceClass`) it runs the
  class-aware MILP and pins each job to a device class.

All policies emit Schedule IR (:class:`repro.core.schedule.Schedule`)
and are device-class aware: on heterogeneous clusters their entries are
class-qualified, on legacy single-class clusters they reduce exactly to
the historical behavior.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Tuple

import numpy as np

from .perfmodel import iter_job_class_profiles, iter_job_profiles
from .portfolio import solve_portfolio
from .schedule import Policy, Schedule, ScheduleEntry
from .solver import (OBJECTIVES, Assignment, Solution, class_choice_map,
                     pooled_choice_map, solve_joint, solve_joint_classes,
                     solve_joint_nodes, solve_residual,
                     split_fixed_running)


def _is_hetero(cluster) -> bool:
    return getattr(cluster, "hetero", False)


def _feasible(job, profiles, device_class=None):
    """Feasible (technique, g, step_time) triples for one job on one
    device class — from the legacy dict or a PerfModel's curves."""
    return [(tech, g, p.step_time_s)
            for tech, g, p in iter_job_profiles(profiles, job.name,
                                                device_class=device_class)
            if p.feasible]


def _feasible_classes(job, profiles):
    """Feasible (technique, device_class, g, step_time) across every
    class the profiles cover."""
    return [(tech, dc, g, p.step_time_s)
            for tech, dc, g, p in iter_job_class_profiles(profiles,
                                                          job.name)
            if p.feasible]


def _best_at_count(job, profiles, g, device_class=None):
    cands = [(tech, p.step_time_s)
             for tech, gg, p in iter_job_profiles(profiles, job.name,
                                                  device_class=device_class)
             if gg == g and p.feasible]
    if not cands:
        return None
    return min(cands, key=lambda x: x[1])


def _cluster_nodes(cluster) -> List[Tuple]:
    """Every (device_class, gpus_per_node) node in the cluster, in
    declaration order — what "one job per node" task parallelism
    round-robins over."""
    out = []
    for dc in cluster.device_classes:
        out.extend([(dc.name, dc.gpus_per_node)] * dc.nodes)
    return out


class CurrentPractice(Policy):
    """Typical current practice (paper §3): every job gets a full node
    and runs under the standard go-to setup — FSDP — one job per node at
    a time, task-parallel across nodes.  (No per-job tuning: that is
    exactly what Saturn automates.)  On a mixed fleet, jobs take whole
    nodes round-robin across ALL nodes regardless of generation — the
    class-blind behavior Saturn's class-aware planning beats."""

    name = "current-practice"
    dynamic = False
    default_technique = "fsdp"

    def _entry_for(self, j, profiles, g, dclass):
        """Full-node entry on one class: default technique if feasible
        there, else the best feasible technique at that count."""
        cands = {tech: p.step_time_s
                 for tech, gg, p in iter_job_profiles(profiles, j.name,
                                                      device_class=dclass)
                 if gg == g and p.feasible}
        if cands:
            tech = self.default_technique if self.default_technique \
                in cands else min(cands, key=cands.get)
            return ScheduleEntry(j.name, tech, g, device_class=dclass)
        # fall back to any feasible config on this class
        feas = _feasible(j, profiles, device_class=dclass)
        if not feas:
            return None
        tech, g, _ = min(feas, key=lambda x: x[2])
        return ScheduleEntry(j.name, tech, g, device_class=dclass)

    def plan(self, jobs, remaining, profiles, cluster, current):
        if _is_hetero(cluster):
            nodes = _cluster_nodes(cluster)
            entries = []
            for i, j in enumerate(jobs):
                dclass, g = nodes[i % len(nodes)]
                e = self._entry_for(j, profiles, g, dclass)
                if e is None:  # does not fit this node class: any class
                    feas = _feasible_classes(j, profiles)
                    if not feas:
                        raise ValueError(f"{j.name}: infeasible everywhere")
                    tech, dc, g, _ = min(feas, key=lambda x: x[3])
                    e = ScheduleEntry(j.name, tech, g, device_class=dc)
                entries.append(e)
            return Schedule(entries, solver=self.name)
        entries = []
        for j in jobs:
            g = cluster.gpus_per_node
            if (j.name, self.default_technique, g) in profiles and \
                    profiles[(j.name, self.default_technique, g)].feasible:
                tech = self.default_technique
            else:
                best = _best_at_count(j, profiles, g)
                if best is None:  # fall back to any feasible
                    feas = _feasible(j, profiles)
                    if not feas:
                        raise ValueError(f"{j.name}: infeasible everywhere")
                    tech, g, _ = min(feas, key=lambda x: x[2])
                else:
                    tech = best[0]
            entries.append(ScheduleEntry(j.name, tech, g))
        return Schedule(entries, solver=self.name)


class CurrentPracticeTuned(CurrentPractice):
    """Ablation: current practice but with the per-job BEST technique at
    full-node allocation (isolates Saturn's packing/allocation gains
    from its parallelism-selection gains)."""

    name = "current-practice-tuned"
    # the per-job best technique: never prefer the go-to default
    default_technique = ""

    def plan(self, jobs, remaining, profiles, cluster, current):
        if _is_hetero(cluster):
            return super().plan(jobs, remaining, profiles, cluster,
                                current)
        entries = []
        for j in jobs:
            g = cluster.gpus_per_node
            best = _best_at_count(j, profiles, g)
            if best is None:
                feas = _feasible(j, profiles)
                if not feas:
                    raise ValueError(f"{j.name}: infeasible everywhere")
                tech, g, _ = min(feas, key=lambda x: x[2])
            else:
                tech = best[0]
            entries.append(ScheduleEntry(j.name, tech, g))
        return Schedule(entries, solver=self.name)


class RandomPolicy(Policy):
    name = "random"
    dynamic = False

    def __init__(self, seed: int = 0):
        self.seed = seed

    def plan(self, jobs, remaining, profiles, cluster, current):
        rng = np.random.RandomState(self.seed)
        if _is_hetero(cluster):
            entries = []
            for j in jobs:
                feas = _feasible_classes(j, profiles)
                tech, dc, g, _ = feas[rng.randint(len(feas))]
                entries.append(ScheduleEntry(j.name, tech, g,
                                             device_class=dc))
            rng.shuffle(entries)
            return Schedule(entries, solver=self.name)
        order = []
        for j in jobs:
            feas = _feasible(j, profiles)
            tech, g, _ = feas[rng.randint(len(feas))]
            order.append((j.name, tech, g))
        rng.shuffle(order)
        return Schedule.from_tuples(order, solver=self.name)


class Optimus(Policy):
    """Greedy marginal-gain allocation: every job starts at its smallest
    feasible GPU count; remaining GPUs go one-at-a-time to the job with
    the largest estimated marginal runtime reduction.

    On a heterogeneous cluster the allocation key is (device_class, g)
    and each class has its own GPU budget: jobs start on their cheapest
    feasible start, and the marginal-gain loop may grow a job within its
    class OR move it to a strictly faster budget-feasible config on
    another class — so both pools get spent.  (Migrating an already
    RUNNING job across classes remains Saturn's introspection edge.)
    """

    name = "optimus"
    dynamic = False

    def plan(self, jobs, remaining, profiles, cluster, current):
        if _is_hetero(cluster):
            return self._plan_hetero(jobs, remaining, profiles, cluster)
        live = [j for j in jobs if remaining.get(j.name, 0) > 0]
        runtime_at: Dict[str, Dict[int, Tuple[str, float]]] = {}
        for j in live:
            per_g: Dict[int, Tuple[str, float]] = {}
            for tech, g, p in iter_job_profiles(profiles, j.name):
                if not p.feasible:
                    continue
                t = p.step_time_s * remaining[j.name]
                if g not in per_g or t < per_g[g][1]:
                    per_g[g] = (tech, t)
            runtime_at[j.name] = per_g
        alloc: Dict[str, int] = {}
        budget = cluster.total_gpus
        # min feasible first (paper: one GPU at a time, from zero)
        for j in sorted(live, key=lambda j: -remaining.get(j.name, 0)):
            gmin = min(runtime_at[j.name]) if runtime_at[j.name] else None
            if gmin is not None and gmin <= budget:
                alloc[j.name] = gmin
                budget -= gmin
        # marginal gains
        improved = True
        while budget > 0 and improved:
            improved = False
            best_gain, best_job, best_g = 0.0, None, None
            for jname, g in alloc.items():
                per_g = runtime_at[jname]
                uppers = [gg for gg in per_g if gg > g and gg - g <= budget]
                if not uppers:
                    continue
                g2 = min(uppers)
                gain = (per_g[g][1] - per_g[g2][1]) / max(g2 - g, 1)
                if gain > best_gain:
                    best_gain, best_job, best_g = gain, jname, g2
            if best_job is not None:
                budget -= best_g - alloc[best_job]
                alloc[best_job] = best_g
                improved = True
        order = []
        for j in live:
            if j.name in alloc:
                g = alloc[j.name]
                order.append((j.name, runtime_at[j.name][g][0], g))
        # unallocated jobs queue behind with their min feasible config
        for j in live:
            if j.name not in alloc and runtime_at[j.name]:
                gmin = min(runtime_at[j.name])
                order.append((j.name, runtime_at[j.name][gmin][0], gmin))
        return Schedule.from_tuples(order, solver=self.name)

    def _plan_hetero(self, jobs, remaining, profiles, cluster):
        live = [j for j in jobs if remaining.get(j.name, 0) > 0]
        # runtime_at[job][(class, g)] = (technique, est total runtime)
        runtime_at: Dict[str, Dict[Tuple[str, int], Tuple[str, float]]] = {}
        for j in live:
            per_cg: Dict[Tuple[str, int], Tuple[str, float]] = {}
            for tech, dc, g, p in iter_job_class_profiles(profiles, j.name):
                if not p.feasible:
                    continue
                t = p.step_time_s * remaining[j.name]
                key = (dc, g)
                if key not in per_cg or t < per_cg[key][1]:
                    per_cg[key] = (tech, t)
            runtime_at[j.name] = per_cg
        budgets = {dc.name: dc.total_gpus for dc in cluster.device_classes}
        alloc: Dict[str, Tuple[str, int]] = {}
        for j in sorted(live, key=lambda j: -remaining.get(j.name, 0)):
            # cheapest feasible start: fewest GPUs, fastest class on ties
            starts = sorted(runtime_at[j.name],
                            key=lambda cg: (cg[1],
                                            runtime_at[j.name][cg][1]))
            for dc, g in starts:
                if g <= budgets[dc]:
                    alloc[j.name] = (dc, g)
                    budgets[dc] -= g
                    break
        improved = True
        while improved:
            improved = False
            best_gain, best_job, best_key = 0.0, None, None
            for jname, (dc, g) in alloc.items():
                per_cg = runtime_at[jname]
                cur_rt = per_cg[(dc, g)][1]
                for (dc2, g2), (_, rt2) in per_cg.items():
                    if rt2 >= cur_rt - 1e-12:
                        continue      # only strictly faster configs
                    back = g if dc2 == dc else 0   # GPUs given back
                    if g2 > budgets[dc2] + back:
                        continue
                    gain = (cur_rt - rt2) / max(g2 - back, 1)
                    if gain > best_gain:
                        best_gain, best_job = gain, jname
                        best_key = (dc2, g2)
            if best_job is not None:
                dc, g = alloc[best_job]
                dc2, g2 = best_key
                budgets[dc] += g
                budgets[dc2] -= g2
                alloc[best_job] = best_key
                improved = True
        entries = []
        for j in live:
            if j.name in alloc:
                dc, g = alloc[j.name]
                entries.append(ScheduleEntry(
                    j.name, runtime_at[j.name][(dc, g)][0], g,
                    device_class=dc))
        for j in live:  # unallocated: queue behind on cheapest start
            if j.name not in alloc and runtime_at[j.name]:
                dc, g = min(runtime_at[j.name],
                            key=lambda cg: (cg[1],
                                            runtime_at[j.name][cg][1]))
                entries.append(ScheduleEntry(
                    j.name, runtime_at[j.name][(dc, g)][0], g,
                    device_class=dc))
        return Schedule(entries, solver=self.name)


class OptimusDynamic(Optimus):
    name = "optimus-dynamic"
    dynamic = True


class SaturnPolicy(Policy):
    """The joint MILP; with ``dynamic`` the runtime re-invokes it at
    introspection intervals / arrivals on observed remaining work.

    On a node-aware cluster (``cluster.placement == "node"``) the plan
    comes from ``solve_joint_nodes`` and carries node assignments; on a
    heterogeneous cluster it comes from ``solve_joint_classes`` and
    pins each job to a device class — so an introspection replan may
    migrate a job across classes, paying the real restart penalty.

    ``refine`` enables the solver's coarse-to-fine slot refinement;
    ``incremental`` (default) makes replans warm-started: running jobs
    whose current config cannot profitably be switched (best remaining
    runtime + restart cost is no better) are fixed as capacity
    reservations, the previous plan's start times window the residual
    MILP, and only the residual (waiting jobs + remaining work) is
    re-solved.  The node-aware MILP has no incremental path and replans
    from scratch.

    ``objective`` picks what the MILP minimizes (``OBJECTIVES`` in
    :mod:`repro.core.solver`): the paper's makespan (default), weighted
    completion time, deadline tardiness, or per-tenant fair share.  The
    node-aware MILP supports only makespan.

    ``solver="portfolio"`` races the MILP against the interval-time LNS
    (:mod:`repro.core.portfolio`) under ``time_limit_s`` of shared wall
    budget with ``mip_gap`` as the first-to-gap target — the setting for
    large job counts (64+) where the dense MILP caps out.  Replans reuse
    the warm start both ways: previous starts window the MILP and seed
    the LNS incumbent.  Not available under node-aware placement (the
    node MILP has no portfolio peer).

    Every plan carries ``Schedule.telemetry`` — ``{backend, wall_s, gap,
    status, n_jobs}`` — which the runtime collects per (re)plan into
    ``SimResult.stats["solver"]``.
    """

    name = "saturn"
    dynamic = True
    replan_on_completion = False  # paper: re-solve on fixed intervals

    def __init__(self, n_slots: int = 24, time_limit_s: float = 10.0, *,
                 mip_gap: float = 0.05, refine: bool = False,
                 incremental: bool = True, objective: str = "makespan",
                 solver: str = "milp", seed: int = 0):
        if objective not in OBJECTIVES:
            raise ValueError(f"unknown objective {objective!r}; "
                             f"expected one of {OBJECTIVES}")
        if solver not in ("milp", "portfolio"):
            raise ValueError(f"unknown solver {solver!r}; "
                             "expected 'milp' or 'portfolio'")
        self.n_slots = n_slots
        self.time_limit_s = time_limit_s
        self.mip_gap = mip_gap
        self.refine = refine
        self.incremental = incremental
        self.objective = objective
        self.solver = solver
        self.seed = seed
        self._last_plan_t = 0.0

    @staticmethod
    def _live(jobs, remaining, now_s: float = 0.0):
        """Remaining-work copies of unfinished jobs.  The solver plans
        from t=0 = "now", so absolute deadlines shift by ``now_s`` (a
        deadline already blown clamps to 0: all further delay is
        tardiness)."""
        out = []
        for j in jobs:
            rem = remaining.get(j.name, j.total_steps)
            if rem <= 0:
                continue
            dl = getattr(j, "deadline_s", None)
            if dl is not None and now_s:
                dl = max(0.0, dl - now_s)
            out.append(dataclasses.replace(j, total_steps=rem,
                                           deadline_s=dl))
        return out

    def _choice_map(self, live, profiles, cluster):
        """Per-job choice lists, class-qualified on heterogeneous
        clusters — the SAME builders the full solvers use, so the
        incremental replan optimizes over an identical space."""
        if _is_hetero(cluster):
            return class_choice_map(live, profiles,
                                    cluster.device_classes)
        return (pooled_choice_map(live, profiles),
                {None: int(cluster.total_gpus)})

    @staticmethod
    def _emit(sol, n_jobs: int, t0: float) -> Schedule:
        """Solution -> Schedule, guaranteeing telemetry: backends that
        measured themselves (portfolio/LNS) pass theirs through;
        plain-MILP solves get it synthesized here."""
        sched = sol.to_schedule()
        if sched.telemetry is None:
            sched.telemetry = {"backend": sol.solver,
                               "wall_s": time.perf_counter() - t0,
                               "gap": None,
                               "status": sol.milp_status or sol.solver,
                               "n_jobs": n_jobs}
        return sched

    def plan(self, jobs, remaining, profiles, cluster, current,
             now_s: float = 0.0):
        t0 = time.perf_counter()
        live = self._live(jobs, remaining, now_s)
        if not live:
            return Schedule([], solver=self.name)
        if self.solver == "portfolio":
            if getattr(cluster, "placement", "flat") == "node":
                raise ValueError("solver='portfolio' does not support "
                                 "node-aware placement; use the node "
                                 "MILP (solver='milp')")
            choice_map, budgets = self._choice_map(live, profiles,
                                                   cluster)
            sol = solve_portfolio(
                live, choice_map, budgets, objective=self.objective,
                wall_budget_s=self.time_limit_s,
                gap_target=self.mip_gap, seed=self.seed)
            return self._emit(sol, len(live), t0)
        if _is_hetero(cluster):
            sol = solve_joint_classes(
                live, profiles, cluster, n_slots=min(self.n_slots, 20),
                time_limit_s=self.time_limit_s, mip_gap=self.mip_gap,
                refine=self.refine, objective=self.objective)
        elif getattr(cluster, "placement", "flat") == "node":
            if self.objective != "makespan":
                raise ValueError(
                    "the node-aware MILP supports only the makespan "
                    f"objective (got {self.objective!r})")
            sol = solve_joint_nodes(
                live, profiles, cluster.nodes, cluster.gpus_per_node,
                n_slots=min(self.n_slots, 16),
                time_limit_s=self.time_limit_s, mip_gap=self.mip_gap)
        else:
            sol = solve_joint(live, profiles, cluster.total_gpus,
                              n_slots=self.n_slots,
                              time_limit_s=self.time_limit_s,
                              mip_gap=self.mip_gap, refine=self.refine,
                              objective=self.objective)
        return self._emit(sol, len(live), t0)

    def plan_incremental(self, jobs, remaining, profiles, cluster,
                         current, *, prev=None, now_s=0.0,
                         running=frozenset()):
        if now_s < self._last_plan_t:
            # clock went backwards: the policy instance is being reused
            # for a fresh simulation — stale plan times must not shift
            # (or fail to shift) this run's warm windows
            self._last_plan_t = now_s
        elapsed = now_s - self._last_plan_t
        self._last_plan_t = now_s
        if not self.incremental or not running or prev is None \
                or not len(prev) \
                or getattr(cluster, "placement", "flat") == "node":
            # ``now_s`` (for deadline shifting) is SaturnPolicy.plan's
            # extension; subclasses overriding ``plan`` keep the base
            # Policy signature and manage their own world view
            if type(self).plan is SaturnPolicy.plan:
                return self.plan(jobs, remaining, profiles, cluster,
                                 current, now_s=now_s)
            return self.plan(jobs, remaining, profiles, cluster, current)
        t0 = time.perf_counter()
        live = self._live(jobs, remaining, now_s)
        if not live:
            return Schedule([], solver=self.name)
        choice_map, budgets = self._choice_map(live, profiles, cluster)
        fixed, residual = split_fixed_running(
            live, remaining, current, running, choice_map, profiles,
            cluster.restart_cost_s)
        if not residual:
            # every running job keeps its config; nothing to re-solve
            sol = solve_residual([], choice_map, budgets, fixed,
                                 objective=self.objective)
            return self._emit(sol, 0, t0)
        # warm incumbent: the previous plan's starts, shifted to now
        residual_names = {j.name for j in residual}
        warm = {e.job: max(0.0, e.start_s - elapsed)
                for e in prev.entries
                if e.start_s is not None and e.job in residual_names}
        if self.solver == "portfolio":
            sol = self._portfolio_residual(residual, choice_map,
                                           budgets, fixed, prev,
                                           elapsed, warm)
            return self._emit(sol, len(residual), t0)
        n_slots = min(self.n_slots, 20) if _is_hetero(cluster) \
            else self.n_slots
        sol = solve_residual(
            residual, choice_map, budgets, fixed, n_slots=n_slots,
            time_limit_s=self.time_limit_s, mip_gap=self.mip_gap,
            warm_starts=warm or None, objective=self.objective)
        return self._emit(sol, len(residual), t0)

    def _portfolio_residual(self, residual, choice_map, budgets, fixed,
                            prev, elapsed, warm):
        """The portfolio's incremental replan: fixed running jobs become
        ``reserved=`` capacity triples (exactly like ``solve_residual``),
        previous-plan starts window the MILP (``warm_starts``) AND seed
        the LNS incumbent (previous entries re-expressed as Assignments
        with remaining-work runtimes, shifted to now)."""
        reserved = [(a.device_class, a.n_gpus, a.runtime_s)
                    for a in fixed]
        residual_names = {j.name for j in residual}
        incumbent = []
        for e in prev.entries:
            if e.job not in residual_names or e.start_s is None:
                continue
            for c in choice_map[e.job]:
                if (c.technique == e.technique
                        and c.n_gpus == e.n_gpus
                        and c.device_class == e.device_class):
                    incumbent.append(Assignment(
                        e.job, c.technique, c.n_gpus,
                        max(0.0, e.start_s - elapsed), c.runtime_s,
                        device_class=c.device_class))
                    break
        sol = solve_portfolio(
            residual, choice_map, budgets, reserved=reserved,
            objective=self.objective, wall_budget_s=self.time_limit_s,
            gap_target=self.mip_gap, seed=self.seed,
            warm_starts=warm or None, incumbent=incumbent or None)
        assignments = list(fixed) + list(sol.assignments)
        mk = max(a.end_s for a in assignments)
        return Solution(assignments, mk, sol.solver,
                        milp_status=sol.milp_status,
                        telemetry=sol.telemetry)


class SaturnStatic(SaturnPolicy):
    """Ablation: the MILP without introspection."""
    name = "saturn-static"
    dynamic = False


def static_partition_fleets(serves, cluster, *, window_s: float = 60.0,
                            horizon_s=None, util_cap: float = 0.7):
    """The serving-side current practice: a peak-provisioned GPU
    partition per service, held for the whole run.  Returns a
    non-adaptive :class:`~repro.serving.fleet.FleetManager` — every
    fleet is sized for its trace's WORST window and never scales down,
    so training only ever sees the leftover capacity.  The contrast
    baseline for Saturn's adaptive fleets, which return off-peak GPUs
    to the sweep and evict training again when bursts land."""
    from ..serving.fleet import FleetManager
    return FleetManager(serves, cluster, window_s=window_s,
                        horizon_s=horizon_s, util_cap=util_cap,
                        adaptive=False)
