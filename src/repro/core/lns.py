"""Interval-time Large-Neighborhood-Search scheduler (ROADMAP item 3).

The time-indexed MILP discretizes time into slots, so its binary count
— and therefore its wall time — scales with ``n_jobs * n_choices *
n_slots``; BENCH_solver shows it pinned at the wall cap from 32 jobs
up.  This module is the portfolio's second engine: it plans over an
*interval-time* representation — every job has a real-valued start and
one chosen ``Choice``; no slot grid, no discretization error — and
searches by Large-Neighborhood Search:

1. seed with the objective-aware reservation-aware greedy incumbent
   (:func:`~repro.core.solver.greedy_schedule`), or a caller-provided
   previous plan when replanning incrementally;
2. each iteration DESTROYS a neighborhood (random job subset /
   worst-contributing jobs under the active objective / a time window
   around the makespan critical path / one device-class's jobs) and
   REPAIRS it by earliest-fit reinsertion against per-class
   free-capacity step functions;
3. candidates are accepted by a simulated-annealing schedule, and the
   best-so-far plan is returned at the deadline — so the search is
   *anytime*: more budget, better plan, never worse than its seed.

Per-class capacity is enforced by event sweep: occupancy deltas at
start/end times, prefix-summed into a free-capacity step function per
budget pool.  ``reserved=`` triples ``(class_or_None, gpus,
release_s)`` pre-load the sweep exactly as the MILP's capacity rows do,
so serving fleets and kept-running jobs co-exist.  All four
``OBJECTIVES`` are supported; candidate plans are scored through the
vectorized :func:`~repro.core.solver.objective_values_batch` (per-job
completion arrays — no per-job Python loops in the hot path).

Determinism: the search is driven by one seeded RNG and the deadline is
only consulted *between* iterations, so two runs with the same seed
whose iteration budget (``max_iters``) binds before the wall deadline
produce bit-identical plans.
"""
from __future__ import annotations

import math
import time
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from .job import Job
from .solver import (Assignment, Choice, Solution, _pool_of, _rank_jobs,
                     OBJECTIVES, greedy_schedule, objective_arrays,
                     objective_values_batch)

_EPS = 1e-9


def validate_capacity(assignments: Iterable[Assignment],
                      budgets: Dict[Optional[str], int],
                      reserved: Iterable[Tuple] = (),
                      tol: float = 1e-6) -> bool:
    """Event-sweep conservation check: per budget pool, the running
    GPU occupancy (assignments + ``reserved`` triples) never exceeds the
    pool's capacity.  The plan-level twin of the runtime's
    ``verify_conservation`` — used on solver output, before execution.
    """
    events: Dict[Optional[str], List[Tuple[float, float]]] = \
        {p: [] for p in budgets}
    for dc, g, release_s in reserved:
        p = dc if dc in budgets else None
        events[p].append((0.0, float(g)))
        if math.isfinite(release_s):
            events[p].append((float(release_s), -float(g)))
    for a in assignments:
        p = a.device_class if a.device_class in budgets else None
        events[p].append((a.start_s, float(a.n_gpus)))
        events[p].append((a.end_s, -float(a.n_gpus)))
    for p, evs in events.items():
        if not evs:
            continue
        ev = np.asarray(evs)
        t, d = ev[:, 0], ev[:, 1]
        ut, inv = np.unique(t, return_inverse=True)
        delta = np.zeros(ut.size)
        np.add.at(delta, inv, d)   # same-instant end+start nets out
        if np.cumsum(delta).max() > budgets[p] + tol:
            return False
    return True


class _Timeline:
    """Per-pool free-capacity step functions under construction.

    Holds occupancy events (reservations + already-placed jobs) and
    answers "earliest feasible start for g GPUs over rt seconds" with
    one vectorized pass: free capacity per segment via prefix sum, then
    for each candidate segment the next too-full segment via
    searchsorted — O(E) per query after an O(E log E) rebuild, rebuilt
    lazily only for pools that changed.
    """

    def __init__(self, budgets: Dict[Optional[str], int],
                 reserved: Iterable[Tuple] = ()):
        self.cap = {p: float(g) for p, g in budgets.items()}
        self._ev: Dict[Optional[str], List[Tuple[float, float]]] = \
            {p: [] for p in budgets}
        for dc, g, release_s in reserved:
            p = dc if dc in budgets else None
            self._ev[p].append((0.0, -float(g)))
            if math.isfinite(release_s):
                self._ev[p].append((float(release_s), float(g)))
        self._cache: Dict[Optional[str], Tuple[np.ndarray, np.ndarray]] = {}

    def add(self, pool: Optional[str], t0: float, t1: float,
            g: int) -> None:
        self._ev[pool].append((t0, -float(g)))
        self._ev[pool].append((t1, float(g)))
        self._cache.pop(pool, None)

    def _arrays(self, pool) -> Tuple[np.ndarray, np.ndarray]:
        """(times, free): free[i] GPUs available on [times[i],
        times[i+1]) (last segment extends to +inf); times[0] == 0."""
        got = self._cache.get(pool)
        if got is not None:
            return got
        evs = self._ev[pool]
        if not evs:
            out = (np.zeros(1), np.array([self.cap[pool]]))
            self._cache[pool] = out
            return out
        ev = np.asarray(evs)
        ut, inv = np.unique(ev[:, 0], return_inverse=True)
        delta = np.zeros(ut.size)
        np.add.at(delta, inv, ev[:, 1])
        free = self.cap[pool] + np.cumsum(delta)
        if ut[0] > 0.0:
            ut = np.concatenate([[0.0], ut])
            free = np.concatenate([[self.cap[pool]], free])
        out = (ut, free)
        self._cache[pool] = out
        return out

    def earliest_start(self, pool: Optional[str], g: int,
                       rt: float) -> Optional[float]:
        """Earliest t >= 0 with >= g GPUs free throughout [t, t+rt), or
        None when the pool can never host g GPUs (standing reservations
        eat the capacity forever)."""
        times, free = self._arrays(pool)
        ok_seg = free >= g - _EPS
        bad = np.flatnonzero(~ok_seg)
        if bad.size == 0:
            return 0.0
        # next too-full segment at or after each segment i; feasible
        # starts need that segment to begin at or after t_i + rt
        nxt_i = np.searchsorted(bad, np.arange(times.size))
        nxt_t = np.where(nxt_i < bad.size,
                         times[bad[np.minimum(nxt_i, bad.size - 1)]],
                         np.inf)
        ok = ok_seg & (nxt_t >= times + rt - _EPS)
        if not ok.any():
            return None
        return float(times[int(np.argmax(ok))])


class _Plan:
    """One interval-time plan: per-job choice index + real start."""

    __slots__ = ("ci", "start")

    def __init__(self, ci: np.ndarray, start: np.ndarray):
        self.ci = ci
        self.start = start

    def copy(self) -> "_Plan":
        return _Plan(self.ci.copy(), self.start.copy())


class LnsState:
    """Problem instance + precomputed per-job arrays shared across the
    search (choice attributes, objective arrays, greedy insertion
    rank)."""

    def __init__(self, jobs: List[Job],
                 choice_map: Dict[str, List[Choice]],
                 budgets: Dict[Optional[str], int],
                 reserved: Iterable[Tuple] = (),
                 objective: str = "makespan"):
        if objective not in OBJECTIVES:
            raise ValueError(f"unknown objective {objective!r}; "
                             f"expected one of {OBJECTIVES}")
        self.jobs = jobs
        self.choice_map = choice_map
        self.budgets = dict(budgets)
        self.reserved = list(reserved)
        self.objective = objective
        self.n = len(jobs)
        # flat per-(job, choice) attributes
        self.ch_g = [np.array([c.n_gpus for c in choice_map[j.name]])
                     for j in jobs]
        self.ch_rt = [np.array([c.runtime_s for c in choice_map[j.name]])
                      for j in jobs]
        self.ch_pool = [[_pool_of(c, self.budgets)
                         for c in choice_map[j.name]] for j in jobs]
        self.arrays = objective_arrays(jobs)
        self._cap_total = float(max(sum(self.budgets.values()), 1))
        order = _rank_jobs(jobs, choice_map, objective)
        pos = {j.name: i for i, j in enumerate(jobs)}
        self.rank = np.array([pos[j.name] for j in order])  # insert order
        self.deadline_arr = self.arrays["deadline"]
        self.weight_arr = self.arrays["weight"]

    def ends(self, plan: _Plan) -> np.ndarray:
        rt = np.array([self.ch_rt[i][plan.ci[i]] for i in range(self.n)])
        return plan.start + rt

    def value(self, plan: _Plan) -> float:
        return objective_values_batch(self.ends(plan),
                                      objective=self.objective,
                                      arrays=self.arrays)

    def timeline_of(self, plan: _Plan,
                    skip: Optional[np.ndarray] = None) -> _Timeline:
        """Occupancy timeline of ``plan`` minus the ``skip`` job mask."""
        tl = _Timeline(self.budgets, self.reserved)
        for i in range(self.n):
            if skip is not None and skip[i]:
                continue
            ci = plan.ci[i]
            tl.add(self.ch_pool[i][ci], plan.start[i],
                   plan.start[i] + self.ch_rt[i][ci],
                   int(self.ch_g[i][ci]))
        return tl

    def insert(self, tl: _Timeline, i: int, beta: float = 0.0,
               target: Optional[float] = None) -> Tuple[int, float]:
        """Insertion of job i, committed to the timeline.

        Default rule: over the job's choices, pick the (choice,
        earliest feasible start) minimizing ``end + beta * gpu_area /
        total_capacity`` (ties: fewer GPUs).  ``beta`` trades completion
        time against GPU-seconds consumed: at 0 this is pure
        earliest-completion (the greedy's rule); at higher values jobs
        prefer efficient sub-linear-scaling configs, freeing capacity
        for parallelism — the LNS samples beta per repair round, and
        simulated annealing keeps what helps.

        With ``target`` set (makespan-driven repair): among choices
        finishing by the target, take the cheapest GPU area — the
        balanced-allocation rule that packs toward a candidate makespan
        — falling back to earliest completion when none makes it."""
        best = None
        found = None
        for ci in range(len(self.ch_g[i])):
            g = int(self.ch_g[i][ci])
            rt = float(self.ch_rt[i][ci])
            t = tl.earliest_start(self.ch_pool[i][ci], g, rt)
            if t is None:
                continue
            if target is not None:
                meets = t + rt <= target + _EPS
                key = (not meets,
                       g * rt if meets else t + rt, t + rt, g, ci)
            else:
                key = (t + rt + beta * (g * rt) / self._cap_total,
                       g, ci)
            if best is None or key < best:
                best = key
                found = (ci, t, g, rt)
        if found is None:
            raise RuntimeError(
                f"LNS: job {self.jobs[i].name} fits no pool "
                f"(standing reservations exceed capacity?)")
        ci, t, g, rt = found
        tl.add(self.ch_pool[i][ci], t, t + rt, g)
        return ci, t

    def build(self, order: np.ndarray,
              ci_hint: Optional[np.ndarray] = None,
              beta: float = 0.0) -> _Plan:
        """Construct a feasible plan by inserting every job in ``order``
        (``ci_hint`` pins a job's choice where >= 0)."""
        tl = _Timeline(self.budgets, self.reserved)
        ci = np.zeros(self.n, dtype=np.int64)
        start = np.zeros(self.n)
        for i in order:
            i = int(i)
            hint = -1 if ci_hint is None else int(ci_hint[i])
            if hint >= 0:
                g = int(self.ch_g[i][hint])
                rt = float(self.ch_rt[i][hint])
                t = tl.earliest_start(self.ch_pool[i][hint], g, rt)
                if t is not None:
                    tl.add(self.ch_pool[i][hint], t, t + rt, g)
                    ci[i], start[i] = hint, t
                    continue
            ci[i], start[i] = self.insert(tl, i, beta=beta)
        return _Plan(ci, start)

    def from_assignments(self, assignments: Iterable[Assignment]
                         ) -> Optional[_Plan]:
        """Adopt an external plan (greedy seed / previous incremental
        plan): match each assignment to a choice and re-insert in start
        order, pinning the matched choices — always feasible, and equal
        to the source plan whenever that plan was left-justified."""
        byname = {j.name: i for i, j in enumerate(self.jobs)}
        ci_hint = np.full(self.n, -1, dtype=np.int64)
        start_hint = np.full(self.n, np.inf)
        for a in assignments:
            i = byname.get(a.job)
            if i is None:
                continue
            for ci, c in enumerate(self.choice_map[a.job]):
                if c.technique == a.technique and c.n_gpus == a.n_gpus \
                        and c.device_class == a.device_class:
                    ci_hint[i] = ci
                    start_hint[i] = a.start_s
                    break
        # jobs the source plan did not cover insert last, greedily
        order = np.argsort(np.where(np.isfinite(start_hint),
                                    start_hint, np.inf), kind="stable")
        return self.build(order, ci_hint=ci_hint)


# ------------------------------------------------- destroy neighborhoods

_NEIGHBORHOODS = ("random", "worst", "window", "pool")


def _destroy(state: LnsState, plan: _Plan, ends: np.ndarray,
             rng: np.random.RandomState) -> np.ndarray:
    """Pick a neighborhood and return the boolean removal mask."""
    n = state.n
    k = max(2, min(n, int(math.ceil(n * rng.uniform(0.1, 0.35)))))
    kind = _NEIGHBORHOODS[rng.randint(len(_NEIGHBORHOODS))]
    mask = np.zeros(n, dtype=bool)
    if kind == "random" or n <= 2:
        mask[rng.choice(n, size=k, replace=False)] = True
        return mask
    if kind == "worst":
        # per-job contribution under the active objective (ends for
        # makespan/fair-share, weighted ends for completion, weighted
        # lateness for tardiness) + noise so ties break differently
        if state.objective == "weighted_completion":
            contrib = state.weight_arr * ends
        elif state.objective == "tardiness":
            dl = state.deadline_arr
            contrib = state.weight_arr * np.maximum(
                0.0, ends - np.where(np.isfinite(dl), dl, np.inf))
        else:
            contrib = ends.astype(np.float64)
        contrib = contrib + rng.uniform(0.0, 1.0, n) * \
            (1e-6 * max(contrib.max(), 1.0))
        mask[np.argsort(-contrib, kind="stable")[:k]] = True
        return mask
    if kind == "window":
        # jobs finishing inside a window below the makespan: the
        # critical tail the incumbent cannot shorten without moving them
        mk = float(ends.max())
        w = rng.uniform(0.15, 0.45) * max(mk, _EPS)
        cand = np.flatnonzero(ends > mk - w)
        if cand.size > 2 * k:
            cand = cand[np.argsort(-ends[cand], kind="stable")[:2 * k]]
        if cand.size >= 2:
            mask[cand] = True
            return mask
        mask[rng.choice(n, size=k, replace=False)] = True
        return mask
    # "pool": every job currently drawing from one budget pool (on a
    # flat cluster there is one pool, which degrades to a large-random)
    pools = sorted(state.budgets.keys(), key=lambda p: (p is None, p))
    p = pools[rng.randint(len(pools))]
    cand = np.flatnonzero(np.array(
        [state.ch_pool[i][plan.ci[i]] == p for i in range(n)]))
    if cand.size < 2:
        mask[rng.choice(n, size=k, replace=False)] = True
        return mask
    if cand.size > 2 * k:
        cand = rng.choice(cand, size=2 * k, replace=False)
    mask[cand] = True
    return mask


# per-round GPU-area penalties the repair samples from: 0 is the pure
# earliest-completion greedy rule; the higher values steer removed jobs
# onto efficient (sub-linear-scaling) configs so more of them overlap
_BETAS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0)


def _repair(state: LnsState, plan: _Plan, mask: np.ndarray,
            rng: np.random.RandomState,
            target: Optional[float] = None) -> _Plan:
    """Reinsert the removed jobs onto the kept jobs' timeline.  Order is
    the objective rank most rounds, a random permutation otherwise; the
    insertion rule alternates between an area-penalized earliest-fit
    (``beta`` sampled per round) and, when a ``target`` value is known,
    a deadline-driven rule (cheapest area finishing by the target)."""
    out = plan.copy()
    tl = state.timeline_of(plan, skip=mask)
    beta = _BETAS[rng.randint(len(_BETAS))]
    if target is not None and rng.random_sample() < 0.5:
        target = target * rng.uniform(0.8, 1.0)
    else:
        target = None
    removed = [int(i) for i in state.rank if mask[int(i)]]
    if rng.random_sample() < 0.3:
        removed = [removed[k] for k in rng.permutation(len(removed))]
    for i in removed:
        out.ci[i], out.start[i] = state.insert(tl, i, beta=beta,
                                               target=target)
    return out


def lns_solve(jobs: List[Job], choice_map: Dict[str, List[Choice]],
              budgets: Dict[Optional[str], int], *,
              reserved: Iterable[Tuple] = (),
              objective: str = "makespan",
              deadline_s: float = 10.0,
              max_iters: Optional[int] = None,
              seed: int = 0,
              incumbent: Optional[List[Assignment]] = None,
              gap_target: Optional[float] = None,
              lower_bound: Optional[float] = None,
              stop=None) -> Solution:
    """Deadline-bounded LNS over interval time.  Anytime: returns the
    best plan found, never worse than the greedy seed under
    ``objective``.

    ``incumbent`` seeds the search with a previous plan's assignments
    (the incremental-replan warm start) — adopted when it scores better
    than the greedy seed.  ``gap_target`` + ``lower_bound`` enable the
    portfolio's first-to-gap early exit; ``stop`` (a
    ``threading.Event``-alike) aborts between iterations when another
    backend already won.  Same ``seed`` + an iteration budget that binds
    before ``deadline_s`` -> bit-identical plans.
    """
    t0 = time.perf_counter()
    if not jobs:
        return Solution([], 0.0, "lns",
                        telemetry={"backend": "lns", "wall_s": 0.0,
                                   "gap": None, "status": "empty",
                                   "iters": 0, "n_jobs": 0})
    state = LnsState(jobs, choice_map, budgets, reserved=reserved,
                     objective=objective)
    rng = np.random.RandomState(seed)

    greedy = greedy_schedule(jobs, choice_map, budgets,
                             reserved=list(reserved), objective=objective)
    cur = state.from_assignments(greedy.assignments)
    cur_val = state.value(cur)
    # constructive seed sweep: one earliest-fit build per area penalty —
    # a balanced-area build often beats the list-scheduler greedy
    # outright, and each build is a single O(n * E) insertion pass
    for beta in _BETAS:
        alt = state.build(state.rank, beta=beta)
        alt_val = state.value(alt)
        if alt_val < cur_val:
            cur, cur_val = alt, alt_val
    if incumbent:
        alt = state.from_assignments(incumbent)
        alt_val = state.value(alt)
        if alt_val < cur_val:
            cur, cur_val = alt, alt_val
    best, best_val = cur.copy(), cur_val

    def gap_of(v: float) -> Optional[float]:
        if lower_bound is None or objective != "makespan":
            return None
        return max(0.0, v - lower_bound) / max(v, _EPS)

    status = "deadline"
    it = 0
    limit = max_iters if max_iters is not None else 10_000_000
    T0 = 0.05 * max(cur_val, _EPS)
    g = gap_of(best_val)
    if gap_target is not None and g is not None and g <= gap_target:
        status, limit = "gap_target", 0      # seed already good enough
    while it < limit:
        if stop is not None and stop.is_set():
            status = "stopped"
            break
        if time.perf_counter() - t0 >= deadline_s:
            status = "deadline"
            break
        ends = state.ends(cur)
        mask = _destroy(state, cur, ends, rng)
        cand = _repair(state, cur, mask, rng,
                       target=best_val if objective == "makespan"
                       else None)
        cand_val = state.value(cand)
        temp = max(T0 * (0.995 ** it), 1e-12)
        dv = cand_val - cur_val
        if dv < 0 or rng.random_sample() < math.exp(
                -min(dv / temp, 700.0)):
            cur, cur_val = cand, cand_val
        if cand_val < best_val - _EPS:
            best, best_val = cand.copy(), cand_val
            g = gap_of(best_val)
            if gap_target is not None and g is not None \
                    and g <= gap_target:
                status = "gap_target"
                it += 1
                break
        it += 1
    else:
        status = "max_iters" if limit > 0 else status

    assignments = []
    for i, j in enumerate(jobs):
        c = choice_map[j.name][int(best.ci[i])]
        assignments.append(Assignment(j.name, c.technique, c.n_gpus,
                                      float(best.start[i]), c.runtime_s,
                                      device_class=c.device_class))
    mk = max(a.end_s for a in assignments)
    wall = time.perf_counter() - t0
    return Solution(
        assignments, mk, "lns",
        telemetry={"backend": "lns", "wall_s": wall,
                   "gap": gap_of(best_val), "status": status,
                   "iters": it, "n_jobs": state.n,
                   "value": float(best_val)})
