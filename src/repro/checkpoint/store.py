"""npz-based pytree checkpoint store with end-to-end integrity.

Used by Saturn's introspection mechanism (checkpoint + relaunch when the
solver produces a new plan), by the execution backends' preemption and
crash-recovery paths, and by the end-to-end training examples.

Commit protocol (single atomic commit point):

- The array payload AND the JSON metadata (step counter, loss, content
  checksum) are bundled into ONE ``.npz`` written to a temp file and
  published with a single ``os.replace`` — there is no window in which
  a reader can observe new arrays with stale metadata (the historical
  two-file race: the ``.meta.json`` sidecar used to be written after,
  and non-atomically, so a crash between the two resumed at a stale
  step).
- Before publishing, the previous checkpoint is rotated to
  ``path + ".prev"`` — the last-known-good fallback
  :func:`load_training_state` resumes from when the current file turns
  out corrupt or truncated (e.g. the process was SIGKILLed mid-write of
  something else entirely, or the disk lied).
- A sha256 content checksum over every array (name, dtype, shape,
  bytes) is stored in the bundled metadata and verified by
  :func:`load_checkpoint`; mismatch raises
  :class:`CheckpointCorruptError`.
- A ``.meta.json`` sidecar is still written (atomically, after the
  commit) as a human-inspectable convenience, but the bundled metadata
  is authoritative: :func:`load_metadata` prefers it.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import warnings
from typing import Any, Optional

import jax
import numpy as np

# npz entry under which the JSON metadata (incl. checksum) is bundled;
# the name cannot collide with pytree paths (they never start with "__")
META_KEY = "__saturn_meta__"


class CheckpointCorruptError(RuntimeError):
    """The checkpoint file is unreadable or fails its content checksum."""


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub" or arr.dtype.itemsize == 0 or \
                str(arr.dtype) == "bfloat16":
            arr = np.asarray(leaf, dtype=np.float32)  # bf16 etc: lossless up
        out[key] = arr
    return out


def _content_checksum(arrays: dict) -> str:
    """sha256 over every array's (name, dtype, shape, bytes), in sorted
    key order — invariant to npz member ordering."""
    h = hashlib.sha256()
    for key in sorted(arrays):
        arr = np.ascontiguousarray(arrays[key])
        h.update(key.encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def _atomic_write(path: str, write_fn) -> None:
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def save_checkpoint(path: str, tree: Any, metadata: Optional[dict] = None,
                    keep_previous: bool = True):
    """Atomically commit a pytree + metadata to ``path`` (.npz).

    Arrays and metadata land in ONE file published by ONE
    ``os.replace`` (the single commit point); the metadata carries a
    content checksum verified on load.  With ``keep_previous`` the
    outgoing checkpoint is rotated to ``path + ".prev"`` as the
    last-known-good fallback.
    """
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    arrays = _flatten_with_paths(tree)
    meta = dict(metadata or {})
    meta["checksum"] = _content_checksum(arrays)
    payload = dict(arrays)
    payload[META_KEY] = np.frombuffer(
        json.dumps(meta).encode(), dtype=np.uint8)
    if keep_previous and os.path.exists(path):
        os.replace(path, path + ".prev")
    _atomic_write(path, lambda f: np.savez(f, **payload))
    if metadata is not None:
        # convenience sidecar (atomic too); the bundled copy is
        # authoritative and load_metadata prefers it
        _atomic_write(path + ".meta.json",
                      lambda f: f.write(json.dumps(metadata).encode()))


def _read_bundle(path: str):
    """Load (arrays, bundled_meta_or_None); raises
    :class:`CheckpointCorruptError` on unreadable files or checksum
    mismatch.  Pre-checksum checkpoints (no bundled metadata) load
    without verification."""
    try:
        with np.load(path) as data:
            arrays = dict(data)
    except Exception as e:
        raise CheckpointCorruptError(
            f"checkpoint {path} is unreadable: {type(e).__name__}: {e}"
        ) from e
    meta = None
    raw = arrays.pop(META_KEY, None)
    if raw is not None:
        try:
            meta = json.loads(raw.tobytes().decode())
        except Exception as e:
            raise CheckpointCorruptError(
                f"checkpoint {path} has undecodable metadata: {e}") from e
        want = meta.get("checksum")
        if want is not None and _content_checksum(arrays) != want:
            raise CheckpointCorruptError(
                f"checkpoint {path} failed its content checksum")
    return arrays, meta


def verify_checkpoint(path: str) -> dict:
    """Integrity-check ``path`` without materializing a pytree; returns
    the bundled metadata ({} for pre-checksum files).  Raises
    :class:`CheckpointCorruptError` on corruption."""
    _, meta = _read_bundle(path)
    return meta or {}


def load_checkpoint(path: str, like: Any):
    """Restore into the structure of ``like`` (a pytree template),
    verifying the content checksum when present."""
    arrays, _ = _read_bundle(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = "/".join(
            str(x.key) if hasattr(x, "key") else str(x.idx) for x in p)
        try:
            arr = arrays[key]
        except KeyError:
            raise CheckpointCorruptError(
                f"checkpoint {path} is missing array {key!r}") from None
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_metadata(path: str) -> Optional[dict]:
    """Metadata for the checkpoint at ``path``: the bundled (atomic,
    checksummed) copy when present, else the legacy ``.meta.json``
    sidecar.  The internal checksum entry is stripped."""
    if os.path.exists(path):
        try:
            _, meta = _read_bundle(path)
        except CheckpointCorruptError:
            meta = None
        if meta is not None:
            return {k: v for k, v in meta.items() if k != "checksum"}
    sidecar = path + ".meta.json"
    if os.path.exists(sidecar):
        with open(sidecar) as f:
            return json.load(f)
    return None


def load_training_state(path: str, params: Any, opt: Any):
    """Resume helper: restore ``(params, opt, start_step)`` from
    ``path`` if a checkpoint exists there, else return the inputs
    unchanged at step 0.

    Validates before trusting: a checkpoint that is unreadable or fails
    its content checksum is skipped with a recorded warning and the
    previous good checkpoint (``path + ".prev"``, rotated by
    :func:`save_checkpoint`) is tried instead; if that fails too, the
    run restarts from step 0 — never raises mid-run over a bad file.

    This is the single source of truth for the resume contract shared
    by ``LocalRunner.run_job`` and the execution-backend workers — the
    caller seeds fresh state, then continues from wherever the last
    run (or a preemption) checkpointed.
    """
    like = {"params": params, "opt": opt}
    for i, p in enumerate((path, path + ".prev")):
        if not os.path.exists(p):
            continue
        try:
            meta = verify_checkpoint(p)
            state = load_checkpoint(p, like)
        except CheckpointCorruptError as e:
            warnings.warn(
                f"skipping corrupt checkpoint: {e}; "
                + ("falling back to previous good checkpoint"
                   if i == 0 else "restarting from step 0"),
                RuntimeWarning, stacklevel=2)
            continue
        if not meta:
            meta = load_metadata(p) or {}
        if i > 0:
            warnings.warn(
                f"resumed from previous good checkpoint {p} "
                f"(step {int(meta.get('step', 0))})",
                RuntimeWarning, stacklevel=2)
        return state["params"], state["opt"], int(meta.get("step", 0))
    return params, opt, 0
