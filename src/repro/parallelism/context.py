"""Logical-axis partitioning context (flax-partitioning style).

Model code annotates activations with *logical* axis names via
``shard(x, "batch", "seq", None)``.  The parallelism layer installs a
rules mapping (logical axis -> mesh axis or None) with ``axis_rules``;
outside any rules context the calls are no-ops, so model code stays
mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec

_state = threading.local()


def current_rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


def current_mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def axis_rules(rules: dict, mesh):
    """rules: {logical_axis_name: mesh_axis | tuple[mesh_axis] | None}"""
    prev = (current_rules(), current_mesh())
    _state.rules, _state.mesh = rules, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev


def spec_for(axes: Sequence[Optional[str]], rules=None) -> PartitionSpec:
    rules = rules if rules is not None else (current_rules() or {})
    entries = []
    used = set()
    for a in axes:
        m = rules.get(a) if a is not None else None
        # one mesh axis may shard only one tensor dim
        if m is not None:
            key = tuple(m) if isinstance(m, (list, tuple)) else (m,)
            if any(k in used for k in key):
                m = None
            else:
                used.update(key)
        entries.append(tuple(m) if isinstance(m, list) else m)
    return PartitionSpec(*entries)


def shard(x, *axes):
    """Annotate activation x with logical axes (no-op without rules)."""
    rules, mesh = current_rules(), current_mesh()
    if rules is None or mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(axes, rules)))
