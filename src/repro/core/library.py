"""The Parallelism Library (paper Fig. 1): a registry of techniques that
users can extend with the two-function interface (``search_space`` +
``plan``) and reuse across execution sessions.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Iterable, List, Optional, Tuple

from ..models.config import ModelConfig
from ..parallelism.base import Technique
from ..parallelism.techniques import DEFAULT_TECHNIQUES


class ParallelismLibrary:
    def __init__(self, techniques: Optional[Iterable[Technique]] = None):
        self._techniques: Dict[str, Technique] = {}
        for t in (techniques if techniques is not None else DEFAULT_TECHNIQUES):
            self.register(t)

    def register(self, technique: Technique):
        """Register (or replace) a technique under ``technique.name``."""
        if not hasattr(technique, "search_space") or not hasattr(technique, "plan"):
            raise TypeError(
                "technique must implement the two-function interface "
                "(search_space, plan)")
        self._techniques[technique.name] = technique
        return technique

    def get(self, name: str) -> Technique:
        return self._techniques[name]

    def names(self) -> List[str]:
        return list(self._techniques)

    def items(self):
        return self._techniques.items()

    def candidates(self, cfg: ModelConfig, gpu_counts: Iterable[int]
                   ) -> List[Tuple[str, int]]:
        """All valid (technique, n_gpus) choices for a model — the search
        space the Trial Runner profiles and the Solver optimizes over."""
        out = []
        for g in gpu_counts:
            for name, t in self._techniques.items():
                if t.search_space(cfg, g):
                    out.append((name, g))
        return out

    # persistence: registered technique names survive across sessions
    def save(self, path: str):
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump({"techniques": self.names()}, f)

    @staticmethod
    def load(path: str, available: Optional[Iterable[Technique]] = None,
             strict: bool = True) -> "ParallelismLibrary":
        """Rebuild a library from saved technique names, resolved
        against ``available`` (default: the built-in techniques).

        Saved names missing from the pool raise ``KeyError`` listing
        them — a silently shrunken library would make the Solver skip
        choices the user thinks are registered.  ``strict=False``
        restores the old drop-silently behavior.
        """
        with open(path) as f:
            names = set(json.load(f)["techniques"])
        pool = {t.name: t for t in (available or DEFAULT_TECHNIQUES)}
        missing = sorted(names - set(pool))
        if missing and strict:
            raise KeyError(
                f"techniques {missing} are not in the available pool "
                f"{sorted(pool)}; register them (the ``available`` "
                f"argument) or pass strict=False to drop them")
        return ParallelismLibrary([pool[n] for n in names if n in pool])
