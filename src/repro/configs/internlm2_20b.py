"""InternLM2-20B dense decoder with GQA [arXiv:2403.17297]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", arch_type="dense",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92544, head_dim=128,
    block_pattern=("attn",), rope_theta=1000000.0,
    tie_embeddings=False,
    source="GQA [arXiv:2403.17297]",
)
