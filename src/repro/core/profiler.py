"""The Trial Runner (paper §2): profiles every ⟨model, parallelism,
GPU-count⟩ combination the Solver may choose.

Two interchangeable backends share one cache and result type:

- **empirical** — run 1–2 real minibatches of the job's step and time
  them (exactly the paper's mechanism; requires the device count to be
  available locally, e.g. under ``--xla_force_host_platform_device_count``).
- **analytic** — ``jit(...).lower().compile()`` the real step, then derive
  a three-term roofline time (compute / memory / collectives) from
  ``cost_analysis()`` + collective bytes parsed out of the HLO, against
  the target hardware's constants.  This is the CPU-container stand-in
  for running the two minibatches on real accelerators.

A third ``napkin`` mode skips lowering entirely (pure closed-form
roofline) — the cheap backend for benchmarks and the performance-model
layer's synthetic sweeps.

``profile_all`` supports three strategies (paper §2's <5% overhead
budget): ``"exhaustive"`` runs a real trial for every valid combo and
returns the legacy dict; ``"interpolate"`` runs trials only at a
geometric subset of counts per ⟨job, technique⟩ and returns a
:class:`~repro.core.perfmodel.PerfModel` of fitted throughput curves;
``"roofline"`` compiles each ⟨job, technique⟩ ONCE, converts the HLO's
op counts into a three-term roofline (compute / HBM / interconnect)
whose per-device-class efficiency coefficients are least-squares fit
from a handful of real calibration trials, and predicts every other
combo analytically — new device classes and 1000-combo search spaces
become essentially free to profile.  Either way, the outstanding real
trials run on a thread worker pool and land in a versioned,
atomically-written JSON cache (batched flushes: one rewrite per
``flush_every`` new profiles, temp-file + ``os.replace`` so a crash
mid-write can never corrupt the cache); the roofline calibration
coefficients persist in the same cache file.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import re
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..launch.hlo_analysis import analyze, link_seconds, scale_analysis
from ..models.params import abstract_params, param_count
from ..models.transformer import model_spec
from ..parallelism.base import Plan
from ..parallelism.build import BuiltJob
from .job import DEFAULT_CLASS, Job
from .library import ParallelismLibrary


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    flops: float          # peak FLOP/s per device (bf16)
    hbm_bw: float         # bytes/s per device
    link_bw: float        # bytes/s per device interconnect
    hbm_capacity: float   # bytes per device


HARDWARE = {
    # TPU v5e (production dry-run target)
    "v5e": HardwareSpec("v5e", 197e12, 819e9, 50e9, 16e9),
    # A100-40GB (the paper's p4d.24xlarge nodes)
    "a100": HardwareSpec("a100", 312e12, 1555e9, 600e9 / 8, 40e9),
    # V100-16GB (p3.16xlarge) — the mixed-fleet second class
    "v100": HardwareSpec("v100", 125e12, 900e9, 300e9 / 8, 16e9),
}


def hardware_for_class(base: HardwareSpec, device_class) -> HardwareSpec:
    """Derive a per-class HardwareSpec from the cluster's reference
    hardware and a :class:`~repro.core.job.DeviceClass`: rates scale by
    ``speed_hint``; capacity comes from the class's HBM size."""
    s = float(device_class.speed_hint)
    return HardwareSpec(device_class.name, base.flops * s,
                        base.hbm_bw * s, base.link_bw * s,
                        device_class.hbm_per_gpu)

_COLLECTIVE_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^\n]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_DTYPE_BYTES = {
    "f32": 4, "f16": 2, "bf16": 2, "f64": 8, "s32": 4, "u32": 4,
    "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2,
}


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum output sizes of collective ops per kind from HLO text."""
    out: Dict[str, float] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        numel = 1
        if dims:
            for d in dims.split(","):
                if d:
                    numel *= int(d)
        out[kind] = out.get(kind, 0.0) + numel * nbytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclasses.dataclass
class Profile:
    job: str
    technique: str
    n_devices: int
    step_time_s: float
    mem_per_device: float
    feasible: bool
    source: str
    terms: Dict[str, float] = dataclasses.field(default_factory=dict)
    device_class: str = DEFAULT_CLASS

    def to_json(self):
        return dataclasses.asdict(self)


# v4: the cache also persists per-class roofline calibration fits —
# older caches (v3 and before) are discarded on load, not migrated: a
# v3 cache has no calibration section and re-running the trials is
# cheaper than guessing one
CACHE_VERSION = 4
PROFILE_MODES = ("analytic", "empirical", "napkin")
PROFILE_STRATEGIES = ("exhaustive", "interpolate", "roofline")


@dataclasses.dataclass
class ClassCalibration:
    """Per-device-class roofline efficiency fit.

    ``coef`` scales the three raw roofline features — the dominant
    ``max(compute, HBM)`` term, the interconnect term, and the fixed
    per-step launch latency — so ``t = coef · features``.  With fewer
    than 4 calibration points the fit collapses to a single shared
    efficiency (``coef[0] == coef[1] == coef[2]``): a scalar is all the
    data can support, and it is exactly the "machine balance" knob the
    roofline literature calibrates.  ``residual`` is the relative RMS
    error on the calibration points themselves (used as a confidence
    signal, not a held-out estimate).
    """
    device_class: str
    coef: Tuple[float, float, float]
    n_points: int
    residual: float
    mode: str

    def predict(self, features) -> float:
        t = float(np.dot(np.asarray(self.coef), np.asarray(features)))
        return max(t, 1e-9)

    def to_json(self):
        d = dataclasses.asdict(self)
        d["coef"] = list(self.coef)
        return d

    @classmethod
    def from_json(cls, d) -> "ClassCalibration":
        d = dict(d)
        d["coef"] = tuple(float(c) for c in d["coef"])
        return cls(**d)


def fit_calibration(device_class: str, points, mode: str
                    ) -> ClassCalibration:
    """Least-squares fit of per-class efficiency coefficients over the
    calibration trials.  ``points`` is a sequence of
    ``(features, observed_step_s)`` with 3-vector features.

    >=4 points fit the full 3-coefficient model (falling back when the
    solution goes non-physical, i.e. a negative dominant coefficient);
    fewer points — the default ~2 real trials per class — fit the
    single shared efficiency ``a = Σ x·y / Σ x·x`` over the summed
    features.
    """
    A = np.asarray([f for f, _ in points], dtype=float)
    y = np.asarray([t for _, t in points], dtype=float)
    coef = None
    if len(points) >= 4:
        full, *_ = np.linalg.lstsq(A, y, rcond=None)
        if np.all(np.isfinite(full)) and full[0] > 0 and \
                full[1] >= 0 and full[2] >= 0:
            coef = tuple(float(c) for c in full)
    if coef is None:
        x = A.sum(axis=1)
        denom = float(np.dot(x, x))
        a = float(np.dot(x, y) / denom) if denom > 0 else 1.0
        a = a if math.isfinite(a) and a > 0 else 1.0
        coef = (a, a, a)
    pred = A @ np.asarray(coef)
    rel = np.abs(pred - y) / np.maximum(np.abs(y), 1e-12)
    residual = float(np.sqrt(np.mean(rel ** 2))) if len(y) else math.inf
    return ClassCalibration(device_class, coef, len(points), residual,
                            mode)


class TrialRunner:
    def __init__(self, library: ParallelismLibrary,
                 hardware: HardwareSpec = HARDWARE["a100"],
                 cache_path: Optional[str] = None,
                 flush_every: int = 16,
                 hardware_by_class: Optional[Dict[str, HardwareSpec]] = None):
        self.library = library
        self.hw = hardware
        # per-device-class hardware: the reference spec under "default";
        # register_class / hardware_by_class add mixed-fleet entries
        self.hw_by_class: Dict[str, HardwareSpec] = {DEFAULT_CLASS: hardware}
        self.hw_by_class.update(hardware_by_class or {})
        self.cache_path = cache_path
        self.flush_every = max(1, flush_every)
        self.trials = 0            # real trials computed by THIS runner
        self._dirty = 0            # new profiles since the last flush
        self._lock = threading.Lock()
        self._cache: Dict[Tuple[str, str, int, str, str], Profile] = {}
        # one compile per ⟨shape-identical job, technique, mesh shape⟩:
        # empirical trials reuse the BuiltJob (jit cache follows the
        # step fn), and every analytic/roofline consumer reuses the
        # lowered executable + its parsed HLO analysis
        self._built_cache: Dict[Tuple, BuiltJob] = {}
        self._compile_cache: Dict[Tuple, object] = {}
        self._analysis_cache: Dict[Tuple, Dict[str, float]] = {}
        # per-device-class roofline calibration (persisted in the cache)
        self.calibration: Dict[str, ClassCalibration] = {}
        if cache_path:
            # real compiles during trials hit the persistent XLA cache,
            # keyed alongside this profile cache
            from .compile_cache import enable_persistent_compilation_cache
            enable_persistent_compilation_cache(
                os.path.join(os.path.dirname(os.path.abspath(cache_path)),
                             "xla-cache"))
            if os.path.exists(cache_path):
                self._load_cache(cache_path)

    def register_class(self, device_class) -> HardwareSpec:
        """Register a :class:`~repro.core.job.DeviceClass`, deriving its
        HardwareSpec from the reference hardware (idempotent; an
        explicit ``hardware_by_class`` entry wins)."""
        hw = self.hw_by_class.get(device_class.name)
        if hw is None:
            hw = hardware_for_class(self.hw, device_class)
            self.hw_by_class[device_class.name] = hw
        return hw

    def _class_hw(self, device_class: str) -> HardwareSpec:
        try:
            return self.hw_by_class[device_class]
        except KeyError:
            raise ValueError(
                f"unknown device class {device_class!r}; register it "
                f"(register_class / hardware_by_class); have "
                f"{list(self.hw_by_class)}") from None

    def _load_cache(self, path: str) -> None:
        """Versioned load: stale schemas (the legacy bare list, an older
        version number) and torn/corrupt files are silently discarded —
        a cache is a cache, never a crash."""
        try:
            with open(path) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError, UnicodeDecodeError):
            return
        if not isinstance(data, dict) or data.get("version") != CACHE_VERSION:
            return
        for rec in data.get("profiles", []):
            try:
                p = Profile(**rec)
            except TypeError:
                continue
            self._cache[(p.job, p.technique, p.n_devices, p.source,
                         p.device_class)] = p
        for dc, rec in (data.get("calibration") or {}).items():
            try:
                self.calibration[dc] = ClassCalibration.from_json(rec)
            except (TypeError, KeyError, ValueError):
                continue

    # ------------------------------------------------------------- public
    def profile(self, job: Job, technique: str, n_devices: int,
                mode: str = "analytic",
                device_class: str = DEFAULT_CLASS) -> Profile:
        if mode not in PROFILE_MODES:
            raise ValueError(f"unknown profiling mode {mode!r}; "
                             f"expected one of {PROFILE_MODES}")
        hw = self._class_hw(device_class)
        key = (job.name, technique, n_devices, mode, device_class)
        with self._lock:
            if key in self._cache:
                return self._cache[key]
        tech = self.library.get(technique)
        if not tech.search_space(job.cfg, n_devices):
            prof = Profile(job.name, technique, n_devices, float("inf"),
                           float("inf"), False, mode,
                           device_class=device_class)
            ran_trial = False
        else:
            if mode == "empirical":
                prof = self._profile_empirical(job, technique, n_devices,
                                               hw, device_class)
            elif mode == "napkin":
                prof = self._profile_napkin(job, technique, n_devices,
                                            hw, device_class)
            else:
                prof = self._profile_analytic(job, technique, n_devices,
                                              hw, device_class)
            ran_trial = True
        with self._lock:
            self._cache[key] = prof
            if ran_trial:
                self.trials += 1
            self._dirty += 1
            if self.cache_path and self._dirty >= self.flush_every:
                self._flush_locked()
        return prof

    def profile_all(self, jobs, gpu_counts, mode="analytic", *,
                    strategy: str = "exhaustive",
                    workers: Optional[int] = None,
                    anchor_ratio: float = 2.0,
                    classes=None,
                    calibration_trials: int = 2,
                    confidence_threshold: float = 0.3):
        """Profile a workload over ``gpu_counts``.

        ``strategy="exhaustive"`` runs a real trial at every valid
        (technique, count) and returns the legacy profile dict.

        ``strategy="interpolate"`` runs trials only at the geometric
        anchor subset per ⟨job, technique, device class⟩ (plus
        feasibility boundary counts) and returns a
        :class:`~repro.core.perfmodel.PerfModel` whose curves evaluate
        every other count.

        ``strategy="roofline"`` runs only ``calibration_trials`` real
        trials per device class to fit that class's roofline efficiency
        coefficients (persisted in the profile cache, so a later run —
        or a new device class with a cached fit — runs NO trials at
        all), predicts every combo from compiled-HLO op counts, and
        returns a :class:`~repro.core.perfmodel.PerfModel`.  Combos the
        prediction cannot be confident about — unfit collective
        patterns in the HLO, memory within a few percent of capacity,
        a poor calibration fit — fall back to real trials when their
        confidence drops below ``confidence_threshold`` (0 disables the
        fallback, 1 escalates everything).

        ``classes`` (a sequence of :class:`~repro.core.job.DeviceClass`)
        switches on heterogeneous profiling: every class gets its OWN
        anchor trials against its own hardware constants, counts are
        truncated to each class's capacity, and results are keyed
        ``(job, tech, device_class, g)`` (dict) / carry class-qualified
        curves (PerfModel).  Without it, the legacy single-class shapes
        are preserved exactly.
        """
        from .perfmodel import (PerfModel, ThroughputCurve,
                                select_anchor_counts)
        if strategy not in PROFILE_STRATEGIES:
            raise ValueError(
                f"unknown profiling strategy {strategy!r}; expected one "
                f"of {PROFILE_STRATEGIES}")
        counts = sorted(set(int(g) for g in gpu_counts))
        hetero = classes is not None
        if hetero:
            class_counts = {dc.name: [g for g in counts
                                      if g <= dc.total_gpus]
                            for dc in classes}
            for dc in classes:
                self.register_class(dc)
        else:
            class_counts = {DEFAULT_CLASS: counts}
        if strategy == "exhaustive":
            tasks = [(job, tech, g, dc)
                     for job in jobs for dc, cts in class_counts.items()
                     for tech, g in self.library.candidates(job.cfg, cts)]
            self._run_trials(tasks, mode, workers)
            self.flush()
            if hetero:
                return {(job.name, tech, dc, g):
                        self._cache[(job.name, tech, g, mode, dc)]
                        for job, tech, g, dc in tasks}
            return {(job.name, tech, g):
                    self._cache[(job.name, tech, g, mode, DEFAULT_CLASS)]
                    for job, tech, g, _ in tasks}
        if strategy == "roofline":
            return self._profile_all_roofline(
                jobs, counts, class_counts, mode, workers, hetero,
                calibration_trials, confidence_threshold)
        plan: Dict[Tuple[str, str, str], Tuple[Job, list, list]] = {}
        tasks = []
        for job in jobs:
            for dc, cts in class_counts.items():
                for tech_name, tech in self.library.items():
                    valid = [g for g in cts
                             if tech.search_space(job.cfg, g)]
                    if not valid:
                        continue
                    anchors = select_anchor_counts(valid, anchor_ratio)
                    plan[(job.name, tech_name, dc)] = (job, valid, anchors)
                    tasks.extend((job, tech_name, g, dc) for g in anchors)
        self._run_trials(tasks, mode, workers)
        self.flush()
        curves = {}
        for (jname, tech_name, dc), (job, valid, anchors) in plan.items():
            profs = {g: self._cache[(jname, tech_name, g, mode, dc)]
                     for g in anchors}
            curve = ThroughputCurve(
                jname, tech_name, self._class_hw(dc).hbm_capacity, profs,
                valid=valid, domain=class_counts[dc], device_class=dc)
            if hetero:
                curves[(jname, tech_name, dc)] = curve
            else:
                curves[(jname, tech_name)] = curve
        return PerfModel(curves, counts,
                         counts_by_class=class_counts if hetero else None)

    def _run_trials(self, tasks, mode: str, workers: Optional[int]) -> None:
        """Run the outstanding real trials, in parallel where safe.

        Empirical trials time real minibatches, so they must not share
        the machine — those always run serially.  Analytic/napkin trials
        are compile/arithmetic work and fan out over a thread pool.
        """
        seen = set()
        todo = []
        for job, tech, g, dc in tasks:
            key = (job.name, tech, g, dc)
            if key in seen:
                continue
            seen.add(key)
            todo.append((job, tech, g, dc))
        if workers is None:
            workers = 1 if mode == "empirical" else \
                min(8, os.cpu_count() or 1)
        if workers <= 1 or len(todo) <= 1 or mode == "empirical":
            for job, tech, g, dc in todo:
                self.profile(job, tech, g, mode, device_class=dc)
            return
        with ThreadPoolExecutor(max_workers=workers) as pool:
            futs = [pool.submit(self.profile, job, tech, g, mode,
                                device_class=dc)
                    for job, tech, g, dc in todo]
            for f in futs:
                f.result()

    # ------------------------------------------------- roofline strategy
    def _calibration_combos(self, combos, k: int, mode: str):
        """Pick the ~k ⟨job, technique, count⟩ combos whose real trials
        anchor one class's calibration: round-robin over distinct
        (job, technique) pairs, alternating each pair's largest and
        smallest valid count so the fit sees both the collective-heavy
        and the single-device regime.  Empirical trials can only run on
        counts the local pool hosts."""
        local = len(jax.devices())
        picked, out = set(), []
        i = 0
        while len(out) < max(1, k) and i < 4 * max(1, len(combos)):
            job, tech_name, valid = combos[i % len(combos)]
            i += 1
            cts = [g for g in valid if g <= local] \
                if mode == "empirical" else valid
            if not cts:
                continue
            g = cts[-1] if len(out) % 2 == 0 else cts[0]
            key = (job.name, tech_name, g)
            if key in picked:
                continue
            picked.add(key)
            out.append((job, tech_name, g))
        return out

    def _profile_all_roofline(self, jobs, counts, class_counts, mode,
                              workers, hetero, calibration_trials,
                              confidence_threshold):
        from .perfmodel import PerfModel, ThroughputCurve
        plan: Dict[Tuple[str, str, str], Tuple[Job, list]] = {}
        by_class: Dict[str, list] = {}
        for job in jobs:
            for dc, cts in class_counts.items():
                for tech_name, tech in self.library.items():
                    valid = [g for g in cts
                             if tech.search_space(job.cfg, g)]
                    if not valid:
                        continue
                    plan[(job.name, tech_name, dc)] = (job, valid)
                    by_class.setdefault(dc, []).append(
                        (job, tech_name, valid))
        # ---- 1) per-class calibration: reuse a persisted fit when one
        # exists for this mode, otherwise run the calibration trials
        calib: Dict[str, list] = {}
        tasks = []
        for dc, combos in by_class.items():
            cached = self.calibration.get(dc)
            if cached is not None and cached.mode == mode and \
                    cached.n_points >= 1:
                continue
            calib[dc] = self._calibration_combos(
                combos, calibration_trials, mode)
            tasks.extend((job, tech_name, g, dc)
                         for job, tech_name, g in calib[dc])
        self._run_trials(tasks, mode, workers)
        for dc, picked in calib.items():
            hw = self._class_hw(dc)
            pts = []
            for job, tech_name, g in picked:
                p = self._cache[(job.name, tech_name, g, mode, dc)]
                if not (math.isfinite(p.step_time_s)
                        and p.step_time_s > 0):
                    continue
                tech_plan = self.library.get(tech_name).plan(job.cfg, g)
                feats, _, _ = self._raw_features(job, tech_plan, hw, mode)
                pts.append((feats, p.step_time_s))
            self.calibration[dc] = fit_calibration(dc, pts, mode) if pts \
                else ClassCalibration(dc, (1.0, 1.0, 1.0), 0,
                                      float("inf"), mode)
        # ---- 2) predict every combo; collect low-confidence escalations
        anchors: Dict[Tuple[str, str, str], Dict[int, Profile]] = {}
        escalate = []
        n_predicted = 0
        for (jname, tech_name, dc), (job, valid) in plan.items():
            hw = self._class_hw(dc)
            cal = self.calibration[dc]
            a: Dict[int, Profile] = {}
            for g in valid:
                real = self._cache.get((jname, tech_name, g, mode, dc))
                if real is not None:
                    a[g] = real
                    continue
                pred = self._predict_roofline(job, tech_name, g, hw, dc,
                                              cal, mode)
                hostable = mode != "empirical" or g <= len(jax.devices())
                if pred.terms["confidence"] < confidence_threshold \
                        and hostable:
                    escalate.append((job, tech_name, g, dc))
                a[g] = pred
                n_predicted += 1
            anchors[(jname, tech_name, dc)] = a
        # ---- 3) escalated combos get REAL trials that replace their
        # predictions (and land in the persistent cache)
        self._run_trials(escalate, mode, workers)
        for job, tech_name, g, dc in escalate:
            anchors[(job.name, tech_name, dc)][g] = \
                self._cache[(job.name, tech_name, g, mode, dc)]
        self.roofline_stats = {
            "predicted": n_predicted - len(escalate),
            "escalated": len(escalate),
            "calibration_trials": sum(len(v) for v in calib.values()),
        }
        # predictions are cached too (source="roofline", so they can
        # never be mistaken for a real trial of any mode)
        with self._lock:
            for (jname, tech_name, dc), a in anchors.items():
                for g, p in a.items():
                    if p.source == "roofline":
                        self._cache[(jname, tech_name, g, "roofline",
                                     dc)] = p
                        self._dirty += 1
        self.flush()
        curves = {}
        for (jname, tech_name, dc), (job, valid) in plan.items():
            curve = ThroughputCurve(
                jname, tech_name, self._class_hw(dc).hbm_capacity,
                anchors[(jname, tech_name, dc)], valid=valid,
                domain=class_counts[dc], device_class=dc)
            if hetero:
                curves[(jname, tech_name, dc)] = curve
            else:
                curves[(jname, tech_name)] = curve
        return PerfModel(curves, counts,
                         counts_by_class=class_counts if hetero else None)

    # --------------------------------------------------------- empirical
    def _profile_empirical(self, job: Job, technique: str, n_devices: int,
                           hw: HardwareSpec, device_class: str) -> Profile:
        from ..configs import concrete_batch
        if n_devices > len(jax.devices()):
            raise RuntimeError(
                f"empirical profiling needs {n_devices} local devices")
        tech = self.library.get(technique)
        try:
            plan = tech.plan(job.cfg, n_devices)
            built = self._built_job(job, plan)
            params, opt = built.init(jax.random.PRNGKey(0))
            batch = built.place_batch(
                concrete_batch(job.cfg, job.batch_size, job.seq_len))
            # 1 warmup (compile) + 2 timed minibatches, per the paper
            params, opt, _ = built.step(params, opt, batch)
            jax.block_until_ready(params)
            t0 = time.perf_counter()
            for _ in range(2):
                params, opt, _ = built.step(params, opt, batch)
            jax.block_until_ready(params)
            dt = (time.perf_counter() - t0) / 2
        except (AssertionError, ValueError, TypeError, ZeroDivisionError,
                RuntimeError) as e:
            # a trial that cannot even build/run its step for THIS
            # job's concrete shape (e.g. pipeline microbatching vs the
            # batch size) is an infeasible choice, not a crashed sweep
            # — exactly what a real cluster trial would conclude
            print(f"trial {job.name}/{technique}x{n_devices} failed "
                  f"({e!r}); recording infeasible")
            return Profile(job.name, technique, n_devices, float("inf"),
                           float("inf"), False, "empirical",
                           {"trial_error": 1.0},
                           device_class=device_class)
        mem = self._mem_estimate(job, plan)
        return Profile(job.name, technique, n_devices, dt, mem,
                       mem <= hw.hbm_capacity, "empirical",
                       device_class=device_class)

    # ---------------------------------------------------------- analytic
    def _profile_analytic(self, job: Job, technique: str, n_devices: int,
                          hw: HardwareSpec, device_class: str) -> Profile:
        tech = self.library.get(technique)
        plan = tech.plan(job.cfg, n_devices)
        return self._finish(job, technique, n_devices,
                            self._roofline_terms(job, plan, hw),
                            "analytic", hw, device_class)

    def _profile_napkin(self, job: Job, technique: str, n_devices: int,
                        hw: HardwareSpec, device_class: str) -> Profile:
        """Closed-form roofline only — no lowering/compilation.  The
        cheap deterministic backend for benchmark sweeps."""
        tech = self.library.get(technique)
        plan = tech.plan(job.cfg, n_devices)
        return self._finish(job, technique, n_devices,
                            self._roofline_napkin(job, plan, hw),
                            "napkin", hw, device_class)

    def _finish(self, job: Job, technique: str, n_devices: int,
                terms: Dict[str, float], source: str,
                hw: HardwareSpec, device_class: str) -> Profile:
        tech = self.library.get(technique)
        mem = terms.pop("mem_per_device")
        # roofline: compute and memory overlap with collectives imperfectly;
        # take max(compute, memory) + collective (conservative serial comm)
        t = max(terms["compute_s"], terms["memory_s"]) + terms["collective_s"]
        t *= tech.step_overhead()
        terms["modeled_step_s"] = t
        return Profile(job.name, technique, n_devices, t, mem,
                       mem <= hw.hbm_capacity, source, terms,
                       device_class=device_class)

    def _mem_estimate(self, job: Job, plan: Plan) -> float:
        """Params + AdamW state + activation estimate, per device."""
        tech = self.library.get(plan.technique)
        n_params = param_count(model_spec(job.cfg))
        # fp32 params + mu + nu = 12 bytes/param, sharded per technique
        state = 12.0 * n_params * tech.memory_fraction(job.cfg, plan.n_devices)
        act = self._activation_bytes(job, plan)
        return state + act

    def _activation_bytes(self, job: Job, plan: Plan) -> float:
        cfg = job.cfg
        b, s = job.batch_size, job.seq_len
        if plan.rules.get("batch"):
            b = max(1, b // dict(plan.mesh_axes).get(plan.rules["batch"], 1))
        per_layer = 2.0 * b * s * cfg.d_model * 6  # bf16, ~6 tensors/block
        layers = cfg.num_layers / plan.stages
        if plan.remat:
            return 2.0 * b * s * cfg.d_model * layers  # one residual/layer
        return per_layer * layers

    def _roofline_terms(self, job: Job, plan: Plan,
                        hw: HardwareSpec) -> Dict[str, float]:
        """Lower + compile the real step on a placeholder mesh and read
        cost_analysis / HLO collectives.  Falls back to a napkin model if
        the local device pool can't host the mesh."""
        try:
            return self._roofline_from_compile(job, plan, hw)
        except Exception:
            return self._roofline_napkin(job, plan, hw)

    # ------------------------------------------------ compile memoization
    def _shape_key(self, job: Job, technique: str, mesh_shape) -> Tuple:
        """Jobs that lower to the same program share one compile: the
        step's HLO depends on the model shape, the batch shape, and the
        technique's mesh — not on the job's name, lr, or seed."""
        cfg = job.cfg
        return (cfg.name, cfg.d_model, cfg.num_layers, job.batch_size,
                job.seq_len, technique, tuple(mesh_shape))

    def _built_job(self, job: Job, plan: Plan) -> BuiltJob:
        """Memoized BuiltJob per shape key — repeat empirical trials of
        shape-identical jobs reuse the step fn (and its jit cache)
        instead of re-lowering per job."""
        key = self._shape_key(job, plan.technique, plan.mesh_shape)
        with self._lock:
            built = self._built_cache.get(key)
        if built is None:
            built = BuiltJob(job.cfg, plan, job.opt_cfg,
                             devices=jax.devices()[:plan.n_devices])
            with self._lock:
                self._built_cache.setdefault(key, built)
        return built

    def _compiled_step(self, job: Job, plan: Plan):
        """Memoized ``lower().compile()`` of the real step per
        ⟨job-shape, technique, mesh-shape⟩, shared by the analytic
        roofline, the HLO analyzer, and the roofline strategy."""
        key = self._shape_key(job, plan.technique, plan.mesh_shape)
        with self._lock:
            compiled = self._compile_cache.get(key)
        if compiled is not None:
            return compiled
        from ..configs import concrete_batch
        n = plan.n_devices
        if n > len(jax.devices()):
            raise RuntimeError("not enough local devices to lower")
        built = self._built_job(job, plan)
        spec = model_spec(job.cfg)
        p_abs = abstract_params(spec, jnp.float32)
        o_abs = {"mu": abstract_params(spec, jnp.float32),
                 "nu": abstract_params(spec, jnp.float32),
                 "step": jax.ShapeDtypeStruct((), jnp.int32)}
        batch = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            concrete_batch(job.cfg, job.batch_size, job.seq_len))
        compiled = built.step.lower(p_abs, o_abs, batch).compile()
        with self._lock:
            self._compile_cache.setdefault(key, compiled)
        return compiled

    def _hlo_analysis(self, job: Job, plan: Plan) -> Dict[str, float]:
        """Memoized loop-aware HLO analysis of the compiled step (see
        :mod:`repro.launch.hlo_analysis`)."""
        key = self._shape_key(job, plan.technique, plan.mesh_shape)
        with self._lock:
            a = self._analysis_cache.get(key)
        if a is None:
            a = analyze(self._compiled_step(job, plan).as_text())
            with self._lock:
                self._analysis_cache.setdefault(key, a)
        return a

    def _roofline_from_compile(self, job: Job, plan: Plan,
                               hw: HardwareSpec):
        compiled = self._compiled_step(job, plan)
        n = plan.n_devices
        cost = compiled.cost_analysis()
        flops = float(cost.get("flops", 0.0)) / n
        bytes_acc = float(cost.get("bytes accessed", 0.0)) / n
        coll = collective_bytes_from_hlo(compiled.as_text())
        coll_bytes = coll["total"] / n
        mem = self._compiled_mem(compiled) or self._mem_estimate(job, plan)
        return {
            "compute_s": flops / hw.flops,
            "memory_s": bytes_acc / hw.hbm_bw,
            "collective_s": coll_bytes / hw.link_bw,
            "hlo_flops": flops * n,
            "collective_bytes": coll["total"],
            "mem_per_device": mem,
        }

    @staticmethod
    def _compiled_mem(compiled) -> Optional[float]:
        try:
            ma = compiled.memory_analysis()
            return float(ma.temp_size_in_bytes + ma.argument_size_in_bytes +
                         ma.output_size_in_bytes) / max(
                             len(compiled.devices()), 1)
        except Exception:
            return None

    def _utilization(self, job: Job, plan: Plan) -> float:
        """MXU/SM utilization model: saturates with per-device tokens;
        the knee sits higher for narrow models (small matmuls need more
        batch to fill the MXU/SMs) — this is what makes right-sizing
        matter.  TP shards the *width*, so its effective matmul width
        is d/g."""
        cfg = job.cfg
        g = plan.n_devices
        tokens = job.batch_size * job.seq_len
        tok_dev = tokens if plan.technique == "tp" else tokens / g
        d_eff = cfg.d_model / g if plan.technique == "tp" else cfg.d_model
        knee = 8192.0 * 2048.0 / (d_eff + 2048.0)
        util = (d_eff / (d_eff + 1024.0)) * (tok_dev / (tok_dev + knee))
        return max(util, 0.02)

    @staticmethod
    def _fixed_step_s(cfg, g: int) -> float:
        """Fixed per-step overhead: launch + per-layer collective
        latency, growing with device count."""
        return 2e-3 + 1e-4 * g + cfg.num_layers * 5e-5 * np.log2(max(g, 2))

    def _napkin_raw(self, job: Job, plan: Plan,
                    hw: HardwareSpec) -> Dict[str, float]:
        """6·N·D closed-form raw roofline terms (no lowering), with the
        fixed per-step latency split out so the calibration fit can
        weigh it separately."""
        cfg = job.cfg
        n_params = param_count(model_spec(cfg))
        if cfg.is_moe:
            n_active = n_params * (cfg.moe.top_k / cfg.moe.num_experts)
        else:
            n_active = n_params
        g = plan.n_devices
        tokens = job.batch_size * job.seq_len
        util = self._utilization(job, plan)
        flops = 6.0 * n_active * tokens / g
        compute_s = flops / (hw.flops * util)
        fixed_s = self._fixed_step_s(cfg, g)
        # bytes: params read 3x (fwd, bwd, opt) + activations
        tech = self.library.get(plan.technique)
        bytes_acc = (12.0 * n_params * tech.memory_fraction(cfg, g)
                     + self._activation_bytes(job, plan) * 4)
        coll = 4.0 * n_params / max(g, 1) if g > 1 else 0.0  # grad reduce
        return {
            "compute_s": compute_s,
            "memory_s": bytes_acc / hw.hbm_bw,
            "collective_s": coll / hw.link_bw,
            "fixed_s": fixed_s,
            "hlo_flops": flops * g,
            "collective_bytes": coll * g,
            "mem_per_device": self._mem_estimate(job, plan),
            "utilization": util,
        }

    def _roofline_napkin(self, job: Job, plan: Plan,
                         hw: HardwareSpec) -> Dict[str, float]:
        """6·N·D flops model when compile-based profiling is unavailable.

        Includes the two effects that make right-sizing matter (and that
        Saturn exploits): (a) MXU/SM utilization collapses when the
        per-device work gets small (tiny models on many GPUs waste
        capacity), and (b) fixed per-step latency (launch + collective
        setup) grows with device count."""
        raw = self._napkin_raw(job, plan, hw)
        return {
            "compute_s": raw["compute_s"] + raw["fixed_s"],
            "memory_s": raw["memory_s"],
            "collective_s": raw["collective_s"],
            "hlo_flops": raw["hlo_flops"],
            "collective_bytes": raw["collective_bytes"],
            "mem_per_device": raw["mem_per_device"],
            "utilization": raw["utilization"],
        }

    # ---------------------------------------------------------- roofline
    #
    # strategy="roofline": one compile per ⟨job-shape, technique⟩, op
    # counts from the loop-aware HLO analyzer scaled across device
    # counts, per-class efficiency coefficients fit from a handful of
    # real calibration trials — every other combo is predicted, not run.

    def _raw_features(self, job: Job, plan: Plan, hw: HardwareSpec,
                      mode: str = "analytic"
                      ) -> Tuple[Tuple[float, float, float],
                                 Dict[str, float], List[str]]:
        """Raw roofline features for one combo: ``(dominant, link,
        fixed)`` seconds (technique overhead folded in), the term dict
        for the Profile record, and any UNFIT collective kinds (present
        in the HLO, absent from the ring model — a low-confidence
        signal).

        Op counts come from ONE memoized compile per ⟨job-shape,
        technique⟩, rescaled to this count (`scale_analysis`); when no
        local mesh can host even a base compile — or under
        ``mode="napkin"``, whose simulated ground truth is the
        closed-form model itself and where a real compile would defeat
        the simulation's purpose — the closed-form napkin terms stand
        in.
        """
        g = plan.n_devices
        unfit: List[str] = []
        base = None if mode == "napkin" \
            else self._hlo_base_analysis(job, plan)
        if base is not None:
            n_base, analysis = base
            scaled = scale_analysis(analysis, n_base, g)
            util = self._utilization(job, plan)
            compute_s = scaled["flops"] / (hw.flops * util)
            memory_s = scaled["bytes_written"] / hw.hbm_bw
            collective_s, unfit = link_seconds(
                scaled["collectives"], g, hw.link_bw) if g > 1 \
                else (0.0, [])
            terms = {"hlo_flops": scaled["flops"] * g,
                     "collective_bytes": scaled["collectives"]["total"],
                     "utilization": util, "hlo_base_n": float(n_base)}
        else:
            raw = self._napkin_raw(job, plan, hw)
            compute_s = raw["compute_s"]
            memory_s = raw["memory_s"]
            collective_s = raw["collective_s"]
            terms = {"hlo_flops": raw["hlo_flops"],
                     "collective_bytes": raw["collective_bytes"],
                     "utilization": raw["utilization"]}
        fixed_s = self._fixed_step_s(job.cfg, g)
        ovh = self.library.get(plan.technique).step_overhead()
        feats = (ovh * max(compute_s, memory_s), ovh * collective_s,
                 ovh * fixed_s)
        terms.update({"compute_s": compute_s, "memory_s": memory_s,
                      "collective_s": collective_s, "fixed_s": fixed_s})
        return feats, terms, unfit

    def _hlo_base_analysis(self, job: Job, plan: Plan
                           ) -> Optional[Tuple[int, Dict[str, float]]]:
        """The ⟨base count, HLO analysis⟩ this combo's raw terms scale
        from: the combo's own mesh when the local pool can host it,
        otherwise the largest hostable valid count for the technique
        (compiled once, memoized).  None when nothing can be lowered."""
        tech = self.library.get(plan.technique)
        local = len(jax.devices())
        seen = set()
        for n in [plan.n_devices] + \
                list(range(min(local, plan.n_devices), 0, -1)):
            if n in seen or n > local or \
                    not tech.search_space(job.cfg, n):
                continue
            seen.add(n)
            base_plan = plan if n == plan.n_devices \
                else tech.plan(job.cfg, n)
            try:
                return n, self._hlo_analysis(job, base_plan)
            except Exception:
                continue
        return None

    def _predict_roofline(self, job: Job, technique: str, n_devices: int,
                          hw: HardwareSpec, device_class: str,
                          cal: ClassCalibration,
                          mode: str = "analytic") -> Profile:
        """One predicted Profile (``source="roofline"``) with a
        confidence term the fallback knob acts on."""
        tech = self.library.get(technique)
        plan = tech.plan(job.cfg, n_devices)
        feats, terms, unfit = self._raw_features(job, plan, hw, mode)
        t = cal.predict(feats)
        mem = self._mem_estimate(job, plan)
        confidence = 1.0
        if cal.n_points < 2:
            confidence *= 0.5
        if cal.residual > 0.25:
            confidence *= 0.5
        if unfit:
            confidence *= 0.25
            terms["unfit_collectives"] = float(len(unfit))
        # memory-boundary cases: the fit-or-doesn't-fit call is made on
        # an ESTIMATE — within a few percent of capacity the analytic
        # answer is a coin flip, so flag it for escalation
        if hw.hbm_capacity > 0 and \
                0.95 <= mem / hw.hbm_capacity <= 1.05:
            confidence *= 0.25
        terms["confidence"] = confidence
        terms["modeled_step_s"] = t
        return Profile(job.name, technique, n_devices, t, mem,
                       mem <= hw.hbm_capacity, "roofline", terms,
                       device_class=device_class)

    # -------------------------------------------------------------- misc
    def flush(self) -> None:
        """Write the cache to disk now (atomic temp-file + rename)."""
        with self._lock:
            self._flush_locked()

    # flushes are batched, so direct profile() callers could otherwise
    # lose the tail of their (possibly expensive empirical) trials when
    # the runner goes away without an explicit flush()
    def __enter__(self) -> "TrialRunner":
        return self

    def __exit__(self, *exc) -> None:
        self.flush()

    def __del__(self):
        try:
            self.flush()
        except Exception:
            pass               # interpreter teardown: best effort only

    def _flush_locked(self) -> None:
        if not self.cache_path or not self._dirty:
            return
        path = os.path.abspath(self.cache_path)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        payload = {"version": CACHE_VERSION,
                   "profiles": [p.to_json() for p in self._cache.values()],
                   "calibration": {dc: c.to_json()
                                   for dc, c in self.calibration.items()}}
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, path)
        self._dirty = 0

    # back-compat alias (pre-batching callers)
    _flush = flush
