"""Interval-time LNS scheduler (repro.core.lns): property tests.

Every LNS plan must (a) respect per-device-class capacity at every
instant (event-sweep validation — no slot grid to hide behind),
(b) respect ``reserved=`` fleet/running-job capacity triples, (c) never
come back worse than its greedy seed under the active objective (the
anytime contract), and (d) be bit-identical for the same seed when the
iteration cap binds before the wall clock (the determinism contract).

Property tests run through tests/_hypothesis_compat.py so tier-1 works
with or without hypothesis installed.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.core.job import Job
from repro.core.lns import lns_solve, validate_capacity
from repro.core.solver import (OBJECTIVES, Choice, greedy_schedule,
                               objective_arrays, objective_value,
                               objective_values_batch)

CFG = get_config("xlstm-125m").reduced()


def mk_job(name, steps=100, **kw):
    return Job(name, CFG, batch_size=8, seq_len=64, total_steps=steps,
               **kw)


def workload(n_jobs, seed, classes=(None,), deadlines=False,
             tenants=1):
    """Jobs + per-class choice lists + budgets, with scaling-efficiency
    spread so packing actually matters."""
    rng = np.random.RandomState(seed)
    budgets = {dc: 16 for dc in classes}
    jobs, cm = [], {}
    for i in range(n_jobs):
        kw = {}
        if deadlines and rng.rand() < 0.7:
            kw["deadline_s"] = float(rng.uniform(50, 400))
        if tenants > 1:
            kw["tenant"] = f"t{rng.randint(tenants)}"
        kw["weight"] = float(rng.uniform(0.5, 3.0))
        j = mk_job(f"j{i}", steps=int(rng.randint(50, 300)), **kw)
        jobs.append(j)
        base = rng.uniform(20.0, 200.0)
        eff = rng.uniform(0.5, 0.95)
        choices = []
        for dc in classes:
            speed = 1.0 if dc in (None, "a100") else 0.5
            for g in (1, 2, 4, 8):
                choices.append(Choice("fsdp", g,
                                      base / (g ** eff) / speed,
                                      device_class=dc))
        cm[j.name] = choices
    return jobs, cm, budgets


# ------------------------------------------------------------ properties

@settings(max_examples=10)
@given(seed=st.integers(0, 10_000),
       n_jobs=st.integers(2, 14),
       objective=st.sampled_from(OBJECTIVES),
       hetero=st.booleans())
def test_lns_conserves_capacity_and_beats_seed(seed, n_jobs, objective,
                                               hetero):
    """Core property: per-class capacity clean AND never worse than the
    greedy seed under the active objective, for every objective, flat
    and heterogeneous."""
    classes = ("a100", "v100") if hetero else (None,)
    jobs, cm, budgets = workload(n_jobs, seed, classes=classes,
                                 deadlines=True, tenants=3)
    sol = lns_solve(jobs, cm, budgets, objective=objective,
                    deadline_s=0.3, seed=seed)
    assert {a.job for a in sol.assignments} == {j.name for j in jobs}
    assert validate_capacity(sol.assignments, budgets)
    seed_sol = greedy_schedule(jobs, cm, budgets, objective=objective)
    lv = objective_value(sol.assignments, jobs, objective)
    gv = objective_value(seed_sol.assignments, jobs, objective)
    assert lv <= gv + 1e-6, f"LNS {lv} worse than greedy seed {gv}"


@settings(max_examples=8)
@given(seed=st.integers(0, 10_000), n_jobs=st.integers(2, 10))
def test_lns_respects_reserved_triples(seed, n_jobs):
    """``reserved=`` capacity (running jobs / serving fleets) is never
    double-booked: the event sweep including the reservations stays
    within budget, and an infinite-release reservation is permanent."""
    jobs, cm, budgets = workload(n_jobs, seed)
    reserved = [(None, 6, 80.0), (None, 4, float("inf"))]
    sol = lns_solve(jobs, cm, budgets, reserved=reserved,
                    deadline_s=0.3, seed=seed)
    assert {a.job for a in sol.assignments} == {j.name for j in jobs}
    assert validate_capacity(sol.assignments, budgets,
                             reserved=reserved)
    # the permanent 4-GPU reservation leaves at most 12 concurrent
    for a in sol.assignments:
        assert a.n_gpus <= 12


def test_lns_determinism_same_seed_same_plan():
    """Same seed + an iteration cap that binds before the wall clock
    => bit-identical plans (the wall deadline is only checked between
    iterations, so it can't truncate differently across runs)."""
    jobs, cm, budgets = workload(10, 42, deadlines=True, tenants=2)
    kw = dict(deadline_s=60.0, max_iters=60, seed=7,
              objective="weighted_completion")
    a = lns_solve(jobs, cm, budgets, **kw)
    b = lns_solve(jobs, cm, budgets, **kw)
    pa = sorted((x.job, x.technique, x.n_gpus, x.device_class,
                 round(x.start_s, 9)) for x in a.assignments)
    pb = sorted((x.job, x.technique, x.n_gpus, x.device_class,
                 round(x.start_s, 9)) for x in b.assignments)
    assert pa == pb
    assert a.telemetry["iters"] == b.telemetry["iters"]


def test_lns_different_seeds_explore_differently():
    jobs, cm, budgets = workload(12, 5)
    a = lns_solve(jobs, cm, budgets, deadline_s=60.0, max_iters=40,
                  seed=0)
    b = lns_solve(jobs, cm, budgets, deadline_s=60.0, max_iters=40,
                  seed=1)
    # both valid; they need not match (and essentially never do)
    assert validate_capacity(a.assignments, budgets)
    assert validate_capacity(b.assignments, budgets)


def test_lns_incumbent_adopted_when_better():
    """A warm incumbent (the previous plan on a replan) seeds the
    search: the result is never worse than the incumbent's value."""
    jobs, cm, budgets = workload(8, 3)
    good = lns_solve(jobs, cm, budgets, deadline_s=1.0, seed=0)
    warm = lns_solve(jobs, cm, budgets, deadline_s=60.0, max_iters=5,
                     seed=1, incumbent=good.assignments)
    gv = objective_value(good.assignments, jobs, "makespan")
    wv = objective_value(warm.assignments, jobs, "makespan")
    assert wv <= gv + 1e-6


def test_lns_gap_target_early_exit():
    """With the trivial lower bound of 0 every plan has gap 1, so a
    gap_target of 1.0 exits after the seed round."""
    jobs, cm, budgets = workload(8, 11)
    sol = lns_solve(jobs, cm, budgets, deadline_s=60.0, seed=0,
                    gap_target=1.0, lower_bound=1e-9)
    assert sol.telemetry["status"] == "gap_target"


def test_lns_empty_jobs():
    sol = lns_solve([], {}, {None: 8})
    assert sol.assignments == [] and sol.makespan_s == 0.0
    assert sol.telemetry["status"] == "empty"


def test_lns_telemetry_shape():
    jobs, cm, budgets = workload(6, 9)
    sol = lns_solve(jobs, cm, budgets, deadline_s=0.2, seed=0)
    tel = sol.telemetry
    assert tel["backend"] == "lns"
    assert {"wall_s", "gap", "status", "iters", "n_jobs"} <= set(tel)
    assert tel["n_jobs"] == 6


def test_lns_infeasible_choice_raises():
    """A job whose every choice exceeds every pool's budget cannot be
    placed — that is a planning error, not a silent drop."""
    j = mk_job("big")
    cm = {"big": [Choice("fsdp", 64, 10.0)]}
    with pytest.raises(RuntimeError):
        lns_solve([j], cm, {None: 8}, deadline_s=0.1)


# ----------------------------------------- vectorized objective batches

@settings(max_examples=10)
@given(seed=st.integers(0, 10_000),
       objective=st.sampled_from(OBJECTIVES))
def test_objective_values_batch_matches_scalar(seed, objective):
    """The vectorized per-plan scorer (what makes an LNS round cheap)
    agrees with the reference ``objective_value`` on single plans."""
    jobs, cm, budgets = workload(9, seed, deadlines=True, tenants=3)
    sol = greedy_schedule(jobs, cm, budgets, objective=objective)
    ref = objective_value(sol.assignments, jobs, objective)
    by = {a.job: a.end_s for a in sol.assignments}
    ends = np.array([by[j.name] for j in jobs])
    got = objective_values_batch(ends, jobs, objective)
    assert got == pytest.approx(ref, rel=1e-9, abs=1e-9)
    # and the (B, n) form scores B plans at once
    batch = np.stack([ends, ends * 2.0])
    arrays = objective_arrays(jobs)
    vals = objective_values_batch(batch, jobs, objective, arrays=arrays)
    assert vals.shape == (2,)
    assert vals[0] == pytest.approx(ref, rel=1e-9, abs=1e-9)


def test_objective_values_batch_unknown_objective():
    with pytest.raises(ValueError):
        objective_values_batch(np.zeros(3), [], "latency")


def test_validate_capacity_catches_violation():
    from repro.core.solver import Assignment
    bad = [Assignment("a", "fsdp", 8, 0.0, 10.0),
           Assignment("b", "fsdp", 8, 5.0, 10.0)]
    assert not validate_capacity(bad, {None: 8})
    assert validate_capacity(bad, {None: 16})
