"""Deterministic synthetic data pipeline.

Generates seeded token streams (a stationary bigram process so the loss
is learnable, not pure noise) and frontend embeddings for audio/VLM
archs.  Batches are yielded per-host and can be sharded onto a mesh via
``shard_batch``.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig


class SyntheticLM:
    """Seeded bigram-ish token source: next token depends on previous via
    a fixed random permutation + noise, giving a learnable structure."""

    def __init__(self, cfg: ModelConfig, seed: int = 0, noise: float = 0.3):
        self.cfg = cfg
        self.seed = seed
        self.noise = noise
        rng = np.random.RandomState(seed)
        v = cfg.vocab_size
        self._perm = rng.permutation(v)

    def _raw_batch(self, rng: np.random.RandomState, batch: int,
                   seq: int) -> dict:
        """One batch as host numpy arrays.  ALL rng draws happen here,
        in a fixed order, so fast-forwarding the stream (``skip``) lands
        on exactly the batch an uninterrupted consumer would see."""
        cfg = self.cfg
        toks = np.empty((batch, seq + 1), np.int64)
        toks[:, 0] = rng.randint(0, cfg.vocab_size, batch)
        for t in range(1, seq + 1):
            nxt = self._perm[toks[:, t - 1]]
            flip = rng.rand(batch) < self.noise
            nxt = np.where(flip, rng.randint(0, cfg.vocab_size, batch), nxt)
            toks[:, t] = nxt
        out = {}
        if cfg.frontend == "audio":
            out["embeds"] = rng.randn(batch, seq, cfg.d_model) * 0.02
            out["labels"] = toks[:, 1:]
        elif cfg.frontend == "vision":
            p = min(cfg.num_patch_tokens, max(seq - 2, 1))
            out["embeds"] = rng.randn(batch, p, cfg.d_model) * 0.02
            out["tokens"] = toks[:, : seq - p]
        else:
            out["tokens"] = toks[:, :seq]
        return out

    def batches(self, batch: int, seq: int, *, dtype=jnp.float32,
                num_batches: Optional[int] = None,
                skip: int = 0) -> Iterator[dict]:
        """Yield device batches.  ``skip`` fast-forwards the stream past
        that many batches first (checkpoint resume: a run continued from
        step k must see batch k next, not batch 0 again)."""
        rng = np.random.RandomState(self.seed + 1)
        for _ in range(max(0, int(skip))):
            self._raw_batch(rng, batch, seq)
        i = 0
        while num_batches is None or i < num_batches:
            raw = self._raw_batch(rng, batch, seq)
            out = {k: jnp.asarray(v, dtype if v.dtype.kind == "f"
                                  else jnp.int32)
                   for k, v in raw.items()}
            yield out
            i += 1


def shard_batch(batch: dict, mesh, batch_axes=("data",)):
    """Place a host-local batch onto the mesh, sharded along batch dim."""
    from jax.sharding import NamedSharding, PartitionSpec

    def put(x):
        spec = PartitionSpec(batch_axes) if x.ndim >= 1 else PartitionSpec()
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, batch)
