"""CI bench regression gate: compare a freshly generated BENCH_*.json
against the committed baseline and fail on makespan regressions.

    python benchmarks/check_regression.py \\
        --baseline /tmp/BENCH_schedule.base.json \\
        --fresh BENCH_schedule.json [--tolerance 0.10]

Only *makespan-like* metrics are gated (lower is better); wall-clock
fields are machine-dependent and ignored.  Relative metrics present in
the fresh file but absent from the baseline are skipped (adding new
scenarios never breaks the gate), but a baseline metric MISSING from
the fresh run fails — silently dropping a scenario is a coverage
regression.  Absolute-limit metrics (ABSOLUTE_MAX / ABSOLUTE_MIN) are
checked on EVERY fresh path, baseline-present or not: a fixed ceiling
taken from a bench's acceptance criteria must not be evadable by being
new.

Two further gate shapes exist for metrics where a relative band around
the baseline is the wrong yardstick: ABSOLUTE_MAX pins a fixed ceiling
(error medians near zero, signed percentage deltas) and ABSOLUTE_MIN a
fixed floor (higher-is-better reductions) — both taken straight from
the bench's own acceptance criteria, so the gate can never drift with a
lucky baseline.
"""
from __future__ import annotations

import argparse
import json
import sys

# lower-is-better metrics worth gating across machines
GATED_METRICS = (
    "saturn_s",
    "current_practice_s",
    "makespan_exhaustive_s",
    "makespan_interpolated_s",
    "interp_err_median",
    "makespan_aware_s",
    "makespan_blind_s",
    # BENCH_solver.json (scheduling core): makespan quality of the fast
    # paths plus the wall-time ratios
    "makespan_dense_s",
    "makespan_refined_s",
    "makespan_replan_incremental_s",
    "wall_refined_over_dense",
    "wall_incremental_over_scratch",
    # ISSUE 10: the solver-portfolio (MILP vs interval-time LNS race)
    # makespans at every tier, including the 128/256-job tiers the
    # dense MILP cannot touch
    "makespan_portfolio_s",
    # BENCH_e2e.json (unified execution backends): how faithful the
    # sim-predicted makespan is to the actually-executed one
    "makespan_executed_over_predicted",
    # BENCH_profile.json (roofline strategy): the roofline-planned
    # makespan replayed against ground truth
    "makespan_roofline_s",
    # BENCH_serve.json (mixed train+serve cluster): the sweep makespan
    # under each fleet policy
    "makespan_saturn_serve_s",
    "makespan_static_partition_s",
)

# fixed-ceiling gates (ISSUE 6 acceptance criteria): fresh > limit fails
ABSOLUTE_MAX = {
    "roofline_err_median": 0.15,
    "makespan_roofline_delta_pct": 10.0,
    # BENCH_recover.json (fault-tolerant process backend): recovery
    # from injected faults must reproduce the uninterrupted loss
    # trajectory (exact replay — any real divergence is orders of
    # magnitude above this ceiling) at bounded makespan overhead
    "recover_traj_err": 1e-6,
    "recover_overhead_x": 4.0,
    # BENCH_solver.json (ISSUE 10 headline): the 64-job portfolio race
    # runs on a fifth of the dense MILP's wall budget, so its wall over
    # the CAPPED dense wall (a machine-independent constant) must stay
    # well under one — 0.5 leaves 2x headroom over the bench's own
    # tl/5 budget for thread/fork overhead on slow runners
    "portfolio_wall_over_dense": 0.5,
}

# fixed-floor gates (higher is better): fresh < limit fails
ABSOLUTE_MIN = {
    "roofline_trial_reduction_x": 20.0,
    # BENCH_serve.json acceptance criteria: serving never misses its
    # SLO, and adaptive sharing beats the static partition by a margin
    "serve_attainment": 0.99,
    "static_over_saturn_x": 1.2,
    # BENCH_recover.json: every injected-fault scenario completes
    # un-quarantined, and the zero-budget scenario records its
    # quarantine instead of deadlocking
    "recover_completes": 1.0,
    "quarantine_recorded": 1.0,
    # BENCH_solver.json (ISSUE 10 headline): the 256-job tier — beyond
    # the dense MILP's reach — must produce a feasible,
    # conservation-clean plan inside its fixed 40 s budget, every run
    "portfolio_completes_256": 1.0,
}

# per-metric tolerance overrides (take precedence over --tolerance):
# wall ratios move with runner speed (a time-capped dense wall is a
# constant while the refined wall scales), and the dense/scratch
# makespans at the big tiers are time-limit INCUMBENTS, so both get
# wide bands; the refined/incremental makespans come from gap-closed
# solves and stay on the default tolerance
TOLERANCE_OVERRIDES = {
    "wall_refined_over_dense": 1.5,
    # the incremental numerator is sub-second at the smaller capped
    # tier, so runner-speed scaling needs more headroom; a broken warm
    # start drives the ratio toward 1.0 and still fails by an order of
    # magnitude
    "wall_incremental_over_scratch": 3.0,
    "makespan_dense_s": 0.5,
    # portfolio makespans are ANYTIME incumbents under a wall budget:
    # a slower runner gets fewer LNS iterations / MILP nodes, so the
    # value breathes with machine speed (a real quality regression —
    # e.g. losing the warm seed — blows past 50% immediately)
    "makespan_portfolio_s": 0.5,
    # sim-vs-real fidelity mixes JIT compile costs and CPU contention
    # into real wall clock, both of which swing with runner speed and
    # core count; the bench itself hard-fails outside [0.1, 8]
    "makespan_executed_over_predicted": 2.0,
}


def collect(obj, prefix=""):
    """Flatten nested dicts to {dotted.path: (metric, value)} for gated
    metrics (the metric name keeps per-metric tolerances applicable)."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            path = f"{prefix}.{k}" if prefix else str(k)
            if isinstance(v, dict):
                out.update(collect(v, path))
            elif isinstance(v, (int, float)) and (
                    k in GATED_METRICS or k in ABSOLUTE_MAX
                    or k in ABSOLUTE_MIN):
                out[path] = (k, float(v))
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed relative regression (default 10%)")
    args = ap.parse_args()

    with open(args.baseline) as f:
        base = collect(json.load(f))
    with open(args.fresh) as f:
        fresh = collect(json.load(f))

    if not base:
        print(f"no gated metrics in baseline {args.baseline}; skipping")
        return 0

    failures = []
    for path, (metric, b) in sorted(base.items()):
        if path not in fresh:
            print(f"FAIL {path}: missing from fresh run "
                  f"(scenario dropped?)")
            failures.append(path)
            continue
        _, fv = fresh[path]
        if metric in ABSOLUTE_MAX:
            limit = ABSOLUTE_MAX[metric]
            bad = fv > limit
            print(f"{'FAIL' if bad else 'ok':4s} {path}: fresh={fv:.4g} "
                  f"(absolute ceiling {limit:.4g})")
        elif metric in ABSOLUTE_MIN:
            limit = ABSOLUTE_MIN[metric]
            bad = fv < limit
            print(f"{'FAIL' if bad else 'ok':4s} {path}: fresh={fv:.4g} "
                  f"(absolute floor {limit:.4g})")
        else:
            tol = TOLERANCE_OVERRIDES.get(metric, args.tolerance)
            limit = b * (1.0 + tol)
            bad = fv > limit
            print(f"{'FAIL' if bad else 'ok':4s} {path}: baseline={b:.4g} "
                  f"fresh={fv:.4g} (limit {limit:.4g}, tol {tol:.0%})")
        if bad:
            failures.append(path)

    # absolute gates are acceptance criteria, not baseline comparisons:
    # apply them to fresh-only paths too (a new scenario must not dodge
    # its fixed ceiling/floor just because the baseline predates it)
    for path, (metric, fv) in sorted(fresh.items()):
        if path in base:
            continue
        if metric in ABSOLUTE_MAX:
            limit, bad = ABSOLUTE_MAX[metric], fv > ABSOLUTE_MAX[metric]
            print(f"{'FAIL' if bad else 'ok':4s} {path}: fresh={fv:.4g} "
                  f"(absolute ceiling {limit:.4g}, no baseline)")
        elif metric in ABSOLUTE_MIN:
            limit, bad = ABSOLUTE_MIN[metric], fv < ABSOLUTE_MIN[metric]
            print(f"{'FAIL' if bad else 'ok':4s} {path}: fresh={fv:.4g} "
                  f"(absolute floor {limit:.4g}, no baseline)")
        else:
            continue
        if bad:
            failures.append(path)

    if failures:
        print(f"\n{len(failures)} metric(s) regressed beyond tolerance: "
              f"{', '.join(failures)}")
        return 1
    print("\nno regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
