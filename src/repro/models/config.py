"""Model configuration for the composable decoder-transformer family.

One ``ModelConfig`` drives every assigned architecture: dense GQA
attention (full / sliding-window / local:global), RG-LRU hybrid blocks,
xLSTM (mLSTM/sLSTM) blocks, and MoE FFNs.  Layers are described by a
repeating ``block_pattern``; the transformer executes the pattern as a
``lax.scan`` over repeats plus an unrolled remainder, which keeps the
HLO small enough to compile 94-layer models on a 512-device mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Block-type vocabulary ("mixer" part of a block).
ATTN = "attn"      # full causal attention
SWA = "swa"        # sliding-window causal attention (cfg.window_size)
RGLRU = "rglru"    # RG-LRU recurrent block (Griffin/RecurrentGemma)
MLSTM = "mlstm"    # xLSTM matrix-memory block
SLSTM = "slstm"    # xLSTM scalar-memory block

MIXERS = (ATTN, SWA, RGLRU, MLSTM, SLSTM)

# Block types that can decode with O(<<seq) state (no full-seq KV cache)
RECURRENT = (RGLRU, MLSTM, SLSTM)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss weight


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str               # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                    # dense FFN hidden size (0 = no FFN, e.g. xLSTM)
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    block_pattern: Tuple[str, ...] = (ATTN,)
    window_size: int = 0         # for SWA blocks
    moe: Optional[MoEConfig] = None
    frontend: Optional[str] = None   # None | "audio" | "vision"
    num_patch_tokens: int = 256      # VLM: patch-embedding prefix length
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    d_rnn: int = 0               # RG-LRU recurrence width (0 -> d_model)
    conv_width: int = 4          # temporal conv width in recurrent blocks
    long_context: bool = False   # eligible for the long_500k decode shape
    source: str = ""             # citation for the config

    def __post_init__(self):
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError("num_heads must be divisible by num_kv_heads")
        for b in self.block_pattern:
            if b not in MIXERS:
                raise ValueError(f"unknown block type {b!r}")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def resolved_d_rnn(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    def layer_types(self) -> Tuple[str, ...]:
        """Expand block_pattern over num_layers."""
        p = self.block_pattern
        reps = (self.num_layers + len(p) - 1) // len(p)
        return (p * reps)[: self.num_layers]

    def layer_plan(self):
        """[(kind, pattern, n)] — 'scan' over full pattern repeats plus an
        unrolled remainder.  A pattern of length L repeated n times is
        executed as one lax.scan with per-position stacked params."""
        p = self.block_pattern
        n_full = self.num_layers // len(p)
        rem = self.num_layers % len(p)
        plan = []
        if n_full > 0:
            plan.append(("scan", p, n_full))
        if rem:
            plan.append(("unroll", p[:rem], 1))
        return plan

    def supports_long_context(self) -> bool:
        """True if decode state is sub-linear in history for every layer
        (recurrent) or bounded-window — i.e. no layer needs an unbounded
        full-attention KV cache *except* ones we explicitly shard."""
        return all(t in RECURRENT or t == SWA for t in self.block_pattern)

    def has_global_attention(self) -> bool:
        return any(t == ATTN for t in self.block_pattern)

    def reduced(self, *, num_layers: int = 2, max_d_model: int = 256,
                max_experts: int = 4, max_vocab: int = 512) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        d = min(self.d_model, max_d_model)
        heads = min(self.num_heads, 4)
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, num_experts=min(self.moe.num_experts, max_experts),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, 2 * d))
        pat = self.block_pattern
        if num_layers < len(pat):
            num_layers = len(pat)  # keep at least one full pattern
        return dataclasses.replace(
            self, name=self.name + "-smoke", num_layers=num_layers,
            d_model=d, num_heads=heads, num_kv_heads=kv,
            d_ff=min(self.d_ff, 2 * d) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, max_vocab),
            head_dim=d // heads, moe=moe,
            window_size=min(self.window_size, 32) if self.window_size else 0,
            d_rnn=min(self.resolved_d_rnn, d) if self.d_rnn else 0,
            num_patch_tokens=min(self.num_patch_tokens, 8),
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
