"""InternVL2-1B: InternViT vision encoder (stub) + InternLM2 backbone
[arXiv:2404.16821].  ``input_specs`` supplies projector-output patch
embeddings; the language backbone is fully implemented."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", arch_type="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655, head_dim=64,
    block_pattern=("attn",), frontend="vision", num_patch_tokens=256,
    rope_theta=1000000.0, tie_embeddings=True,
    source="InternViT + InternLM2 [arXiv:2404.16821]",
)
