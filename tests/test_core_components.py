"""Parallelism Library registry, Trial Runner, checkpoint store, data
pipeline, MoE routing properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.job import Job
from repro.core.library import ParallelismLibrary
from repro.core.profiler import HARDWARE, TrialRunner, collective_bytes_from_hlo
from repro.parallelism.base import Plan, Technique


# ------------------------------------------------------ Parallelism Library

class _Custom(Technique):
    name = "my-custom"

    def search_space(self, cfg, n):
        return n == 2

    def plan(self, cfg, n):
        return Plan(self.name, n, (("data", n),), {"batch": "data"})


def test_library_register_and_candidates():
    lib = ParallelismLibrary()
    assert set(lib.names()) >= {"ddp", "fsdp", "tp", "gpipe", "remat-offload"}
    lib.register(_Custom())
    cfg = get_config("xlstm-125m").reduced()
    cands = lib.candidates(cfg, [1, 2, 4])
    assert ("my-custom", 2) in cands
    assert ("my-custom", 4) not in cands
    assert ("ddp", 1) in cands


def test_library_rejects_wrong_interface():
    lib = ParallelismLibrary()
    with pytest.raises(TypeError):
        lib.register(object())


def test_library_persistence(tmp_path):
    lib = ParallelismLibrary()
    p = str(tmp_path / "lib.json")
    lib.save(p)
    lib2 = ParallelismLibrary.load(p)
    assert set(lib2.names()) == set(lib.names())


# ------------------------------------------------------------ Trial Runner

def test_profiler_napkin_monotonic_and_cached(tmp_path):
    lib = ParallelismLibrary()
    runner = TrialRunner(lib, HARDWARE["a100"],
                         cache_path=str(tmp_path / "cache.json"))
    job = Job("t", get_config("stablelm-12b"), 16, 1024, 100)
    p1 = runner.profile(job, "fsdp", 2)
    p8 = runner.profile(job, "fsdp", 8)
    assert p8.step_time_s < p1.step_time_s, "more GPUs must model faster"
    assert p8.mem_per_device < p1.mem_per_device
    # cache: second runner reads the same numbers from disk (flushes
    # are batched now, so persist explicitly)
    runner.flush()
    runner2 = TrialRunner(lib, HARDWARE["a100"],
                          cache_path=str(tmp_path / "cache.json"))
    assert runner2.profile(job, "fsdp", 8).step_time_s == p8.step_time_s


def test_profiler_empirical_single_device():
    lib = ParallelismLibrary()
    runner = TrialRunner(lib, HARDWARE["a100"])
    job = Job("e", get_config("xlstm-125m").reduced(), 2, 32, 10)
    prof = runner.profile(job, "ddp", 1, mode="empirical")
    assert prof.source == "empirical"
    assert prof.step_time_s > 0
    assert prof.feasible


def test_infeasible_technique_marked():
    lib = ParallelismLibrary()
    runner = TrialRunner(lib, HARDWARE["a100"])
    job = Job("i", get_config("xlstm-125m").reduced(), 2, 32, 10)
    prof = runner.profile(job, "tp", 7)  # 4 heads % 7 != 0
    assert not prof.feasible


def test_collective_regex_parser():
    hlo = """
  %ag = bf16[8,128] all-gather(%x), dimensions={0}
  %ar = f32[1024] all-reduce(%y), to_apply=%add
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 1024 * 4
    assert out["total"] == out["all-gather"] + out["all-reduce"]


# --------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.store import (load_checkpoint, load_metadata,
                                        save_checkpoint)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": [jnp.ones(4), {"c": jnp.zeros((2, 2), jnp.bfloat16)}]}
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, tree, {"step": 7})
    back = load_checkpoint(path, tree)
    for x, y in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
        assert x.dtype == y.dtype
    assert load_metadata(path)["step"] == 7


# ------------------------------------------------------------------- data

def test_data_deterministic():
    from repro.data.synthetic import SyntheticLM
    cfg = get_config("gemma3-4b").reduced()
    a = list(SyntheticLM(cfg, seed=3).batches(2, 16, num_batches=2))
    b = list(SyntheticLM(cfg, seed=3).batches(2, 16, num_batches=2))
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    c = next(SyntheticLM(cfg, seed=4).batches(2, 16, num_batches=1))
    assert not np.array_equal(np.asarray(a[0]["tokens"]),
                              np.asarray(c["tokens"]))


def test_data_learnable_structure():
    """Bigram structure: next token equals perm[prev] most of the time."""
    from repro.data.synthetic import SyntheticLM
    cfg = get_config("h2o-danube-3-4b").reduced()
    src = SyntheticLM(cfg, seed=0, noise=0.2)
    b = next(src.batches(4, 128, num_batches=1))
    toks = np.asarray(b["tokens"])
    match = np.mean(src._perm[toks[:, :-1]] == toks[:, 1:])
    assert match > 0.6


# -------------------------------------------------------------------- MoE

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50))
def test_moe_router_weights_and_dropping(seed):
    """Combine weights are convex per token; dropped tokens only reduce
    output norm, never corrupt other tokens."""
    from repro.models.moe import _route_row, moe_capacity
    cfg = get_config("olmoe-1b-7b").reduced()
    m = cfg.moe
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    xrow = jax.random.normal(k1, (32, d))
    p = {"router": jax.random.normal(k2, (d, m.num_experts)) * 0.1}
    cap = moe_capacity(cfg, 32)
    xg, tok, w, aux = _route_row(p, xrow, cfg, cap)
    w = np.asarray(w)
    assert (w >= 0).all() and w.max() <= 1.0 + 1e-6
    # every token's total routed weight <= 1 (== 1 unless dropped)
    tok = np.asarray(tok)
    sums = np.zeros(32)
    np.add.at(sums, tok.reshape(-1), w.reshape(-1))
    assert (sums <= 1.0 + 1e-5).all()
    assert float(aux) > 0


def test_moe_forward_matches_dense_when_one_expert():
    """With num_experts=1, top_k=1, MoE must equal a plain FFN."""
    import dataclasses
    from repro.models.moe import moe_ffn, moe_spec
    from repro.models.params import init_params
    cfg0 = get_config("olmoe-1b-7b").reduced()
    cfg = dataclasses.replace(
        cfg0, moe=dataclasses.replace(cfg0.moe, num_experts=1, top_k=1,
                                      capacity_factor=2.0))
    p = init_params(moe_spec(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    out, aux = moe_ffn(p, x, cfg)
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wi_gate"][0]))
    u = jnp.einsum("bsd,df->bsf", x, p["wi_up"][0])
    dense = jnp.einsum("bsf,fd->bsd", g * u, p["wo"][0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               atol=1e-5, rtol=1e-5)
