"""Turn a (model config, Plan) pair into an executable: mesh, shardings,
and a jitted train step.  Used by the Trial Runner (profiling), the local
executor (real runs) and reused by the launch path.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from ..models.config import ModelConfig
from ..models.transformer import model_spec
from ..optim.adamw import AdamWConfig
from ..train.steps import make_train_step
from .base import Plan
from .context import axis_rules
from .pipeline import make_pipeline_loss
from .shardings import (make_mesh_from_plan,
                        opt_state_shardings, param_shardings)


class BuiltJob:
    """Executable artifact for one (model, technique, n_devices) choice."""

    def __init__(self, cfg: ModelConfig, plan: Plan, opt_cfg: AdamWConfig,
                 devices=None):
        self.cfg, self.plan, self.opt_cfg = cfg, plan, opt_cfg
        self.mesh = make_mesh_from_plan(plan, devices)
        self.spec_tree = model_spec(cfg)
        self.p_sh = param_shardings(self.spec_tree, plan, self.mesh)
        self.o_sh = opt_state_shardings(self.spec_tree, plan, self.mesh)
        self._step = None

    # ------------------------------------------------------------- build
    def _make_step(self):
        cfg, plan, mesh = self.cfg, self.plan, self.mesh
        if plan.technique == "gpipe":
            loss_fn = make_pipeline_loss(cfg, plan, mesh)
            base = make_train_step(cfg, self.opt_cfg, loss_fn=loss_fn)
        else:
            base = make_train_step(cfg, self.opt_cfg, remat=plan.remat)

        def step(params, opt_state, batch):
            with axis_rules(plan.rules, mesh):
                return base(params, opt_state, batch)

        metric_sh = NamedSharding(self.mesh, PartitionSpec())
        return jax.jit(
            step,
            in_shardings=(self.p_sh, self.o_sh, self._batch_sh_tree()),
            out_shardings=(self.p_sh, self.o_sh, None),
        )

    def _batch_axis(self):
        return self.plan.rules.get("batch")

    def _batch_sh_tree(self):
        ax = self._batch_axis()
        if ax is None:
            return NamedSharding(self.mesh, PartitionSpec())
        return NamedSharding(self.mesh, PartitionSpec(ax))

    @property
    def step(self):
        if self._step is None:
            self._step = self._make_step()
        return self._step

    # ----------------------------------------------------------- helpers
    def init(self, key, dtype=jnp.float32):
        """Initialize params + opt state with the plan's shardings."""
        from ..models.params import init_params
        from ..optim.adamw import init_opt_state
        with self.mesh:
            params = jax.jit(
                lambda k: init_params(self.spec_tree, k, dtype),
                out_shardings=self.p_sh)(key)
            opt = jax.jit(init_opt_state, out_shardings=self.o_sh)(params)
        return params, opt

    def place_batch(self, batch):
        sh = self._batch_sh_tree()
        return jax.tree.map(lambda x: jax.device_put(x, sh), batch)

    def lower(self, batch_specs, params_abstract, opt_abstract):
        """Lower + compile without execution (profiling / dry-run)."""
        return self.step.lower(params_abstract, opt_abstract, batch_specs)
