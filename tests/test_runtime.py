"""Event-driven cluster runtime: node-aware placement, online arrivals,
restart GPU-second conservation, and legacy-wrapper equivalence."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.baselines import (CurrentPractice, Optimus, OptimusDynamic,
                                  RandomPolicy, SaturnPolicy)
from repro.core.executor import simulate, simulate_legacy
from repro.core.job import ClusterSpec, Job
from repro.core.placement import FlatPool, NodeAware, PlacementError
from repro.core.profiler import Profile
from repro.core.schedule import Placement, Schedule, ScheduleEntry
from repro.core.solver import solve_joint_nodes

CFG = get_config("xlstm-125m").reduced()


def mk_workload(n_jobs=6, seed=0, total_gpus=8, extra_counts=()):
    rng = np.random.RandomState(seed)
    jobs, profiles = [], {}
    for i in range(n_jobs):
        j = Job(f"j{i}", CFG, 8, 64, total_steps=int(rng.randint(100, 400)))
        jobs.append(j)
        base = rng.uniform(1.0, 4.0)
        eff = rng.uniform(0.5, 0.95)
        counts = []
        g = 1
        while g <= total_gpus:
            counts.append(g)
            g *= 2
        counts += [c for c in extra_counts if c not in counts]
        for g in counts:
            for tech, mult in (("ddp", 1.0), ("fsdp", 1.1), ("gpipe", 1.25)):
                profiles[(j.name, tech, g)] = Profile(
                    j.name, tech, g, base * mult / g ** eff, 1e9, True, "t")
    return jobs, profiles


CLUSTER = ClusterSpec(nodes=1, gpus_per_node=8, restart_cost_s=10.0)
CLUSTER2 = ClusterSpec(nodes=2, gpus_per_node=8, restart_cost_s=10.0)


# ------------------------------------------------------ placement backends

def test_flat_pool_allocate_release():
    b = FlatPool(8)
    p1 = b.allocate(5)
    p2 = b.allocate(3)
    assert p1.n_gpus == 5 and p2.n_gpus == 3
    assert not set(p1.devices) & set(p2.devices)
    assert b.allocate(1) is None
    b.release(p1)
    assert b.free_gpus == 5


def test_node_aware_rejects_split_single_node_config():
    """A 5-GPU job must live inside ONE node: two can run on two nodes,
    a third cannot squeeze into the 2x3 leftover GPUs."""
    b = NodeAware(nodes=2, gpus_per_node=8)
    p1 = b.allocate(5)
    p2 = b.allocate(5)
    assert p1 is not None and p2 is not None
    assert len(p1.nodes(8)) == 1 and len(p2.nodes(8)) == 1
    assert p1.nodes(8) != p2.nodes(8)
    assert b.free_gpus == 6          # 3 free on each node
    assert b.allocate(5) is None     # flat pool would have said yes
    assert FlatPool(16).feasible(5) and NodeAware(2, 8).feasible(5)
    assert not NodeAware(2, 8).feasible(12)   # not a whole-node multiple


def test_node_aware_whole_node_multiples():
    b = NodeAware(nodes=2, gpus_per_node=8)
    p16 = b.allocate(16)
    assert p16 is not None and p16.nodes(8) == (0, 1)
    b.release(p16)
    p_small = b.allocate(1)
    assert b.allocate(16) is None    # node 0 no longer fully free
    assert b.allocate(8) is not None  # node 1 still whole
    b.release(p_small)


def test_node_aware_honors_preferred_nodes():
    b = NodeAware(nodes=2, gpus_per_node=8)
    p = b.allocate(4, preferred_nodes=[1])
    assert p.nodes(8) == (1,)


# --------------------------------------------------- node-aware runtime

def test_runtime_node_aware_never_overpacks_node():
    """Three 5-GPU-only jobs on 2x8 nodes: flat runs all three at once
    (15<=16); node-aware placement never co-schedules two jobs whose
    combined GPUs exceed a node's capacity on that node."""
    jobs = [Job(f"n{i}", CFG, 8, 64, 100) for i in range(3)]
    profiles = {(j.name, "fsdp", 5): Profile(j.name, "fsdp", 5, 1.0, 1e9,
                                             True, "t") for j in jobs}
    flat = simulate(jobs, CurrentPractice(), profiles, CLUSTER2,
                    noise_sigma=0.0)
    node = simulate(jobs, CurrentPractice(), profiles, CLUSTER2,
                    noise_sigma=0.0, placement="node")
    assert flat.makespan_s < 1.5 * 100      # all three concurrent
    assert node.makespan_s >= 1.9 * 100     # two waves
    gpn = CLUSTER2.gpus_per_node
    runs = [g for g in node.gantt if g.kind == "run"]
    events = sorted({g.start_s for g in runs})
    for t in events:
        live = [g for g in runs if g.start_s <= t < g.end_s - 1e-9]
        for nu in range(CLUSTER2.nodes):
            used = sum(len([d for d in g.devices if d // gpn == nu])
                       for g in live)
            assert used <= gpn, f"node {nu} overpacked at t={t}"
        # and every single-node config sits inside one node
        for g in live:
            assert len({d // gpn for d in g.devices}) == 1


def test_runtime_honors_node_milp_plan():
    """Saturn on a node-aware cluster runs the node MILP and the runtime
    places its node hints."""
    cluster = ClusterSpec(nodes=2, gpus_per_node=8, restart_cost_s=10.0,
                          placement="node")
    jobs, profiles = mk_workload(n_jobs=4, seed=2, total_gpus=8,
                                 extra_counts=(16,))
    res = simulate(jobs, SaturnPolicy(time_limit_s=5), profiles, cluster,
                   noise_sigma=0.0)
    assert {g.job for g in res.gantt if g.kind == "run"} == \
        {j.name for j in jobs}
    for g in res.gantt:
        if g.kind != "run":
            continue
        touched = {d // 8 for d in g.devices}
        if g.n_gpus <= 8:
            assert len(touched) == 1
        else:
            assert g.n_gpus % 8 == 0 and len(touched) == g.n_gpus // 8


def test_node_milp_schedule_carries_node_hints():
    jobs = [Job("big", CFG, 8, 64, 100), Job("small", CFG, 8, 64, 100)]
    p = {("big", "fsdp", 16): Profile("big", "fsdp", 16, 1.0, 1e9, True, "t"),
         ("small", "ddp", 4): Profile("small", "ddp", 4, 1.0, 1e9, True, "t")}
    sol = solve_joint_nodes(jobs, p, nodes=2, gpus_per_node=8, n_slots=10)
    sched = sol.to_schedule()
    assert sched.solver == "milp-nodes"
    big = sched.entry_for("big")
    small = sched.entry_for("small")
    assert big.nodes == (0, 1)
    assert small.nodes is not None and len(small.nodes) == 1


def test_infeasible_node_config_raises():
    jobs = [Job("odd", CFG, 8, 64, 100)]
    profiles = {("odd", "tp", 12): Profile("odd", "tp", 12, 1.0, 1e9,
                                           True, "t")}
    with pytest.raises(PlacementError):
        simulate(jobs, CurrentPracticeLike12(), profiles, CLUSTER2,
                 placement="node")


class CurrentPracticeLike12(CurrentPractice):
    def plan(self, jobs, remaining, profiles, cluster, current):
        return Schedule([ScheduleEntry(j.name, "tp", 12) for j in jobs])


# ------------------------------------------------------- online arrivals

def test_jobs_never_start_before_arrival():
    jobs, profiles = mk_workload(n_jobs=4, seed=1)
    arrivals = {"j0": 0.0, "j1": 50.0, "j2": 120.0, "j3": 400.0}
    import dataclasses
    jobs = [dataclasses.replace(j, arrival_s=arrivals[j.name]) for j in jobs]
    res = simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                   noise_sigma=0.0)
    first_start = {}
    for g in res.gantt:
        if g.kind == "run":
            first_start.setdefault(g.job, g.start_s)
            first_start[g.job] = min(first_start[g.job], g.start_s)
    for name, arr in arrivals.items():
        assert first_start[name] >= arr - 1e-9, name
    assert set(first_start) == set(arrivals)


def test_online_arrivals_trigger_replans():
    jobs, profiles = mk_workload(n_jobs=5, seed=4)
    import dataclasses
    jobs = [dataclasses.replace(j, arrival_s=60.0 * i)
            for i, j in enumerate(jobs)]
    offline = simulate([dataclasses.replace(j, arrival_s=0.0) for j in jobs],
                       OptimusDynamic(), profiles, CLUSTER, noise_sigma=0.0)
    online = simulate(jobs, OptimusDynamic(), profiles, CLUSTER,
                      noise_sigma=0.0)
    # one replan per distinct arrival instant beyond the initial batch
    assert online.replans >= offline.replans + len(jobs) - 1


def test_online_saturn_beats_current_practice():
    """Acceptance: >=3 staggered jobs, Saturn-dynamic <= current practice."""
    jobs, profiles = mk_workload(n_jobs=6, seed=7)
    import dataclasses
    jobs = [dataclasses.replace(j, arrival_s=30.0 * i)
            for i, j in enumerate(jobs)]
    cp = simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                  noise_sigma=0.0)
    sat = simulate(jobs, SaturnPolicy(time_limit_s=5), profiles, CLUSTER,
                   introspect_every_s=300, noise_sigma=0.0)
    assert sat.makespan_s <= cp.makespan_s + 1e-6


def test_arrival_replan_sees_settled_progress():
    """Preemption triggered by an arrival must charge only the REMAINING
    steps: progress made since the last settle is not thrown away."""
    class SwitchOnArrival(CurrentPractice):
        name = "switch"
        dynamic = True

        def plan(self, jobs, remaining, profiles, cluster, current):
            two = len(jobs) > 1
            return Schedule([
                ScheduleEntry(j.name, "ddp", 2 if two and j.name == "A"
                              else 1) for j in jobs])

    a = Job("A", CFG, 8, 64, total_steps=1000)
    b = Job("B", CFG, 8, 64, total_steps=100, arrival_s=500.0)
    profiles = {("A", "ddp", 1): Profile("A", "ddp", 1, 1.0, 1e9, True, "t"),
                ("A", "ddp", 2): Profile("A", "ddp", 2, 0.5, 1e9, True, "t"),
                ("B", "ddp", 1): Profile("B", "ddp", 1, 1.0, 1e9, True, "t")}
    res = simulate([a, b], SwitchOnArrival(), profiles, CLUSTER,
                   noise_sigma=0.0)
    # A: 500 steps done by t=500, preempted (restart 10s), 500 left at
    # 0.5 s/step -> done at 760.  Without the settle, A redoes all 1000
    # steps and finishes at 1010.
    assert res.restarts == 1
    assert res.makespan_s == pytest.approx(760.0, abs=1e-6)


def test_tick_chain_survives_empty_prelude():
    """Introspection ticks scheduled before any job has arrived must not
    kill the tick chain for the rest of the run."""
    jobs, profiles = mk_workload(n_jobs=4, seed=8)
    import dataclasses
    jobs = [dataclasses.replace(j, arrival_s=700.0) for j in jobs]
    res = simulate(jobs, OptimusDynamic(), profiles, CLUSTER,
                   introspect_every_s=600, noise_sigma=0.3)
    # arrival replan at t=700 plus at least one tick replan afterwards
    assert res.replans >= 2


def test_session_submit_staggered():
    from repro.core.api import SaturnSession
    sess = SaturnSession(CLUSTER)
    jobs, _ = mk_workload(n_jobs=3)
    out = sess.submit(jobs, arrival_s=[0.0, 10.0, 20.0])
    assert [j.arrival_s for j in out] == [0.0, 10.0, 20.0]
    out2 = sess.submit(jobs[:1], arrival_s=99.0)
    assert out2[0].arrival_s == 99.0
    assert len(sess.jobs) == 4
    with pytest.raises(ValueError):
        sess.submit(jobs, arrival_s=[1.0])


# ------------------------------------------------- restart accounting

def _per_device_intervals(res):
    by_dev = {}
    for g in res.gantt:
        if g.kind != "run":
            continue
        for d in g.devices:
            by_dev.setdefault(d, []).append((g.start_s, g.end_s, g.job))
    return by_dev


def test_restart_conserves_gpu_seconds():
    """No device is double-booked, and a preempted job's relaunch begins
    only after its restart penalty elapses."""
    jobs, profiles = mk_workload(n_jobs=6, seed=5)
    res = simulate(jobs, OptimusDynamic(), profiles, CLUSTER,
                   introspect_every_s=100, noise_sigma=0.4)
    assert res.restarts > 0, "workload must exercise preemption"
    for d, ivs in _per_device_intervals(res).items():
        ivs.sort()
        for (s1, e1, j1), (s2, e2, j2) in zip(ivs, ivs[1:]):
            assert e1 <= s2 + 1e-9, \
                f"device {d} double-booked: {j1}[{s1},{e1}] vs {j2}[{s2},{e2}]"
    # restart gap: a job's run segments never overlap its restart windows
    restarts = [(g.job, g.start_s, g.end_s) for g in res.gantt
                if g.kind == "restart"]
    for (name, rs, re_) in restarts:
        for g in res.gantt:
            if g.kind == "run" and g.job == name:
                assert g.end_s <= rs + 1e-9 or g.start_s >= re_ - 1e-9
    for g in res.gantt:
        if g.kind == "restart":
            assert abs((g.end_s - g.start_s) - CLUSTER.restart_cost_s) < 1e-9


def test_gantt_devices_match_counts():
    jobs, profiles = mk_workload(n_jobs=5, seed=9)
    res = simulate(jobs, Optimus(), profiles, CLUSTER)
    for g in res.gantt:
        if g.kind == "run":
            assert len(g.devices) == g.n_gpus
            assert len(set(g.devices)) == g.n_gpus


# ------------------------------------------- wrapper/legacy equivalence

@pytest.mark.parametrize("policy_fn,introspect", [
    (lambda: CurrentPractice(), None),
    (lambda: RandomPolicy(3), None),
    (lambda: Optimus(), None),
    (lambda: OptimusDynamic(), 150.0),
])
def test_wrapper_matches_fixed_legacy(policy_fn, introspect):
    """simulate() (runtime, flat pool) must reproduce the fixed legacy
    while-loop exactly on offline workloads."""
    jobs, profiles = mk_workload(n_jobs=7, seed=13)
    new = simulate(jobs, policy_fn(), profiles, CLUSTER,
                   introspect_every_s=introspect, noise_sigma=0.25)
    old = simulate_legacy(jobs, policy_fn(), profiles, CLUSTER,
                          introspect_every_s=introspect, noise_sigma=0.25)
    assert new.makespan_s == pytest.approx(old.makespan_s, rel=1e-12)
    assert new.restarts == old.restarts
    assert len([g for g in new.gantt if g.kind == "run"]) == \
        len([g for g in old.gantt if g.kind == "run"])


def test_wrapper_matches_fixed_legacy_with_restarts():
    jobs, profiles = mk_workload(n_jobs=8, seed=21)
    new = simulate(jobs, OptimusDynamic(), profiles, CLUSTER,
                   introspect_every_s=80, noise_sigma=0.4)
    old = simulate_legacy(jobs, OptimusDynamic(), profiles, CLUSTER,
                          introspect_every_s=80, noise_sigma=0.4)
    assert new.restarts == old.restarts > 0
    assert new.makespan_s == pytest.approx(old.makespan_s, rel=1e-12)


# ----------------------------------------------------------- schedule IR

def test_schedule_coerce_roundtrip():
    tuples = [("a", "ddp", 2), ("b", "fsdp", 4)]
    s = Schedule.coerce(tuples)
    assert s.to_tuples() == tuples
    assert Schedule.coerce(s) is s
    assert s.assignment_map() == {"a": ("ddp", 2), "b": ("fsdp", 4)}
    assert len(Schedule.coerce(None)) == 0


def test_legacy_tuple_policy_still_runs():
    """User policies that return raw tuples keep working end to end."""
    class TuplePolicy(CurrentPractice):
        def plan(self, jobs, remaining, profiles, cluster, current):
            sched = super().plan(jobs, remaining, profiles, cluster,
                                 current)
            return sched.to_tuples()

    jobs, profiles = mk_workload(n_jobs=3, seed=6)
    res = simulate(jobs, TuplePolicy(), profiles, CLUSTER)
    assert {g.job for g in res.gantt if g.kind == "run"} == \
        {j.name for j in jobs}


def test_placement_nodes_helper():
    p = Placement((0, 1, 2, 8, 9))
    assert p.n_gpus == 5
    assert p.nodes(8) == (0, 1)
