"""Cluster execution: an event-driven simulator (drives the paper-table
benchmark and the introspection mechanism) and a local runner that really
trains models on this machine for the end-to-end examples.

The simulator separates *estimated* step times (what policies see, from
the Trial Runner) from *true* step times (estimate × seeded noise), so
dynamic policies (introspection) win for the same reason they do on a
real cluster: plans based on estimates drift from reality, and re-solving
with observed remaining work recovers the gap — plus freed-GPU
reallocation at completion events.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .job import ClusterSpec, Job
from .profiler import Profile


@dataclasses.dataclass
class GanttEntry:
    job: str
    technique: str
    n_gpus: int
    start_s: float
    end_s: float
    kind: str = "run"          # run | restart


@dataclasses.dataclass
class SimResult:
    policy: str
    makespan_s: float
    gantt: List[GanttEntry]
    replans: int = 0
    restarts: int = 0

    def utilization(self, cluster: ClusterSpec) -> float:
        busy = sum((g.end_s - g.start_s) * g.n_gpus for g in self.gantt
                   if g.kind == "run")
        return busy / (self.makespan_s * cluster.total_gpus + 1e-9)


class Policy:
    """Interface: produce an ordered list of (job_name, technique, g).

    The simulator starts jobs in list order whenever GPUs free up
    (list scheduling).  ``replan`` is invoked at introspection intervals
    and at completion events if ``dynamic``."""

    name = "policy"
    dynamic = False           # replan at introspection intervals?
    replan_on_completion = True   # also replan when a job finishes?

    def plan(self, jobs: List[Job], remaining_steps: Dict[str, int],
             profiles, cluster: ClusterSpec,
             current: Dict[str, Tuple[str, int]]) -> List[Tuple[str, str, int]]:
        raise NotImplementedError


@dataclasses.dataclass
class _Running:
    job: Job
    technique: str
    n_gpus: int
    start_s: float
    true_step_s: float
    steps_at_start: int


def _noise_factors(jobs, profiles, seed: int, sigma: float):
    rng = np.random.RandomState(seed)
    out = {}
    for key in profiles:
        out[key] = float(np.exp(rng.randn() * sigma))
    return out


def simulate(jobs: List[Job], policy: Policy,
             profiles: Dict[Tuple[str, str, int], Profile],
             cluster: ClusterSpec, *,
             introspect_every_s: Optional[float] = None,
             noise_sigma: float = 0.1, noise_seed: int = 0,
             max_events: int = 100000) -> SimResult:
    noise = _noise_factors(jobs, profiles, noise_seed, noise_sigma)

    def est_step(jname, tech, g):
        return profiles[(jname, tech, g)].step_time_s

    def true_step(jname, tech, g):
        return est_step(jname, tech, g) * noise[(jname, tech, g)]

    remaining = {j.name: j.total_steps for j in jobs}
    by_name = {j.name: j for j in jobs}
    waiting = [j.name for j in jobs]
    running: Dict[str, _Running] = {}
    free = cluster.total_gpus
    t = 0.0
    gantt: List[GanttEntry] = []
    replans = restarts = 0
    current_assign: Dict[str, Tuple[str, int]] = {}
    order: List[Tuple[str, str, int]] = policy.plan(
        jobs, dict(remaining), profiles, cluster, {})
    replans += 1
    next_introspect = (introspect_every_s if introspect_every_s else math.inf)

    def settle(upto_t):
        """Account finished steps for running jobs up to time upto_t."""
        for name, r in running.items():
            done = int((upto_t - r.start_s) / r.true_step_s)
            remaining[name] = max(0, r.steps_at_start - done)

    def start_fitting():
        nonlocal free
        started = True
        while started:
            started = False
            for (jname, tech, g) in order:
                if jname in waiting and g <= free:
                    st = true_step(jname, tech, g)
                    running[jname] = _Running(by_name[jname], tech, g, t,
                                              st, remaining[jname])
                    current_assign[jname] = (tech, g)
                    waiting.remove(jname)
                    free -= g
                    started = True
                    break

    start_fitting()
    events = 0
    while (waiting or running) and events < max_events:
        events += 1
        if not running:
            raise RuntimeError(
                f"deadlock: waiting={waiting} free={free} order={order}")
        next_done_t, next_done = min(
            ((r.start_s + r.steps_at_start * r.true_step_s, name)
             for name, r in running.items()), key=lambda x: x[0])
        if next_introspect < next_done_t - 1e-12:
            # ---- introspection point: re-solve on remaining work
            t = next_introspect
            next_introspect += introspect_every_s
            settle(t)
            if policy.dynamic:
                replans += 1
                new_order = policy.plan(
                    jobs, dict(remaining), profiles, cluster,
                    dict(current_assign))
                new_assign = {j: (tech, g) for j, tech, g in new_order}
                # restart running jobs whose assignment changed
                for name in list(running):
                    if name in new_assign and new_assign[name] != \
                            current_assign.get(name):
                        r = running.pop(name)
                        free += r.n_gpus
                        gantt.append(GanttEntry(name, r.technique, r.n_gpus,
                                                r.start_s, t))
                        # checkpoint + relaunch penalty
                        gantt.append(GanttEntry(name, "restart", 0, t,
                                                t + cluster.restart_cost_s,
                                                kind="restart"))
                        remaining[name] = max(1, remaining[name])
                        waiting.append(name)
                        restarts += 1
                order = new_order
                # restart penalty: delay those jobs' availability
                start_fitting()
            continue
        # ---- completion event
        t = next_done_t
        settle(t)
        r = running.pop(next_done)
        remaining[next_done] = 0
        free += r.n_gpus
        gantt.append(GanttEntry(next_done, r.technique, r.n_gpus,
                                r.start_s, t))
        if policy.dynamic and policy.replan_on_completion and waiting:
            replans += 1
            order = policy.plan(jobs, dict(remaining), profiles, cluster,
                                dict(current_assign))
        start_fitting()
    if events >= max_events:
        raise RuntimeError("simulate: event cap hit")
    return SimResult(policy.name, t, gantt, replans, restarts)


# --------------------------------------------------------------- local run

class LocalRunner:
    """Really execute a plan on this machine (reduced models, CPU): jobs
    run in list order under their assigned technique, with checkpointing.
    Used by the end-to-end examples; wall-times feed back as profiles."""

    def __init__(self, cluster_devices=None, ckpt_dir: str = "/tmp/saturn_ckpts"):
        self.devices = cluster_devices
        self.ckpt_dir = ckpt_dir

    def run_job(self, job: Job, technique, n_devices: int, *,
                steps: Optional[int] = None, resume: bool = True):
        import time as _time

        import jax

        from ..checkpoint.store import (load_checkpoint, load_metadata,
                                        save_checkpoint)
        from ..configs import concrete_batch
        from ..data.synthetic import SyntheticLM
        from ..parallelism.build import BuiltJob

        devs = (self.devices or jax.devices())[:n_devices]
        plan = technique.plan(job.cfg, n_devices)
        built = BuiltJob(job.cfg, plan, job.opt_cfg, devices=devs)
        params, opt = built.init(jax.random.PRNGKey(job.seed))
        start_step = 0
        path = f"{self.ckpt_dir}/{job.name}.npz"
        import os
        if resume and os.path.exists(path):
            meta = load_metadata(path) or {}
            start_step = int(meta.get("step", 0))
            state = load_checkpoint(path, {"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
        n = steps if steps is not None else job.total_steps - start_step
        data = SyntheticLM(job.cfg, seed=job.seed).batches(
            job.batch_size, job.seq_len, num_batches=n)
        t0 = _time.perf_counter()
        m = {}
        for b in data:
            params, opt, m = built.step(params, opt, built.place_batch(b))
        jax.block_until_ready(params)
        dt = _time.perf_counter() - t0
        save_checkpoint(path, {"params": params, "opt": opt},
                        {"step": start_step + n,
                         "loss": float(m.get("loss", float("nan")))})
        return {"job": job.name, "steps": n, "wall_s": dt,
                "loss": float(m.get("loss", float("nan"))),
                "done": start_step + n >= job.total_steps}
