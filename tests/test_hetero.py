"""Heterogeneous-cluster support: DeviceClass specs, class-keyed
profiles/curves, the class-dimension MILP, per-class placement pools,
cross-class migration restarts, and per-class GPU-second conservation."""
import json

import pytest

from repro.configs import get_config
from repro.core.baselines import (CurrentPractice, Optimus, OptimusDynamic,
                                  RandomPolicy, SaturnPolicy)
from repro.core.executor import simulate
from repro.core.job import DEFAULT_CLASS, ClusterSpec, DeviceClass, Job
from repro.core.library import ParallelismLibrary
from repro.core.perfmodel import (iter_job_class_profiles,
                                  iter_job_profiles, step_time_of)
from repro.core.placement import ClassPool, PlacementError, make_backend
from repro.core.profiler import (CACHE_VERSION, HARDWARE, Profile,
                                 TrialRunner, hardware_for_class)
from repro.core.schedule import Policy, Schedule, ScheduleEntry
from repro.core.solver import solve_joint_classes

CFG = get_config("xlstm-125m").reduced()

FAST = DeviceClass("fast", nodes=1, gpus_per_node=8,
                   hbm_per_gpu=40e9, speed_hint=1.0)
SLOW = DeviceClass("slow", nodes=1, gpus_per_node=8,
                   hbm_per_gpu=16e9, speed_hint=0.4)
HET = ClusterSpec(restart_cost_s=10.0, device_classes=(FAST, SLOW))


def mk_hetero_profiles(jobs, counts=(1, 2, 4, 8), slow_factor=2.5,
                       techs=(("ddp", 1.0), ("fsdp", 1.1))):
    profiles = {}
    for i, j in enumerate(jobs):
        base = 1.0 + 0.5 * i
        for dc, slow in (("fast", 1.0), ("slow", slow_factor)):
            for g in counts:
                for tech, mult in techs:
                    profiles[(j.name, tech, dc, g)] = Profile(
                        j.name, tech, g, base * mult * slow / g ** 0.8,
                        1e9, True, "t", device_class=dc)
    return profiles


# ------------------------------------------------------------ ClusterSpec

def test_legacy_cluster_shim():
    c = ClusterSpec(nodes=2, gpus_per_node=8)
    assert not c.hetero
    assert c.total_gpus == 16
    assert [dc.name for dc in c.device_classes] == [DEFAULT_CLASS]
    assert c.device_classes[0].hbm_per_gpu == c.hbm_per_gpu


def test_single_explicit_class_is_class_aware():
    """A lone EXPLICIT DeviceClass must flow through the class-aware
    machinery — its speed_hint / hbm_per_gpu are real hardware facts,
    not the reference defaults.  Only the shim's synthesized "default"
    class reduces to the legacy single-pool behavior."""
    lone = ClusterSpec(device_classes=(
        DeviceClass("v100-16g", 1, 8, hbm_per_gpu=16e9, speed_hint=0.4),))
    assert lone.hetero
    from repro.core.api import SaturnSession
    sess = SaturnSession(lone)
    sess.submit([Job("a", CFG, 8, 64, 50)])
    pm = sess.profile(mode="napkin")
    assert pm.hetero and pm.classes == ["v100-16g"]
    # the class's own hardware, not the A100 reference, did the trials
    hw = sess.runner.hw_by_class["v100-16g"]
    assert hw.hbm_capacity == 16e9
    assert hw.flops == pytest.approx(HARDWARE["a100"].flops * 0.4)
    ref = TrialRunner(ParallelismLibrary(), HARDWARE["a100"]).profile(
        Job("a", CFG, 8, 64, 50), "ddp", 2, mode="napkin")
    assert pm.step_time("a", "ddp", 2, "v100-16g") > ref.step_time_s


def test_hetero_cluster_spec():
    assert HET.hetero
    assert HET.total_gpus == 16
    assert HET.device_ranges() == {"fast": (0, 8), "slow": (8, 16)}
    assert HET.class_of_device(3) == "fast"
    assert HET.class_of_device(11) == "slow"
    assert HET.class_named("slow") is SLOW
    with pytest.raises(KeyError):
        HET.class_named("h100")
    with pytest.raises(ValueError):
        ClusterSpec(device_classes=(FAST, FAST))


# --------------------------------------------------------------- ClassPool

def test_class_pool_pinned_and_blind_allocation():
    b = make_backend(HET)
    assert isinstance(b, ClassPool)
    pinned = b.allocate(5, device_class="slow")
    assert pinned.device_class == "slow"
    assert all(8 <= d < 16 for d in pinned.devices)
    blind = b.allocate(6)                 # first class with room: fast
    assert blind.device_class == "fast"
    assert b.allocate(4, device_class="fast") is None   # only 2 left
    spill = b.allocate(3)                 # blind spills to slow (3 free)
    assert spill.device_class == "slow"
    b.release(pinned)
    assert b.free_in("slow") == 5
    assert b.feasible(8, device_class="slow")
    assert not b.feasible(9, device_class="slow")
    assert b.feasible(8)                  # some class can host 8
    with pytest.raises(PlacementError):
        b.allocate(1, device_class="h100")


def test_node_placement_rejected_on_hetero():
    import dataclasses
    with pytest.raises(ValueError):
        make_backend(dataclasses.replace(HET, placement="node"))


# ---------------------------------------------------- profiler + perfmodel

def test_profiler_keys_and_per_class_speed():
    jobs = [Job("a", CFG, 8, 64, 100)]
    runner = TrialRunner(ParallelismLibrary(), HARDWARE["a100"])
    d = runner.profile_all(jobs, [1, 2, 4, 8], mode="napkin",
                           classes=(FAST, SLOW))
    assert all(len(k) == 4 for k in d)
    fast = d[("a", "ddp", "fast", 2)]
    slow = d[("a", "ddp", "slow", 2)]
    assert fast.device_class == "fast" and slow.device_class == "slow"
    # speed_hint scales the roofline: the slow class is really slower
    assert slow.step_time_s > fast.step_time_s
    # single-class calls keep the legacy 3-tuple shape exactly
    d3 = runner.profile_all(jobs, [1, 2], mode="napkin")
    assert all(len(k) == 3 for k in d3)


def test_per_class_hbm_feasibility():
    tiny = DeviceClass("tiny", 1, 4, hbm_per_gpu=1e6, speed_hint=1.0)
    jobs = [Job("a", CFG, 8, 64, 100)]
    runner = TrialRunner(ParallelismLibrary(), HARDWARE["a100"])
    d = runner.profile_all(jobs, [1, 2, 4], mode="napkin",
                           classes=(FAST, tiny))
    assert d[("a", "ddp", "fast", 2)].feasible
    assert not d[("a", "ddp", "tiny", 2)].feasible   # 1 MB HBM


def test_hardware_for_class_scaling():
    hw = hardware_for_class(HARDWARE["a100"], SLOW)
    assert hw.name == "slow"
    assert hw.flops == pytest.approx(HARDWARE["a100"].flops * 0.4)
    assert hw.hbm_capacity == 16e9


def test_perfmodel_hetero_contract():
    jobs = [Job("a", CFG, 8, 64, 100)]
    runner = TrialRunner(ParallelismLibrary(), HARDWARE["a100"])
    small = DeviceClass("small", 1, 4, 40e9, 0.5)
    pm = runner.profile_all(jobs, list(range(1, 9)), mode="napkin",
                            strategy="interpolate",
                            classes=(FAST, small))
    assert pm.hetero and pm.classes == ["fast", "small"]
    assert all(len(k) == 4 for k in pm)
    # counts truncate to each class's capacity
    assert pm.counts_for("small")[-1] == 4
    assert pm.counts_for("fast")[-1] == 8
    # per-class curves answer any count; the half-speed class is slower
    assert pm.step_time("a", "ddp", 3, "small") > \
        pm.step_time("a", "ddp", 3, "fast")
    # 4-tuple getitem, and anchors are class-qualified
    p = pm[("a", "ddp", "small", 3)]
    assert p.device_class == "small"
    assert all(len(k) == 4 for k in pm.anchor_keys())
    # a 3-tuple lookup cannot silently hit the wrong generation
    with pytest.raises(KeyError):
        pm[("a", "ddp", 3)]
    # adapters
    assert {dc for _, dc, _, _ in iter_job_class_profiles(pm, "a")} == \
        {"fast", "small"}
    fast_only = list(iter_job_profiles(pm, "a", device_class="fast"))
    assert fast_only and all(g <= 8 for _, g, _ in fast_only)
    assert step_time_of(pm, "a", "ddp", 3, "small") == \
        pm.step_time("a", "ddp", 3, "small")


def test_cache_version_bump_discards_old_schema(tmp_path):
    path = tmp_path / "cache.json"
    old = {"version": CACHE_VERSION - 1,
           "profiles": [{"job": "a", "technique": "ddp", "n_devices": 2,
                         "step_time_s": 1.0, "mem_per_device": 1e9,
                         "feasible": True, "source": "napkin"}]}
    path.write_text(json.dumps(old))
    runner = TrialRunner(ParallelismLibrary(), HARDWARE["a100"],
                         cache_path=str(path))
    assert runner._cache == {}            # old cache discarded, not migrated
    runner.profile(Job("a", CFG, 8, 64, 100), "ddp", 2, mode="napkin")
    runner.flush()
    fresh = json.loads(path.read_text())
    assert fresh["version"] == CACHE_VERSION
    assert fresh["profiles"][0]["device_class"] == DEFAULT_CLASS


# ------------------------------------------------------------- class MILP

def test_solve_joint_classes_respects_per_class_capacity():
    jobs = [Job(f"j{i}", CFG, 8, 64, 100 + 40 * i) for i in range(5)]
    profiles = mk_hetero_profiles(jobs)
    sol = solve_joint_classes(jobs, profiles, HET, n_slots=12,
                              time_limit_s=10)
    assert {a.job for a in sol.assignments} == {j.name for j in jobs}
    assert all(a.device_class in ("fast", "slow") for a in sol.assignments)
    events = sorted({a.start_s for a in sol.assignments})
    for t in events:
        for dc in ("fast", "slow"):
            used = sum(a.n_gpus for a in sol.assignments
                       if a.device_class == dc
                       and a.start_s <= t < a.end_s - 1e-9)
            assert used <= 8, f"class {dc} overpacked at t={t}"
    # the plan carries class pins into Schedule IR
    sched = sol.to_schedule()
    assert all(e.device_class is not None for e in sched.entries)
    assert sched.entries[0].assignment[2] in ("fast", "slow")


def test_class_runtime_matters_to_solver():
    """One job, both classes idle: the solver must put it on the class
    where it actually runs faster, not just any class with room."""
    jobs = [Job("a", CFG, 8, 64, 100)]
    profiles = mk_hetero_profiles(jobs, slow_factor=4.0)
    sol = solve_joint_classes(jobs, profiles, HET, n_slots=8,
                              time_limit_s=10)
    assert sol.assignments[0].device_class == "fast"


# ----------------------------------------------------------- runtime paths

def test_runtime_pins_classes_and_conserves_per_class():
    jobs = [Job(f"j{i}", CFG, 8, 64, 150 + 60 * i) for i in range(5)]
    profiles = mk_hetero_profiles(jobs)
    res = simulate(jobs, SaturnPolicy(n_slots=12, time_limit_s=5),
                   profiles, HET, introspect_every_s=120, noise_sigma=0.3)
    runs = [g for g in res.gantt if g.kind == "run"]
    assert {g.job for g in runs} == {j.name for j in jobs}
    ranges = HET.device_ranges()
    for g in runs:
        lo, hi = ranges[g.device_class]
        assert all(lo <= d < hi for d in g.devices), \
            f"{g.job} devices {g.devices} escaped class {g.device_class}"
    # simulate() already ran verify_conservation; double-check per-class
    # GPU-seconds from the Gantt against the device intervals
    by_dev = {}
    for g in runs:
        for d in g.devices:
            by_dev.setdefault(d, []).append((g.start_s, g.end_s))
    for d, ivs in by_dev.items():
        ivs.sort()
        for (s1, e1), (s2, e2) in zip(ivs, ivs[1:]):
            assert e1 <= s2 + 1e-9, f"device {d} double-booked"


def test_class_blind_entries_skip_infeasible_class():
    """An unpinned entry must not land on a class where the config is
    not runnable (infinite estimated step time)."""
    jobs = [Job("a", CFG, 8, 64, 100)]
    profiles = {
        ("a", "ddp", "fast", 2): Profile("a", "ddp", 2, float("inf"),
                                         float("inf"), False, "t",
                                         device_class="fast"),
        ("a", "ddp", "slow", 2): Profile("a", "ddp", 2, 1.0, 1e9, True,
                                         "t", device_class="slow"),
    }

    class Blind(Policy):
        name = "blind"

        def plan(self, jobs_, remaining, _p, cluster, current):
            return Schedule([ScheduleEntry(j.name, "ddp", 2)
                             for j in jobs_])

    res = simulate(jobs, Blind(), profiles, HET, noise_sigma=0.0)
    (run,) = [g for g in res.gantt if g.kind == "run"]
    assert run.device_class == "slow"
    assert all(8 <= d < 16 for d in run.devices)


class MigrateOnTick(Policy):
    """Plans the job on 'fast' until the first introspection tick, then
    pins it to 'slow' forever (a single intended migration)."""

    name = "migrate"
    dynamic = True
    replan_on_completion = False

    def __init__(self):
        self.plans = 0

    def plan(self, jobs, remaining, profiles, cluster, current):
        self.plans += 1
        dc = "fast" if self.plans == 1 else "slow"
        return Schedule([ScheduleEntry(j.name, "ddp", 2, device_class=dc)
                         for j in jobs])


def test_cross_class_migration_pays_exactly_one_restart():
    """Satellite: an introspection replan that migrates a job across
    device classes pays exactly one restart_cost_s and never
    double-books a device."""
    job = Job("a", CFG, 8, 64, total_steps=1000)
    profiles = {
        ("a", "ddp", "fast", 2): Profile("a", "ddp", 2, 1.0, 1e9, True,
                                         "t", device_class="fast"),
        ("a", "ddp", "slow", 2): Profile("a", "ddp", 2, 2.0, 1e9, True,
                                         "t", device_class="slow"),
    }
    res = simulate([job], MigrateOnTick(), profiles, HET,
                   introspect_every_s=100.0, noise_sigma=0.0)
    assert res.restarts == 1
    restarts = [g for g in res.gantt if g.kind == "restart"]
    assert len(restarts) == 1
    (rst,) = restarts
    assert rst.end_s - rst.start_s == pytest.approx(HET.restart_cost_s)
    runs = sorted((g for g in res.gantt if g.kind == "run"),
                  key=lambda g: g.start_s)
    assert [g.device_class for g in runs] == ["fast", "slow"]
    assert all(0 <= d < 8 for d in runs[0].devices)
    assert all(8 <= d < 16 for d in runs[1].devices)
    # relaunch only after the restart window; devices never double-booked
    assert runs[1].start_s >= rst.end_s - 1e-9
    assert not set(runs[0].devices) & set(runs[1].devices)
    # 100 steps done at 1 s/step, preempt at t=100, restart 10 s, then
    # 900 steps at 2 s/step on the slow class
    assert res.makespan_s == pytest.approx(100 + 10 + 900 * 2, abs=1e-6)


def test_stable_class_assignment_does_not_restart():
    """Replans that keep (technique, g, class) identical must not pay
    restart penalties."""
    class Stay(MigrateOnTick):
        def plan(self, jobs, remaining, profiles, cluster, current):
            return Schedule([ScheduleEntry(j.name, "ddp", 2,
                                           device_class="fast")
                             for j in jobs])

    job = Job("a", CFG, 8, 64, total_steps=500)
    profiles = mk_hetero_profiles([job], counts=(2,), techs=(("ddp", 1.0),))
    res = simulate([job], Stay(), profiles, HET,
                   introspect_every_s=50.0, noise_sigma=0.0)
    assert res.restarts == 0


# ----------------------------------------------------- baselines + session

@pytest.mark.parametrize("policy_fn", [
    lambda: CurrentPractice(),
    lambda: RandomPolicy(1),
    lambda: Optimus(),
    lambda: OptimusDynamic(),
])
def test_baselines_complete_on_hetero_cluster(policy_fn):
    jobs = [Job(f"j{i}", CFG, 8, 64, 120 + 30 * i) for i in range(5)]
    profiles = mk_hetero_profiles(jobs)
    pol = policy_fn()
    res = simulate(jobs, pol, profiles, HET,
                   introspect_every_s=200 if pol.dynamic else None,
                   noise_sigma=0.1)
    runs = [g for g in res.gantt if g.kind == "run"]
    assert {g.job for g in runs} == {j.name for j in jobs}
    assert {g.device_class for g in runs} <= {"fast", "slow"}


def test_optimus_spends_both_class_budgets():
    jobs = [Job(f"j{i}", CFG, 8, 64, 300) for i in range(4)]
    profiles = mk_hetero_profiles(jobs, slow_factor=1.5)
    sched = Optimus().plan(jobs, {j.name: 300 for j in jobs}, profiles,
                           HET, {})
    per_class = {}
    for e in sched.entries:
        per_class[e.device_class] = per_class.get(e.device_class, 0) \
            + e.n_gpus
        assert e.n_gpus <= 8
    # with 4 big jobs and two 8-GPU pools, a single class cannot hold
    # the allocation Optimus hands out
    assert len(per_class) == 2


def test_session_end_to_end_hetero():
    from repro.core.api import SaturnSession
    cluster = ClusterSpec(restart_cost_s=10.0, device_classes=(
        DeviceClass("big", 1, 4, 40e9, 1.0),
        DeviceClass("small", 1, 2, 16e9, 0.5)))
    sess = SaturnSession(cluster)
    assert "big" in sess.runner.hw_by_class
    sess.submit([Job("a", CFG, 8, 64, 60), Job("b", CFG, 8, 64, 90)])
    pm = sess.profile(mode="napkin")
    assert pm.hetero
    res = sess.run(policy=SaturnPolicy(n_slots=8, time_limit_s=5))
    runs = [g for g in res.gantt if g.kind == "run"]
    assert {g.job for g in runs} == {"a", "b"}
    assert all(g.device_class in ("big", "small") for g in runs)
