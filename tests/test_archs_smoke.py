"""Per-architecture smoke tests (deliverable f): reduced variant of each
assigned config — one forward + one train step on CPU, asserting output
shapes and no NaNs; plus decode-vs-full equivalence for every family."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, concrete_batch, get_config
from repro.data.synthetic import SyntheticLM
from repro.models.params import param_count
from repro.models.transformer import (decode_step, forward, init_decode_state,
                                      init_model, model_spec, prefill_forward)
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.train.steps import make_train_step

# published parameter counts (billions) the FULL configs must land near
EXPECTED_PARAMS_B = {
    "stablelm-12b": (11.0, 13.5),
    "internlm2-20b": (18.5, 21.5),
    "xlstm-125m": (0.10, 0.17),
    "recurrentgemma-2b": (2.4, 3.2),
    "musicgen-medium": (1.3, 2.1),
    "qwen3-moe-235b-a22b": (225.0, 245.0),
    "gemma3-4b": (3.3, 4.5),
    "internvl2-1b": (0.4, 0.7),       # LLM backbone only (ViT is stubbed)
    "h2o-danube-3-4b": (3.5, 4.4),
    "olmoe-1b-7b": (6.4, 7.4),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count(arch):
    cfg = get_config(arch)
    n = param_count(model_spec(cfg)) / 1e9
    lo, hi = EXPECTED_PARAMS_B[arch]
    assert lo <= n <= hi, f"{arch}: {n:.2f}B params outside [{lo},{hi}]"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= max(2, len(cfg.block_pattern))
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, 2, 16)
    logits, aux = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    seq = 16 if cfg.frontend != "vision" else 16  # vlm: patches + text = 16
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    # one real train step
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    b = next(SyntheticLM(cfg, seed=0).batches(2, 16, num_batches=1))
    p2, o2, m = step(params, opt, b)
    assert not bool(jnp.isnan(m["loss"])), arch
    assert float(m["loss"]) > 0


@pytest.mark.parametrize("arch", ["stablelm-12b", "gemma3-4b",
                                  "recurrentgemma-2b", "xlstm-125m",
                                  "olmoe-1b-7b", "musicgen-medium"])
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    params = init_model(cfg, jax.random.PRNGKey(1))
    if cfg.frontend == "audio":
        toks = concrete_batch(cfg, 2, 8)["labels"]
    else:
        toks = concrete_batch(cfg, 2, 8)["tokens"]
    full_logits, _ = forward(params, cfg, {"tokens": toks})
    state = init_decode_state(cfg, 2, 8, dtype=jnp.float32)
    step = jax.jit(lambda p, t, s: decode_step(p, cfg, t, s))
    for i in range(toks.shape[1]):
        lg, state = step(params, toks[:, i:i + 1], state)
    err = float(jnp.max(jnp.abs(lg[:, 0] - full_logits[:, -1])))
    assert err < 5e-4, f"{arch}: decode diverges from full forward ({err})"


@pytest.mark.parametrize("arch", ["gemma3-4b", "xlstm-125m",
                                  "recurrentgemma-2b", "h2o-danube-3-4b"])
def test_prefill_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    params = init_model(cfg, jax.random.PRNGKey(1))
    toks = concrete_batch(cfg, 2, 8)["tokens"]
    full_logits, _ = forward(params, cfg, {"tokens": toks})
    pl_logits, state = prefill_forward(params, cfg, {"tokens": toks})
    err = float(jnp.max(jnp.abs(pl_logits[:, 0] - full_logits[:, -1])))
    assert err < 1e-4
    assert int(state["pos"]) == 8


def test_vlm_prefix_handling():
    cfg = get_config("internvl2-1b").reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, 2, 16)
    p = batch["embeds"].shape[1]
    logits, _ = forward(params, cfg, batch)
    assert logits.shape[1] == p + batch["tokens"].shape[1]


def test_long_context_flags():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        if arch in ("xlstm-125m", "recurrentgemma-2b", "gemma3-4b",
                    "h2o-danube-3-4b"):
            assert cfg.long_context, arch
        else:
            assert not cfg.long_context, arch
