"""Loop-aware analysis of compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
``lax.scan`` (our layer stacks) under-counts FLOPs/bytes/collectives by
the trip count.  This module parses the post-optimization HLO, recovers
trip counts from loop conditions, propagates multipliers through the
call graph (while bodies, fusions, conditionals), and produces:

- ``flops``: 2*M*N*K summed over dot ops (x multiplier) — the MXU work
- ``collective_bytes``: per collective kind, operand bytes x multiplier
- ``bytes_written``: sum of instruction output bytes (HBM write-traffic
  proxy) x multiplier

These feed the three-term roofline in EXPERIMENTS.md.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f32": 4, "f16": 2, "bf16": 2, "f64": 8, "s32": 4, "u32": 4,
    "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "c64": 8, "c128": 16, "s4": 1,
    "u4": 1,
}

def _comp_header_name(line: str) -> Optional[str]:
    s = line.strip()
    if not s.endswith("{") or ") -> " not in s:
        return None
    if not (s.startswith("%") or s.startswith("ENTRY")):
        return None
    tok = s.split()[1] if s.startswith("ENTRY") else s.split()[0]
    return tok.lstrip("%").split("(")[0].rstrip()
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(?:\(([^)]*)\)|(\w+)\[([0-9,]*)\][^\s]*)\s*"
    r"([\w\-]+)\((.*)$")
_CALLED = re.compile(
    r"(?:body|condition|to_apply|calls)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCHDIMS = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")


def _shape_numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n


class Instr:
    __slots__ = ("name", "dtype", "dims", "op", "rest", "tuple_types")

    def __init__(self, name, dtype, dims, op, rest, tuple_types=None):
        self.name, self.dtype, self.dims = name, dtype, dims
        self.op, self.rest = op, rest
        self.tuple_types = tuple_types

    @property
    def out_bytes(self) -> int:
        if self.tuple_types is not None:
            total = 0
            for t in re.finditer(r"(\w+)\[([0-9,]*)\]", self.tuple_types):
                total += _DTYPE_BYTES.get(t.group(1), 4) * _shape_numel(
                    t.group(2))
            return total
        return _DTYPE_BYTES.get(self.dtype, 4) * _shape_numel(self.dims or "")


def parse_computations(hlo: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            name = _comp_header_name(line)
            if name is not None:
                cur = name
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            name, tup, dtype, dims, op, rest = m.groups()
            comps[cur].append(Instr(name, dtype, dims, op, rest, tup))
    return comps


def _instr_index(comps):
    idx = {}
    for cname, instrs in comps.items():
        for i in instrs:
            idx[(cname, i.name)] = i
    return idx


def _trip_count(cond_instrs: List[Instr]) -> int:
    """Largest integer constant in the loop condition (scan bound)."""
    best = 1
    for i in cond_instrs:
        for m in _CONST_INT.finditer(i.rest or ""):
            best = max(best, int(m.group(1)))
        if i.op == "constant" and i.dims == "" and i.rest:
            m = re.match(r"(\d+)", i.rest.strip(") ,"))
            if m:
                best = max(best, int(m.group(1)))
    return best


_REF_KINDS = (
    ("body", re.compile(r"body=%?([\w\.\-]+)")),
    ("condition", re.compile(r"condition=%?([\w\.\-]+)")),
    ("calls", re.compile(r"calls=%?([\w\.\-]+)")),
    ("to_apply", re.compile(r"to_apply=%?([\w\.\-]+)")),
)


def computation_multipliers(comps: Dict[str, List[Instr]],
                            entry: Optional[str] = None
                            ) -> Dict[str, Tuple[float, float]]:
    """(flops_mult, bytes_mult) per computation.

    While bodies multiply by the trip count; fusion callees (``calls=``)
    keep the flops multiplier but contribute NO HBM bytes (their
    instruction outputs live in registers/fused buffers); ``to_apply``
    reducers contribute neither; conditional branches count once."""
    all_refs: Dict[str, set] = {}
    for cname, instrs in comps.items():
        refs = set()
        for i in instrs:
            for kind, rx in _REF_KINDS:
                for m in rx.finditer(i.rest or ""):
                    refs.add(m.group(1))
            b = _BRANCHES.search(i.rest or "")
            if b:
                for name in b.group(1).split(","):
                    refs.add(name.strip().lstrip("%"))
        all_refs[cname] = refs
    if entry is None:
        referenced = set().union(*all_refs.values()) if all_refs else set()
        entries = [c for c in comps if c not in referenced]
        mains = [c for c in entries if "main" in c]
        if mains:
            entry = mains[0]
        elif entries:
            entry = max(entries, key=lambda c: len(comps[c]))
        else:
            entry = next(iter(comps))
    mult: Dict[str, Tuple[float, float]] = {c: (0.0, 0.0) for c in comps}
    mult[entry] = (1.0, 1.0)
    for _ in range(len(comps)):
        changed = False
        for cname, instrs in comps.items():
            fbase, bbase = mult.get(cname, (0.0, 0.0))
            if fbase == 0.0 and bbase == 0.0:
                continue
            for i in instrs:
                trips = 1.0
                if i.op == "while":
                    mcond = re.search(r"condition=%?([\w\.\-]+)",
                                      i.rest or "")
                    if mcond and mcond.group(1) in comps:
                        trips = float(_trip_count(comps[mcond.group(1)]))
                updates: List[Tuple[str, float, float]] = []
                for kind, rx in _REF_KINDS:
                    for m in rx.finditer(i.rest or ""):
                        rname = m.group(1)
                        if rname not in mult:
                            continue
                        if kind in ("body", "condition"):
                            updates.append((rname, fbase * trips,
                                            bbase * trips))
                        elif kind == "calls":
                            updates.append((rname, fbase, 0.0))
                        else:  # to_apply: per-element reducer, skip
                            pass
                b = _BRANCHES.search(i.rest or "")
                if b:
                    for name in b.group(1).split(","):
                        rname = name.strip().lstrip("%")
                        if rname in mult:
                            updates.append((rname, fbase, bbase))
                for rname, fw, bw in updates:
                    f0, b0 = mult[rname]
                    if fw > f0 or bw > b0:
                        mult[rname] = (max(f0, fw), max(b0, bw))
                        changed = True
        if not changed:
            break
    return mult


def _operand_shapes(i: Instr, sym: Dict[str, Tuple[str, str]]):
    """Shapes of %operand references in order of appearance."""
    out = []
    for m in re.finditer(r"%?([\w\.\-]+)", i.rest or ""):
        if m.group(1) in sym:
            out.append(sym[m.group(1)])
    return out


def _effective_out_bytes(i: Instr, comps, sym) -> float:
    """HBM write bytes for one instruction.  dynamic-update-slice (bare
    or as a fusion root) executes IN PLACE: only the updated slice is
    written, not the whole buffer — essential for scans that update a
    (S, ...) buffer once per iteration."""
    root = i
    root_sym = sym
    callee = None
    if i.op == "fusion":
        m = re.search(r"calls=%?([\w\.\-]+)", i.rest or "")
        if m and m.group(1) in comps:
            callee = comps[m.group(1)]
            if callee:
                root = callee[-1]
                root_sym = {x.name: (x.dtype, x.dims) for x in callee}
    if root.op == "dynamic-update-slice":
        ops = _operand_shapes(root, root_sym)
        if len(ops) >= 2:
            dtype, dims = ops[1]
            return _DTYPE_BYTES.get(dtype, 4) * _shape_numel(dims or "")
    if callee is not None:
        # fusion containing DUS ops (possibly bitcast/convert-wrapped or
        # multi-output): the in-place buffers contribute only their
        # update slices; other non-trivial instrs' outputs stay fused
        # (no HBM), so the fusion's write = sum of DUS update slices,
        # or the full output if no DUS is present.
        dus = [x for x in callee if x.op == "dynamic-update-slice"]
        if dus:
            total = 0.0
            for el in dus:
                ops = _operand_shapes(el, root_sym)
                if len(ops) >= 2:
                    dtype, dims = ops[1]
                    total += _DTYPE_BYTES.get(dtype, 4) * _shape_numel(
                        dims or "")
            if total > 0:
                return total
    return i.out_bytes


def analyze(hlo: str) -> Dict[str, float]:
    comps = parse_computations(hlo)
    mult = computation_multipliers(comps)
    flops = 0.0
    bytes_written = 0.0
    coll: Dict[str, float] = {}
    for cname, instrs in comps.items():
        k, kb = mult.get(cname, (0.0, 0.0))
        if k == 0.0 and kb == 0.0:
            continue
        sym = {i.name: (i.dtype, i.dims) for i in instrs}
        for i in instrs:
            if i.op not in ("parameter", "constant", "get-tuple-element",
                            "tuple", "bitcast", "while", "conditional"):
                # while/conditional outputs alias their body buffers
                bytes_written += kb * _effective_out_bytes(i, comps, sym)
            if i.op == "dot":
                out_numel = _shape_numel(i.dims or "")
                mc = _CONTRACT.search(i.rest or "")
                csize = 1
                if mc:
                    ops = _operand_shapes(i, sym)
                    if ops:
                        lhs_dims = [int(d) for d in ops[0][1].split(",")
                                    if d.strip()]
                        for ax in mc.group(1).split(","):
                            if ax.strip() and int(ax) < len(lhs_dims):
                                csize *= lhs_dims[int(ax)]
                flops += k * 2.0 * out_numel * csize
            elif i.op == "convolution":
                # rough: 2 * out_numel * (in_ch * kernel_spatial)
                flops += k * 2.0 * _shape_numel(i.dims or "") * 64
            elif i.op in ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute",
                          "all-gather-start", "all-reduce-start",
                          "collective-permute-start"):
                kind = i.op.replace("-start", "")
                coll[kind] = coll.get(kind, 0.0) + k * i.out_bytes
    coll["total"] = sum(v for kk, v in coll.items() if kk != "total")
    return {"flops": flops, "bytes_written": bytes_written,
            "collectives": coll,
            "n_computations": len(comps)}


# ------------------------------------------------- roofline conversion
#
# Effective bytes-on-wire per device for the standard ring algorithms,
# as a multiple of the payload bytes ``analyze()`` reports.  These map a
# collective KIND onto the link-bandwidth term of the roofline: an
# all-reduce of P bytes on n devices moves ~2P(n-1)/n bytes through
# each device's interconnect, an all-gather/reduce-scatter ~P(n-1)/n,
# a permute exactly P.  Kinds missing from this table make a combo
# LOW-CONFIDENCE (the profiler escalates it to a real trial).

KNOWN_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")


def collective_link_factor(kind: str, n_devices: int) -> Optional[float]:
    """Bytes-on-wire multiplier for one collective kind at ``n_devices``
    (None for kinds the ring model does not cover)."""
    n = max(int(n_devices), 1)
    ring = (n - 1) / n if n > 1 else 0.0
    return {
        "all-reduce": 2.0 * ring,
        "all-gather": ring,
        "reduce-scatter": ring,
        "all-to-all": ring,
        "collective-permute": 1.0 if n > 1 else 0.0,
    }.get(kind.replace("-start", ""))


def link_seconds(collectives: Dict[str, float], n_devices: int,
                 link_bw: float) -> Tuple[float, List[str]]:
    """Interconnect seconds for an ``analyze()`` collectives dict, plus
    the list of UNFIT kinds (present in the HLO but absent from the
    ring-model table) the caller should treat as low confidence."""
    total = 0.0
    unfit: List[str] = []
    for kind, payload in collectives.items():
        if kind == "total":
            continue
        f = collective_link_factor(kind, n_devices)
        if f is None:
            unfit.append(kind)
            total += payload / max(link_bw, 1e-9)   # conservative: 1x
        else:
            total += payload * f / max(link_bw, 1e-9)
    return total, unfit


def scale_analysis(analysis: Dict[str, float], n_from: int, n_to: int,
                   *, work_scales: bool = True) -> Dict[str, float]:
    """Rescale an ``analyze()`` result from a mesh over ``n_from``
    devices to ``n_to`` devices WITHOUT recompiling.

    The compiled module is SPMD — ``analyze()`` counts one device's
    program — so where shapes permit (the sharded axis divides evenly,
    which every registered technique guarantees inside its
    ``search_space``), per-device FLOPs and HBM traffic scale as
    ``n_from/n_to`` (the same global work divided over more devices)
    while each collective's PAYLOAD per device stays constant (grad
    all-reduce moves the full gradient, FSDP gathers the full params,
    TP reduces the full activations — none depend on the ring size; the
    ring-size dependence lives in :func:`collective_link_factor`).
    ``work_scales=False`` keeps per-device work constant instead (e.g.
    a technique that replicates rather than shards the batch).
    """
    s = (n_from / n_to) if work_scales else 1.0
    out = dict(analysis)
    out["flops"] = analysis["flops"] * s
    out["bytes_written"] = analysis["bytes_written"] * s
    out["collectives"] = dict(analysis.get("collectives", {"total": 0.0}))
    out["scaled_from"] = float(n_from)
    out["scaled_to"] = float(n_to)
    return out


def top_writers(hlo: str, k: int = 15):
    """Profile helper: top-k (op, computation, bytes x multiplier) HBM
    writers — the 'where is the memory term coming from' view."""
    comps = parse_computations(hlo)
    mult = computation_multipliers(comps)
    rows = []
    for cname, instrs in comps.items():
        _, kb = mult.get(cname, (0.0, 0.0))
        if kb == 0.0:
            continue
        sym = {x.name: (x.dtype, x.dims) for x in instrs}
        for i in instrs:
            if i.op in ("parameter", "constant", "get-tuple-element",
                        "tuple", "bitcast", "while", "conditional"):
                continue
            rows.append((kb * _effective_out_bytes(i, comps, sym), i.op,
                         cname, i.name,
                         (i.dims or i.tuple_types or "")[:60], kb))
    rows.sort(reverse=True)
    return rows[:k]


def collective_details(hlo: str, k: int = 10):
    """Top-k collectives by bytes x multiplier."""
    comps = parse_computations(hlo)
    mult = computation_multipliers(comps)
    rows = []
    for cname, instrs in comps.items():
        kf, _ = mult.get(cname, (0.0, 0.0))
        if kf == 0.0:
            continue
        for i in instrs:
            if i.op.replace("-start", "") in (
                    "all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute"):
                rows.append((kf * i.out_bytes, i.op, cname, i.name,
                             (i.dims or i.tuple_types or "")[:60], kf))
    rows.sort(reverse=True)
    return rows[:k]
