"""Jit'd public wrappers for the Pallas TPU kernels.

``kernel_opts(cfg)`` builds the ``opts`` dict consumed by the model
layer (``forward(..., opts=...)``): on TPU backends it routes the
attention / RG-LRU / mLSTM hot-spots through the Pallas kernels; on CPU
(this container) the pure-jnp blockwise paths are used unless
``interpret=True`` is forced (tests do this to execute the kernel
bodies).
"""
from __future__ import annotations

import jax

from .flash_attention import flash_attention
from .mlstm_chunk import mlstm_chunk
from .rglru_scan import rglru_scan

__all__ = ["flash_attention", "rglru_scan", "mlstm_chunk", "kernel_opts"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def kernel_opts(cfg=None, *, force: bool = False, interpret: bool = False):
    """opts dict wiring the kernels into the model forward pass."""
    if not (on_tpu() or force):
        return {}
    ip = interpret or not on_tpu()
    return {
        "attn_fn": lambda q, k, v, w: flash_attention(
            q, k, v, window=w, interpret=ip),
        "rglru_scan": lambda a, b: rglru_scan(a, b, interpret=ip),
        "mlstm_fn": lambda q, k, v, i_, f_: mlstm_chunk(
            q, k, v, i_, f_, interpret=ip),
    }
