"""GPipe-style pipeline parallelism, TPU-native: ``shard_map`` over a
"stage" mesh axis, microbatch schedule driven by ``lax.scan``, activations
handed between stages with ``lax.ppermute``.  Differentiable end-to-end
(autodiff runs the reverse schedule), so it composes with the normal
train step.

Layout contract (GPipe.search_space): the model has a single scanned
layer group whose repeat count is divisible by the stage count; stacked
layer params are sharded over "stage" along the layer axis, so each
device holds its stage's contiguous repeats.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec

from ..models.config import ModelConfig
from ..models.layers import rmsnorm
from ..models.transformer import _block_apply, embed_inputs, unembed
from .base import Plan


def _stage_fn(cfg: ModelConfig, pattern):
    """Apply this stage's repeats (r, ...) of the block pattern."""

    def fn(stage_params, x):
        def body(carry, lp):
            x_, aux_ = carry
            for i, kind in enumerate(pattern):
                x_, _, a = _block_apply(lp[f"pos{i}_{kind}"], x_,
                                        kind=kind, cfg=cfg)
                aux_ = aux_ + a
            return (x_, aux_), None
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   stage_params)
        return x, aux

    return fn


def make_pipelined_blocks(cfg: ModelConfig, plan: Plan, mesh: Mesh):
    """Returns f(group_params, x_mb) -> (outputs, aux) running the block
    stack through the pipeline.  x_mb: (M, mb, S, d) microbatched input
    (replicated); outputs: same shape, valid on all devices."""
    stages, M = plan.stages, plan.microbatches
    pattern = cfg.layer_plan()[0][1]
    stage_fn = _stage_fn(cfg, pattern)
    perm = [(i, i + 1) for i in range(stages - 1)]

    def body_fn(stage_params, x_mb):
        stage = jax.lax.axis_index("stage")
        T = M + stages - 1

        def step(carry, t):
            recv, outputs, aux = carry
            first_in = jax.lax.dynamic_index_in_dim(
                x_mb, jnp.clip(t, 0, M - 1), 0, keepdims=False)
            my_in = jnp.where(stage == 0, first_in, recv)
            out, a = stage_fn(stage_params, my_in)
            m_idx = t - stage
            valid = (m_idx >= 0) & (m_idx < M)
            aux = aux + jnp.where(valid, a, 0.0)
            store_idx = jnp.clip(t - (stages - 1), 0, M - 1)
            is_store = (stage == stages - 1) & (t >= stages - 1)
            cur = jax.lax.dynamic_index_in_dim(
                outputs, store_idx, 0, keepdims=False)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, jnp.where(is_store, out, cur), store_idx, 0)
            nxt = jax.lax.ppermute(out, "stage", perm) if stages > 1 else out
            return (recv if stages == 1 else nxt, outputs, aux), None

        init = (jnp.zeros_like(x_mb[0]), jnp.zeros_like(x_mb),
                jnp.zeros((), jnp.float32))
        (_, outputs, aux), _ = jax.lax.scan(step, init, jnp.arange(T))
        # broadcast final outputs (held by the last stage) to every stage
        outputs = jax.lax.psum(
            jnp.where(stage == stages - 1, outputs, jnp.zeros_like(outputs)),
            "stage")
        aux = jax.lax.psum(aux, "stage") / M
        return outputs, aux

    def stage_param_spec(tree):
        return jax.tree.map(
            lambda x: PartitionSpec("stage", *([None] * (x.ndim - 1))), tree)

    def run(group_params, x_mb):
        in_specs = (stage_param_spec(group_params), PartitionSpec())
        return jax.shard_map(
            body_fn, mesh=mesh, in_specs=in_specs,
            out_specs=(PartitionSpec(), PartitionSpec()),
            check_vma=False)(group_params, x_mb)

    return run


def make_pipeline_loss(cfg: ModelConfig, plan: Plan, mesh: Mesh):
    """Full-model loss with the block stack pipelined (embedding and
    unembedding replicated outside the shard_map region)."""
    M = plan.microbatches
    blocks = make_pipelined_blocks(cfg, plan, mesh)

    def loss_fn(params, batch):
        from ..train.steps import _ce_from_logits
        x = embed_inputs(params, cfg, batch)
        b, s, d = x.shape
        assert b % M == 0, f"batch {b} not divisible by microbatches {M}"
        x_mb = x.reshape(M, b // M, s, d)
        outs, aux = blocks(params["groups"][0], x_mb)
        x = outs.reshape(b, s, d)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = unembed(params, cfg, x)
        loss, metrics = _ce_from_logits(cfg, logits, batch)
        metrics["aux_loss"] = aux
        return loss + aux, metrics

    return loss_fn
