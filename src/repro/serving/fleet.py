"""SLO-aware fleet planning + the runtime's serving-fleet manager.

This is the planning/execution layer between :class:`~repro.core.job.ServeJob`
(a model + latency SLO + traffic trace) and the
:class:`~repro.serving.engine.ContinuousBatchingEngine` replicas that
serve it:

- :func:`serve_profiles` turns a cluster's device classes into per-class
  serve profiles (per-token engine step time of one replica), the same
  ``(name, technique, class, count)`` key shape training profiles use —
  so serve throughput rides the existing profile plumbing
  (:class:`~repro.core.perfmodel.ObservedProfiles` overlays, noise
  factors, solver adapters) unchanged.
- :func:`plan_fleets` picks, per fleet, a device class and a per-window
  replica count from those curves under the p99-latency SLO — the
  serving half of the joint plan.  :func:`fleet_reservations` converts a
  plan into the solver's ``(class, gpus, release_s)`` capacity
  reservations so the training MILP optimizes around it.
- :func:`simulate_fleet` is the queueing model the virtual-time backend
  scores traces with: each replica contributes ``slots`` deterministic
  servers (a request occupies one slot for ``tokens_per_request`` engine
  steps), server count follows the fleet's resize history.
- :class:`FleetManager` drives fleets inside the event runtime:
  allocates replica device blocks from the placement pool (so GPU-second
  conservation covers serving), rescales them at introspection ticks as
  traffic shifts, records measured step times for the
  ``ObservedProfiles`` feedback loop, and computes the per-window
  p50/p99/attainment stats that land in ``SimResult.stats``.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.job import SERVE_TECH, ClusterSpec, ServeJob
from ..core.perfmodel import profile_key, step_time_of
from ..core.profiler import Profile
from ..data.traffic import window_rates

# a replica's deterministic service time must leave this fraction of the
# SLO as queueing headroom for the class to be considered feasible
SERVICE_SLO_FRAC = 0.6
# target utilization the replica count is sized to (capacity headroom
# absorbs within-window burstiness so the p99 stays inside the SLO)
DEFAULT_UTIL_CAP = 0.7


def serve_profiles(serves: Sequence[ServeJob], cluster: ClusterSpec, *,
                   base_step_s: float = 0.02,
                   ref_d_model: int = 512) -> Dict[Tuple, Profile]:
    """Analytic per-class serve profiles: one :class:`Profile` per
    (fleet, device class) keyed ``(name, SERVE_TECH, class,
    gpus_per_replica)`` whose ``step_time_s`` is the per-token engine
    step time of a single replica.

    Decode is memory-bound, so the step time scales with model width
    and inversely with the class's ``speed_hint`` — the same shape the
    roofline profiler uses for training steps.  Callers with measured
    engines overwrite these through ``ObservedProfiles``.
    """
    out: Dict[Tuple, Profile] = {}
    for s in serves:
        width = getattr(s.cfg, "d_model", ref_d_model) or ref_d_model
        for dc in cluster.device_classes:
            st = base_step_s * (width / ref_d_model) / max(dc.speed_hint,
                                                           1e-9)
            out[(s.name, SERVE_TECH, dc.name, s.gpus_per_replica)] = \
                Profile(s.name, SERVE_TECH, s.gpus_per_replica, st,
                        mem_per_device=0.0, feasible=True,
                        source="analytic-serve", device_class=dc.name)
    return out


def required_replicas(serve: ServeJob, step_time_s: float, rate_rps: float,
                      *, util_cap: float = DEFAULT_UTIL_CAP) -> int:
    """Smallest replica count whose slot capacity covers ``rate_rps``
    with ``util_cap`` headroom.  A replica serves ``slots`` concurrent
    requests, each holding a slot for ``tokens_per_request *
    step_time_s`` seconds."""
    service_s = serve.tokens_per_request * step_time_s
    per_replica = serve.slots / service_s          # req/s at 100% util
    if rate_rps <= 0:
        return 1
    return max(1, int(math.ceil(rate_rps / (util_cap * per_replica))))


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """The solver-facing serving plan for one fleet: the chosen device
    class and the replica count per traffic window."""
    serve: ServeJob
    device_class: str
    step_time_s: float               # per-token step estimate used
    window_s: float
    replicas: Tuple[int, ...]        # per window

    @property
    def peak_replicas(self) -> int:
        return max(self.replicas) if self.replicas else 1

    def gpus(self, window: int) -> int:
        w = min(max(window, 0), len(self.replicas) - 1)
        return self.replicas[w] * self.serve.gpus_per_replica

    @property
    def peak_gpus(self) -> int:
        return self.peak_replicas * self.serve.gpus_per_replica


def plan_fleet(serve: ServeJob, profiles, cluster: ClusterSpec, *,
               window_s: float, horizon_s: float,
               util_cap: float = DEFAULT_UTIL_CAP,
               device_class: Optional[str] = None) -> FleetPlan:
    """Pick a device class + per-window replica counts for one fleet.

    A class is feasible when its deterministic service time fits inside
    ``SERVICE_SLO_FRAC`` of the SLO (queueing needs the rest) and its
    peak replica demand fits the class's capacity.  Among feasible
    classes the one spending the fewest GPU-seconds wins; ties go to the
    slowest class (lowest ``speed_hint``) so fast GPUs stay available
    for training."""
    rates = window_rates(serve.trace, window_s, horizon_s)
    candidates = []
    for dc in cluster.device_classes:
        if device_class is not None and dc.name != device_class:
            continue                   # fleet already pinned to a class
        try:
            st = step_time_of(profiles, serve.name, SERVE_TECH,
                              serve.gpus_per_replica, device_class=dc.name)
        except KeyError:
            continue
        if not math.isfinite(st):
            continue
        service_s = serve.tokens_per_request * st
        if service_s > SERVICE_SLO_FRAC * serve.slo_p99_s:
            continue                       # class too slow for the SLO
        reps = tuple(min(serve.max_replicas,
                         required_replicas(serve, st, r,
                                           util_cap=util_cap))
                     for r in rates)
        if max(reps) * serve.gpus_per_replica > dc.total_gpus:
            continue                       # peak does not fit the class
        gpu_s = sum(reps) * serve.gpus_per_replica * window_s
        candidates.append((gpu_s, dc.speed_hint, dc.name, st, reps))
    if not candidates:
        raise ValueError(
            f"fleet {serve.name}: no device class meets the "
            f"{serve.slo_p99_s:g}s p99 SLO within capacity")
    gpu_s, _, name, st, reps = min(candidates)
    return FleetPlan(serve, name, st, window_s, reps)


def plan_fleets(serves: Sequence[ServeJob], profiles,
                cluster: ClusterSpec, *, window_s: float,
                horizon_s: float,
                util_cap: float = DEFAULT_UTIL_CAP
                ) -> Dict[str, FleetPlan]:
    return {s.name: plan_fleet(s, profiles, cluster, window_s=window_s,
                               horizon_s=horizon_s, util_cap=util_cap)
            for s in serves}


def fleet_reservations(plans: Dict[str, FleetPlan]
                       ) -> List[Tuple[Optional[str], int, float]]:
    """Convert fleet plans into the solver's ``(class, gpus,
    release_s)`` reservation triples.

    Reservations hold from t=0 until release, so the tightest expressible
    envelope of a time-varying demand is its non-increasing majorant:
    ``env(w) = max demand over windows >= w``.  Growth later in the
    horizon is therefore pre-reserved (conservative for the SLO; the
    runtime's replans reclaim the slack as windows pass)."""
    out: List[Tuple[Optional[str], int, float]] = []
    for plan in plans.values():
        demand = [plan.gpus(w) for w in range(len(plan.replicas))]
        if not demand:
            continue
        env = list(demand)
        for w in range(len(env) - 2, -1, -1):
            env[w] = max(env[w], env[w + 1])
        # decompose the non-increasing envelope into hold-until triples
        out.append((plan.device_class, env[-1], math.inf))
        for w in range(len(env) - 1):
            drop = env[w] - env[w + 1]
            if drop > 0:
                out.append((plan.device_class, drop,
                            (w + 1) * plan.window_s))
    return out


def simulate_fleet(arrivals: Sequence[float], service_s: float,
                   servers: Sequence[Tuple[float, int]]) -> List[float]:
    """FIFO multi-server queueing sim: request latencies under a
    time-varying server count.

    ``servers`` is the fleet's resize history ``[(t, n_servers), ...]``
    (each entry: total concurrent slots from ``t`` on).  Service is
    deterministic (``service_s`` per request).  Shrinks drop the most
    backlogged servers — in-flight latencies already assigned stand, the
    survivors carry the queue.  A request that can never be served
    (no servers for the rest of time) gets ``inf``."""
    if service_s <= 0:
        raise ValueError("service_s must be > 0")
    changes = sorted(servers)
    free: List[float] = []               # next-free time per live server
    cur, ci = 0, 0
    lat: List[float] = []

    def resize(n: int, t: float) -> None:
        nonlocal cur
        if n > cur:
            for _ in range(n - cur):
                heapq.heappush(free, t)
        elif n < cur:
            keep = sorted(free)[:n]
            free[:] = keep
            heapq.heapify(free)
        cur = n

    for a in sorted(arrivals):
        while ci < len(changes) and changes[ci][0] <= a:
            resize(changes[ci][1], changes[ci][0])
            ci += 1
        if not free:
            # no capacity now: the request waits for the next grow
            j = ci
            while j < len(changes) and changes[j][1] <= 0:
                j += 1
            if j == len(changes):
                lat.append(math.inf)
                continue
            while ci <= j:
                resize(changes[ci][1], changes[ci][0])
                ci += 1
        start = max(a, heapq.heappop(free))
        heapq.heappush(free, start + service_s)
        lat.append(start - a + service_s)
    return lat


def window_stats(arrivals: Sequence[float], latencies: Sequence[float],
                 slo_s: float, window_s: float, horizon_s: float) -> dict:
    """Per-window p50/p99 latency + SLO attainment, and the overall
    attainment across every request (the bench's gate)."""
    n = max(1, int(math.ceil(horizon_s / window_s)))
    buckets: List[List[float]] = [[] for _ in range(n)]
    for a, l in zip(sorted(arrivals), latencies):
        if 0.0 <= a < horizon_s:
            buckets[min(n - 1, int(a // window_s))].append(l)
    windows = []
    for w, bucket in enumerate(buckets):
        if not bucket:
            windows.append({"t_s": w * window_s, "requests": 0})
            continue
        arr = np.asarray(bucket)
        windows.append({
            "t_s": w * window_s,
            "requests": len(bucket),
            # "lower" avoids inf-inf interpolation when a request never
            # found a server (fleet scaled to zero under live traffic)
            "p50_s": float(np.percentile(arr, 50, method="lower")),
            "p99_s": float(np.percentile(arr, 99, method="lower")),
            "attainment": float(np.mean(arr <= slo_s)),
        })
    served = [l for b in buckets for l in b]
    overall = float(np.mean(np.asarray(served) <= slo_s)) \
        if served else 1.0
    return {"slo_p99_s": slo_s, "requests": len(served),
            "attainment": overall, "windows": windows}


class _FleetState:
    """Runtime state of one live fleet: its replica allocations and the
    (time, total-slots) resize history the queueing sim replays."""

    def __init__(self, serve: ServeJob, device_class: str):
        self.serve = serve
        self.device_class = device_class
        self.handles: List = []          # live per-replica LaunchHandles
        self.history: List[Tuple[float, int]] = []   # (t, total slots)
        self.step_time_s: float = float("nan")       # measured per-token

    @property
    def replicas(self) -> int:
        return len(self.handles)

    def log_size(self, t: float) -> None:
        self.history.append((t, self.replicas * self.serve.slots))


class FleetManager:
    """Drives serving fleets inside :func:`~repro.core.runtime.
    execute_runtime`.

    ``adaptive=True`` (Saturn) rescales each fleet at every introspection
    tick to the demand of the windows the coming interval covers;
    ``adaptive=False`` is the static-partition practice: peak-provision
    once at t=0 and never touch it again.  Either way replicas are real
    placement-pool allocations with Gantt segments and GPU-second
    accounting, and measured step times feed the ``observed`` overlay
    replans plan over."""

    def __init__(self, serves: Sequence[ServeJob], cluster: ClusterSpec,
                 *, window_s: float, horizon_s: Optional[float] = None,
                 util_cap: float = DEFAULT_UTIL_CAP,
                 adaptive: bool = True):
        if window_s <= 0:
            raise ValueError("window_s must be > 0")
        self.serves = list(serves)
        self.cluster = cluster
        self.window_s = float(window_s)
        self.horizon_s = float(horizon_s) if horizon_s is not None else \
            max([max(s.trace) if s.trace else 0.0
                 for s in self.serves] + [window_s])
        self.util_cap = util_cap
        self.adaptive = adaptive
        self.observed: Dict[Tuple, float] = {}
        self._fleets: Dict[str, _FleetState] = {}
        self._plans: Dict[str, FleetPlan] = {}
        self._stats: Dict[str, dict] = {}
        self.evictions = 0               # training launches evicted

    # ------------------------------------------------------------ sizing
    def plans(self, profiles) -> Dict[str, FleetPlan]:
        """(Re)plan every fleet's class + per-window replicas from the
        current profile view — estimates at first, measured step times
        once the fleets run (the ObservedProfiles feedback loop).  A
        fleet that is already live stays pinned to its class; if the
        observed curve makes the pinned class infeasible the previous
        plan is kept (the SLO stats will show the miss honestly)."""
        for s in self.serves:
            fs = self._fleets.get(s.name)
            pin = fs.device_class if fs is not None else None
            try:
                self._plans[s.name] = plan_fleet(
                    s, profiles, self.cluster, window_s=self.window_s,
                    horizon_s=self.horizon_s, util_cap=self.util_cap,
                    device_class=pin)
            except ValueError:
                if s.name not in self._plans:
                    raise
        return self._plans

    def target_replicas(self, name: str, t: float,
                        lookahead_s: float) -> int:
        """Replica target at time ``t``: the max windowed demand over
        ``[t, t + lookahead_s)`` (adaptive) or the all-horizon peak
        (static)."""
        plan = self._plans[name]
        if not self.adaptive:
            return plan.peak_replicas
        if t >= self.horizon_s:
            return 0                     # trace exhausted: stand down
        w0 = int(t // self.window_s)
        w1 = int(math.ceil((t + max(lookahead_s, self.window_s))
                           / self.window_s))
        return max(plan.replicas[min(w, len(plan.replicas) - 1)]
                   for w in range(w0, max(w1, w0 + 1)))

    def held(self, device_class: Optional[str] = None) -> int:
        total = 0
        for fs in self._fleets.values():
            if device_class is None or fs.device_class == device_class:
                total += sum(h.n_gpus for h in fs.handles)
        return total

    def can_shrink_later(self, t: float) -> bool:
        """Whether any fleet's future target is below its current size —
        the runtime's deadlock check waits on this."""
        if not self.adaptive:
            return False
        for name, fs in self._fleets.items():
            future = [self.target_replicas(name, tt, self.window_s)
                      for tt in np.arange(t, self.horizon_s + self.window_s,
                                          self.window_s)] + [0]
            if min(future) < fs.replicas:
                return True
        return False

    # ---------------------------------------------------------- runtime
    def resize(self, runtime, t: float, lookahead_s: float) -> bool:
        """Bring every fleet to its target for the coming interval.
        ``runtime`` is the engine's :class:`FleetRuntimeHooks` bridge
        (allocate/release/evict + step-time measurement).  Returns True
        when any fleet changed size (the policy should replan)."""
        any_changed = False
        for serve in self.serves:
            name = serve.name
            plan = self._plans[name]
            fs = self._fleets.get(name)
            if fs is None:
                fs = self._fleets[name] = _FleetState(serve,
                                                      plan.device_class)
                if t > 0:
                    fs.log_size(0.0)     # no capacity before it came up
            target = self.target_replicas(name, t, lookahead_s)
            changed = False
            while fs.replicas > target:
                runtime.release_replica(fs, t)
                changed = True
            while fs.replicas < target:
                if not runtime.grow_replica(fs, t):
                    break                # truly no capacity: retry next tick
                changed = True
            if changed or not fs.history:
                fs.log_size(t)
            if fs.handles and name not in self.observed_keys():
                st = runtime.measure_step_time(fs)
                fs.step_time_s = st
                key = profile_key(runtime.profiles, name, SERVE_TECH,
                                  serve.gpus_per_replica, fs.device_class)
                self.observed[key] = st
            any_changed = any_changed or changed
        return any_changed

    def observed_keys(self):
        return {k[0] for k in self.observed}

    def finish(self, runtime, t: float) -> None:
        """Release every fleet and score the full run: replay each trace
        through the queueing sim against the fleet's resize history."""
        for name, fs in self._fleets.items():
            while fs.handles:
                runtime.release_replica(fs, t)
            fs.log_size(t)
            serve = fs.serve
            st = fs.step_time_s
            if not math.isfinite(st):
                st = self._plans[name].step_time_s
            service_s = serve.tokens_per_request * st
            horizon = min(self.horizon_s, max(t, self.window_s))
            arrivals = [a for a in serve.trace if a < horizon]
            lat = simulate_fleet(arrivals, service_s, fs.history)
            stats = window_stats(arrivals, lat, serve.slo_p99_s,
                                 self.window_s, horizon)
            stats["device_class"] = fs.device_class
            stats["step_time_s"] = st
            stats["peak_replicas"] = max(
                (n // serve.slots for _, n in fs.history), default=0)
            stats["history"] = list(fs.history)
            self._stats[name] = stats

    def stats(self) -> Dict[str, dict]:
        out = dict(self._stats)
        out["evictions"] = self.evictions
        return out
