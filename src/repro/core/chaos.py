"""Fault injection and elasticity: typed cluster events + seeded
generators (ROADMAP item 5).

A :class:`ChaosTrace` is an ordered sequence of concrete
:class:`~repro.core.events.ClusterEvent` subtypes the runtime injects
through its :class:`~repro.core.events.EventQueue`:

- :class:`NodeFailure` — ``n_gpus`` devices of a class die, busy or not
  (lowest present ids).  Launches on dead devices are killed and salvage
  their last periodic checkpoint: progress since
  ``ChaosTrace.checkpoint_every_s`` is lost, NOT the whole launch.  An
  optional ``recover_after_s`` schedules the matching
  :class:`NodeRecovery` automatically.
- :class:`NodeRecovery` / :class:`SpotGrant` — capacity returns / a spot
  grant lands: the placement pool grows by ``n_gpus`` FRESH device ids
  (ids are never reused, so Gantt history and conservation accounting
  stay unambiguous).
- :class:`SpotRevoke` — the provider reclaims ``n_gpus`` spot devices.
  Unlike a failure, revocation is polite: free devices go first, busy
  ones only when the free pool cannot cover the revocation (victims
  still salvage their checkpoints).
- :class:`CapacityChange` — signed administrative resize: ``delta > 0``
  grows the pool, ``delta < 0`` shrinks it (free-first, like a revoke).

All events are count-based, not id-based: which concrete devices die is
resolved by the runtime at processing time against the devices actually
present then — so a trace composed of independent generators stays valid
no matter how the pool has grown or shrunk in between.

The generators are seeded and deterministic.  Failure sweeps use Poisson
THINNING: :func:`poisson_node_failures` draws the event stream once at
``max_rate_per_hour`` and keeps each event with probability
``rate / max_rate`` using per-event uniform marks — so the failures at a
higher rate are a strict superset of those at a lower rate (same seed),
which is what makes "Saturn's margin widens with churn" a monotone,
gateable claim rather than seed noise.
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Optional, Sequence, Tuple

from .events import ClusterEvent
from .job import DEFAULT_CLASS


@dataclasses.dataclass(frozen=True)
class NodeFailure(ClusterEvent):
    """``n_gpus`` devices of ``device_class`` fail hard (busy included:
    lowest present ids die).  ``recover_after_s`` schedules the matching
    :class:`NodeRecovery` for however many devices actually died."""
    n_gpus: int = 1
    device_class: str = DEFAULT_CLASS
    recover_after_s: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class NodeRecovery(ClusterEvent):
    n_gpus: int = 1
    device_class: str = DEFAULT_CLASS


@dataclasses.dataclass(frozen=True)
class SpotGrant(ClusterEvent):
    n_gpus: int = 1
    device_class: str = DEFAULT_CLASS


@dataclasses.dataclass(frozen=True)
class SpotRevoke(ClusterEvent):
    """Free devices are reclaimed first; busy ones only if the free pool
    cannot cover the revocation."""
    n_gpus: int = 1
    device_class: str = DEFAULT_CLASS


@dataclasses.dataclass(frozen=True)
class CapacityChange(ClusterEvent):
    """Administrative resize: ``delta > 0`` adds fresh devices,
    ``delta < 0`` removes (free-first)."""
    delta: int = 0
    device_class: str = DEFAULT_CLASS


@dataclasses.dataclass(frozen=True)
class ChaosTrace:
    """A seeded scenario: cluster events + the checkpoint cadence that
    governs how much progress a killed launch salvages.

    ``checkpoint_every_s`` is the periodic-checkpoint interval measured
    from each launch's start; a launch killed at ``t`` resumes from
    ``start + floor((t - start) / interval) * interval``.  The launch
    start itself always counts as a checkpoint, so a failure never
    erases progress from before the launch."""
    events: Tuple[ClusterEvent, ...] = ()
    checkpoint_every_s: float = 600.0
    name: str = "chaos"

    def __post_init__(self):
        if self.checkpoint_every_s <= 0:
            raise ValueError("checkpoint_every_s must be positive")
        for e in self.events:
            if not isinstance(e, ClusterEvent):
                raise TypeError(f"not a ClusterEvent: {e!r}")
            if e.t < 0:
                raise ValueError(f"event before t=0: {e!r}")
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events, key=lambda e: e.t)))

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)


def poisson_node_failures(rate_per_hour: float, horizon_s: float, *,
                          seed: int = 0,
                          device_class: str = DEFAULT_CLASS,
                          n_gpus: int = 1,
                          recover_after_s: Optional[float] = None,
                          max_rate_per_hour: Optional[float] = None
                          ) -> Tuple[NodeFailure, ...]:
    """Seeded Poisson failure arrivals over ``[0, horizon_s)``.

    With ``max_rate_per_hour`` set, the stream is generated ONCE at the
    max rate and thinned: an event survives iff its uniform mark is
    below ``rate / max_rate``.  Sweeping ``rate_per_hour`` under a fixed
    ``max_rate_per_hour`` and seed therefore yields nested traces —
    every failure at rate r also occurs at every rate r' > r.
    """
    if rate_per_hour < 0:
        raise ValueError("rate_per_hour must be >= 0")
    max_rate = max_rate_per_hour if max_rate_per_hour is not None \
        else rate_per_hour
    if rate_per_hour > max_rate:
        raise ValueError(f"rate_per_hour {rate_per_hour} exceeds "
                         f"max_rate_per_hour {max_rate}")
    if max_rate <= 0:
        return ()
    rng = random.Random(seed)
    lam = max_rate / 3600.0
    out: List[NodeFailure] = []
    t = 0.0
    while True:
        # draw the gap AND the thinning mark unconditionally so the
        # underlying stream is identical across rates (superset property)
        t += rng.expovariate(lam)
        keep = rng.random() * max_rate < rate_per_hour
        if t >= horizon_s:
            break
        if keep:
            out.append(NodeFailure(t, n_gpus, device_class,
                                   recover_after_s))
    return tuple(out)


def spot_capacity_trace(horizon_s: float, *, seed: int = 0,
                        device_class: str = DEFAULT_CLASS,
                        n_gpus: int = 1,
                        mean_up_s: float = 1800.0,
                        mean_down_s: float = 900.0
                        ) -> Tuple[ClusterEvent, ...]:
    """Two-state spot availability: the capacity starts granted, is
    revoked after an Exp(mean_up_s) hold, re-granted after an
    Exp(mean_down_s) outage, and so on — the classic price-spike
    availability trace, alternating :class:`SpotRevoke` /
    :class:`SpotGrant` events over ``n_gpus`` devices."""
    if mean_up_s <= 0 or mean_down_s <= 0:
        raise ValueError("mean_up_s and mean_down_s must be positive")
    rng = random.Random(seed)
    out: List[ClusterEvent] = []
    t, available = 0.0, True
    while True:
        t += rng.expovariate(1.0 / (mean_up_s if available
                                    else mean_down_s))
        if t >= horizon_s:
            break
        out.append(SpotRevoke(t, n_gpus, device_class) if available
                   else SpotGrant(t, n_gpus, device_class))
        available = not available
    return tuple(out)


def merge_events(*seqs: Sequence[ClusterEvent]
                 ) -> Tuple[ClusterEvent, ...]:
    """Merge independently generated event streams into one time-sorted
    tuple (e.g. a failure trace + a spot trace over different classes)."""
    out: List[ClusterEvent] = []
    for s in seqs:
        out.extend(s)
    return tuple(sorted(out, key=lambda e: e.t))
