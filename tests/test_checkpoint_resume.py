"""Checkpoint resume continuity: the bf16→f32→bf16 roundtrip in
checkpoint/store.py is lossless, and a run continued from a mid-run
checkpoint reproduces the uninterrupted loss trajectory exactly
(state AND data-stream position restored)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (load_checkpoint, load_metadata,
                                    save_checkpoint)
from repro.configs import get_config
from repro.core.executor import LocalRunner
from repro.core.job import Job
from repro.core.library import ParallelismLibrary
from repro.data.synthetic import SyntheticLM

MICRO = dataclasses.replace(get_config("xlstm-125m").reduced(),
                            d_model=64, num_heads=2, num_kv_heads=2,
                            head_dim=32, name="xlstm-micro")


def test_bf16_roundtrip_exact(tmp_path):
    """bf16 leaves are upcast to f32 on save and cast back on load —
    a lossless roundtrip (f32 holds every bf16 value exactly)."""
    rng = np.random.RandomState(0)
    tree = {
        "w": jnp.asarray(rng.randn(16, 8), jnp.bfloat16),
        "b": jnp.asarray(rng.randn(8), jnp.float32),
        "step": jnp.asarray(7, jnp.int32),
    }
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, tree, {"step": 7})
    out = load_checkpoint(path, tree)
    assert out["w"].dtype == jnp.bfloat16
    assert out["b"].dtype == jnp.float32
    assert bool(jnp.all(out["w"] == tree["w"]))
    assert bool(jnp.all(out["b"] == tree["b"]))
    assert int(out["step"]) == 7
    assert load_metadata(path) == {"step": 7}


def test_data_stream_skip_is_deterministic():
    """skip=k lands exactly on the k-th batch of the uninterrupted
    stream (the resume path's data-position contract)."""
    src = SyntheticLM(MICRO, seed=3)
    full = list(src.batches(2, 16, num_batches=6))
    tail = list(src.batches(2, 16, num_batches=3, skip=3))
    for a, b in zip(full[3:], tail):
        assert a.keys() == b.keys()
        for k in a:
            assert bool(jnp.all(a[k] == b[k]))


@pytest.mark.slow
def test_resume_trajectory_matches_uninterrupted(tmp_path):
    """Save mid-run, reload, continue: the resumed run's losses and
    final parameters must match an uninterrupted run bit-for-bit
    (covers the checkpoint roundtrip AND the data-stream skip)."""
    job = Job("cont", MICRO, 2, 32, total_steps=8, lr=1e-3, seed=0)
    lib = ParallelismLibrary()
    tech = lib.get("ddp")

    r_full = LocalRunner(ckpt_dir=str(tmp_path / "a")).run_job(
        job, tech, 1, resume=False)
    runner_b = LocalRunner(ckpt_dir=str(tmp_path / "b"))
    r_half = runner_b.run_job(job, tech, 1, steps=5, resume=False)
    r_rest = runner_b.run_job(job, tech, 1)   # resumes from checkpoint

    assert r_rest["steps"] == 3 and r_rest["done"]
    assert r_half["loss"] != r_full["loss"]
    assert r_rest["loss"] == pytest.approx(r_full["loss"], rel=1e-6)
    # the whole state roundtrips: compare final parameters, not just loss
    a = dict(np.load(str(tmp_path / "a" / "cont.npz")))
    b = dict(np.load(str(tmp_path / "b" / "cont.npz")))
    assert a.keys() == b.keys()
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-6, atol=1e-7)
    # the timing fix: compile time reported separately, not in wall_s
    assert r_full["compile_s"] > 0
    assert r_full["wall_s"] < r_full["compile_s"]
    assert r_full["step_time_s"] == pytest.approx(
        r_full["wall_s"] / (r_full["steps"] - 1))
