"""Performance-model layer: anchor selection, curve interpolation,
feasibility boundaries, cache versioning/atomicity, and solver parity
between interpolated and exhaustive profiles."""
import json
import math
import os

import pytest

from repro.configs import get_config
from repro.core.job import ClusterSpec, Job
from repro.core.library import ParallelismLibrary
from repro.core.perfmodel import (PerfModel, ThroughputCurve,
                                  iter_job_profiles, select_anchor_counts,
                                  step_time_of)
from repro.core.profiler import (CACHE_VERSION, HARDWARE, Profile,
                                 TrialRunner)
from repro.core.solver import choices_from_profiles, solve_joint

CFG = get_config("xlstm-125m").reduced()


def mk_curve(times, valid, domain, cap=1e12, mems=None):
    anchors = {g: Profile("j", "ddp", g, t, (mems or {}).get(g, 1e9),
                          (mems or {}).get(g, 1e9) <= cap, "test")
               for g, t in times.items()}
    return ThroughputCurve("j", "ddp", cap, anchors, valid, domain)


# ----------------------------------------------------- anchor selection

def test_anchor_selection_geometric_with_boundaries():
    assert select_anchor_counts(range(1, 33)) == [1, 2, 4, 8, 16, 32]
    # boundaries always kept, even off the geometric ladder
    assert select_anchor_counts([3, 4, 5, 6, 7]) == [3, 6, 7]
    assert select_anchor_counts([5]) == [5]
    assert select_anchor_counts([]) == []
    # a wider ratio profiles fewer counts
    assert select_anchor_counts(range(1, 33), ratio=4.0) == [1, 4, 16, 32]


def test_anchor_reduction_at_least_4x_on_dense_grid():
    counts = list(range(1, 33))
    anchors = select_anchor_counts(counts)
    assert len(counts) / len(anchors) >= 4.0


# ------------------------------------------------------- interpolation

def test_interpolation_monotone_nonincreasing_between_anchors():
    c = mk_curve({1: 10.0, 4: 3.5, 16: 1.2}, valid=range(1, 17),
                 domain=range(1, 17))
    prev = math.inf
    for g in range(1, 17):
        t = c.step_time(g)
        assert t <= prev + 1e-12, f"step time increased at g={g}"
        prev = t
    # exact at anchors
    assert c.step_time(4) == 3.5
    assert c.profile(4).source == "test"
    assert c.profile(5).source == "interpolated"


def test_extrapolation_never_beats_perfect_scaling():
    c = mk_curve({1: 10.0, 4: 3.0}, valid=range(1, 33), domain=range(1, 33))
    t4, t32 = c.step_time(4), c.step_time(32)
    assert t32 >= t4 * 4 / 32 - 1e-12
    # below the anchored range: fewer GPUs can never be faster
    assert c.step_time(1) >= c.step_time(4) - 1e-12


def test_single_anchor_is_constant():
    c = mk_curve({4: 2.0}, valid=range(1, 9), domain=range(1, 9))
    assert c.step_time(2) == pytest.approx(2.0)
    assert c.step_time(8) == pytest.approx(2.0)


# -------------------------------------------------- feasibility limits

def test_invalid_counts_report_infeasible():
    c = mk_curve({2: 5.0, 8: 2.0}, valid=[2, 4, 8], domain=range(1, 17))
    assert not c.feasible(1)          # outside search space
    assert c.step_time(1) == math.inf
    assert not c.feasible(12)         # in domain, not valid
    assert c.feasible(4)              # interpolated, valid, fits memory
    assert not c.valid_at(3)


def test_memory_infeasible_counts():
    # memory shrinks with g; counts below the fit threshold are flagged
    cap = 3e9
    c = mk_curve({1: 10.0, 8: 2.0}, valid=range(1, 9), domain=range(1, 9),
                 cap=cap, mems={1: 8e9, 8: 1e9})
    assert not c.feasible(1)
    assert c.feasible(8)
    # interpolated memory is monotone between the anchors, so there is
    # one crossing point
    flips = [c.feasible(g) for g in range(1, 9)]
    assert flips == sorted(flips)


# --------------------------------------------------- PerfModel mapping

def _small_model(counts=(1, 2, 3, 4, 5, 6, 7, 8)):
    lib = ParallelismLibrary()
    runner = TrialRunner(lib, HARDWARE["a100"])
    jobs = [Job("a", CFG, 8, 64, 200), Job("b", CFG, 8, 64, 300)]
    pm = runner.profile_all(jobs, counts, mode="napkin",
                            strategy="interpolate")
    return jobs, pm, runner


def test_perfmodel_mapping_contract():
    jobs, pm, runner = _small_model()
    assert isinstance(pm, PerfModel)
    assert len(pm) > 0
    # iteration yields only search-space-valid keys, and __getitem__
    # synthesizes a Profile for each
    for key in pm:
        p = pm[key]
        assert (p.job, p.technique, p.n_devices) == key
    assert ("a", "ddp", 3) in pm
    assert pm[("a", "ddp", 3)].source in ("interpolated", "napkin")
    with pytest.raises(KeyError):
        pm[("nope", "ddp", 2)]
    # anchors are real trials; the rest interpolate for free
    assert runner.trials == len(pm.anchor_keys())
    assert len(pm) > len(pm.anchor_keys())


def test_adapters_work_on_both_representations():
    jobs, pm, _ = _small_model()
    d = pm.to_dict()
    trip_pm = sorted((t, g) for t, g, _ in iter_job_profiles(pm, "a"))
    trip_d = sorted((t, g) for t, g, _ in iter_job_profiles(d, "a"))
    assert trip_pm == trip_d
    assert step_time_of(pm, "a", "ddp", 3) == \
        step_time_of(d, "a", "ddp", 3)


def test_simulate_runs_on_perfmodel():
    from repro.core.baselines import CurrentPractice
    from repro.core.executor import simulate
    jobs, pm, _ = _small_model()
    res = simulate(jobs, CurrentPractice(), pm,
                   ClusterSpec(nodes=1, gpus_per_node=8), noise_sigma=0.1)
    assert {g.job for g in res.gantt if g.kind == "run"} == {"a", "b"}


# -------------------------------------------------------- cache safety

def test_cache_version_mismatch_discarded(tmp_path):
    path = str(tmp_path / "cache.json")
    stale = [Profile("x", "ddp", 2, 1.0, 1e9, True, "napkin").to_json()]
    # legacy bare-list schema
    with open(path, "w") as f:
        json.dump(stale, f)
    r = TrialRunner(ParallelismLibrary(), HARDWARE["a100"], cache_path=path)
    assert not r._cache
    # wrong version number
    with open(path, "w") as f:
        json.dump({"version": CACHE_VERSION + 1, "profiles": stale}, f)
    r = TrialRunner(ParallelismLibrary(), HARDWARE["a100"], cache_path=path)
    assert not r._cache
    # torn write / corrupt JSON must not raise
    with open(path, "w") as f:
        f.write('{"version": 2, "profiles": [{"job": "x", "tech')
    r = TrialRunner(ParallelismLibrary(), HARDWARE["a100"], cache_path=path)
    assert not r._cache
    # records with unknown fields are skipped, not fatal
    with open(path, "w") as f:
        json.dump({"version": CACHE_VERSION,
                   "profiles": [{"bogus": 1}] + stale}, f)
    r = TrialRunner(ParallelismLibrary(), HARDWARE["a100"], cache_path=path)
    assert len(r._cache) == 1


def test_cache_roundtrip_and_batched_atomic_flush(tmp_path):
    path = str(tmp_path / "cache.json")
    lib = ParallelismLibrary()
    job = Job("c", CFG, 8, 64, 100)
    r = TrialRunner(lib, HARDWARE["a100"], cache_path=path, flush_every=3)
    r.profile(job, "ddp", 1, mode="napkin")
    r.profile(job, "ddp", 2, mode="napkin")
    assert not os.path.exists(path), "flush must batch, not rewrite per call"
    p4 = r.profile(job, "ddp", 4, mode="napkin")   # 3rd -> auto-flush
    assert os.path.exists(path)
    assert not [f for f in os.listdir(tmp_path) if ".tmp." in f], \
        "atomic write must not leave temp files"
    with open(path) as f:
        data = json.load(f)
    assert data["version"] == CACHE_VERSION
    r2 = TrialRunner(lib, HARDWARE["a100"], cache_path=path)
    assert r2.profile(job, "ddp", 4, mode="napkin").step_time_s == \
        p4.step_time_s
    assert r2.trials == 0, "cache hit must not rerun the trial"


# ----------------------------------------------- solver on curves

def test_solver_interpolated_close_to_exhaustive():
    lib = ParallelismLibrary()
    jobs = [Job(f"s{i}", CFG, 8, 64, 200 + 100 * i) for i in range(3)]
    counts = list(range(1, 9))
    hw = HARDWARE["a100"]
    ex = TrialRunner(lib, hw).profile_all(jobs, counts, mode="napkin")
    pm = TrialRunner(lib, hw).profile_all(jobs, counts, mode="napkin",
                                          strategy="interpolate")
    # curve-backed choices cover the same (tech, g) space
    for j in jobs:
        got = {(c.technique, c.n_gpus)
               for c in choices_from_profiles(j, pm, prune=False)}
        want = {(c.technique, c.n_gpus)
                for c in choices_from_profiles(j, ex, prune=False)}
        assert got == want
    s_ex = solve_joint(jobs, ex, 8, n_slots=12, time_limit_s=5)
    s_in = solve_joint(jobs, pm, 8, n_slots=12, time_limit_s=5)
    assert s_in.makespan_s == pytest.approx(s_ex.makespan_s, rel=0.10)


def test_napkin_curves_monotone_where_scaling_holds():
    """Interpolated ddp step times inherit the napkin model's scaling:
    wherever the anchors decrease, the curve between them decreases."""
    _, pm, _ = _small_model()
    for curve in pm.curves_for("a"):
        anchors = sorted(curve.anchors)
        for lo, hi in zip(anchors, anchors[1:]):
            t_lo, t_hi = curve.step_time(lo), curve.step_time(hi)
            if not (math.isfinite(t_lo) and math.isfinite(t_hi)):
                continue
            if t_lo >= t_hi:            # scaling holds on this segment
                prev = t_lo
                for g in range(lo, hi + 1):
                    if not curve.valid_at(g):
                        continue
                    t = curve.step_time(g)
                    assert t <= prev + 1e-12
                    prev = t
