"""Loop-aware HLO analyzer: the roofline instrument must be exact on
known workloads (scan trip counts, nested loops, in-place DUS)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile().as_text()


def test_scan_flops_multiplied_by_trip_count():
    def g(x):
        def body(c, _):
            return c @ x, None
        return jax.lax.scan(body, x, None, length=10)[0]
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    r = analyze(_compile(g, a))
    np.testing.assert_allclose(r["flops"], 10 * 2 * 512 ** 3, rtol=0.02)


def test_nested_scan_flops():
    def h(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ x, None
            return jax.lax.scan(inner, c, None, length=5)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    r = analyze(_compile(h, a))
    np.testing.assert_allclose(r["flops"], 15 * 2 * 256 ** 3, rtol=0.02)


def test_inplace_dus_not_overcounted():
    """A scan writing one row per step into an (S, D) buffer must count
    ~S*D bytes, not S^2*D."""
    S, D = 256, 512

    def g(x):
        def body(c, i):
            buf, v = c
            v = v * 1.0001
            buf = jax.lax.dynamic_update_index_in_dim(buf, v, i, 0)
            return (buf, v), None
        init = (jnp.zeros((S, D)), x)
        (buf, _), _ = jax.lax.scan(body, init, jnp.arange(S))
        return buf
    a = jax.ShapeDtypeStruct((D,), jnp.float32)
    r = analyze(_compile(g, a))
    written = r["bytes_written"]
    assert written < 6 * S * D * 4, f"DUS overcounted: {written:.2e}"
    assert written >= S * D * 4 * 0.5


def test_flops_scan_vs_unrolled_agree():
    def body_fn(c, x):
        return jnp.tanh(c @ x), None

    def scanned(x):
        return jax.lax.scan(body_fn, x, jnp.stack([x] * 6))[0]

    def unrolled(x):
        c = x
        for _ in range(6):
            c, _ = body_fn(c, x)
        return c
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    r1 = analyze(_compile(scanned, a))
    r2 = analyze(_compile(unrolled, a))
    np.testing.assert_allclose(r1["flops"], r2["flops"], rtol=0.05)


def test_collective_parse_smoke():
    hlo = """
ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %ar = f32[8]{0} all-reduce(%p), to_apply=%add
  ROOT %r = f32[8]{0} add(%ar, %p)
}
"""
    r = analyze(hlo)
    assert r["collectives"].get("all-reduce") == 32.0
