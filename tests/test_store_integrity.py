"""Checkpoint store integrity: atomic single-point commit of arrays +
metadata, content checksums verified on load, last-known-good fallback
for corrupt/truncated files, and the validate-before-trust resume
contract of load_training_state."""
import json
import os
import warnings

import numpy as np
import pytest

from repro.checkpoint.store import (META_KEY, CheckpointCorruptError,
                                    load_checkpoint, load_metadata,
                                    load_training_state, save_checkpoint,
                                    verify_checkpoint)


def tree(seed, scale=1.0):
    rng = np.random.RandomState(seed)
    return {"w": rng.randn(4, 4).astype(np.float32) * scale,
            "b": rng.randn(4).astype(np.float32) * scale}


def assert_tree_equal(a, b):
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


# ----------------------------------------------------- atomic commit

def test_metadata_is_bundled_inside_the_npz(tmp_path):
    """Arrays and metadata commit at ONE atomic point: the npz itself
    carries the metadata, so no crash window can pair new arrays with
    stale metadata."""
    p = str(tmp_path / "c.npz")
    save_checkpoint(p, tree(0), {"step": 7, "loss": 1.5})
    with np.load(p) as data:
        assert META_KEY in data
        meta = json.loads(bytes(data[META_KEY].tobytes()).decode())
    assert meta["step"] == 7
    assert "checksum" in meta


def test_no_stray_temp_files_after_save(tmp_path):
    p = str(tmp_path / "c.npz")
    save_checkpoint(p, tree(0), {"step": 1})
    names = set(os.listdir(tmp_path))
    assert not any(n.endswith(".tmp") for n in names)


def test_sidecar_still_written_and_metadata_prefers_bundle(tmp_path):
    p = str(tmp_path / "c.npz")
    save_checkpoint(p, tree(0), {"step": 3})
    assert os.path.exists(p + ".meta.json")
    # poison the sidecar: the bundled copy must win
    with open(p + ".meta.json", "w") as f:
        json.dump({"step": 999}, f)
    assert load_metadata(p)["step"] == 3
    assert "checksum" not in load_metadata(p)


def test_legacy_sidecar_fallback(tmp_path):
    """A checkpoint with no bundled metadata (pre-checksum format or
    missing file) falls back to the .meta.json sidecar."""
    p = str(tmp_path / "c.npz")
    with open(p + ".meta.json", "w") as f:
        json.dump({"step": 11}, f)
    assert load_metadata(p)["step"] == 11


# --------------------------------------------------------- checksums

def test_roundtrip_verifies_checksum(tmp_path):
    p = str(tmp_path / "c.npz")
    t = tree(1)
    save_checkpoint(p, t, {"step": 5})
    assert verify_checkpoint(p)["step"] == 5
    out = load_checkpoint(p, tree(99))
    assert_tree_equal(out, t)


def test_truncated_file_raises_corrupt(tmp_path):
    p = str(tmp_path / "c.npz")
    save_checkpoint(p, tree(1), {"step": 5}, keep_previous=False)
    size = os.path.getsize(p)
    with open(p, "r+b") as f:
        f.truncate(size // 2)
    with pytest.raises(CheckpointCorruptError):
        verify_checkpoint(p)
    with pytest.raises(CheckpointCorruptError):
        load_checkpoint(p, tree(1))


def test_bitflip_fails_checksum(tmp_path):
    """Same length, flipped payload bytes: only a CONTENT checksum
    catches this (zip structure can stay parseable)."""
    p = str(tmp_path / "c.npz")
    t = tree(1)
    save_checkpoint(p, t, {"step": 5}, keep_previous=False)
    with open(p, "rb") as f:
        blob = bytearray(f.read())
    # npz members are stored uncompressed: locate w's raw payload and
    # flip bytes there (zip structure and npy headers stay intact)
    off = blob.find(t["w"].tobytes())
    assert off > 0
    for i in range(off, off + 8):
        blob[i] ^= 0xFF
    with open(p, "wb") as f:
        f.write(blob)
    with pytest.raises(CheckpointCorruptError):
        load_checkpoint(p, tree(1))


def test_missing_array_raises_corrupt(tmp_path):
    p = str(tmp_path / "c.npz")
    save_checkpoint(p, {"w": np.zeros(3, np.float32)}, {"step": 1})
    with pytest.raises(CheckpointCorruptError, match="missing array"):
        load_checkpoint(p, tree(0))


# ------------------------------------------------- last-known-good

def training_tree(seed):
    # the {"params", "opt"} layout load_training_state restores into
    return {"params": {"w": tree(seed)["w"]}, "opt": {"b": tree(seed)["b"]}}


def test_prev_rotation(tmp_path):
    p = str(tmp_path / "c.npz")
    save_checkpoint(p, tree(1), {"step": 10})
    save_checkpoint(p, tree(2), {"step": 20})
    assert verify_checkpoint(p)["step"] == 20
    assert verify_checkpoint(p + ".prev")["step"] == 10
    assert_tree_equal(load_checkpoint(p + ".prev", tree(0)), tree(1))


def test_load_training_state_falls_back_to_prev(tmp_path):
    p = str(tmp_path / "c.npz")
    save_checkpoint(p, training_tree(1), {"step": 10})
    save_checkpoint(p, training_tree(2), {"step": 20})
    with open(p, "r+b") as f:
        f.truncate(os.path.getsize(p) // 2)
    with pytest.warns(RuntimeWarning, match="previous good checkpoint"):
        params, _, step = load_training_state(
            p, {"w": tree(0)["w"]}, {"b": tree(0)["b"]})
    assert step == 10
    np.testing.assert_array_equal(np.asarray(params["w"]), tree(1)["w"])


def test_load_training_state_step0_when_all_corrupt(tmp_path):
    p = str(tmp_path / "c.npz")
    save_checkpoint(p, training_tree(1), {"step": 10})
    save_checkpoint(p, training_tree(2), {"step": 20})
    for q in (p, p + ".prev"):
        with open(q, "r+b") as f:
            f.truncate(os.path.getsize(q) // 2)
    fresh_p, fresh_o = {"w": tree(7)["w"]}, {"b": tree(7)["b"]}
    with pytest.warns(RuntimeWarning):
        params, opt, step = load_training_state(p, fresh_p, fresh_o)
    assert step == 0
    assert params is fresh_p and opt is fresh_o


def test_load_training_state_clean_paths(tmp_path):
    p = str(tmp_path / "c.npz")
    # no checkpoint at all: inputs unchanged, step 0, NO warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        params, opt, step = load_training_state(
            p, {"w": tree(0)["w"]}, {"b": tree(0)["b"]})
    assert step == 0
    save_checkpoint(p, training_tree(3), {"step": 42})
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        params, _, step = load_training_state(
            p, {"w": tree(0)["w"]}, {"b": tree(0)["b"]})
    assert step == 42
    np.testing.assert_array_equal(np.asarray(params["w"]), tree(3)["w"])
