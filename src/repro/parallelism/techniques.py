"""The five concrete techniques registered in Saturn's Parallelism
Library (paper §3 registers FSDP, DDP, GPipe, offloading; we add TP and
implement offloading as full-remat — see DESIGN.md §5).
"""
from __future__ import annotations

from .base import Plan, Technique


class DDP(Technique):
    """Replicated params, batch sharded (torch-DDP analogue via pjit)."""

    name = "ddp"

    def search_space(self, cfg, n):
        return n >= 1  # memory feasibility is checked by the Trial Runner

    def plan(self, cfg, n):
        return Plan(self.name, n, (("data", n),),
                    {"batch": "data"}, param_policy="replicate")

    def memory_fraction(self, cfg, n):
        return 1.0

    def step_overhead(self):
        return 1.05  # grad all-reduce


class FSDP(Technique):
    """ZeRO-3: params + opt state sharded over data axis, batch sharded."""

    name = "fsdp"

    def search_space(self, cfg, n):
        return n >= 2

    def plan(self, cfg, n):
        return Plan(self.name, n, (("data", n),),
                    {"batch": "data"}, param_policy="fsdp")

    def memory_fraction(self, cfg, n):
        return 1.0 / n

    def step_overhead(self):
        return 1.15  # per-layer all-gather + reduce-scatter


class TP(Technique):
    """Megatron-style tensor parallelism: heads / FFN / experts sharded
    over the model axis; batch replicated.  For MoE archs this is expert
    parallelism (experts over the model axis, all-to-all dispatch)."""

    name = "tp"

    def search_space(self, cfg, n):
        if n < 2:
            return False
        ok_heads = cfg.num_heads % n == 0
        ok_ffn = (cfg.d_ff % n == 0) if cfg.d_ff else True
        ok_exp = (cfg.moe.num_experts % n == 0) if cfg.is_moe else True
        return ok_heads and ok_ffn and ok_exp

    def plan(self, cfg, n):
        kv_ok = cfg.num_kv_heads % n == 0
        rules = {
            "batch": None,
            "heads": "model",
            "kv_heads": "model" if kv_ok else None,
            "ffn": "model",
            "experts": "model",
            "vocab": "model",
            "rnn": "model",
        }
        return Plan(self.name, n, (("model", n),), rules,
                    param_policy="rules")

    def memory_fraction(self, cfg, n):
        return 1.0 / n + 0.05

    def step_overhead(self):
        return 1.25  # per-layer all-reduce of activations


class GPipe(Technique):
    """Pipeline parallelism: contiguous repeats of the block pattern per
    stage, microbatched with a shard_map + ppermute schedule."""

    name = "gpipe"

    def __init__(self, microbatches: int = 4):
        self.microbatches = microbatches

    def search_space(self, cfg, n):
        if n < 2:
            return False
        plan = cfg.layer_plan()
        # need a single scanned group whose repeat count divides by stages
        if len(plan) != 1 or plan[0][0] != "scan":
            return False
        return plan[0][2] % n == 0

    def plan(self, cfg, n):
        return Plan(self.name, n, (("stage", n),), {"batch": None},
                    param_policy="stage", stages=n,
                    microbatches=self.microbatches)

    def memory_fraction(self, cfg, n):
        return 1.0 / n + 0.1

    def step_overhead(self):
        # bubble fraction (S-1)/(M+S-1) baked in empirically; rough prior
        return 1.3


class RematOffload(Technique):
    """Activation rematerialization — the TPU-native stand-in for
    FairScale CPU offloading (same system role: fit on fewer chips at
    the cost of step time; see DESIGN.md §5)."""

    name = "remat-offload"

    def search_space(self, cfg, n):
        return n >= 1

    def plan(self, cfg, n):
        return Plan(self.name, n, (("data", n),),
                    {"batch": "data"}, param_policy="fsdp", remat=True)

    def memory_fraction(self, cfg, n):
        return 0.6 / n  # sharded params + no stored activations

    def step_overhead(self):
        return 1.33  # forward recompute in backward


DEFAULT_TECHNIQUES = [DDP(), FSDP(), TP(), GPipe(), RematOffload()]
