"""ProcessJaxBackend: per-job worker processes supervised over pipes —
clean multi-process training, real fault injection (SIGKILL mid-step,
stalled heartbeats, truncated checkpoints) with bit-for-bit verified
recovery, quarantine on budget exhaustion, and crash-then-resume across
backend lifetimes."""
import dataclasses
import os

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.baselines import CurrentPractice
from repro.core.chaos import ChaosTrace, RetryPolicy, WorkerFault
from repro.core.executor import simulate
from repro.core.job import ClusterSpec, Job
from repro.core.process_backend import ProcessJaxBackend
from repro.core.profiler import Profile

CFG = get_config("xlstm-125m").reduced()
MICRO = dataclasses.replace(CFG, d_model=64, num_heads=2, num_kv_heads=2,
                            head_dim=32, name="xlstm-micro")
CLUSTER = ClusterSpec(nodes=1, gpus_per_node=1, restart_cost_s=0.5)
STEPS = 400   # faults below strike on the first checkpoint at step 5
              # (WorkerFault.min_step), deep mid-run at this budget


def mk_jobs(n_jobs=1, steps=STEPS):
    jobs = [Job(f"j{i}", MICRO, 2, 32, total_steps=steps, lr=1e-3, seed=i)
            for i in range(n_jobs)]
    profiles = {(j.name, "ddp", 1): Profile(j.name, "ddp", 1, 0.01, 1e9,
                                            True, "t") for j in jobs}
    return jobs, profiles


def trajectory(res, name):
    """Absolute step -> loss, last write wins: steps replayed after a
    salvage overwrite their pre-crash records, leaving the trajectory
    training actually converged on."""
    d = {}
    for s, v in res.stats[name]["losses"]:
        d[s] = v
    return d


@pytest.fixture(scope="module")
def baseline(tmp_path_factory):
    """One uninterrupted 400-step run: the reference loss trajectory
    every recovery below must reproduce exactly."""
    jobs, profiles = mk_jobs()
    be = ProcessJaxBackend(
        ckpt_dir=str(tmp_path_factory.mktemp("base")), ckpt_every_steps=5)
    res = simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                   exec_backend=be)
    assert res.worker_failures == 0 and res.quarantined == {}
    return trajectory(res, "j0")


@pytest.mark.slow
def test_process_backend_trains_for_real(tmp_path):
    """Two jobs really train in separate OS processes through the
    Schedule IR: exact step budgets, real finite losses, checkpoints on
    disk, measured step times in the feedback channel."""
    jobs, profiles = mk_jobs(n_jobs=2, steps=40)
    be = ProcessJaxBackend(ckpt_dir=str(tmp_path))
    res = simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                   exec_backend=be)
    assert res.worker_failures == 0 and res.quarantined == {}
    for j in jobs:
        st = res.stats[j.name]
        assert sum(s["steps"] for s in st["segments"]) == j.total_steps
        assert len(st["losses"]) == j.total_steps
        assert all(np.isfinite(v) for _, v in st["losses"])
        assert os.path.exists(tmp_path / f"{j.name}.npz")
    assert be.observed
    for v in be.observed.values():
        assert 0 < v < 10


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["sigkill", "hang", "corrupt"])
def test_fault_recovery_matches_baseline_bit_for_bit(kind, tmp_path,
                                                     baseline):
    """Inject a real fault mid-run; the supervisor must detect it
    (process sentinel / heartbeat deadline / checksum), salvage the
    durable checkpoint, relaunch under backoff, and land the EXACT
    uninterrupted loss trajectory — recovery that loses or perturbs
    steps cannot hide."""
    jobs, profiles = mk_jobs()
    be = ProcessJaxBackend(ckpt_dir=str(tmp_path), ckpt_every_steps=5)
    res = simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                   exec_backend=be,
                   chaos=ChaosTrace((WorkerFault(1.0, kind, "j0",
                                                 min_step=5),)))
    assert res.worker_failures >= 1
    assert res.restarts >= 1
    assert res.quarantined == {}
    segs = res.stats["j0"]["segments"]
    assert len(segs) >= 2 and segs[0]["failed"]
    # the relaunch resumed from the durable checkpoint, not step 0 and
    # not the victim's in-memory progress
    assert segs[-1]["start_step"] + segs[-1]["steps"] == STEPS
    got = trajectory(res, "j0")
    assert set(got) == set(baseline)
    assert max(abs(got[s] - baseline[s]) for s in baseline) == 0.0


@pytest.mark.slow
def test_budget_exhaustion_quarantines(tmp_path):
    """With a zero retry budget the first SIGKILL quarantines the job:
    the run completes (no deadlock, no raise) with the reason
    recorded and the durable progress preserved on disk."""
    jobs, profiles = mk_jobs()
    be = ProcessJaxBackend(ckpt_dir=str(tmp_path), ckpt_every_steps=5,
                           retry_policy=RetryPolicy(budget=0))
    res = simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                   exec_backend=be,
                   chaos=ChaosTrace((WorkerFault(1.0, "sigkill", "j0",
                                                 min_step=5),)))
    assert res.worker_failures == 1
    assert "j0" in res.quarantined
    assert "retry budget exhausted" in res.quarantined["j0"]
    assert "SIGKILL" in res.quarantined["j0"]
    seg = res.stats["j0"]["segments"][0]
    assert seg["failed"] and seg["steps"] < STEPS


@pytest.mark.slow
def test_crash_then_resume_across_backends(tmp_path, baseline):
    """Verified crash recovery across process AND coordinator
    lifetimes: a run killed mid-flight leaves a durable checkpoint; a
    fresh backend with resume=True continues from exactly that step and
    the union of both trajectories is the uninterrupted one,
    bit for bit."""
    from repro.checkpoint.store import verify_checkpoint

    jobs, profiles = mk_jobs()
    be1 = ProcessJaxBackend(ckpt_dir=str(tmp_path), ckpt_every_steps=5,
                            retry_policy=RetryPolicy(budget=0))
    r1 = simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                  exec_backend=be1,
                  chaos=ChaosTrace((WorkerFault(1.0, "sigkill", "j0",
                                                min_step=5),)))
    assert "j0" in r1.quarantined
    durable = int(verify_checkpoint(str(tmp_path / "j0.npz"))["step"])
    assert 0 < durable < STEPS

    be2 = ProcessJaxBackend(ckpt_dir=str(tmp_path), ckpt_every_steps=5,
                            resume=True)
    r2 = simulate(jobs, CurrentPractice(), profiles, CLUSTER,
                  exec_backend=be2)
    assert r2.worker_failures == 0 and r2.quarantined == {}
    segs = r2.stats["j0"]["segments"]
    assert segs[0]["start_step"] == durable
    assert sum(s["steps"] for s in segs) == STEPS - durable

    merged = trajectory(r1, "j0")
    merged.update(trajectory(r2, "j0"))
    assert set(merged) == set(baseline)
    assert max(abs(merged[s] - baseline[s]) for s in baseline) == 0.0
