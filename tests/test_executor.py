"""Simulator + policies: determinism, capacity, introspection wins, and
the paper's qualitative policy ordering."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.baselines import (CurrentPractice, Optimus, OptimusDynamic,
                                  RandomPolicy, SaturnPolicy, SaturnStatic)
from repro.core.executor import simulate
from repro.core.job import ClusterSpec, Job
from repro.core.profiler import Profile

CFG = get_config("xlstm-125m").reduced()


def mk_workload(n_jobs=6, seed=0, total_gpus=8):
    rng = np.random.RandomState(seed)
    jobs, profiles = [], {}
    for i in range(n_jobs):
        j = Job(f"j{i}", CFG, 8, 64, total_steps=int(rng.randint(100, 400)))
        jobs.append(j)
        base = rng.uniform(1.0, 4.0)
        eff = rng.uniform(0.5, 0.95)
        g = 1
        while g <= total_gpus:
            for tech, mult in (("ddp", 1.0), ("fsdp", 1.1), ("gpipe", 1.25)):
                profiles[(j.name, tech, g)] = Profile(
                    j.name, tech, g, base * mult / g ** eff, 1e9, True, "t")
            g *= 2
    return jobs, profiles


CLUSTER = ClusterSpec(nodes=1, gpus_per_node=8, restart_cost_s=10.0)


def test_simulation_deterministic():
    jobs, profiles = mk_workload()
    r1 = simulate(jobs, SaturnPolicy(time_limit_s=5), profiles, CLUSTER,
                  introspect_every_s=300)
    r2 = simulate(jobs, SaturnPolicy(time_limit_s=5), profiles, CLUSTER,
                  introspect_every_s=300)
    assert r1.makespan_s == r2.makespan_s


def test_gantt_capacity_respected():
    jobs, profiles = mk_workload(n_jobs=8)
    res = simulate(jobs, Optimus(), profiles, CLUSTER)
    events = sorted({g.start_s for g in res.gantt}
                    | {g.end_s for g in res.gantt})
    for t in events:
        used = sum(g.n_gpus for g in res.gantt
                   if g.kind == "run" and g.start_s <= t < g.end_s - 1e-9)
        assert used <= CLUSTER.total_gpus


def test_all_jobs_complete():
    jobs, profiles = mk_workload(n_jobs=5, seed=3)
    for pol in (CurrentPractice(), RandomPolicy(1), Optimus(),
                OptimusDynamic(), SaturnStatic(time_limit_s=5)):
        res = simulate(jobs, pol, profiles, CLUSTER,
                       introspect_every_s=200 if pol.dynamic else None)
        ran = {g.job for g in res.gantt if g.kind == "run"}
        assert ran == {j.name for j in jobs}, pol.name


def test_saturn_beats_current_practice():
    """The paper's headline: joint optimization beats one-job-per-node."""
    jobs, profiles = mk_workload(n_jobs=8, seed=7)
    base = simulate(jobs, CurrentPractice(), profiles, CLUSTER)
    sat = simulate(jobs, SaturnPolicy(time_limit_s=10), profiles, CLUSTER,
                   introspect_every_s=300)
    assert sat.makespan_s < base.makespan_s


def test_introspection_improves_optimus():
    jobs, profiles = mk_workload(n_jobs=8, seed=11)
    static = simulate(jobs, Optimus(), profiles, CLUSTER, noise_sigma=0.2)
    dyn = simulate(jobs, OptimusDynamic(), profiles, CLUSTER,
                   introspect_every_s=200, noise_sigma=0.2)
    assert dyn.makespan_s <= static.makespan_s * 1.02


def test_restart_penalty_charged():
    jobs, profiles = mk_workload(n_jobs=6, seed=5)
    res = simulate(jobs, SaturnPolicy(time_limit_s=5), profiles, CLUSTER,
                   introspect_every_s=100, noise_sigma=0.3)
    restarts = [g for g in res.gantt if g.kind == "restart"]
    assert res.restarts == len(restarts)
    for g in restarts:
        assert abs((g.end_s - g.start_s) - CLUSTER.restart_cost_s) < 1e-9


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), n_jobs=st.integers(2, 7))
def test_makespan_lower_bound_property(seed, n_jobs):
    jobs, profiles = mk_workload(n_jobs=n_jobs, seed=seed)
    res = simulate(jobs, Optimus(), profiles, CLUSTER, noise_sigma=0.0)
    # makespan >= the longest single job under its fastest config
    lb = max(min(p.step_time_s for (jn, _, _g), p in profiles.items()
                 if jn == j.name) * j.total_steps for j in jobs)
    assert res.makespan_s >= lb * 0.999
