"""Recurrent mixer blocks: RG-LRU (Griffin/RecurrentGemma), mLSTM and
sLSTM (xLSTM).

Each block exposes ``<block>_spec(cfg)``, a full-sequence apply
(train/prefill; linear-scan blocks use ``lax.associative_scan``) and a
single-token decode apply carrying a small recurrent state.  State
pytrees are created by ``<block>_state_spec``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import rmsnorm_spec
from .params import P

# ------------------------------------------------------------ causal conv

def conv1d_spec(width: int, channels: int):
    return {"w": P((width, channels), (None, "rnn"), init="normal", scale=0.5),
            "b": P((channels,), ("rnn",), init="zeros")}


def conv1d(p, x):
    """Causal depthwise conv, full sequence.  x: (B, S, C)."""
    w = p["w"]
    width = w.shape[0]
    out = x * w[width - 1]
    for i in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[width - 1 - i]
    return out + p["b"]


def conv1d_step(p, x_t, conv_state):
    """x_t: (B, C); conv_state: (B, width-1, C) past inputs (oldest first)."""
    w = p["w"]
    width = w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # (B,width,C)
    out = jnp.einsum("bwc,wc->bc", window, w) + p["b"]
    return out, window[:, 1:]


# ----------------------------------------------------------------- RG-LRU

_RGLRU_C = 8.0


def rglru_block_spec(cfg: ModelConfig):
    d, r = cfg.d_model, cfg.resolved_d_rnn
    return {
        "norm": rmsnorm_spec(d),
        "w_gelu": P((d, r), ("embed", "rnn")),
        "w_branch": P((d, r), ("embed", "rnn")),
        "conv": conv1d_spec(cfg.conv_width, r),
        "w_rec_gate": P((r, r), ("rnn", "rnn_in")),
        "w_in_gate": P((r, r), ("rnn", "rnn_in")),
        "lam": P((r,), ("rnn",), init="const", scale=4.0),  # a=sigmoid(4)≈.982
        "w_out": P((r, d), ("rnn", "embed")),
    }


def _rglru_coeffs(p, u):
    """u: (..., r) post-conv branch.  Returns (a, b) of h = a*h_prev + b."""
    r_gate = jax.nn.sigmoid(u @ p["w_rec_gate"])
    i_gate = jax.nn.sigmoid(u @ p["w_in_gate"])
    log_a = -_RGLRU_C * r_gate * jax.nn.softplus(p["lam"])  # log sigmoid(lam)^(c*r)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * (i_gate * u)
    return a, b


def rglru_scan_ref(a, b):
    """h_t = a_t h_{t-1} + b_t over axis 1 (seq), h_0 = 0.  Pure jnp."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block(p, x, cfg: ModelConfig, state: Optional[dict] = None,
                scan_fn=None, return_state: bool = False):
    """Full Griffin recurrent block.  x: (B,S,d).  Returns (y, new_state)."""
    gelu_branch = jax.nn.gelu(x @ p["w_gelu"])
    u = x @ p["w_branch"]
    if state is None:
        u_raw = u
        u = conv1d(p["conv"], u)
        a, b = _rglru_coeffs(p, u)
        h = (scan_fn or rglru_scan_ref)(a, b)
        y = (h * gelu_branch) @ p["w_out"]
        if return_state:
            w = p["conv"]["w"].shape[0]
            pad = jnp.pad(u_raw, ((0, 0), (w - 1, 0), (0, 0)))
            new_state = {"h": h[:, -1].astype(jnp.float32),
                         "conv": pad[:, -(w - 1):]}
            return y, new_state
        return y, None
    # decode step: x is (B, 1, d)
    u_t, conv_state = conv1d_step(p["conv"], u[:, 0], state["conv"])
    a, b = _rglru_coeffs(p, u_t)
    h = a.astype(jnp.float32) * state["h"] + b.astype(jnp.float32)
    y = ((h.astype(x.dtype) * gelu_branch[:, 0]) @ p["w_out"])[:, None]
    return y.astype(x.dtype), {"h": h, "conv": conv_state}


def rglru_state_spec(cfg: ModelConfig, batch: int, dtype):
    r = cfg.resolved_d_rnn
    return {"h": jax.ShapeDtypeStruct((batch, r), jnp.float32),
            "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, r), dtype)}


# ------------------------------------------------------------------ mLSTM

def mlstm_block_spec(cfg: ModelConfig):
    d, h = cfg.d_model, cfg.num_heads
    up = 2 * d
    dh = up // h
    return {
        "norm": rmsnorm_spec(d),
        "w_up": P((d, up), ("embed", "ffn")),
        "w_gate": P((d, up), ("embed", "ffn")),
        "conv": conv1d_spec(cfg.conv_width, up),
        "wq": P((up, h, dh), ("ffn", "heads", "head_dim")),
        "wk": P((up, h, dh), ("ffn", "heads", "head_dim")),
        "wv": P((up, h, dh), ("ffn", "heads", "head_dim")),
        "wi": P((up, h), ("ffn", "heads"), init="normal", scale=0.1),
        "bi": P((h,), ("heads",), init="const", scale=-3.0),
        "wf": P((up, h), ("ffn", "heads"), init="normal", scale=0.1),
        "bf": P((h,), ("heads",), init="const", scale=3.0),
        "w_down": P((up, d), ("ffn", "embed")),
    }


def mlstm_parallel_ref(q, k, v, i_pre, f_pre):
    """Parallel (quadratic) mLSTM form.

    q,k,v: (B,S,H,D); i_pre,f_pre: (B,S,H) pre-activations.
    Returns h: (B,S,H,D).
    """
    b, s, nh, d = q.shape
    lf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))       # (B,S,H)
    cum = jnp.cumsum(lf, axis=1)
    # log decay from j -> i: cum_i - cum_j  (for j <= i)
    logd = cum[:, :, None, :] - cum[:, None, :, :]           # (B,S_i,S_j,H)
    logd = logd + i_pre.astype(jnp.float32)[:, None, :, :]   # + i_tilde_j
    mask = jnp.tril(jnp.ones((s, s), bool))
    logd = jnp.where(mask[None, :, :, None], logd, -jnp.inf)
    m = jnp.max(logd, axis=2, keepdims=True)                 # (B,S,1,H)
    m = jnp.maximum(m, -1e30)  # rows with all -inf
    dmat = jnp.exp(logd - m)
    scores = jnp.einsum("bihd,bjhd->bijh", q, k) * (d ** -0.5)
    c = scores.astype(jnp.float32) * dmat
    n = jnp.maximum(jnp.abs(jnp.sum(c, axis=2)), jnp.exp(-m[:, :, 0]))  # (B,S,H)
    hout = jnp.einsum("bijh,bjhd->bihd", c, v.astype(jnp.float32))
    return (hout / n[..., None]).astype(q.dtype)


def mlstm_block(p, x, cfg: ModelConfig, state: Optional[dict] = None,
                parallel_fn=None, return_state: bool = False):
    b, s, d = x.shape
    nh = cfg.num_heads
    up = p["w_up"].shape[1]
    dh = up // nh
    xin = x @ p["w_up"]
    z = x @ p["w_gate"]
    if state is None:
        c = jax.nn.silu(conv1d(p["conv"], xin))
        q = jnp.einsum("bsu,uhd->bshd", c, p["wq"])
        k = jnp.einsum("bsu,uhd->bshd", c, p["wk"])
        v = jnp.einsum("bsu,uhd->bshd", xin, p["wv"])
        i_pre = jnp.einsum("bsu,uh->bsh", c, p["wi"]) + p["bi"]
        f_pre = jnp.einsum("bsu,uh->bsh", c, p["wf"]) + p["bf"]
        if return_state:
            from .blockwise import mlstm_chunked
            h, (C, n, m) = mlstm_chunked(q, k, v, i_pre, f_pre,
                                         return_final=True)
            out = h.reshape(b, s, up) * jax.nn.silu(z)
            w = p["conv"]["w"].shape[0]
            pad = jnp.pad(xin, ((0, 0), (w - 1, 0), (0, 0)))
            return out @ p["w_down"], {"C": C, "n": n, "m": m,
                                       "conv": pad[:, -(w - 1):]}
        if parallel_fn is None:
            if s > 512:
                from .blockwise import mlstm_chunked
                parallel_fn = mlstm_chunked
            else:
                parallel_fn = mlstm_parallel_ref
        h = parallel_fn(q, k, v, i_pre, f_pre)
        out = h.reshape(b, s, up) * jax.nn.silu(z)
        return out @ p["w_down"], None
    # ---- decode step
    c_t, conv_state = conv1d_step(p["conv"], xin[:, 0], state["conv"])
    c_t = jax.nn.silu(c_t)
    q = jnp.einsum("bu,uhd->bhd", c_t, p["wq"]) * (dh ** -0.5)
    k = jnp.einsum("bu,uhd->bhd", c_t, p["wk"])
    v = jnp.einsum("bu,uhd->bhd", xin[:, 0], p["wv"])
    i_pre = (c_t @ p["wi"] + p["bi"]).astype(jnp.float32)
    f_pre = (c_t @ p["wf"] + p["bf"]).astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(lf + state["m"], i_pre)
    fg = jnp.exp(lf + state["m"] - m_new)[..., None]
    ig = jnp.exp(i_pre - m_new)[..., None]
    C = fg[..., None] * state["C"] + ig[..., None] * (
        k[..., :, None].astype(jnp.float32) * v[..., None, :].astype(jnp.float32))
    n = fg * state["n"] + ig * k.astype(jnp.float32)
    num = jnp.einsum("bhkv,bhk->bhv", C, q.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q.astype(jnp.float32))),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(b, up).astype(x.dtype)
    out = (h * jax.nn.silu(z[:, 0])) @ p["w_down"]
    return out[:, None], {"C": C, "n": n, "m": m_new, "conv": conv_state}


def mlstm_state_spec(cfg: ModelConfig, batch: int, dtype):
    nh = cfg.num_heads
    up = 2 * cfg.d_model
    dh = up // nh
    return {
        "C": jax.ShapeDtypeStruct((batch, nh, dh, dh), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, nh, dh), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, nh), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, up), dtype),
    }


# ------------------------------------------------------------------ sLSTM

def slstm_block_spec(cfg: ModelConfig):
    d, h = cfg.d_model, cfg.num_heads
    dh = d // h
    gate = lambda: P((d, h, dh), ("embed", "heads", "head_dim"), scale=0.5)
    rec = lambda: P((h, dh, dh), ("heads", "head_dim", "head_dim_in"), scale=0.5)
    return {
        "norm": rmsnorm_spec(d),
        "wz": gate(), "wi": gate(), "wf": gate(), "wo": gate(),
        "rz": rec(), "ri": rec(), "rf": rec(), "ro": rec(),
        "bi": P((h, dh), ("heads", "head_dim"), init="const", scale=-3.0),
        "bf": P((h, dh), ("heads", "head_dim"), init="const", scale=3.0),
        "w_out": P((d, d), ("embed", "embed_out")),
    }


def _slstm_step(p, carry, gates_t):
    """carry: (c, n, m, h); gates_t: per-time preactivations (B,H,D,4)."""
    c, n, m, h = carry
    zx, ix, fx, ox = [gates_t[..., i] for i in range(4)]
    z_pre = zx + jnp.einsum("bhd,hed->bhe", h, p["rz"])
    i_pre = (ix + jnp.einsum("bhd,hed->bhe", h, p["ri"])).astype(jnp.float32)
    f_pre = (fx + jnp.einsum("bhd,hed->bhe", h, p["rf"])).astype(jnp.float32)
    o_pre = ox + jnp.einsum("bhd,hed->bhe", h, p["ro"])
    z = jnp.tanh(z_pre).astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(lf + m, i_pre)
    fg = jnp.exp(lf + m - m_new)
    ig = jnp.exp(i_pre - m_new)
    c_new = fg * c + ig * z
    n_new = jnp.maximum(fg * n + ig, 1e-6)
    h_new = (jax.nn.sigmoid(o_pre).astype(jnp.float32) * c_new / n_new).astype(h.dtype)
    return (c_new, n_new, m_new, h_new)


def slstm_block(p, x, cfg: ModelConfig, state: Optional[dict] = None,
                return_state: bool = False, unroll: int = 1,
                batched_grad: bool = False):
    b, s, d = x.shape
    nh = cfg.num_heads
    dh = d // nh
    gates = jnp.stack([
        jnp.einsum("bsd,dhe->bshe", x, p["wz"]),
        jnp.einsum("bsd,dhe->bshe", x, p["wi"]) + p["bi"],
        jnp.einsum("bsd,dhe->bshe", x, p["wf"]) + p["bf"],
        jnp.einsum("bsd,dhe->bshe", x, p["wo"]),
    ], axis=-1)  # (B,S,H,D,4)
    if state is None:
        init = (jnp.zeros((b, nh, dh), jnp.float32),
                jnp.zeros((b, nh, dh), jnp.float32),
                jnp.full((b, nh, dh), -1e30, jnp.float32),
                jnp.zeros((b, nh, dh), x.dtype))
        if batched_grad:
            from .slstm_scan import slstm_scan
            R = {"rz": p["rz"], "ri": p["ri"], "rf": p["rf"],
                 "ro": p["ro"]}
            final, hs = slstm_scan(R, jnp.swapaxes(gates, 0, 1), init)
        else:
            def step(carry, g_t):
                new = _slstm_step(p, carry, g_t)
                return new, new[3]
            final, hs = jax.lax.scan(step, init, jnp.swapaxes(gates, 0, 1),
                                     unroll=unroll)
        h = jnp.swapaxes(hs, 0, 1).reshape(b, s, d)
        if return_state:
            return h @ p["w_out"], {"c": final[0], "n": final[1],
                                    "m": final[2], "h": final[3]}
        return h @ p["w_out"], None
    carry = (state["c"], state["n"], state["m"], state["h"])
    new = _slstm_step(p, carry, gates[:, 0])
    y = (new[3].reshape(b, d) @ p["w_out"])[:, None]
    return y, {"c": new[0], "n": new[1], "m": new[2], "h": new[3]}


def slstm_state_spec(cfg: ModelConfig, batch: int, dtype):
    nh = cfg.num_heads
    dh = cfg.d_model // nh
    f32 = lambda: jax.ShapeDtypeStruct((batch, nh, dh), jnp.float32)
    return {"c": f32(), "n": f32(), "m": f32(),
            "h": jax.ShapeDtypeStruct((batch, nh, dh), dtype)}
