"""ClusterState + event-driven execution engine.

Replaces the monolithic ``simulate()`` while-loop with an explicit
discrete-event simulation over :mod:`.events`:

- jobs arrive at ``Job.arrival_s`` (online workloads) and policies
  replan on arrival batches;
- preempted jobs pay a REAL restart penalty: their GPUs are released at
  preemption time but the job is only admissible again when its
  :class:`RestartDone` event fires at ``t + restart_cost_s`` (the legacy
  loop re-admitted them immediately while also recording a restart
  Gantt entry — double-booking the GPUs);
- placement is pluggable (:mod:`.placement`): flat pool or node-aware,
  so the executor can honor what ``solve_joint_nodes`` plans;
- every Gantt entry records the concrete device set it occupied, making
  GPU-second conservation checkable per device.

The simulator separates *estimated* step times (what policies see, from
the Trial Runner — either an exhaustive profile dict or a curve-backed
:class:`~repro.core.perfmodel.PerfModel`) from *true* step times
(estimate x seeded noise), so
dynamic policies (introspection) win for the same reason they do on a
real cluster: plans based on estimates drift from reality, and
re-solving on observed remaining work recovers the gap.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .events import (EventQueue, IntrospectionTick, JobArrival,
                     JobCompletion, RestartDone)
from .job import ClusterSpec, Job
from .perfmodel import step_time_of
from .placement import PlacementBackend, PlacementError, make_backend
from .profiler import Profile
from .schedule import Placement, Policy, Schedule


@dataclasses.dataclass
class GanttEntry:
    job: str
    technique: str
    n_gpus: int
    start_s: float
    end_s: float
    kind: str = "run"          # run | restart
    devices: Tuple[int, ...] = ()


@dataclasses.dataclass
class SimResult:
    policy: str
    makespan_s: float
    gantt: List[GanttEntry]
    replans: int = 0
    restarts: int = 0

    def utilization(self, cluster: ClusterSpec) -> float:
        busy = sum((g.end_s - g.start_s) * g.n_gpus for g in self.gantt
                   if g.kind == "run")
        return busy / (self.makespan_s * cluster.total_gpus + 1e-9)


def _noise_factors(jobs, profiles, seed: int, sigma: float):
    """Seeded multiplicative drift between estimated and true step times.
    Iterates profiles in insertion order so legacy and runtime paths see
    identical factors."""
    rng = np.random.RandomState(seed)
    out = {}
    for key in profiles:
        out[key] = float(np.exp(rng.randn() * sigma))
    return out


@dataclasses.dataclass
class _Running:
    job: Job
    technique: str
    n_gpus: int
    placement: Placement
    start_s: float
    true_step_s: float
    steps_at_start: int
    token: int


class ClusterState:
    """Mutable simulation state: job phases, remaining work, placements,
    and the Gantt log under construction."""

    def __init__(self, jobs: List[Job], backend: PlacementBackend):
        self.by_name: Dict[str, Job] = {j.name: j for j in jobs}
        self.remaining: Dict[str, int] = {j.name: j.total_steps for j in jobs}
        self.arrived: set = set()
        self.waiting: List[str] = []
        self.restarting: set = set()
        self.running: Dict[str, _Running] = {}
        self.backend = backend
        self.gantt: List[GanttEntry] = []
        self.current_assign: Dict[str, Tuple[str, int]] = {}
        self.t = 0.0

    def settle(self, upto_t: float) -> None:
        """Account finished steps for running jobs up to ``upto_t``."""
        for name, r in self.running.items():
            done = int((upto_t - r.start_s) / r.true_step_s)
            self.remaining[name] = max(0, r.steps_at_start - done)

    def live_jobs(self) -> List[Job]:
        """Arrived, unfinished jobs (running, waiting, or restarting) —
        what planners plan over."""
        return [self.by_name[n] for n in self.by_name
                if n in self.arrived and self.remaining[n] > 0]

    def all_done(self) -> bool:
        return all(v == 0 for v in self.remaining.values())


def simulate_runtime(jobs: List[Job], policy: Policy,
                     profiles: Dict[Tuple[str, str, int], Profile],
                     cluster: ClusterSpec, *,
                     introspect_every_s: Optional[float] = None,
                     noise_sigma: float = 0.1, noise_seed: int = 0,
                     max_events: int = 100000,
                     backend: Optional[PlacementBackend] = None) -> SimResult:
    """Run ``jobs`` under ``policy`` on the event-driven cluster runtime."""
    noise = _noise_factors(jobs, profiles, noise_seed, noise_sigma)
    backend = backend or make_backend(cluster)
    state = ClusterState(jobs, backend)
    q = EventQueue()
    for j in jobs:
        q.push(JobArrival(max(0.0, getattr(j, "arrival_s", 0.0)), j))
    if introspect_every_s:
        q.push(IntrospectionTick(introspect_every_s))

    order = Schedule([])
    replans = 0
    restarts = 0
    launch_tokens = {}            # job -> token of its current launch
    next_token = [0]

    def est_step(jname, tech, g):
        # curve-backed performance models answer at ANY count, so
        # introspection replans may pick counts nobody profiled
        return step_time_of(profiles, jname, tech, g)

    def true_step(jname, tech, g):
        return est_step(jname, tech, g) * noise.get((jname, tech, g), 1.0)

    def start_fitting():
        """List scheduling: repeatedly start the first schedule entry
        whose job is admissible and whose GPU request fits."""
        progressed = True
        while progressed:
            progressed = False
            for entry in order.entries:
                name = entry.job
                if name not in state.waiting:
                    continue
                if not backend.feasible(entry.n_gpus):
                    raise PlacementError(
                        f"{name}: {entry.n_gpus} GPUs can never be placed "
                        f"on backend {backend.kind!r} "
                        f"({getattr(backend, 'nodes', '?')} nodes x "
                        f"{getattr(backend, 'gpus_per_node', '?')} GPUs)")
                pl = backend.allocate(entry.n_gpus,
                                      preferred_nodes=entry.nodes)
                if pl is None:
                    continue
                st = true_step(name, entry.technique, entry.n_gpus)
                next_token[0] += 1
                tok = next_token[0]
                state.running[name] = _Running(
                    state.by_name[name], entry.technique, entry.n_gpus,
                    pl, state.t, st, state.remaining[name], tok)
                launch_tokens[name] = tok
                state.current_assign[name] = (entry.technique, entry.n_gpus)
                state.waiting.remove(name)
                q.push(JobCompletion(
                    state.t + state.remaining[name] * st, name, tok))
                progressed = True
                break

    def replan(preempt: bool):
        nonlocal order, replans, restarts
        live = state.live_jobs()
        if not live:
            return
        order = Schedule.coerce(policy.plan(
            live, dict(state.remaining), profiles, cluster,
            dict(state.current_assign)))
        replans += 1
        if preempt:
            new_assign = order.assignment_map()
            for name in list(state.running):
                if name in new_assign and \
                        new_assign[name] != state.current_assign.get(name):
                    r = state.running.pop(name)
                    backend.release(r.placement)
                    state.gantt.append(GanttEntry(
                        name, r.technique, r.n_gpus, r.start_s, state.t,
                        devices=r.placement.devices))
                    # checkpoint + relaunch penalty: the job is only
                    # admissible again when RestartDone fires
                    state.gantt.append(GanttEntry(
                        name, "restart", 0, state.t,
                        state.t + cluster.restart_cost_s, kind="restart"))
                    state.remaining[name] = max(1, state.remaining[name])
                    state.restarting.add(name)
                    q.push(RestartDone(
                        state.t + cluster.restart_cost_s, name))
                    restarts += 1

    events = 0
    while q:
        if state.all_done():
            break
        ev = q.pop()
        events += 1
        if events > max_events:
            raise RuntimeError("simulate_runtime: event cap hit")

        if isinstance(ev, JobArrival):
            state.t = ev.t
            state.settle(ev.t)   # replan must see observed progress
            batch = [ev] + q.pop_while(JobArrival, ev.t)
            for e in batch:
                state.arrived.add(e.job.name)
                state.waiting.append(e.job.name)
            # dynamic policies may preempt running jobs to make room for
            # the new arrival; static ones just extend the plan
            if state.t > 0 and not getattr(policy, "replan_on_arrival", True):
                pass
            else:
                replan(preempt=policy.dynamic and state.t > 0)
            start_fitting()

        elif isinstance(ev, JobCompletion):
            if launch_tokens.get(ev.job) != ev.token or \
                    ev.job not in state.running:
                continue                       # stale (preempted launch)
            state.t = ev.t
            state.settle(ev.t)
            r = state.running.pop(ev.job)
            state.remaining[ev.job] = 0
            backend.release(r.placement)
            state.gantt.append(GanttEntry(
                ev.job, r.technique, r.n_gpus, r.start_s, ev.t,
                devices=r.placement.devices))
            if state.all_done():
                break
            if policy.dynamic and policy.replan_on_completion and \
                    state.waiting:
                replan(preempt=False)
            start_fitting()

        elif isinstance(ev, RestartDone):
            state.t = ev.t
            state.restarting.discard(ev.job)
            state.waiting.append(ev.job)
            start_fitting()

        elif isinstance(ev, IntrospectionTick):
            if state.all_done():
                continue
            if not (state.running or state.waiting or state.restarting):
                # nothing in the system yet (future arrivals pending):
                # keep the tick chain alive, but there is nothing to
                # settle or replan
                q.push(IntrospectionTick(ev.t + introspect_every_s))
                continue
            state.t = ev.t
            state.settle(ev.t)
            if policy.dynamic:
                replan(preempt=True)
            q.push(IntrospectionTick(ev.t + introspect_every_s))
            start_fitting()

        # deadlock: nothing running, nothing can ever start it
        if state.waiting and not state.running and not state.restarting \
                and not q.has_any((JobArrival, RestartDone)):
            raise RuntimeError(
                f"deadlock: waiting={state.waiting} "
                f"free={backend.free_gpus} order={order.to_tuples()}")

    if not state.all_done():
        unfinished = [n for n, v in state.remaining.items() if v > 0]
        raise RuntimeError(f"runtime drained with unfinished jobs: "
                           f"{unfinished}")
    return SimResult(policy.name, state.t, state.gantt, replans, restarts)
