"""Parallelism-equivalence integration test: spawns a subprocess with 8
virtual devices (keeps this pytest process at 1 device) and checks every
technique's one-step result against the single-device baseline."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["h2o-danube-3-4b", "olmoe-1b-7b"])
def test_techniques_match_single_device(arch):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.testing.parallel_check", arch],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-2000:]}"
    assert "FAIL" not in out.stdout


def test_plan_shapes():
    from repro.configs import get_config
    from repro.parallelism.techniques import DEFAULT_TECHNIQUES
    cfg = get_config("h2o-danube-3-4b")
    for t in DEFAULT_TECHNIQUES:
        if t.search_space(cfg, 8):
            plan = t.plan(cfg, 8)
            import numpy as np
            assert int(np.prod(plan.mesh_shape)) == 8
            assert 0 < t.memory_fraction(cfg, 8) <= 1.0
            assert t.step_overhead() >= 1.0


def test_gpipe_search_space_rules():
    from repro.configs import get_config
    from repro.parallelism.techniques import GPipe
    g = GPipe()
    assert g.search_space(get_config("h2o-danube-3-4b"), 4)   # 24 % 4 == 0
    assert not g.search_space(get_config("h2o-danube-3-4b"), 5)
    assert not g.search_space(get_config("gemma3-4b"), 4)  # remainder layers
    # 26 = 8 pattern repeats + 2 remainder layers -> not pipelineable
    assert not g.search_space(get_config("recurrentgemma-2b"), 2)
    assert g.search_space(get_config("qwen3-moe-235b-a22b"), 2)  # 94 % 2
