"""End-to-end driver: REALLY train xLSTM variants on this machine
through the full session-driven Saturn pipeline — empirical
Trial-Runner profiling, MILP plan, and the cluster runtime executing
the Schedule IR on the LocalJaxBackend: concurrent per-job device
slices, wall-clock introspection replans with measured-throughput
feedback, and checkpointed preemption/resume.

NOTE: execution goes through ``SaturnSession.run(backend="local")`` —
the same Schedule IR and event engine as the simulator, with the
execution substrate swapped (see README "Execution backends").  The old
hand-rolled LocalRunner loop this example used to carry lives on as the
serial building block in ``repro.core.executor.LocalRunner``.

    PYTHONPATH=src python examples/train_e2e.py --steps 300 --size small

--size full uses the real xlstm-125m config (slower on CPU);
--size small uses a ~12M same-family variant for quick runs.
--gpus N maps N "cluster GPUs" onto N forced host CPU devices so jobs
really train concurrently.
"""
import argparse
import dataclasses
import os
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--size", default="small", choices=["small", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--gpus", type=int, default=2,
                    help="cluster size; maps onto forced host devices")
    ap.add_argument("--introspect-s", type=float, default=60.0)
    ap.add_argument("--ckpt-dir", default="/tmp/saturn_e2e")
    args = ap.parse_args()

    # expose N host devices BEFORE jax initializes, so the runtime can
    # place concurrent jobs on disjoint device slices
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --xla_force_host_platform_device_count"
                                 f"={args.gpus}")
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

    from repro.configs import get_config
    from repro.core.api import SaturnSession
    from repro.core.job import ClusterSpec, Job

    base = get_config("xlstm-125m")
    if args.size == "small":
        # ~12M same-family variant — CPU-tractable for a few hundred
        # steps; --size full runs the real 125M config (use on TPU/GPU
        # or be patient)
        cfg = dataclasses.replace(base, num_layers=4, d_model=256,
                                  num_heads=4, head_dim=64,
                                  name="xlstm-12m")
    else:
        cfg = base
    jobs = [Job(f"{cfg.name}-lr{lr:g}", cfg, args.batch, args.seq,
                total_steps=args.steps, lr=lr, seed=i)
            for i, (lr) in enumerate([3e-4, 1e-3])]

    cluster = ClusterSpec(nodes=1, gpus_per_node=args.gpus,
                          restart_cost_s=2.0)
    sess = SaturnSession(cluster)
    sess.submit(jobs)

    print("== Trial Runner (empirical, real minibatches) ==")
    t0 = time.time()
    profiles = sess.profile(mode="empirical", strategy="exhaustive")
    for (name, tech, g), p in sorted(profiles.items()):
        if p.feasible:
            print(f"  {name} {tech} x{g}: {p.step_time_s * 1e3:.0f} ms/step")
    print(f"  ({time.time() - t0:.0f}s)")

    print("== Solver + LocalJaxBackend (real training, checkpointed) ==")
    t0 = time.time()
    res = sess.run(backend="local", ckpt_dir=args.ckpt_dir,
                   introspect_every_s=args.introspect_s, time_limit_s=10)
    print(f"  makespan {res.makespan_s:.0f}s (wall {time.time() - t0:.0f}s) "
          f"replans={res.replans} restarts={res.restarts}")
    by_name = {j.name: j for j in jobs}
    for name, st in sorted(res.stats.items()):
        segs = st["segments"]
        total = sum(s["steps"] for s in segs)
        first = st["losses"][0][1] if st["losses"] else float("nan")
        last = st["losses"][-1][1] if st["losses"] else float("nan")
        print(f"  {name}: {total} steps in {len(segs)} segment(s), "
              f"loss {first:.3f} -> {last:.3f}, "
              + ", ".join(f"{s['technique']}x{s['n_gpus']}"
                          f"@{s['start_step']}" for s in segs))
        assert total >= by_name[name].total_steps


if __name__ == "__main__":
    main()
