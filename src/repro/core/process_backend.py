"""ProcessJaxBackend — supervised multi-process execution (fault
tolerance for real).

Third implementation of the engine's
:class:`~repro.core.runtime.ExecutionBackend` protocol: like
:class:`~repro.core.local_backend.LocalJaxBackend` every launch REALLY
trains, but each job segment runs in its own OS process, supervised by
this coordinator over a duplex pipe speaking the
:mod:`repro.train.process_worker` protocol (hello / heartbeat-with-step-
counter / checkpoint-ack / exit).  That isolation is what makes worker
death survivable — and injectable:

- a worker process that EXITS without a clean ``exit`` message (crash,
  SIGKILL, OOM-kill) is detected through its process sentinel;
- a worker that goes SILENT past the heartbeat deadline (wedged in a
  syscall, livelocked) is detected through missed heartbeats and
  killed;
- both are surfaced to the engine through ``drain_failures`` as
  synthesized :class:`~repro.core.chaos.WorkerFailure` events, which
  route into checkpoint salvage at the last DURABLE step, relaunch
  under the :class:`~repro.core.chaos.RetryPolicy`'s exponential
  backoff + jitter, and quarantine once the retry budget is exhausted.

The durable checkpoint chain (atomic, checksummed, ``.prev``
last-known-good — :mod:`repro.checkpoint.store`) is the single source
of truth for recovery: ``salvage`` answers from the files a relaunch
will actually load, and a relaunched worker's ``hello`` carries the
absolute step it REALLY resumed from, against which the coordinator
reconciles its own step accounting (``offset``) — so a kill landing
between a checkpoint commit and its ack, or a corrupt-file fallback to
``.prev``, never desynchronizes the engine from the worker.

A dedicated monitor thread owns ALL pipe reads (the engine thread only
sends), waiting on connections and process sentinels together; the
engine's ``wait_until`` sleep is poked on every completion AND every
failure, so the scheduler never sleeps on an event that will not come.

Fault injection (:meth:`inject_fault`, driven by seeded
:class:`~repro.core.chaos.WorkerFault` events) really hurts live
workers — SIGKILL mid-step, command a heartbeat stall, truncate the
checkpoint file on disk — and never shortcuts detection: recovery is
exercised end to end, which is what ``benchmarks/run.py recover``
measures.
"""
from __future__ import annotations

import math
import multiprocessing
import os
import threading
import time
from multiprocessing import connection as mp_conn
from typing import Dict, List, Optional, Tuple

from ..train.process_worker import _worker_main
from .chaos import RetryPolicy, WorkerFault
from .job import ClusterSpec, Job
from .local_backend import LocalJaxBackend
from .runtime import LaunchHandle


class _Proc:
    """Coordinator-side record of one worker process: the supervision
    state the monitor thread maintains plus a ``_Worker``-compatible
    stats surface (``steps_done`` / ``start_step`` / ``losses`` /
    ``measured_step_s`` / ``compile_s`` / ``preempted`` /
    ``finish_clock`` / ``done``) so the feedback and accounting
    plumbing inherited from :class:`LocalJaxBackend` applies as-is."""

    def __init__(self, process, conn, launched_clock: float):
        self.process = process
        self.conn = conn
        self.conn_open = True
        self.dead_handled = False
        # supervision
        self.got_hb = False
        self.last_hb_clock = launched_clock
        self.hb_steps = 0                 # worker-frame step counter
        self._last_progress: Optional[Tuple[float, int]] = None
        self._hb_rate: Optional[float] = None
        self.fail_hint: Optional[str] = None     # set before a kill
        self.error_reason: Optional[str] = None  # child's error message
        self.pending_fault: Optional[WorkerFault] = None
        # reconciliation: worker-frame steps + offset = engine frame
        self.offset = 0
        self.durable_abs: Optional[int] = None   # last checkpoint-ack
        # lifecycle / stats
        self.start_step = 0
        self.exit_msg: Optional[dict] = None
        self.preempted = False
        self.compile_s = 0.0
        self.losses: List[Tuple[int, float]] = []
        self.finish_clock: Optional[float] = None
        self.done = threading.Event()

    @property
    def raw_steps(self) -> int:
        """Steps this segment really ran (worker frame): what the stats
        surface records, so ``start_step + steps`` is the absolute step
        the segment reached even when resume pre-credited progress."""
        return self.exit_msg["steps"] if self.exit_msg is not None \
            else self.hb_steps

    @property
    def steps_done(self) -> int:
        # engine frame: the launch budget includes steps that were
        # already durable on disk at launch (resume), reconciled via
        # the hello offset
        return max(0, self.raw_steps + self.offset)

    @property
    def measured_step_s(self) -> Optional[float]:
        if self.exit_msg is not None and \
                self.exit_msg.get("measured_step_s"):
            return self.exit_msg["measured_step_s"]
        return self._hb_rate

    def note_heartbeat(self, steps: int) -> None:
        now = time.monotonic()
        self.got_hb = True
        self.last_hb_clock = now
        if steps > self.hb_steps:
            if self._last_progress is not None:
                dt = now - self._last_progress[0]
                ds = steps - self._last_progress[1]
                if dt > 0 and ds > 0:
                    r = dt / ds
                    self._hb_rate = r if self._hb_rate is None \
                        else 0.5 * self._hb_rate + 0.5 * r
            self._last_progress = (now, steps)
            self.hb_steps = steps


class ProcHandle(LaunchHandle):
    """LaunchHandle + the worker process executing it."""

    def __init__(self, proc: _Proc, *args):
        super().__init__(*args)
        self.worker = proc

    @property
    def finish_t(self) -> Optional[float]:
        return self.worker.finish_clock


class ProcessJaxBackend(LocalJaxBackend):
    """Execute schedules in supervised per-job worker processes."""

    kind = "process-jax"
    virtual = False
    exact_completions = False

    def __init__(self, library=None, ckpt_dir: Optional[str] = None,
                 min_requeue_s: float = 0.25,
                 fallback_step_s: float = 0.1,
                 resume: bool = False,
                 retry_policy: Optional[RetryPolicy] = None,
                 ckpt_every_steps: int = 10,
                 heartbeat_every_s: float = 0.25,
                 heartbeat_timeout_s: float = 5.0,
                 startup_grace_s: float = 180.0,
                 preempt_timeout_s: float = 120.0):
        super().__init__(library=library, ckpt_dir=ckpt_dir,
                         min_requeue_s=min_requeue_s,
                         fallback_step_s=fallback_step_s, resume=resume,
                         retry_policy=retry_policy)
        self.ckpt_every_steps = int(ckpt_every_steps)
        self.heartbeat_every_s = float(heartbeat_every_s)
        self.heartbeat_timeout_s = float(heartbeat_timeout_s)
        self.startup_grace_s = float(startup_grace_s)
        self.preempt_timeout_s = float(preempt_timeout_s)

    # ------------------------------------------------------------- setup
    def bind(self, jobs, profiles, cluster: ClusterSpec) -> None:
        import tempfile

        import jax

        # protocol grandparent: profile plumbing without the local
        # backend's in-process device checks (children own devices)
        from .runtime import ExecutionBackend
        ExecutionBackend.bind(self, jobs, profiles, cluster)
        # env staging happens BEFORE any spawn: children inherit
        # os.environ, and XLA reads the flag at their jax import
        cur = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in cur:
            os.environ["XLA_FLAGS"] = (
                cur + f" --xla_force_host_platform_device_count="
                f"{cluster.total_gpus}").strip()
        self._gpu = jax.default_backend() == "gpu"
        if self.ckpt_dir is None:
            self.ckpt_dir = tempfile.mkdtemp(prefix="saturn_proc_")
        os.makedirs(self.ckpt_dir, exist_ok=True)
        if not self.resume:
            for j in jobs:
                for suffix in (".npz", ".npz.prev", ".npz.meta.json"):
                    p = os.path.join(self.ckpt_dir, j.name + suffix)
                    if os.path.exists(p):
                        os.remove(p)
        self._ctx = multiprocessing.get_context("spawn")
        self._t0 = time.monotonic()
        self._lock = threading.Lock()
        self._poke = threading.Event()
        self._finished: List[ProcHandle] = []
        self._failed: List[Tuple[ProcHandle, str]] = []
        self._by_worker: Dict[_Proc, ProcHandle] = {}
        self.observed.clear()
        self.job_stats.clear()
        self._shutdown = threading.Event()
        self._monitor_thread = threading.Thread(
            target=self._monitor, daemon=True, name="saturn-proc-monitor")
        self._monitor_thread.start()

    def shutdown(self) -> None:
        """Stop supervision and kill any still-live workers (tests and
        explicit teardown; normal runs end with no workers left)."""
        self._shutdown.set()
        with self._lock:
            procs = list(self._by_worker)
        for p in procs:
            if p.process.is_alive():
                p.process.kill()

    # ------------------------------------------------------- supervision
    def _send(self, p: _Proc, cmd: dict) -> None:
        try:
            p.conn.send(cmd)
        except (BrokenPipeError, OSError):
            pass            # already dead; the sentinel will tell us

    def _handle_msg(self, p: _Proc, h: ProcHandle, m: dict) -> None:
        kind = m.get("msg")
        if kind == "hello":
            # the durable checkpoint the child REALLY resumed from is
            # authoritative; reconcile the engine's step frame to it
            p.start_step = int(m["start_step"])
            p.offset = h.steps_at_start \
                - (h.job.total_steps - p.start_step)
            p.note_heartbeat(0)
        elif kind == "hb":
            p.note_heartbeat(int(m["steps"]))
            # loss records stream with heartbeats so a killed segment
            # still leaves its trajectory behind
            p.losses.extend((int(s), float(v))
                            for s, v in m.get("losses", ()))
        elif kind == "ckpt":
            p.durable_abs = int(m["step"])
            p.note_heartbeat(p.hb_steps)      # a commit proves liveness
            p.losses.extend((int(s), float(v))
                            for s, v in m.get("losses", ()))
            if p.pending_fault is not None \
                    and p.durable_abs >= p.pending_fault.min_step:
                fault, p.pending_fault = p.pending_fault, None
                self._apply_fault(p, h.job.name, fault)
        elif kind == "exit":
            p.exit_msg = m
            p.preempted = bool(m.get("preempted"))
            p.compile_s = float(m.get("compile_s") or 0.0)
            p.losses = [(int(s), float(v)) for s, v in m.get("losses", [])]
            p.finish_clock = self.now()
            p.done.set()
        elif kind == "error":
            p.error_reason = m["reason"]

    def _drain_conn(self, p: _Proc, h: ProcHandle) -> None:
        try:
            while p.conn_open and p.conn.poll(0):
                self._handle_msg(p, h, p.conn.recv())
        except (EOFError, OSError):
            p.conn_open = False

    def _on_death(self, p: _Proc, h: ProcHandle) -> None:
        if p.dead_handled:
            return
        p.dead_handled = True
        # the pipe may still hold the child's last words (a final ckpt
        # ack, the exit payload, an error report): drain before judging
        self._drain_conn(p, h)
        p.conn_open = False
        if p.finish_clock is None:
            p.finish_clock = self.now()
        p.done.set()
        if p.exit_msg is not None:
            if not p.preempted:
                with self._lock:
                    if p in self._by_worker:
                        self._finished.append(h)
            # preempted clean exits are consumed by preempt()
        else:
            reason = p.error_reason or p.fail_hint or (
                f"worker process died without exit message "
                f"(exit code {p.process.exitcode})")
            with self._lock:
                if p in self._by_worker:    # engine already let go: stale
                    self._failed.append((h, reason))
        self._poke.set()

    def _check_heartbeats(self) -> None:
        now = time.monotonic()
        with self._lock:
            procs = list(self._by_worker.items())
        for p, h in procs:
            if p.dead_handled or p.done.is_set():
                continue
            deadline = self.heartbeat_timeout_s if p.got_hb \
                else self.startup_grace_s
            if now - p.last_hb_clock > deadline:
                # a hung worker is killed and handled exactly like a
                # dead one — _on_death fires from the sentinel
                p.fail_hint = (f"heartbeat deadline missed "
                               f"({deadline:.1f}s without heartbeat)")
                p.process.kill()

    def _monitor(self) -> None:
        """The one thread that reads the pipes: worker messages, process
        sentinels, heartbeat deadlines."""
        while not self._shutdown.is_set():
            with self._lock:
                procs = list(self._by_worker.items())
            waitables = {}
            for p, h in procs:
                if p.dead_handled:
                    continue
                if p.conn_open:
                    waitables[p.conn] = (p, h)
                waitables[p.process.sentinel] = (p, h)
            if not waitables:
                self._shutdown.wait(0.05)
                continue
            try:
                ready = mp_conn.wait(list(waitables), timeout=0.2)
            except OSError:
                continue        # a sentinel closed under us; rescan
            for r in ready:
                p, h = waitables[r]
                if r is p.process.sentinel:
                    self._on_death(p, h)
                else:
                    self._drain_conn(p, h)
            self._check_heartbeats()

    # ------------------------------------------------------ run lifecycle
    def launch(self, job: Job, entry, placement, device_class, remaining,
               t, token) -> ProcHandle:
        ckpt = os.path.join(self.ckpt_dir, f"{job.name}.npz")
        device_ids = list(placement.devices)
        spec = {
            "job_name": job.name,
            "model_cfg": job.cfg,
            "batch_size": job.batch_size,
            "seq_len": job.seq_len,
            "total_steps": job.total_steps,
            "lr": job.lr,
            "seed": job.seed,
            "technique": entry.technique,
            "device_ids": (list(range(len(device_ids))) if self._gpu
                           else device_ids),
            "ckpt_path": ckpt,
            "steps_to_run": int(remaining),
            "ckpt_every_steps": self.ckpt_every_steps,
            "heartbeat_every_s": self.heartbeat_every_s,
        }
        parent_conn, child_conn = self._ctx.Pipe(duplex=True)
        stash = os.environ.get("CUDA_VISIBLE_DEVICES")
        if self._gpu:
            os.environ["CUDA_VISIBLE_DEVICES"] = \
                ",".join(str(d) for d in device_ids)
        try:
            process = self._ctx.Process(
                target=_worker_main, args=(child_conn, spec),
                name=f"saturn-proc-{job.name}", daemon=True)
            process.start()
        finally:
            if self._gpu:
                if stash is None:
                    os.environ.pop("CUDA_VISIBLE_DEVICES", None)
                else:
                    os.environ["CUDA_VISIBLE_DEVICES"] = stash
        child_conn.close()      # the child holds its own end now
        proc = _Proc(process, parent_conn, time.monotonic())
        try:
            est = self.est_step(job.name, entry.technique, entry.n_gpus,
                                device_class)
        except KeyError:
            est = self.fallback_step_s
        if not math.isfinite(est) or est <= 0:
            est = self.fallback_step_s
        h = ProcHandle(proc, job, entry.technique, entry.n_gpus,
                       placement, t, est, remaining, token)
        with self._lock:
            self._by_worker[proc] = h
        return h

    def is_finished(self, handle: ProcHandle) -> bool:
        p = handle.worker
        return p.exit_msg is not None and not p.preempted

    def salvage(self, handle: ProcHandle) -> int:
        """A failed launch keeps exactly what recovery can load: the
        durable checkpoint chain on disk (current file, else the
        last-known-good ``.prev``), in the engine's step frame."""
        p = handle.worker
        p.process.join(timeout=5.0)
        self._finish(handle, preempted=False,
                     error=(p.error_reason or p.fail_hint
                            or "worker failed"))
        return self._durable_steps(handle)

    def preempt(self, handle: ProcHandle, t: float) -> int:
        p = handle.worker
        self._send(p, {"cmd": "stop"})
        if not p.done.wait(timeout=self.preempt_timeout_s):
            # checkpoint-and-exit never came back: treat as hung
            p.fail_hint = "no response to preemption"
            p.process.kill()
            p.done.wait(timeout=5.0)
        p.process.join(timeout=5.0)
        if p.exit_msg is not None:
            self._finish(handle, preempted=p.preempted)
            return p.steps_done
        # died instead of checkpointing: only the durable chain counts
        # (its failure record, if the monitor filed one, goes stale the
        # moment the engine drops this launch's token)
        self._finish(handle, preempted=False,
                     error=(p.error_reason or p.fail_hint
                            or "died during preemption"))
        return self._durable_steps(handle)

    def complete(self, handle: ProcHandle, t: float) -> None:
        p = handle.worker
        # wait on the monitor (it owns the pipe): done fires once the
        # exit payload is consumed, or the death is handled
        p.done.wait(timeout=self.preempt_timeout_s)
        p.process.join(timeout=5.0)
        self._finish(handle, preempted=False)
        if p.exit_msg is None:
            raise RuntimeError(
                f"process launch of {handle.job.name} completed without "
                f"an exit message ({p.error_reason or p.fail_hint})")

    # --------------------------------------------------- fault injection
    def inject_fault(self, fault: WorkerFault,
                     running: Dict[str, LaunchHandle], t: float) -> None:
        if fault.kind not in ("sigkill", "hang", "corrupt"):
            raise ValueError(f"unknown worker-fault kind {fault.kind!r}")
        if fault.job is not None:
            h = running.get(fault.job)
            if h is None:
                return      # named victim not live; injection no-ops
            name = fault.job
        elif running:
            name = min(running)     # first live launch, deterministic
            h = running[name]
        else:
            return
        p = h.worker
        if fault.min_step > 0 and (p.durable_abs is None
                                   or p.durable_abs < fault.min_step):
            # worker startup wall time is load-dependent; hold the
            # strike until the durable chain reaches min_step (the
            # monitor applies it on the qualifying checkpoint-ack)
            p.pending_fault = fault
            return
        self._apply_fault(p, name, fault)

    def _apply_fault(self, p: _Proc, name: str,
                     fault: WorkerFault) -> None:
        if fault.kind == "sigkill":
            p.fail_hint = "injected fault: SIGKILL mid-step"
            p.process.kill()
        elif fault.kind == "hang":
            # the child stops heartbeating AND progressing but stays
            # alive; detection must come from the heartbeat deadline
            self._send(p, {"cmd": "hang"})
        elif fault.kind == "corrupt":
            p.fail_hint = "injected fault: checkpoint truncated + SIGKILL"
            ckpt = os.path.join(self.ckpt_dir, f"{name}.npz")
            if os.path.exists(ckpt):
                size = os.path.getsize(ckpt)
                with open(ckpt, "r+b") as f:
                    f.truncate(max(1, size // 2))
            p.process.kill()
        else:
            raise ValueError(f"unknown worker-fault kind {fault.kind!r}")
