"""Cluster execution front-end.

``simulate()`` is now a thin compatibility wrapper over the event-driven
cluster runtime (:mod:`.runtime`): Schedule IR plans, pluggable
placement (flat pool / node-aware), online arrivals, and real preemption
with restart penalties.  ``simulate_legacy()`` keeps the original
closed-form while-loop (with its restart-penalty accounting bug fixed)
as an equivalence comparator for the runtime's flat-pool path.

``LocalRunner`` really trains models on this machine for the end-to-end
examples; wall-times feed back as profiles.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from .job import ClusterSpec, Job
from .profiler import Profile
# Re-exports: these types historically lived here; the runtime owns them
# now but existing callers keep importing from executor.
from .runtime import (GanttEntry, SimResult, _noise_factors,  # noqa: F401
                      simulate_runtime)
from .schedule import Policy, Schedule  # noqa: F401


def simulate(jobs: List[Job], policy: Policy,
             profiles: Dict[Tuple[str, str, int], Profile],
             cluster: ClusterSpec, *,
             introspect_every_s: Optional[float] = None,
             noise_sigma: float = 0.1, noise_seed: int = 0,
             max_events: int = 100000,
             placement: Optional[str] = None,
             exec_backend=None, chaos=None, fleets=None) -> SimResult:
    """Compatibility wrapper: run on the event-driven runtime.

    ``placement`` overrides ``cluster.placement`` ("flat" keeps the
    historical single-pool behavior; "node" enforces node locality).
    ``exec_backend`` selects the execution substrate (default: the
    virtual-time :class:`~repro.core.runtime.SimBackend`; pass a
    :class:`~repro.core.local_backend.LocalJaxBackend` to really train).
    ``chaos`` injects a :class:`~repro.core.chaos.ChaosTrace` of cluster
    events (failures, spot churn, resizes) into the run.  ``fleets``
    runs serving fleets alongside training (a
    :class:`~repro.serving.fleet.FleetManager`).
    """
    import dataclasses as _dc
    if placement is not None and \
            placement != getattr(cluster, "placement", "flat"):
        # the policy must see the same placement the runtime enforces
        # (node-aware Saturn switches MILPs on cluster.placement)
        cluster = _dc.replace(cluster, placement=placement)
    return simulate_runtime(jobs, policy, profiles, cluster,
                            introspect_every_s=introspect_every_s,
                            noise_sigma=noise_sigma, noise_seed=noise_seed,
                            max_events=max_events,
                            exec_backend=exec_backend, chaos=chaos,
                            fleets=fleets)


def simulate_legacy(jobs: List[Job], policy: Policy,
                    profiles: Dict[Tuple[str, str, int], Profile],
                    cluster: ClusterSpec, *,
                    introspect_every_s: Optional[float] = None,
                    noise_sigma: float = 0.1, noise_seed: int = 0,
                    max_events: int = 100000) -> SimResult:
    """The original flat-pool while-loop simulator.

    Kept as the reference implementation the runtime must match on
    offline flat-pool workloads.  The historical restart-penalty bug is
    fixed here too: a preempted job used to be re-admitted by
    ``start_fitting()`` at time ``t`` even though a restart Gantt entry
    through ``t + restart_cost_s`` was just recorded (double-booking the
    GPUs and understating dynamic policies' preemption cost).  Restarted
    jobs now only become admissible at ``t + restart_cost_s``.
    """
    import dataclasses as _dc

    @_dc.dataclass
    class _Running:
        job: Job
        technique: str
        n_gpus: int
        start_s: float
        true_step_s: float
        steps_at_start: int

    noise = _noise_factors(jobs, profiles, noise_seed, noise_sigma)

    def est_step(jname, tech, g):
        return profiles[(jname, tech, g)].step_time_s

    def true_step(jname, tech, g):
        return est_step(jname, tech, g) * noise[(jname, tech, g)]

    remaining = {j.name: j.total_steps for j in jobs}
    by_name = {j.name: j for j in jobs}
    waiting = [j.name for j in jobs]
    restart_ready: Dict[str, float] = {}     # job -> earliest relaunch time
    running: Dict[str, _Running] = {}
    free = cluster.total_gpus
    t = 0.0
    gantt: List[GanttEntry] = []
    replans = restarts = 0
    current_assign: Dict[str, Tuple[str, int]] = {}
    order = Schedule.coerce(policy.plan(
        jobs, dict(remaining), profiles, cluster, {})).to_tuples()
    replans += 1
    next_introspect = (introspect_every_s if introspect_every_s else math.inf)

    def settle(upto_t):
        for name, r in running.items():
            done = int((upto_t - r.start_s) / r.true_step_s)
            remaining[name] = max(0, r.steps_at_start - done)

    def start_fitting():
        nonlocal free
        started = True
        while started:
            started = False
            for (jname, tech, g) in order:
                if jname in waiting and g <= free and \
                        restart_ready.get(jname, 0.0) <= t + 1e-12:
                    st = true_step(jname, tech, g)
                    running[jname] = _Running(by_name[jname], tech, g, t,
                                              st, remaining[jname])
                    current_assign[jname] = (tech, g)
                    waiting.remove(jname)
                    free -= g
                    started = True
                    break

    start_fitting()
    events = 0
    while (waiting or running) and events < max_events:
        events += 1
        next_wake = min((restart_ready[n] for n in waiting
                         if restart_ready.get(n, 0.0) > t + 1e-12),
                        default=math.inf)
        if running:
            next_done_t, next_done = min(
                ((r.start_s + r.steps_at_start * r.true_step_s, name)
                 for name, r in running.items()), key=lambda x: x[0])
        else:
            next_done_t, next_done = math.inf, None
            if not math.isfinite(next_wake):
                raise RuntimeError(
                    f"deadlock: waiting={waiting} free={free} order={order}")
        if next_introspect < min(next_done_t, next_wake) - 1e-12:
            # ---- introspection point: re-solve on remaining work
            t = next_introspect
            next_introspect += introspect_every_s
            settle(t)
            if policy.dynamic:
                replans += 1
                new_order = Schedule.coerce(policy.plan(
                    jobs, dict(remaining), profiles, cluster,
                    dict(current_assign))).to_tuples()
                new_assign = {j: (tech, g) for j, tech, g in new_order}
                # restart running jobs whose assignment changed
                for name in list(running):
                    if name in new_assign and new_assign[name] != \
                            current_assign.get(name):
                        r = running.pop(name)
                        free += r.n_gpus
                        gantt.append(GanttEntry(name, r.technique, r.n_gpus,
                                                r.start_s, t))
                        # checkpoint + relaunch penalty: blocked until
                        # t + restart_cost_s
                        gantt.append(GanttEntry(name, "restart", 0, t,
                                                t + cluster.restart_cost_s,
                                                kind="restart"))
                        remaining[name] = max(1, remaining[name])
                        restart_ready[name] = t + cluster.restart_cost_s
                        waiting.append(name)
                        restarts += 1
                order = new_order
                start_fitting()
            continue
        if next_wake < next_done_t - 1e-12:
            # ---- a restarted job becomes admissible again
            t = next_wake
            start_fitting()
            continue
        # ---- completion event
        t = next_done_t
        settle(t)
        r = running.pop(next_done)
        remaining[next_done] = 0
        free += r.n_gpus
        gantt.append(GanttEntry(next_done, r.technique, r.n_gpus,
                                r.start_s, t))
        if policy.dynamic and policy.replan_on_completion and waiting:
            replans += 1
            order = Schedule.coerce(policy.plan(
                jobs, dict(remaining), profiles, cluster,
                dict(current_assign))).to_tuples()
        start_fitting()
    if events >= max_events:
        raise RuntimeError("simulate: event cap hit")
    return SimResult(policy.name, t, gantt, replans, restarts)


# --------------------------------------------------------------- local run

class LocalRunner:
    """Really execute a plan on this machine (reduced models, CPU): jobs
    run in list order under their assigned technique, with checkpointing.
    Used by the end-to-end examples; wall-times feed back as profiles.

    (The cluster runtime's real-execution path is
    :class:`~repro.core.local_backend.LocalJaxBackend`, which runs the
    Schedule IR concurrently with preemption; this runner is the simple
    serial building block.)
    """

    def __init__(self, cluster_devices=None, ckpt_dir: str = "/tmp/saturn_ckpts"):
        self.devices = cluster_devices
        self.ckpt_dir = ckpt_dir

    def run_job(self, job: Job, technique, n_devices: int, *,
                steps: Optional[int] = None, resume: bool = True):
        """Train ``job`` for ``steps`` (default: its remaining steps),
        resuming state AND data position from its checkpoint.

        The first step after (re)launch is the JIT compile; it is timed
        separately (``compile_s``) so ``wall_s`` / ``step_time_s`` hold
        pure training time — compile time used to be folded into
        ``wall_s``, poisoning any profile feedback derived from it.
        """
        import time as _time

        import jax

        from ..checkpoint.store import load_training_state, save_checkpoint
        from ..data.synthetic import SyntheticLM
        from ..parallelism.build import BuiltJob
        from .compile_cache import enable_persistent_compilation_cache
        enable_persistent_compilation_cache()

        devs = (self.devices or jax.devices())[:n_devices]
        plan = technique.plan(job.cfg, n_devices)
        built = BuiltJob(job.cfg, plan, job.opt_cfg, devices=devs)
        params, opt = built.init(jax.random.PRNGKey(job.seed))
        start_step = 0
        path = f"{self.ckpt_dir}/{job.name}.npz"
        if resume:
            params, opt, start_step = load_training_state(path, params, opt)
        n = steps if steps is not None else job.total_steps - start_step
        data = SyntheticLM(job.cfg, seed=job.seed).batches(
            job.batch_size, job.seq_len, num_batches=n, skip=start_step)
        m = {}
        compile_s = 0.0
        it = iter(data)
        first = next(it, None)
        if first is not None:
            t0 = _time.perf_counter()
            params, opt, m = built.step(params, opt,
                                        built.place_batch(first))
            jax.block_until_ready(params)
            compile_s = _time.perf_counter() - t0
        t0 = _time.perf_counter()
        for b in it:
            params, opt, m = built.step(params, opt, built.place_batch(b))
        jax.block_until_ready(params)
        dt = _time.perf_counter() - t0
        save_checkpoint(path, {"params": params, "opt": opt},
                        {"step": start_step + n,
                         "loss": float(m.get("loss", float("nan")))})
        # a single-step call cannot separate compile from compute: its
        # step time is unknowable, not compile_s — report it as such
        return {"job": job.name, "steps": n, "wall_s": dt,
                "compile_s": compile_s,
                "step_time_s": dt / (n - 1) if n > 1 else float("nan"),
                "loss": float(m.get("loss", float("nan"))),
                "done": start_step + n >= job.total_steps}
