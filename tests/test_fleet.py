"""SLO-aware serving fleets: sizing math, the queueing model, the
class-choice planner, solver reservations, and the full runtime
integration (fleets sharing a cluster with training jobs)."""
import math

import pytest

from repro.configs import get_config
from repro.core.baselines import (CurrentPractice, SaturnPolicy,
                                  static_partition_fleets)
from repro.core.executor import simulate
from repro.core.job import (SERVE_TECH, ClusterSpec, DeviceClass, Job,
                            ServeJob)
from repro.core.profiler import Profile
from repro.core.solver import solve_joint_serving
from repro.data.traffic import bursty_trace, diurnal_trace
from repro.serving.fleet import (FleetManager, fleet_reservations,
                                 plan_fleet, required_replicas,
                                 serve_profiles, simulate_fleet,
                                 window_stats)

CFG = get_config("xlstm-125m").reduced()


def _cluster(gpus=8, extra=()):
    classes = (DeviceClass("a100", nodes=1, gpus_per_node=gpus,
                           hbm_per_gpu=40e9, speed_hint=1.0),) + extra
    return ClusterSpec(device_classes=classes)


def _serve(**kw):
    kw.setdefault("name", "svc")
    kw.setdefault("cfg", CFG)
    kw.setdefault("slo_p99_s", 1.0)
    kw.setdefault("slots", 4)
    kw.setdefault("gpus_per_replica", 1)
    return ServeJob(**kw)


def _train_profiles(jobs, counts=(1, 2, 4), base=0.4):
    return {(j.name, "ddp", "a100", g):
            Profile(j.name, "ddp", g, base / g ** 0.9, 1e9, True, "t",
                    device_class="a100")
            for j in jobs for g in counts}


# ------------------------------------------------------------- unit level

def test_required_replicas_monotone():
    s = _serve()
    st = 0.002
    reps = [required_replicas(s, st, r) for r in (0.0, 1.0, 5.0, 20.0, 80.0)]
    assert reps == sorted(reps)
    assert reps[0] == 1
    # doubling the step time can only need more replicas
    assert required_replicas(s, 2 * st, 20.0) >= required_replicas(
        s, st, 20.0)


def test_simulate_fleet_idle_and_queueing():
    # 1 server, deterministic 1s service, back-to-back arrivals queue
    lat = simulate_fleet([0.0, 0.0, 0.0], 1.0, [(0.0, 1)])
    assert lat == [1.0, 2.0, 3.0]
    # 3 servers: all parallel
    lat = simulate_fleet([0.0, 0.0, 0.0], 1.0, [(0.0, 3)])
    assert lat == [1.0, 1.0, 1.0]
    # no capacity until t=5: the request waits for the grow
    lat = simulate_fleet([1.0], 1.0, [(0.0, 0), (5.0, 1)])
    assert lat == [5.0]
    # never any capacity again: unserveable
    lat = simulate_fleet([1.0], 1.0, [(0.0, 1), (0.5, 0)])
    assert lat == [math.inf]


def test_window_stats_attainment():
    stats = window_stats([0.0, 1.0, 10.0], [0.5, 2.0, 0.5], 1.0, 5.0, 15.0)
    assert stats["requests"] == 3
    assert stats["attainment"] == pytest.approx(2 / 3)
    assert len(stats["windows"]) == 3
    assert stats["windows"][1]["requests"] == 0
    assert stats["windows"][0]["attainment"] == pytest.approx(0.5)


def test_plan_fleet_prefers_cheapest_class():
    """A slow-but-sufficient class wins over a fast one (keeping fast
    GPUs for training); an SLO only the fast class meets flips it."""
    cluster = _cluster(extra=(
        DeviceClass("v100", nodes=1, gpus_per_node=8,
                    hbm_per_gpu=16e9, speed_hint=0.5),))
    serve = _serve(slo_p99_s=3.0, trace=diurnal_trace(2.0, 600.0, seed=0))
    profiles = serve_profiles([serve], cluster, base_step_s=0.004)
    plan = plan_fleet(serve, profiles, cluster, window_s=60.0,
                      horizon_s=600.0)
    assert plan.device_class == "v100"   # half speed still meets 3s SLO
    # a100 service time is 128 tokens * 2ms = 0.256s, v100 twice that:
    # a 0.6s SLO (0.36s budget at SERVICE_SLO_FRAC) only a100 meets
    tight = _serve(slo_p99_s=0.6, trace=serve.trace)
    profiles = serve_profiles([tight], cluster, base_step_s=0.004)
    plan = plan_fleet(tight, profiles, cluster, window_s=60.0,
                      horizon_s=600.0)
    assert plan.device_class == "a100"
    hopeless = _serve(slo_p99_s=0.01, trace=serve.trace)
    profiles = serve_profiles([hopeless], cluster, base_step_s=0.004)
    with pytest.raises(ValueError):
        plan_fleet(hopeless, profiles, cluster, window_s=60.0,
                   horizon_s=600.0)


def test_fleet_reservations_envelope():
    cluster = _cluster()
    serve = _serve(slo_p99_s=2.0,
                   trace=bursty_trace(1.0, 600.0, seed=0, burst_rps=25.0,
                                      burst_every_s=600.0,
                                      burst_len_s=120.0))
    profiles = serve_profiles([serve], cluster, base_step_s=0.004)
    plan = plan_fleet(serve, profiles, cluster, window_s=60.0,
                      horizon_s=600.0)
    res = fleet_reservations({"svc": plan})
    # one permanent triple plus step-downs; total equals the peak
    assert sum(1 for _, _, until in res if until == math.inf) == 1
    assert sum(g for _, g, _ in res) == plan.peak_gpus
    assert all(dc == "a100" for dc, _, _ in res)
    # the burst is at the START, so capacity steps DOWN over the horizon
    assert any(math.isfinite(until) for _, _, until in res)


def test_solve_joint_serving_reserves_capacity():
    """Training packs around the fleet: peak reservation shrinks the
    GPUs the MILP may use at t=0."""
    cluster = _cluster()
    jobs = [Job(f"t{i}", CFG, 8, 64, total_steps=100) for i in range(2)]
    profiles = _train_profiles(jobs)
    serve = _serve(slo_p99_s=2.0,
                   trace=bursty_trace(4.0, 600.0, seed=0, burst_rps=25.0,
                                      burst_every_s=300.0,
                                      burst_len_s=120.0))
    merged = dict(profiles)
    merged.update(serve_profiles([serve], cluster, base_step_s=0.004))
    sol, plans = solve_joint_serving(jobs, [serve], merged, cluster,
                                     window_s=60.0, horizon_s=600.0,
                                     time_limit_s=5)
    assert plans["svc"].peak_gpus >= 1
    assert math.isfinite(sol.makespan_s)
    base = solve_joint_serving(jobs, [], merged, cluster, window_s=60.0,
                               horizon_s=600.0, time_limit_s=5)[0]
    assert sol.makespan_s >= base.makespan_s - 1e-9


# ------------------------------------------------------ runtime integration

def _mixed_run(adaptive, n_jobs=3, horizon=600.0, slo=1.0, steps=800):
    cluster = _cluster()
    jobs = [Job(f"t{i}", CFG, 8, 64, total_steps=steps, seed=i)
            for i in range(n_jobs)]
    profiles = _train_profiles(jobs)
    trace = bursty_trace(2.0, horizon, seed=1, burst_rps=25.0,
                         burst_every_s=horizon / 2, burst_len_s=120.0)
    serve = _serve(slo_p99_s=slo, trace=trace)
    merged = dict(profiles)
    merged.update(serve_profiles([serve], cluster, base_step_s=0.004))
    if adaptive:
        fm = FleetManager([serve], cluster, window_s=60.0,
                          horizon_s=horizon)
        policy = SaturnPolicy(time_limit_s=5)
    else:
        fm = static_partition_fleets([serve], cluster, window_s=60.0,
                                     horizon_s=horizon)
        policy = CurrentPractice()
    res = simulate(jobs, policy, merged, cluster,
                   introspect_every_s=60.0, fleets=fm)
    return res, fm


def test_runtime_serving_stats_and_slo():
    res, fm = _mixed_run(adaptive=True)
    sv = res.stats["serving"]
    svc = sv["svc"]
    assert svc["requests"] > 0
    assert svc["attainment"] >= 0.99
    assert svc["device_class"] == "a100"
    assert math.isfinite(svc["step_time_s"])     # measured, fed back
    assert fm.observed                           # ObservedProfiles overlay
    # run stays alive through the traffic horizon even after training
    assert res.makespan_s >= fm.horizon_s - 60.0
    # serving segments are real Gantt entries under conservation
    serve_segs = [e for e in res.gantt
                  if e.kind == "run" and e.technique == SERVE_TECH]
    assert serve_segs and all(e.job == "svc" for e in serve_segs)


def test_adaptive_fleet_rescales_and_beats_static():
    adaptive, _ = _mixed_run(adaptive=True)
    static, _ = _mixed_run(adaptive=False)

    def train_end(res):
        return max(e.end_s for e in res.gantt
                   if e.kind == "run" and e.technique != SERVE_TECH)

    sizes_a = {n for _, n in adaptive.stats["serving"]["svc"]["history"]}
    # the adaptive fleet really changes size (burst vs quiet windows)
    assert len(sizes_a - {0}) >= 2
    sizes_s = [n for t, n in static.stats["serving"]["svc"]["history"]
               if 0 < t < 500.0]
    # the static fleet never scales DOWN from its provisioned peak
    assert sizes_s == sorted(sizes_s)
    assert static.stats["serving"]["svc"]["attainment"] >= 0.99
    assert train_end(adaptive) < train_end(static)


def test_fleet_growth_evicts_training():
    """A burst landing mid-sweep evicts training launches (restart
    penalty paid) rather than missing the SLO."""
    # enough training work that the sweep still holds the cluster when
    # the t=300s burst lands — growth must evict, not find free GPUs
    res, fm = _mixed_run(adaptive=True, n_jobs=4, steps=3000)
    assert fm.evictions >= 1
    assert res.restarts >= fm.evictions
    assert res.stats["serving"]["svc"]["attainment"] >= 0.99


def test_infeasible_slo_raises():
    cluster = _cluster()
    serve = _serve(slo_p99_s=0.001, trace=diurnal_trace(1.0, 300.0, seed=0))
    jobs = [Job("t0", CFG, 8, 64, total_steps=50)]
    merged = dict(_train_profiles(jobs))
    merged.update(serve_profiles([serve], cluster))
    fm = FleetManager([serve], cluster, window_s=60.0, horizon_s=300.0)
    with pytest.raises(ValueError):
        simulate(jobs, SaturnPolicy(time_limit_s=5), merged, cluster,
                 introspect_every_s=60.0, fleets=fm)
