"""ClusterState + event-driven execution engine.

Replaces the monolithic ``simulate()`` while-loop with an explicit
discrete-event simulation over :mod:`.events`:

- jobs arrive at ``Job.arrival_s`` (online workloads) and policies
  replan on arrival batches;
- preempted jobs pay a REAL restart penalty: their GPUs are released at
  preemption time but the job is only admissible again when its
  :class:`RestartDone` event fires at ``t + restart_cost_s`` (the legacy
  loop re-admitted them immediately while also recording a restart
  Gantt entry — double-booking the GPUs);
- placement is pluggable (:mod:`.placement`): flat pool, node-aware, or
  per-device-class pools on heterogeneous clusters, so the executor can
  honor what ``solve_joint_nodes`` / ``solve_joint_classes`` plan;
- every Gantt entry records the concrete device set (and device class)
  it occupied, and the engine asserts GPU-second conservation PER
  DEVICE CLASS before returning — not just globally — so a migration
  bug that double-books one class while under-booking another cannot
  cancel out;
- an introspection replan may migrate a job across device classes: the
  assignment diff includes the class, so the job pays exactly one
  restart penalty and relaunches from the new class's pool;
- replans are warm-start-capable: the engine hands the previous
  Schedule, the current time and the running set to
  :meth:`Policy.plan_incremental`, so a policy can fix running jobs in
  place and re-solve only the residual (SaturnPolicy does; the default
  delegates to ``plan`` and reproduces the historical behavior exactly).

The simulator separates *estimated* step times (what policies see, from
the Trial Runner — either an exhaustive profile dict or a curve-backed
:class:`~repro.core.perfmodel.PerfModel`) from *true* step times
(estimate x seeded noise), so
dynamic policies (introspection) win for the same reason they do on a
real cluster: plans based on estimates drift from reality, and
re-solving on observed remaining work recovers the gap.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from .events import (EventQueue, IntrospectionTick, JobArrival,
                     JobCompletion, RestartDone)
from .job import DEFAULT_CLASS, ClusterSpec, Job
from .perfmodel import profile_key, step_time_of
from .placement import (ClassPool, PlacementBackend, PlacementError,
                        make_backend)
from .profiler import Profile
from .schedule import Placement, Policy, Schedule


@dataclasses.dataclass
class GanttEntry:
    job: str
    technique: str
    n_gpus: int
    start_s: float
    end_s: float
    kind: str = "run"          # run | restart
    devices: Tuple[int, ...] = ()
    device_class: str = DEFAULT_CLASS


@dataclasses.dataclass
class SimResult:
    policy: str
    makespan_s: float
    gantt: List[GanttEntry]
    replans: int = 0
    restarts: int = 0

    def utilization(self, cluster: ClusterSpec) -> float:
        busy = sum((g.end_s - g.start_s) * g.n_gpus for g in self.gantt
                   if g.kind == "run")
        return busy / (self.makespan_s * cluster.total_gpus + 1e-9)


def _noise_factors(jobs, profiles, seed: int, sigma: float):
    """Seeded multiplicative drift between estimated and true step times.
    Iterates profiles in insertion order so legacy and runtime paths see
    identical factors."""
    rng = np.random.RandomState(seed)
    out = {}
    for key in profiles:
        out[key] = float(np.exp(rng.randn() * sigma))
    return out


@dataclasses.dataclass
class _Running:
    job: Job
    technique: str
    n_gpus: int
    placement: Placement
    start_s: float
    true_step_s: float
    steps_at_start: int
    token: int

    @property
    def device_class(self) -> str:
        return getattr(self.placement, "device_class", DEFAULT_CLASS)


class ClusterState:
    """Mutable simulation state: job phases, remaining work, placements,
    the Gantt log under construction, and per-device-class GPU-second
    accounting (the runtime's conservation invariant)."""

    def __init__(self, jobs: List[Job], backend: PlacementBackend):
        self.by_name: Dict[str, Job] = {j.name: j for j in jobs}
        self.remaining: Dict[str, int] = {j.name: j.total_steps for j in jobs}
        self.arrived: set = set()
        self.waiting: List[str] = []
        self.restarting: set = set()
        self.running: Dict[str, _Running] = {}
        self.backend = backend
        self.gantt: List[GanttEntry] = []
        self.current_assign: Dict[str, Tuple] = {}
        self.busy_gpu_s: Dict[str, float] = {}   # device class -> GPU-seconds
        self._alloc_open: Dict[int, Tuple[float, int, str]] = {}
        self.t = 0.0

    def settle(self, upto_t: float) -> None:
        """Account finished steps for running jobs up to ``upto_t``."""
        for name, r in self.running.items():
            done = int((upto_t - r.start_s) / r.true_step_s)
            self.remaining[name] = max(0, r.steps_at_start - done)

    def note_alloc(self, token: int, t: float, n_gpus: int,
                   device_class: str) -> None:
        """Record an allocation at LAUNCH time.  This bookkeeping is
        written on the launch path (start_fitting), independently of the
        Gantt entries written on the release paths, so the conservation
        check reconciles two genuinely distinct records."""
        self._alloc_open[token] = (t, n_gpus, device_class)

    def close_alloc(self, token: int, end_s: float) -> None:
        """Close an allocation at release time and charge its class."""
        t0, n, dc = self._alloc_open.pop(token)
        self.busy_gpu_s[dc] = self.busy_gpu_s.get(dc, 0.0) \
            + (end_s - t0) * n

    def log_run(self, name: str, r: _Running, end_s: float) -> None:
        """Close a run segment: Gantt entry + launch-side accounting."""
        self.close_alloc(r.token, end_s)
        self.gantt.append(GanttEntry(
            name, r.technique, r.n_gpus, r.start_s, end_s,
            devices=r.placement.devices, device_class=r.device_class))

    def live_jobs(self) -> List[Job]:
        """Arrived, unfinished jobs (running, waiting, or restarting) —
        what planners plan over."""
        return [self.by_name[n] for n in self.by_name
                if n in self.arrived and self.remaining[n] > 0]

    def all_done(self) -> bool:
        return all(v == 0 for v in self.remaining.values())


def verify_conservation(state: ClusterState) -> None:
    """GPU-second conservation, per device class.

    Reconciles the launch-side allocation bookkeeping (token -> launch
    time / size / class, written in ``start_fitting`` from the actual
    Placement) against the release-side Gantt segments (written from the
    ``_Running`` record), and both against the concrete device ids those
    segments claim.  A device double-booked within its class, a segment
    whose devices belong to a different class than recorded, a launch
    whose placement was never released, or busy-seconds leaking from one
    class to another all fail here — even when the GLOBAL totals happen
    to balance out.
    """
    if state._alloc_open:
        raise RuntimeError(
            f"conservation: {len(state._alloc_open)} allocation(s) never "
            f"released: {sorted(state._alloc_open)}")
    runs = [g for g in state.gantt if g.kind == "run"]
    per_class: Dict[str, float] = {}
    by_dev: Dict[int, List[Tuple[float, float, str, str]]] = {}
    for g in runs:
        if len(set(g.devices)) != g.n_gpus:
            raise RuntimeError(
                f"conservation: {g.job} records {g.n_gpus} GPUs but "
                f"{len(set(g.devices))} distinct devices")
        per_class[g.device_class] = per_class.get(g.device_class, 0.0) \
            + (g.end_s - g.start_s) * g.n_gpus
        for d in g.devices:
            dc = state.backend.class_of(d)
            if dc != g.device_class:
                raise RuntimeError(
                    f"conservation: {g.job} recorded class "
                    f"{g.device_class!r} but device {d} belongs to {dc!r}")
            by_dev.setdefault(d, []).append(
                (g.start_s, g.end_s, g.job, g.device_class))
    classes = set(per_class) | set(state.busy_gpu_s)
    for dc in classes:
        a = per_class.get(dc, 0.0)
        b = state.busy_gpu_s.get(dc, 0.0)
        if abs(a - b) > 1e-6 * max(1.0, a, b):
            raise RuntimeError(
                f"conservation: class {dc!r} gantt={a:.6f} GPU-s vs "
                f"accounted={b:.6f} GPU-s")
    for d, ivs in by_dev.items():
        ivs.sort()
        for (s1, e1, j1, _), (s2, e2, j2, _) in zip(ivs, ivs[1:]):
            if e1 > s2 + 1e-9:
                raise RuntimeError(
                    f"conservation: device {d} double-booked: "
                    f"{j1}[{s1},{e1}] overlaps {j2}[{s2},{e2}]")


def simulate_runtime(jobs: List[Job], policy: Policy,
                     profiles: Dict[Tuple[str, str, int], Profile],
                     cluster: ClusterSpec, *,
                     introspect_every_s: Optional[float] = None,
                     noise_sigma: float = 0.1, noise_seed: int = 0,
                     max_events: int = 100000,
                     backend: Optional[PlacementBackend] = None) -> SimResult:
    """Run ``jobs`` under ``policy`` on the event-driven cluster runtime."""
    noise = _noise_factors(jobs, profiles, noise_seed, noise_sigma)
    backend = backend or make_backend(cluster)
    state = ClusterState(jobs, backend)
    q = EventQueue()
    for j in jobs:
        q.push(JobArrival(max(0.0, getattr(j, "arrival_s", 0.0)), j))
    if introspect_every_s:
        q.push(IntrospectionTick(introspect_every_s))

    order = Schedule([])
    replans = 0
    restarts = 0
    launch_tokens = {}            # job -> token of its current launch
    next_token = [0]

    def est_step(jname, tech, g, dclass=None):
        # curve-backed performance models answer at ANY count, so
        # introspection replans may pick counts nobody profiled
        return step_time_of(profiles, jname, tech, g, device_class=dclass)

    def true_step(jname, tech, g, dclass=None):
        key = profile_key(profiles, jname, tech, g, dclass)
        return est_step(jname, tech, g, dclass) * noise.get(key, 1.0)

    def allocate_for(entry):
        """Place one entry: class-pinned entries draw from their class's
        pool; class-blind entries on a heterogeneous cluster take the
        first class with room where the config is actually runnable
        (finite estimated step time)."""
        if entry.device_class is None and isinstance(backend, ClassPool) \
                and len(backend.classes) > 1:
            for dc in backend.classes:
                try:
                    st = est_step(entry.job, entry.technique,
                                  entry.n_gpus, dc.name)
                except KeyError:
                    continue  # unprofiled on this class (e.g. count
                    #           exceeds the class's capacity grid)
                if not math.isfinite(st):
                    continue
                pl = backend.allocate(entry.n_gpus, device_class=dc.name)
                if pl is not None:
                    return pl
            return None
        return backend.allocate(entry.n_gpus,
                                preferred_nodes=entry.nodes,
                                device_class=entry.device_class)

    def start_fitting():
        """List scheduling: repeatedly start the first schedule entry
        whose job is admissible and whose GPU request fits."""
        progressed = True
        while progressed:
            progressed = False
            for entry in order.entries:
                name = entry.job
                if name not in state.waiting:
                    continue
                if not backend.feasible(entry.n_gpus,
                                        device_class=entry.device_class):
                    raise PlacementError(
                        f"{name}: {entry.n_gpus} GPUs "
                        f"(class {entry.device_class!r}) can never be "
                        f"placed on backend {backend.kind!r}")
                pl = allocate_for(entry)
                if pl is None:
                    continue
                dclass = getattr(pl, "device_class", DEFAULT_CLASS)
                st = true_step(name, entry.technique, entry.n_gpus, dclass)
                next_token[0] += 1
                tok = next_token[0]
                state.note_alloc(tok, state.t, pl.n_gpus, dclass)
                state.running[name] = _Running(
                    state.by_name[name], entry.technique, entry.n_gpus,
                    pl, state.t, st, state.remaining[name], tok)
                launch_tokens[name] = tok
                state.current_assign[name] = entry.assignment
                state.waiting.remove(name)
                q.push(JobCompletion(
                    state.t + state.remaining[name] * st, name, tok))
                progressed = True
                break

    def replan(preempt: bool):
        nonlocal order, replans, restarts
        live = state.live_jobs()
        if not live:
            return
        # warm-start-capable policies get the previous schedule, the
        # current time and the running set and may re-solve only the
        # residual; the default delegates to plan() unchanged
        order = Schedule.coerce(policy.plan_incremental(
            live, dict(state.remaining), profiles, cluster,
            dict(state.current_assign), prev=order, now_s=state.t,
            running=frozenset(state.running)))
        replans += 1
        if preempt:
            new_assign = order.assignment_map()
            for name in list(state.running):
                if name in new_assign and \
                        new_assign[name] != state.current_assign.get(name):
                    r = state.running.pop(name)
                    backend.release(r.placement)
                    state.log_run(name, r, state.t)
                    # checkpoint + relaunch penalty: the job is only
                    # admissible again when RestartDone fires
                    state.gantt.append(GanttEntry(
                        name, "restart", 0, state.t,
                        state.t + cluster.restart_cost_s, kind="restart",
                        device_class=r.device_class))
                    state.remaining[name] = max(1, state.remaining[name])
                    state.restarting.add(name)
                    q.push(RestartDone(
                        state.t + cluster.restart_cost_s, name))
                    restarts += 1

    def finalize_if_done(t: float) -> bool:
        """When every job's remaining work hits zero, jobs still marked
        running finished at exactly this instant (their own completion
        events are queued at the same time): close their segments and
        release their devices instead of dropping them on the floor."""
        if not state.all_done():
            return False
        for name in list(state.running):
            r = state.running.pop(name)
            backend.release(r.placement)
            state.log_run(name, r, t)
        return True

    events = 0
    while q:
        if finalize_if_done(state.t):
            break
        ev = q.pop()
        events += 1
        if events > max_events:
            raise RuntimeError("simulate_runtime: event cap hit")

        if isinstance(ev, JobArrival):
            state.t = ev.t
            state.settle(ev.t)   # replan must see observed progress
            batch = [ev] + q.pop_while(JobArrival, ev.t)
            for e in batch:
                state.arrived.add(e.job.name)
                state.waiting.append(e.job.name)
            # dynamic policies may preempt running jobs to make room for
            # the new arrival; static ones just extend the plan
            if state.t > 0 and not getattr(policy, "replan_on_arrival", True):
                pass
            else:
                replan(preempt=policy.dynamic and state.t > 0)
            start_fitting()

        elif isinstance(ev, JobCompletion):
            if launch_tokens.get(ev.job) != ev.token or \
                    ev.job not in state.running:
                continue                       # stale (preempted launch)
            state.t = ev.t
            state.settle(ev.t)
            r = state.running.pop(ev.job)
            state.remaining[ev.job] = 0
            backend.release(r.placement)
            state.log_run(ev.job, r, ev.t)
            if finalize_if_done(ev.t):
                break
            if policy.dynamic and policy.replan_on_completion and \
                    state.waiting:
                replan(preempt=False)
            start_fitting()

        elif isinstance(ev, RestartDone):
            state.t = ev.t
            state.restarting.discard(ev.job)
            state.waiting.append(ev.job)
            start_fitting()

        elif isinstance(ev, IntrospectionTick):
            if state.all_done():
                continue
            if not (state.running or state.waiting or state.restarting):
                # nothing in the system yet (future arrivals pending):
                # keep the tick chain alive, but there is nothing to
                # settle or replan
                q.push(IntrospectionTick(ev.t + introspect_every_s))
                continue
            state.t = ev.t
            state.settle(ev.t)
            if policy.dynamic:
                replan(preempt=True)
            q.push(IntrospectionTick(ev.t + introspect_every_s))
            start_fitting()

        # deadlock: nothing running, nothing can ever start it
        if state.waiting and not state.running and not state.restarting \
                and not q.has_any((JobArrival, RestartDone)):
            raise RuntimeError(
                f"deadlock: waiting={state.waiting} "
                f"free={backend.free_gpus} order={order.to_tuples()}")

    if not state.all_done():
        unfinished = [n for n, v in state.remaining.items() if v > 0]
        raise RuntimeError(f"runtime drained with unfinished jobs: "
                           f"{unfinished}")
    verify_conservation(state)
    return SimResult(policy.name, state.t, state.gantt, replans, restarts)
