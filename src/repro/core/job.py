"""Job and cluster specifications for multi-large-model training."""
from __future__ import annotations

import dataclasses

from ..models.config import ModelConfig
from ..optim.adamw import AdamWConfig


@dataclasses.dataclass(frozen=True)
class Job:
    """One model-selection trial: a model + hyperparameters + work amount.

    The paper's workload (Table 1) is a grid over {model} x {lr} x
    {batch size} for a fixed number of epochs; each grid point is a Job.
    """
    name: str
    cfg: ModelConfig
    batch_size: int
    seq_len: int
    total_steps: int
    lr: float = 1e-4
    seed: int = 0
    arrival_s: float = 0.0          # online workloads: submission time

    @property
    def opt_cfg(self) -> AdamWConfig:
        return AdamWConfig(lr=self.lr, warmup_steps=min(100, self.total_steps // 10 + 1),
                           total_steps=self.total_steps)


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """The GPU cluster: the paper evaluates 1 and 2 p4d.24xlarge nodes
    (8 GPUs each); the TPU adaptation treats a "node" as an ICI slice."""
    nodes: int = 1
    gpus_per_node: int = 8
    hbm_per_gpu: float = 40e9       # bytes (A100-40GB on p4d.24xlarge)
    restart_cost_s: float = 30.0    # checkpoint + relaunch penalty
    placement: str = "flat"         # runtime placement backend: flat | node

    @property
    def total_gpus(self) -> int:
        return self.nodes * self.gpus_per_node


def hpo_grid(models, lrs, batch_sizes, *, seq_len: int, total_steps: int,
             steps_scale=None) -> list:
    """Build the paper-style model-selection workload (Table 1 grid)."""
    jobs = []
    for mname, cfg in models:
        for lr in lrs:
            for bs in batch_sizes:
                steps = total_steps
                if steps_scale:
                    steps = int(total_steps * steps_scale.get(mname, 1.0))
                jobs.append(Job(
                    name=f"{mname}-lr{lr:g}-bs{bs}", cfg=cfg,
                    batch_size=bs, seq_len=seq_len,
                    total_steps=steps, lr=lr, seed=len(jobs)))
    return jobs
